"""Plan-ahead scheduler: cost model units, schedule invariants (token
conservation, wave topology, replica balance) over random forests, async
pipeline ordering/overlap, and the acceptance bar — planner-built plans
are gradient-equivalent to the per-step PR-4 path even with lookahead
re-packing and replica-balanced row padding/permutation."""
import time

import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core.plan_cost import (CompileCacheSim, balanced_row_order,
                                  est_block_skip, packed_signature,
                                  pow2, round_to_multiple, score_packing)
from repro.core.packing import plan_tree_rows
from repro.data.loader import LoaderConfig, tree_stream
from repro.data.synthetic import random_tree
from repro.train.planner import (PlanPipeline, PlannerConfig, plan_stream,
                                 plan_window)

from test_engine import _lc, _max_rel, _two_branch_reference


# ---------------------------------------------------------------------------
# cost model units
# ---------------------------------------------------------------------------

def test_score_packing_counts_padding_and_signatures():
    cache = CompileCacheSim()
    sigs = [packed_signature(4, 128)]
    c = score_packing([[60, 60], [100], []], 128, signatures=sigs,
                      cache=cache)
    assert c.used_tokens == 220
    assert c.padded_tokens == 3 * 128 - 220
    assert c.new_signatures == 1
    cache.commit(sigs)
    c2 = score_packing([[60, 60], [100], []], 128, signatures=sigs,
                       cache=cache)
    assert c2.new_signatures == 0
    assert c2.total < c.total          # cache hit is cheaper


def test_est_block_skip_prefers_many_small_trees():
    # one long tree lights its whole lower-triangle; many small trees
    # stay near the diagonal → higher estimated skip
    one_long = est_block_skip([[256]], 256, 64)
    many_small = est_block_skip([[64, 64, 64, 64]], 256, 64)
    assert many_small > one_long
    assert est_block_skip([[]], 256, 64) == 1.0   # empty row fully skips


def test_pow2_and_round_to_multiple():
    assert [pow2(n) for n in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
    assert pow2(3, lo=8) == 8
    assert round_to_multiple(5, 3) == 6
    assert round_to_multiple(6, 3) == 6
    assert round_to_multiple(7, 1) == 7


def test_balanced_row_order_imbalance_le_one():
    rng = np.random.default_rng(0)
    for R in (2, 3, 4):
        for _ in range(20):
            B = R * rng.integers(1, 5)
            loads = [int(rng.integers(0, 200)) for _ in range(B)]
            k = int(rng.integers(0, B + 1))
            loads[k:] = [0] * (B - k)      # some empty rows
            order = balanced_row_order(loads, R)
            assert sorted(order) == list(range(B))
            per = B // R
            counts = [sum(loads[r] > 0 for r in order[i * per:(i + 1) * per])
                      for i in range(R)]
            assert max(counts) - min(counts) <= 1


def test_plan_tree_rows_bfd_beats_ffd_on_stranded_holes():
    # ffd strands 40 after packing 60 next to 100; bfd fills the tighter
    # row first and needs fewer rows
    sizes = [100, 60, 40, 28]
    ffd = plan_tree_rows(sizes, 128, heuristic="ffd")
    bfd = plan_tree_rows(sizes, 128, heuristic="bfd")
    assert len(bfd) <= len(ffd)
    for rows in (ffd, bfd):
        placed = sorted(i for r in rows for i in r)
        assert placed == list(range(len(sizes)))
        assert all(sum(sizes[i] for i in r) <= 128 for r in rows)


# ---------------------------------------------------------------------------
# schedule invariants over random forests (seeded; hypothesis variant below)
# ---------------------------------------------------------------------------

def _check_window_invariants(cfg, lc, pc, window):
    steps = plan_window(cfg, tiny_lc_copy(lc), pc, window)
    gen_tokens = sum(t.num_unique_tokens() for b in window for t in b)
    gen_trees = sum(len(b) for b in window)
    got_tokens = dropped = seen_trees = 0
    for ps in steps:
        sb = ps.step_batch()
        dropped += sb.dropped
        seen_trees += sb.num_trees
        if sb.tb is not None:
            B = sb.tb.tokens.shape[0]
            R = pc.num_replicas
            # replica-balanced rows: divisible count, non-empty-row
            # imbalance ≤ 1 across contiguous shards
            assert B % max(R, 1) == 0
            got_tokens += int(sb.tb.valid.sum())
            if R > 1:
                per = B // R
                nonempty = sb.tb.valid.any(axis=1)
                counts = [int(nonempty[i * per:(i + 1) * per].sum())
                          for i in range(R)]
                assert max(counts) - min(counts) <= 1
        got_tokens += sum(t.num_unique_tokens() for t in sb.oversized)
        if sb.oversized:
            plan = ps.execution_plan()
            waves = plan.partition.waves
            for w, wp in enumerate(waves):
                for ref in wp.parents:
                    # parents never scheduled later than children
                    assert ref.wave < w
                # wave rows shard evenly too
                Bb = wp.batch["tokens"].shape[0]
                assert Bb % max(R, 1) == 0
                if R > 1:
                    # wave-level load balance: rows are permuted by
                    # gateway + token load (snake-dealt like packed
                    # rows), so contiguous per-replica shards carry
                    # non-empty-row counts within 1 of each other
                    per = Bb // R
                    loads = [int(wp.batch["valid"][r].sum())
                             + wp.A_real[r] for r in range(Bb)]
                    nz = [sum(ld > 0 for ld in loads[i * per:(i + 1) * per])
                          for i in range(R)]
                    assert max(nz) - min(nz) <= 1, (w, loads, nz)
    assert seen_trees + dropped == gen_trees
    if lc.mode != "tree":
        return          # baseline packs replicated path tokens, not unique
    if lc.auto_partition:
        assert dropped == 0
        assert got_tokens == gen_tokens   # every token packed/partitioned
    else:
        assert got_tokens <= gen_tokens


def tiny_lc_copy(lc):
    from dataclasses import replace
    return replace(lc)


def _forest(seed, n, seg=(2, 9), depth=4):
    rng = np.random.default_rng(seed)
    return [random_tree(rng, vocab_size=97, max_depth=depth,
                        seg_len_range=seg) for _ in range(n)]


@pytest.mark.parametrize("mode,route", [
    ("tree", True), ("tree", False), ("baseline", False)])
def test_planner_window_invariants_seeded(mode, route):
    cfg = tiny_cfg("dense")
    for seed in range(3):
        for W, R in ((1, 1), (2, 2), (3, 2)):
            lc = LoaderConfig(seq_len=64, batch_rows=3, trees_per_batch=4,
                              mode=mode, seed=seed,
                              auto_partition=route, capacity=48)
            pc = PlannerConfig(lookahead=W, num_replicas=R)
            window = [_forest(100 * seed + b, 4) for b in range(W)]
            _check_window_invariants(cfg, lc, pc, window)


def test_planner_window_invariants_property():
    """Hypothesis property test: arbitrary forests conserve every token,
    schedule parents no later than children, and keep replica row-load
    imbalance ≤ 1 (the CI fast gate runs this; locally it skips when
    hypothesis is absent)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.core.tree import TrajectoryTree, TreeNode

    @st.composite
    def trees(draw, max_depth=3, max_children=3, max_seg=6):
        def node(depth):
            L = draw(st.integers(1, max_seg))
            toks = draw(st.lists(st.integers(0, 89), min_size=L,
                                 max_size=L))
            n = TreeNode(tokens=np.asarray(toks, np.int32))
            if depth < max_depth:
                k = draw(st.integers(0, max_children))
                if k >= 2 or (k == 1 and draw(st.booleans())):
                    n.children = [node(depth + 1) for _ in range(k)]
            return n

        return TrajectoryTree(root=node(0))

    cfg = tiny_cfg("dense")

    @given(st.lists(st.lists(trees(), min_size=1, max_size=4),
                    min_size=1, max_size=3),
           st.integers(1, 3), st.booleans())
    @settings(max_examples=25, deadline=None)
    def run(window, R, route):
        lc = LoaderConfig(seq_len=64, batch_rows=3,
                          trees_per_batch=max(len(b) for b in window),
                          mode="tree", auto_partition=route, capacity=48)
        pc = PlannerConfig(lookahead=len(window), num_replicas=R)
        _check_window_invariants(cfg, lc, pc, window)

    run()


def test_lookahead_fills_holes_across_batches():
    """The point of plan-ahead: trees from later generator batches fill
    holes the per-step greedy leaves, so the window needs no more padded
    cells and at least as few steps."""
    cfg = tiny_cfg("dense")
    lc = LoaderConfig(seq_len=96, batch_rows=2, trees_per_batch=3,
                      mode="tree", kind="agentic", seed=3,
                      gen_kwargs=dict(turn_len_range=(4, 14), num_turns=2))

    def packed_cells(pc):
        pad = uniq = steps = 0
        for ps in plan_stream(cfg, tiny_lc_copy(lc), 8, pc):
            sb = ps.step_batch()
            if sb.tb is None:
                continue
            steps += 1
            pad += sb.tb.tokens.size - int(sb.tb.valid.sum())
            uniq += int(sb.tb.valid.sum())
        return pad, uniq, steps

    pad_g, uniq_g, steps_g = packed_cells(
        PlannerConfig(lookahead=1, heuristics=("ffd",)))
    pad_p, uniq_p, steps_p = packed_cells(PlannerConfig(lookahead=4))
    assert uniq_p >= uniq_g          # lookahead never trains less data
    assert steps_p <= steps_g
    assert pad_p / max(uniq_p, 1) <= pad_g / max(uniq_g, 1)


# ---------------------------------------------------------------------------
# async pipeline
# ---------------------------------------------------------------------------

def test_plan_pipeline_sync_and_async_agree():
    items = list(range(10))
    build = lambda i: i * i
    sync = PlanPipeline(iter(items), build, workers=0)
    assert list(sync) == [i * i for i in items]
    assert sync.built == 10
    # synchronous: every scheduling/build second is consumer-visible
    assert sync.exposed_s == pytest.approx(
        sync.schedule_s + sync.build_s, rel=1e-6, abs=1e-9)
    for workers in (1, 2):
        pipe = PlanPipeline(iter(items), build, workers=workers)
        assert list(pipe) == [i * i for i in items]   # order preserved
        assert pipe.built == 10


def test_plan_pipeline_overlaps_build_behind_consumer():
    def build(i):
        time.sleep(0.005)
        return i

    pipe = PlanPipeline(iter(range(8)), build, workers=1, depth=2)
    out = []
    for v in pipe:
        time.sleep(0.02)             # the "device step"
        out.append(v)
    assert out == list(range(8))
    # all but the first build hides behind consumer work
    assert pipe.exposed_s < pipe.build_s


def test_plan_pipeline_propagates_errors_in_order():
    def source():
        yield 1
        yield 2
        raise RuntimeError("schedule boom")

    pipe = PlanPipeline(source(), lambda i: i, workers=1)
    it = iter(pipe)
    assert next(it) == 1
    assert next(it) == 2
    with pytest.raises(RuntimeError, match="schedule boom"):
        next(it)

    def bad_build(i):
        if i == 1:
            raise ValueError("build boom")
        return i

    pipe = PlanPipeline(iter(range(3)), bad_build, workers=2)
    it = iter(pipe)
    assert next(it) == 0
    with pytest.raises(ValueError, match="build boom"):
        next(it)


# ---------------------------------------------------------------------------
# the acceptance bar: planner plans ≡ the PR-4 per-step path
# ---------------------------------------------------------------------------

def _check_planner_equivalence(family, impl):
    """Lookahead re-packing, cost-model row layout, replica-balanced
    padding/permutation and wave row rounding must all be gradient-
    neutral: the engine on a planner-built plan matches the pre-refactor
    two-branch math on the very same step data."""
    import jax
    from repro.models.model import init_params
    from repro.train.engine import TreeTrainEngine

    cfg = tiny_cfg(family)
    lc = _lc()
    pc = PlannerConfig(lookahead=2, num_replicas=2)
    steps = list(plan_stream(cfg, lc, 8, pc))
    ps = next(p for p in steps if any(p.rows) and len(p.oversized) >= 1)
    sb = ps.step_batch()
    params = init_params(cfg, jax.random.key(0))
    l_ref, g_ref = _two_branch_reference(cfg, params, sb, lc, impl)

    engine = TreeTrainEngine(cfg, impl=impl, donate=False)
    grads, scal = engine.accumulate(params, ps.execution_plan())
    l_eng = float(np.asarray(scal)[0])
    # 5e-6, not 1e-6: the compile-aware oversized router may co-locate a
    # window's partitioned trees, so the engine's wave grouping (hence
    # its f32 accumulation ORDER) differs from the reference driver's —
    # same math, reordered sums
    assert abs(l_eng - l_ref) / max(abs(l_ref), 1e-9) <= 5e-6
    assert _max_rel(grads, g_ref) <= 5e-6


def test_planner_matches_two_branch_dense_ref():
    _check_planner_equivalence("dense", "ref")


@pytest.mark.slow
@pytest.mark.parametrize("family,impl", [
    ("dense", "chunked"), ("dense", "pallas"),
    ("moe", "chunked"), ("moe", "pallas")])
def test_planner_matches_two_branch(family, impl):
    _check_planner_equivalence(family, impl)


def test_planner_streams_deterministic():
    """Every consumer of the planner (``plans``, the deprecated loader
    wrappers) sees the same schedule — one deterministic plan stream."""
    cfg = tiny_cfg("dense")
    lc = _lc()
    a = [(ps.index, len(ps.fits), len(ps.oversized), ps.dropped)
         for ps in plan_stream(cfg, lc, 6)]
    b = [(ps.index, len(ps.fits), len(ps.oversized), ps.dropped)
         for ps in plan_stream(cfg, lc, 6)]
    assert a == b
    n = sum(1 for _ in tree_stream(cfg, lc, 6))
    assert n == 6
