"""DecodeSession API: shared-prefix parallel prefill equals the step
loop, fork reuses the prefix KV bit-exactly, incremental prefill extends
the chain, snapshots are independent, and the old free functions warn."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.models.model import init_params
from repro.serve.session import DecodeSession


def _toks(seed, n, vocab=89):
    return np.random.default_rng(seed).integers(0, vocab, n).astype(np.int32)


# ---------------------------------------------------------------------------
# parallel prefill ≡ step-wise prefill (cache contents AND logits)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["dense", "moe"])
def test_parallel_prefill_matches_step_loop(family):
    cfg = tiny_cfg(family)
    params = init_params(cfg, jax.random.key(0))
    toks = _toks(0, 10)
    P, buf = len(toks), len(toks) + 4

    fast = DecodeSession.create(cfg, params, buf_len=buf)
    assert fast._can_parallel_prefill(P)
    lg_fast = fast.prefill(toks)

    slow = DecodeSession.create(cfg, params, buf_len=buf)
    lg_slow = slow._prefill_steps(toks)
    slow.stats.prefill_tokens += P

    atol = 1e-5 if family == "dense" else 1e-5
    np.testing.assert_allclose(np.asarray(lg_fast), np.asarray(lg_slow),
                               atol=atol, rtol=1e-5)
    # the written cache slots agree too — later decode steps see the same
    # keys/values/positions either way
    for name in fast.cache:
        if name == "cross":
            continue
        for leaf in ("k", "v", "pos"):
            a = np.asarray(fast.cache[name][leaf][:, :, :P])
            b = np.asarray(slow.cache[name][leaf][:, :, :P])
            np.testing.assert_allclose(a, b, atol=atol, rtol=1e-5)
    assert fast.t == slow.t == P
    assert fast.stats.prefill_tokens == P
    # decode continues identically from either prefill
    nxt = _toks(1, 1)
    np.testing.assert_allclose(np.asarray(fast.step(nxt)),
                               np.asarray(slow.step(nxt)),
                               atol=atol, rtol=1e-5)


def test_incremental_prefill_matches_single():
    """A second prefill on a session holding context rides the cached
    slots in as gateway ancestors — same result as one big prefill."""
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    toks = _toks(2, 12)

    whole = DecodeSession.create(cfg, params, buf_len=16)
    lg_whole = whole.prefill(toks)

    split = DecodeSession.create(cfg, params, buf_len=16)
    split.prefill(toks[:5])
    lg_split = split.prefill(toks[5:])

    np.testing.assert_allclose(np.asarray(lg_split), np.asarray(lg_whole),
                               atol=1e-5, rtol=1e-5)
    for name in whole.cache:
        for leaf in ("k", "v", "pos"):
            np.testing.assert_allclose(
                np.asarray(split.cache[name][leaf][:, :, :12]),
                np.asarray(whole.cache[name][leaf][:, :, :12]),
                atol=1e-5, rtol=1e-5)
    assert split.t == whole.t == 12
    assert split.stats.prefill_tokens == 12


def test_prefill_falls_back_when_unsupported():
    # sliding-window configs use the step loop (ring slots alias)
    cfg = tiny_cfg("dense")
    cfg = cfg.replace(attn=dataclasses.replace(cfg.attn, window=4))
    params = init_params(cfg, jax.random.key(0))
    sess = DecodeSession.create(cfg, params, buf_len=16)
    assert not sess._can_parallel_prefill(6)
    lg = sess.prefill(_toks(3, 6))
    assert lg.shape == (1, cfg.padded_vocab)
    assert sess.t == 6 and sess.stats.prefill_tokens == 6


# ---------------------------------------------------------------------------
# fork: K branches share the prefix KV, bit-exact in fp32
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["dense", "moe"])
def test_fork_bitexact_vs_unshared_prefill(family):
    """Branches decoded off one forked prefix equal K independent
    sessions that each recomputed the prefix — bit for bit (fp32), while
    the forked group computed the prefix exactly once."""
    cfg = tiny_cfg(family)
    params = init_params(cfg, jax.random.key(0))
    prompt = _toks(4, 8)
    K, steps = 3, 4
    branch_toks = np.stack([_toks(10 + k, steps) for k in range(K)])

    shared = DecodeSession.create(cfg, params, buf_len=16)
    shared.prefill(prompt)
    forked = shared.fork(K)
    assert forked.batch == K and forked.t == 8
    assert forked.stats is shared.stats          # group accounting

    # reference: a K-row session where every row pays its own prefill
    solo = DecodeSession.create(cfg, params, batch=K, buf_len=16)
    solo.prefill(prompt)

    for t in range(steps):
        lg_fork = np.asarray(forked.step(branch_toks[:, t]))
        lg_solo = np.asarray(solo.step(branch_toks[:, t]))
        np.testing.assert_array_equal(lg_fork, lg_solo)

    # the proof of prefix reuse: one prefill for K branches
    assert shared.stats.prefill_tokens == len(prompt)
    assert solo.stats.prefill_tokens == K * len(prompt)
    assert forked.stats.decode_tokens == K * steps


def test_fork_requires_single_branch():
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    sess = DecodeSession.create(cfg, params, batch=2, buf_len=8)
    with pytest.raises(AssertionError):
        sess.fork(3)


def test_snapshot_is_independent():
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    sess = DecodeSession.create(cfg, params, buf_len=16)
    sess.prefill(_toks(5, 6))
    snap = sess.snapshot()
    tok = _toks(6, 1)
    lg_a = np.asarray(sess.step(tok))
    assert snap.t == 6 and sess.t == 7       # snapshot untouched
    lg_b = np.asarray(snap.step(tok))        # immutable caches → same path
    np.testing.assert_array_equal(lg_a, lg_b)
    assert snap.stats is sess.stats


# ---------------------------------------------------------------------------
# ops.prefill_attention ≡ full-chain tree_attention
# ---------------------------------------------------------------------------

def test_prefill_attention_matches_full_chain():
    from repro.kernels.ops import prefill_attention, tree_attention
    rng = np.random.default_rng(7)
    B, A, S, H, hd = 2, 5, 6, 4, 8
    q_full = rng.normal(size=(B, A + S, H, hd)).astype(np.float32)
    k_full = rng.normal(size=(B, A + S, H, hd)).astype(np.float32)
    v_full = rng.normal(size=(B, A + S, H, hd)).astype(np.float32)
    scale = hd ** -0.5
    kv_last = jnp.broadcast_to(jnp.asarray(A + S - 1, jnp.int32),
                               (B, A + S))
    ref = tree_attention(jnp.asarray(q_full), jnp.asarray(k_full),
                         jnp.asarray(v_full), kv_last, scale)

    # context path: tail queries against (cached ctx) + (new kv)
    out = prefill_attention(jnp.asarray(q_full[:, A:]),
                            jnp.asarray(k_full[:, A:]),
                            jnp.asarray(v_full[:, A:]), scale,
                            ctx_k=jnp.asarray(k_full[:, :A]),
                            ctx_v=jnp.asarray(v_full[:, :A]),
                            ctx_valid=jnp.ones((B, A), bool))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref[:, A:]),
                               atol=1e-5, rtol=1e-5)

    # no-context path: plain causal chain
    out0 = prefill_attention(jnp.asarray(q_full), jnp.asarray(k_full),
                             jnp.asarray(v_full), scale)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)

    # an invalid ctx row is invisible: equals attention w/o that row
    valid = jnp.ones((B, A), bool).at[:, 2].set(False)
    out_m = prefill_attention(jnp.asarray(q_full[:, A:]),
                              jnp.asarray(k_full[:, A:]),
                              jnp.asarray(v_full[:, A:]), scale,
                              ctx_k=jnp.asarray(k_full[:, :A]),
                              ctx_v=jnp.asarray(v_full[:, :A]),
                              ctx_valid=valid)
    keep = [i for i in range(A) if i != 2]
    sub = np.concatenate([k_full[:, keep], k_full[:, A:]], axis=1)
    subv = np.concatenate([v_full[:, keep], v_full[:, A:]], axis=1)
    kv_last2 = jnp.broadcast_to(jnp.asarray(A - 1 + S, jnp.int32),
                                (B, A - 1 + S))
    ref_m = tree_attention(jnp.asarray(q_full[:, A:]), jnp.asarray(sub),
                           jnp.asarray(subv), kv_last2, scale,
                           q_off=A - 1)
    np.testing.assert_allclose(np.asarray(out_m), np.asarray(ref_m),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# deprecated wrappers still work, but warn
# ---------------------------------------------------------------------------

def test_deprecated_decode_free_functions_warn():
    from repro.serve.decode import decode_step, init_cache
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    with pytest.warns(DeprecationWarning, match="DecodeSession"):
        cache = init_cache(cfg, 1, 8)
    with pytest.warns(DeprecationWarning, match="DecodeSession"):
        lg, cache = decode_step(cfg, params, cache,
                                jnp.zeros((1, 1), jnp.int32),
                                jnp.zeros((1,), jnp.int32),
                                jnp.asarray(0, jnp.int32))
    assert lg.shape == (1, cfg.padded_vocab)


def test_deprecated_loader_wrappers_warn():
    from repro.data.loader import (LoaderConfig, execution_plans,
                                   step_batches)
    cfg = tiny_cfg("dense")
    lc = LoaderConfig(seq_len=96, batch_rows=2, trees_per_batch=2,
                      mode="tree", seed=0, auto_partition=True,
                      gen_kwargs=dict(turn_len_range=(4, 8), num_turns=2))
    with pytest.warns(DeprecationWarning, match="train.planner.plans"):
        sb = next(step_batches(cfg, lc, 1))
    assert sb.dropped == 0
    with pytest.warns(DeprecationWarning, match="train.planner.plans"):
        plan = next(execution_plans(cfg, lc, 1))
    assert plan.num_trees == sb.num_trees
