"""Batched wave-scheduled partitioned driver (Tree Packing over
partitions): gradients equal the whole-tree pass through ``make_grad_fn``
and the existing single-tree recursive driver, for dense GQA and SSM
configs; end-to-end training via launch/train.py drops zero trees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core.gateway import (packed_partitioned_value_and_grad,
                                partitioned_value_and_grad)
from repro.core.packing import pack_trees
from repro.core.tree import serialize_tree
from repro.data.synthetic import random_tree
from repro.models.model import init_params, needs_chunks, prepare_batch
from repro.train.train_step import make_grad_fn

pytestmark = pytest.mark.slow  # multi-minute partition equivalences


def get_tree(seed=0, lo=60, hi=120):
    for s in range(seed, seed + 300):
        t = random_tree(np.random.default_rng(s), vocab_size=89,
                        max_depth=5, seg_len_range=(3, 9))
        if t.num_leaves() >= 4 and lo <= t.num_unique_tokens() <= hi:
            return t
    raise RuntimeError


def _whole_tree_sum(cfg, params, trees, chunk):
    """Σ over trees of (loss, grads) via the standard jitted grad fn on
    whole, un-partitioned serializations (one tree per call)."""
    gfn = make_grad_fn(cfg)
    loss = 0.0
    grads = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
    for t in trees:
        ser = serialize_tree(t, chunk_size=chunk)
        S = ((ser.n + 31) // 32) * 32
        b = prepare_batch(cfg, pack_trees([ser], S, chunk_size=chunk))
        l, g, _ = gfn(params, b)
        loss += float(l)
        grads = jax.tree.map(lambda a, x: a + x.astype(jnp.float32),
                             grads, g)
    return loss, grads


def _max_rel(g, g_ref):
    rels = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max() /
                           (jnp.abs(b).max() + 1e-9)), g, g_ref)
    return max(jax.tree.leaves(rels))


@pytest.mark.parametrize("family", ["dense", "ssm_mamba2"])
def test_wave_driver_matches_whole_tree_grads(family):
    cfg = tiny_cfg(family)
    chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    params = init_params(cfg, jax.random.key(0))
    trees = [get_tree(0), get_tree(40), get_tree(80, lo=30, hi=70)]
    l_ref, g_ref = _whole_tree_sum(cfg, params, trees, chunk)
    l_p, g_p, info = packed_partitioned_value_and_grad(
        cfg, params, trees, capacity=40, seq_len=48)
    assert info["num_waves"] >= 2 and info["num_partitions"] > len(trees)
    assert info["unique_tokens"] == sum(t.num_unique_tokens()
                                        for t in trees)
    np.testing.assert_allclose(l_p, l_ref, rtol=2e-5)
    assert _max_rel(g_p, g_ref) < 1e-4   # paper App. B.8 f32 bound


def test_wave_driver_max_rows_splits_waves_grads_match():
    """max_rows bounds every wave's row count (too-wide waves split into
    consecutive narrower ones, parents still strictly earlier) without
    changing the math — per-wave memory matches a max_rows-row step."""
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    trees = [get_tree(0), get_tree(40), get_tree(80, lo=30, hi=70)]
    l_ref, g_ref, info_ref = packed_partitioned_value_and_grad(
        cfg, params, trees, capacity=40, seq_len=48)
    assert info_ref["max_wave_rows"] > 2  # unbudgeted run is wider
    l_p, g_p, info = packed_partitioned_value_and_grad(
        cfg, params, trees, capacity=40, seq_len=48, max_rows=2)
    assert info["max_wave_rows"] <= 2
    np.testing.assert_allclose(l_p, l_ref, rtol=2e-5)
    assert _max_rel(g_p, g_ref) < 1e-4


def test_wave_driver_pallas_matches_chunked_grads():
    """The fused pallas kernels on the partition-gateway path (ancestor
    extra_kv + front-padding masks + fused backward with ancestor
    cotangent routing) reproduce the XLA chunked path's loss and
    gradients on a partitioned oversized tree — the downgrade that used
    to force wave training off the kernel is gone."""
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    tree = get_tree(7, lo=90, hi=160)
    l_c, g_c, info_c = packed_partitioned_value_and_grad(
        cfg, params, [tree], capacity=24, seq_len=24, impl="chunked")
    l_p, g_p, info_p = packed_partitioned_value_and_grad(
        cfg, params, [tree], capacity=24, seq_len=24, impl="pallas")
    assert info_p["num_partitions"] == info_c["num_partitions"] > 1
    np.testing.assert_allclose(l_p, l_c, rtol=2e-5)
    assert _max_rel(g_p, g_c) < 1e-4
    assert info_p["weight_sum"] > 0


def test_wave_driver_matches_recursive_driver():
    """Same tree, same capacity: the batched scheduler and the recursive
    B=1 driver are the same math."""
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(1))
    tree = get_tree(7, lo=90, hi=160)
    l_r, g_r, info_r = partitioned_value_and_grad(cfg, params, tree,
                                                  capacity=24)
    l_w, g_w, info_w = packed_partitioned_value_and_grad(
        cfg, params, [tree], capacity=24, seq_len=24)
    assert info_w["num_partitions"] == info_r["num_partitions"]
    np.testing.assert_allclose(l_w, l_r, rtol=2e-5)
    assert _max_rel(g_w, g_r) < 1e-4


def test_train_cli_auto_partition_end_to_end():
    """launch/train.py with --auto-partition trains on a stream containing
    trees larger than --seq-len, end to end, with zero dropped trees."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    # collected-alongside shardlint modules force 512 fake XLA devices
    # into os.environ — --rows 2 can't shard over a 512-replica mesh, so
    # the real-device launcher subprocess must not inherit that
    env.pop("XLA_FLAGS", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "qwen1.5-0.5b", "--smoke", "--steps", "3", "--seq-len", "96",
         "--rows", "2", "--trees", "3", "--auto-partition",
         "--capacity", "64"],
        capture_output=True, text=True, timeout=560, env=env, cwd=root)
    assert r.returncode == 0, r.stdout + r.stderr
    out = r.stdout
    assert "0 dropped" in out
    assert "partitioned:" in out
    # at least one oversized tree actually took the partitioned path
    n_part = int(out.split("partitioned: ")[1].split(" ")[0])
    assert n_part > 0
