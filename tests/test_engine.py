"""Unified plan→execute engine: loss/grads match the pre-refactor
two-branch loop (jitted packed step + host-driven wave driver) on mixed
batches, RL with unit advantages is bit-exactly SFT through the whole
engine, and one optimizer step performs exactly one host sync."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core.gateway import packed_partitioned_value_and_grad
from repro.data.loader import LoaderConfig
from repro.models.model import init_params
from repro.train.engine import TreeTrainEngine
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.planner import plans
from repro.train.train_step import jitted_update, make_grad_fn


def _lc(**kw):
    base = dict(seq_len=96, batch_rows=2, trees_per_batch=5, mode="tree",
                kind="agentic", seed=5, auto_partition=True,
                gen_kwargs=dict(turn_len_range=(4, 12), num_turns=2))
    base.update(kw)
    return LoaderConfig(**base)


def _find_mixed(cfg, lc, steps=8, min_oversized=2):
    """First step whose batch holds BOTH packed rows and ≥2 oversized
    trees, as (StepBatch, ExecutionPlan) — one PlannedStep materializes
    both views from the same schedule."""
    for ps in plans(cfg, lc, steps):
        sb = ps.step_batch()
        if sb.inputs is not None and len(sb.oversized) >= min_oversized:
            plan = ps.execution_plan()
            assert plan.packed is not None
            assert plan.num_oversized >= min_oversized
            return sb, plan
    raise AssertionError("no mixed step in this stream; adjust seeds")


def _two_branch_reference(cfg, params, sb, lc, impl):
    """The PRE-refactor training math, verbatim: one jitted grad over the
    packed batch + the wave driver for oversized trees, combined host-side
    (grads /= num_trees for the partitioned share)."""
    n = max(sb.num_trees, 1)
    cap = lc.capacity or lc.seq_len
    loss, grads = 0.0, None
    if sb.inputs is not None:
        inputs = dict(sb.inputs)
        inputs["num_trees"] = n
        li, grads, _ = make_grad_fn(cfg, impl=impl)(params, inputs)
        loss += float(li)
    if sb.oversized:
        l_p, g_p, _ = packed_partitioned_value_and_grad(
            cfg, params, sb.oversized, cap, seq_len=lc.seq_len, impl=impl,
            loss_mode=lc.loss_mode, max_rows=lc.batch_rows)
        loss += l_p / n
        g_p = jax.tree.map(lambda a: a / n, g_p)
        grads = g_p if grads is None else jax.tree.map(
            lambda a, b: a.astype(jnp.float32) + b, grads, g_p)
    return loss, grads


def _max_rel(g, g_ref):
    rels = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max() /
                           (jnp.abs(b).max() + 1e-9)), g, g_ref)
    return max(jax.tree.leaves(rels))


# ---------------------------------------------------------------------------
# engine ≡ two-branch loop (the refactor's acceptance bar)
# ---------------------------------------------------------------------------

def _check_engine_equivalence(family, impl):
    cfg = tiny_cfg(family)
    lc = _lc()
    sb, plan = _find_mixed(cfg, lc)
    params = init_params(cfg, jax.random.key(0))
    l_ref, g_ref = _two_branch_reference(cfg, params, sb, lc, impl)

    engine = TreeTrainEngine(cfg, impl=impl, donate=False)
    grads, scal = engine.accumulate(params, plan)
    l_eng = float(np.asarray(scal)[0])

    assert abs(l_eng - l_ref) / max(abs(l_ref), 1e-9) <= 1e-6
    assert _max_rel(grads, g_ref) <= 1e-6
    assert engine.host_syncs == 0   # accumulation never touches the host


def test_engine_matches_two_branch_dense_ref():
    _check_engine_equivalence("dense", "ref")


@pytest.mark.slow
@pytest.mark.parametrize("family,impl", [
    ("dense", "chunked"), ("dense", "pallas"),
    ("moe", "chunked"), ("moe", "pallas")])
def test_engine_matches_two_branch(family, impl):
    _check_engine_equivalence(family, impl)


# ---------------------------------------------------------------------------
# RL ≡ SFT at unit advantages, through the WHOLE engine (packed + waves)
# ---------------------------------------------------------------------------

def test_engine_rl_unit_advantages_bitexact_sft():
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(1))
    grads = {}
    for mode in ("sep_avg", "rl"):
        lc = _lc(loss_mode=mode)
        _, plan = _find_mixed(cfg, lc)
        engine = TreeTrainEngine(cfg, donate=False)
        g, scal = engine.accumulate(params, plan)
        grads[mode] = (np.asarray(scal), g)
    np.testing.assert_array_equal(grads["sep_avg"][0], grads["rl"][0])
    for a, b in zip(jax.tree.leaves(grads["sep_avg"][1]),
                    jax.tree.leaves(grads["rl"][1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# host-sync discipline + step mechanics
# ---------------------------------------------------------------------------

def test_engine_one_host_sync_per_step():
    cfg = tiny_cfg("dense")
    lc = _lc()
    params = init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    engine = TreeTrainEngine(cfg, OptimizerConfig(lr=1e-3, warmup_steps=2,
                                                  total_steps=4),
                             donate=False)
    steps = 0
    for ps in plans(cfg, lc, 4):
        plan = ps.execution_plan()
        if plan.is_empty:
            continue
        params, opt, m = engine.step(params, opt, plan)
        steps += 1
        assert engine.host_syncs == steps     # exactly one per step
        assert np.isfinite(m["loss"]) and np.isfinite(m["nll"])
        assert m["weight_sum"] > 0
    assert steps >= 2
    assert int(np.asarray(opt["step"])) == steps


def test_engine_rl_training_descends_on_grpo_trees():
    """The RL model-update workload end to end: grpo trees (non-uniform
    group-normalized advantages), loss_mode="rl", engine steps run and
    produce finite losses and updates."""
    cfg = tiny_cfg("dense")
    lc = _lc(loss_mode="rl", kind="grpo",
             gen_kwargs=dict(turn_len_range=(4, 10), num_turns=2))
    params = init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    engine = TreeTrainEngine(cfg, OptimizerConfig(lr=1e-3, warmup_steps=2,
                                                  total_steps=4),
                             donate=False)
    p0 = jax.tree.leaves(params)[0].copy()
    ran = 0
    for ps in plans(cfg, lc, 4):
        plan = ps.execution_plan()
        if plan.is_empty:
            continue
        params, opt, m = engine.step(params, opt, plan)
        assert np.isfinite(m["loss"])
        ran += 1
    assert ran >= 2
    assert not np.array_equal(np.asarray(p0),
                              np.asarray(jax.tree.leaves(params)[0]))


def test_jitted_update_cache_is_shared():
    """Satellite: apply_grads no longer re-jits per call — the jitted
    AdamW update is cached per OptimizerConfig."""
    a = OptimizerConfig(lr=1e-3)
    b = OptimizerConfig(lr=1e-3)
    c = OptimizerConfig(lr=2e-3)
    assert jitted_update(a) is jitted_update(b)
    assert jitted_update(a) is not jitted_update(c)
    assert jitted_update(a) is not jitted_update(a, donate=True)
