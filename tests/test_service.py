"""Async tree-RL service: rollout groups → advantage trees → live planner
source → engine steps, with bounded staleness and exact prefix-KV token
accounting; frozen rollouts reproduce the offline RL gradients; the CLI
soak runs the whole loop end to end (slow)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.data.loader import LoaderConfig
from repro.models.model import init_params
from repro.serve.rollout import RolloutConfig, rollout_group
from repro.serve.service import (AsyncTreeRLService, ServiceConfig,
                                 WeightStore)
from repro.train.checkpoint import (load_checkpoint, load_meta,
                                    save_checkpoint)
from repro.train.engine import TreeTrainEngine
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.planner import PlannerConfig, plans


RC = RolloutConfig(k=3, prompt_len=6, max_new=4)


# ---------------------------------------------------------------------------
# rollout groups: shared-prefix accounting + tree shape
# ---------------------------------------------------------------------------

def test_rollout_group_prefix_computed_once():
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    prompt = np.arange(RC.prompt_len, dtype=np.int32)
    tree, gs = rollout_group(cfg, params, prompt, RC, jax.random.key(1))
    # THE acceptance number: the prefix was computed once, not K times
    assert gs.prefill_tokens == RC.prompt_len
    assert gs.saved_prefill_tokens == (RC.k - 1) * RC.prompt_len
    assert gs.decode_tokens == RC.k * (RC.max_new - 1)
    assert len(gs.rewards) == RC.k
    # every branch is prompt + max_new sampled tokens, merged as a trie
    paths = tree.paths()
    assert len(paths) == RC.k
    for p in paths:
        toks = np.concatenate([n.tokens for n in p])
        assert len(toks) == RC.prompt_len + RC.max_new
        np.testing.assert_array_equal(toks[:RC.prompt_len], prompt)
    assert tree.num_unique_tokens() <= RC.prompt_len + RC.k * RC.max_new


def test_rollout_group_greedy_branches_collapse():
    """temperature 0 → all branches sample identically → the merged trie
    is one chain plus empty duplicate leaves, advantages all zero."""
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    rc = RolloutConfig(k=3, prompt_len=6, max_new=4, temperature=0.0)
    tree, gs = rollout_group(cfg, params,
                             np.arange(6, dtype=np.int32), rc,
                             jax.random.key(1))
    assert tree.num_unique_tokens() == rc.prompt_len + rc.max_new
    assert all(a == b for a, b in zip(gs.rewards, gs.rewards[1:]))
    assert all(p[-1].branch_adv == 0.0 for p in tree.paths())


# ---------------------------------------------------------------------------
# WeightStore: versions, gating, donation safety
# ---------------------------------------------------------------------------

def test_weight_store_versions_and_copies():
    params = {"w": jnp.arange(4.0)}
    store = WeightStore(params, version=0)
    got, ver = store.get()
    assert ver == 0
    assert got["w"] is not params["w"]           # deep-copied on ingest
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(params["w"]))
    assert not store.wait_for(1, timeout=0.05)   # nothing published yet
    new = {"w": jnp.ones(4)}
    store.publish(new, version=3)
    assert store.wait_for(1, timeout=0.05)
    got2, ver2 = store.get()
    assert ver2 == 3
    assert got2["w"] is not new["w"]             # publish copies too
    np.testing.assert_array_equal(np.asarray(got2["w"]), np.ones(4))


# ---------------------------------------------------------------------------
# the loop: service → planner → engine, bounded staleness, zero drops
# ---------------------------------------------------------------------------

def test_async_service_closes_the_loop():
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    opt_state = init_opt_state(params)
    steps = 3
    lc = LoaderConfig(seq_len=64, batch_rows=2, trees_per_batch=2,
                      mode="tree", seed=0, loss_mode="rl",
                      auto_partition=True)
    pcfg = PlannerConfig(lookahead=1, plan_workers=1, max_rows=2)
    sc = ServiceConfig(groups_per_step=2, max_ahead_steps=1, rollout=RC,
                       seed=0, gate_timeout_s=60.0)
    store = WeightStore(params, version=0)
    engine = TreeTrainEngine(cfg, OptimizerConfig(lr=1e-3, warmup_steps=2,
                                                  total_steps=steps),
                             weight_store=store)
    svc = AsyncTreeRLService(cfg, store, sc, num_steps=steps).start()
    pipe = plans(cfg, lc, svc.tree_batches(), pcfg)

    losses, dropped = [], 0
    for ps in pipe:
        plan = ps.execution_plan()
        dropped += plan.dropped
        if plan.is_empty:
            continue
        assert plan.versions is not None         # live trees carry versions
        params, opt_state, m = engine.step(params, opt_state, plan)
        losses.append(m["loss"])
        assert "max_lag" in m
    svc.join(10)

    assert svc._error is None
    assert len(losses) >= 2 and dropped == 0
    assert all(np.isfinite(losses))
    # bounded staleness, audited on BOTH sides of the queue
    bound = sc.max_ahead_steps + pcfg.lookahead - 1
    assert engine.max_lag_seen <= bound
    assert svc.stats.max_gen_lag <= sc.max_ahead_steps
    assert svc.stats.trees_generated == steps * sc.groups_per_step
    # group-level prefix reuse survives aggregation
    assert svc.stats.prefill_tokens == \
        steps * sc.groups_per_step * RC.prompt_len
    assert svc.stats.saved_prefill_tokens == \
        steps * sc.groups_per_step * (RC.k - 1) * RC.prompt_len


def test_service_generation_error_reaches_consumer():
    cfg = tiny_cfg("dense")
    store = WeightStore({"w": jnp.zeros(1)})     # junk params → rollout dies
    sc = ServiceConfig(groups_per_step=1, max_ahead_steps=1, rollout=RC)
    svc = AsyncTreeRLService(cfg, store, sc, num_steps=1).start()
    with pytest.raises(RuntimeError, match="rollout generation failed"):
        for _ in svc.tree_batches():
            pass


# ---------------------------------------------------------------------------
# frozen rollouts: online plan path ≡ offline loss_mode="rl" grads
# ---------------------------------------------------------------------------

def test_frozen_rollout_grads_match_offline():
    from repro.launch.rl_loop import check_frozen_grads
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    lc = LoaderConfig(seq_len=64, batch_rows=2, trees_per_batch=2,
                      mode="tree", seed=0, loss_mode="rl",
                      auto_partition=True)
    pcfg = PlannerConfig(lookahead=1, max_rows=2)
    trees = [rollout_group(cfg, params,
                           np.arange(RC.prompt_len, dtype=np.int32) + g,
                           RC, jax.random.key(g))[0] for g in range(2)]
    err = check_frozen_grads(cfg, lc, pcfg, params, trees, "ref")
    assert err <= 1e-6, err


# ---------------------------------------------------------------------------
# checkpoint: mid-stream resume point round-trips
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_with_meta(tmp_path):
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(3))
    opt_state = init_opt_state(params)
    opt_state["step"] = jnp.asarray(7)
    path = str(tmp_path / "ck")
    save_checkpoint(path, params, opt_state,
                    meta={"arch": cfg.name, "steps": 7})
    p0 = init_params(cfg, jax.random.key(4))
    o0 = init_opt_state(p0)
    p1, o1 = load_checkpoint(path, p0, o0)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(np.asarray(o1["step"])) == 7
    meta = load_meta(path)
    assert meta["steps"] == 7 and meta["arch"] == cfg.name


# ---------------------------------------------------------------------------
# the CLI soak (slow): overlapped generation, grad check, ckpt resume
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_rl_loop_cli_soak(tmp_path):
    env = dict(os.environ)
    # collected-alongside shardlint modules force 512 fake XLA devices
    # into os.environ — a real-device launcher subprocess must not
    # inherit that (512-way SPMD on host CPUs runs ~40x slower)
    env.pop("XLA_FLAGS", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    ck = str(tmp_path / "ck")
    base = [sys.executable, "-m", "repro.launch.rl_loop", "--arch",
            "qwen3-8b", "--smoke", "--check-grads"]
    r = subprocess.run(base + ["--steps", "4", "--save", ck,
                               "--ckpt-every", "2"],
                       capture_output=True, text=True, timeout=560,
                       env=env, cwd=root)
    assert r.returncode == 0, r.stdout + r.stderr
    out = r.stdout
    assert "0 dropped" in out and "max lag 1 (bound 1)" in out
    assert "grad check: max-rel 0.00e+00" in out
    # resume picks up at the saved step and keeps the staleness bound
    r2 = subprocess.run(base + ["--steps", "2", "--resume", ck],
                        capture_output=True, text=True, timeout=560,
                        env=env, cwd=root)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "resumed" in r2.stdout and "@ step 4" in r2.stdout
    assert "0 dropped" in r2.stdout
