"""models/attention.py dispatch equivalences on the partition-gateway and
sliding-window paths: 'pallas' (fused kernels), 'chunked' (XLA scan) and
'ref' (dense oracle) must agree — outputs AND gradients, including the
ancestor (extra_kv) cotangents the wave driver routes child → parent.
Also pins the _attend_chunked divisor fix: a prime-ish KV length must not
degrade the scan to chunk size 1."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttnCfg
from repro.core.packing import pack_trees
from repro.core.tree import serialize_tree
from repro.data.synthetic import trees_for_batch
from repro.models.attention import (_attend_chunked, _attend_ref,
                                    _tree_bias, attention, init_attention)

ATTN = AttnCfg(n_heads=4, n_kv_heads=2, head_dim=8, rope_theta=10_000.0)
D = 32


def _packed_meta(seed: int, B: int, S: int):
    trees = trees_for_batch(seed, n_trees=6 * B, kind="random",
                            seg_len_range=(1, 4), max_depth=3)
    sers, used = [], 0
    for t in trees:
        s = serialize_tree(t)
        if used + s.n <= B * S * 3 // 4:
            sers.append(s)
            used += s.n
    tb = pack_trees(sers, S, batch_size=B)
    return (jnp.asarray(tb.pos_ids), jnp.asarray(tb.kv_last),
            jnp.asarray(tb.valid))


def _gateway_extra(rng, B: int, A: int, pad_rows=(5, 0)):
    Kh, hd = ATTN.n_kv_heads, ATTN.head_dim
    valid = np.ones((B, A), bool)
    for r, p in zip(range(B), pad_rows):
        valid[r, :p] = False
    return {
        "k": jnp.asarray(rng.normal(size=(B, A, Kh, hd)), jnp.float32),
        "v": jnp.asarray(rng.normal(size=(B, A, Kh, hd)), jnp.float32),
        "pos": jnp.broadcast_to(jnp.arange(A, dtype=jnp.int32), (B, A)),
        "valid": jnp.asarray(valid),
    }


@pytest.mark.parametrize("window", [None, 12])
@pytest.mark.parametrize("A", [16, 20])   # aligned + awkward (pad) depths
def test_impls_agree_on_gateway_path(window, A):
    cfg = dataclasses.replace(ATTN, window=window)
    rng = np.random.default_rng(A + (window or 0))
    B, S = 2, 64
    pos_ids, kv_last, valid = _packed_meta(3, B, S)
    params = init_attention(jax.random.key(0), cfg, D)
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    extra = _gateway_extra(rng, B, A)

    def run(impl, x_, ek, ev):
        return attention(params, cfg, x_, pos_ids=pos_ids, kv_last=kv_last,
                         valid=valid, impl=impl,
                         extra_kv={**extra, "k": ek, "v": ev})

    outs, grads = {}, {}
    for impl in ("ref", "chunked", "pallas"):
        outs[impl] = run(impl, x, extra["k"], extra["v"])
        grads[impl] = jax.grad(
            lambda *a, impl=impl: (run(impl, *a) ** 2).sum(),
            argnums=(0, 1, 2))(x, extra["k"], extra["v"])
    for impl in ("chunked", "pallas"):
        np.testing.assert_allclose(np.asarray(outs[impl]),
                                   np.asarray(outs["ref"]),
                                   atol=2e-5, rtol=2e-5, err_msg=impl)
        for name, a, b in zip(("dx", "d_extra_k", "d_extra_v"),
                              grads[impl], grads["ref"]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, rtol=2e-4,
                                       err_msg=f"{impl}:{name}")
    # the ancestor cotangents are live, not zeros
    assert float(jnp.abs(grads["pallas"][1]).max()) > 1e-4


def test_pallas_applies_sliding_window():
    """Regression: the pallas impl used to silently ignore cfg.window —
    windowed configs returned full-attention results."""
    cfg_w = dataclasses.replace(ATTN, window=8)
    cfg_full = ATTN
    rng = np.random.default_rng(17)
    B, S = 2, 128
    pos_ids, kv_last, valid = _packed_meta(5, B, S)
    params = init_attention(jax.random.key(1), cfg_w, D)
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)

    def run(cfg, impl):
        # fully-masked padding rows are undefined in the dense-bias path
        # (uniform softmax) and zero in the kernel — training never reads
        # them, so compare valid rows only
        o = attention(params, cfg, x, pos_ids=pos_ids, kv_last=kv_last,
                      valid=valid, impl=impl)
        return o * valid[..., None]

    np.testing.assert_allclose(np.asarray(run(cfg_w, "pallas")),
                               np.asarray(run(cfg_w, "ref")),
                               atol=2e-5, rtol=2e-5)
    # teeth: windowed ≠ full attention on these trees
    assert float(jnp.abs(run(cfg_w, "ref")
                         - run(cfg_full, "ref")).max()) > 1e-3


def _scan_lengths(closed_jaxpr):
    out = []
    for eqn in closed_jaxpr.jaxpr.eqns:
        if eqn.primitive.name == "scan":
            out.append(eqn.params["length"])
        for p in eqn.params.values():
            if hasattr(p, "jaxpr"):
                out.extend(_scan_lengths(p))
    return out


def test_chunked_prime_kv_len_does_not_degrade():
    """_attend_chunked on a prime-ish Skv (gateway-extended KV): the old
    divisor loop degraded to kv_chunk=1 (an Skv-step scan); now the KV is
    padded to a power-of-two chunk boundary.  Checks both the scan length
    (≤ ceil(Skv/chunk) steps) and numerical agreement with the oracle."""
    rng = np.random.default_rng(29)
    B, S, H, hd = 1, 64, 2, 8
    A = 37                       # Skv = 101, prime
    Skv = A + S
    kv_chunk = 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, H, hd)), jnp.float32)
    kv_last = jnp.concatenate(
        [jnp.full((B, A), 1 << 30, jnp.int32),
         jnp.broadcast_to(jnp.arange(S) // 16 * 16 + 15 + A,
                          (B, S)).astype(jnp.int32)], axis=1)
    i_idx = A + jnp.arange(S)
    pos_q = jnp.broadcast_to(A + jnp.arange(S), (B, S)).astype(jnp.int32)
    pos_k = jnp.concatenate(
        [jnp.broadcast_to(jnp.arange(A), (B, A)).astype(jnp.int32),
         pos_q], axis=1)
    valid_k = jnp.ones((B, Skv), bool)

    def f(q_, k_, v_):
        return _attend_chunked(q_, k_, v_, i_idx, kv_last, pos_q, pos_k,
                               None, False, valid_k, hd ** -0.5,
                               kv_chunk=kv_chunk)

    # prime Skv has no divisor ≥ kv_chunk/4, so the pad path picks a
    # pow2 chunk ≥ 8 — a bounded scan, never the Skv-step degradation
    lengths = _scan_lengths(jax.make_jaxpr(f)(q, k, v))
    assert lengths and max(lengths) <= -(-Skv // 8) + 1, lengths
    assert max(lengths) < Skv
    bias = _tree_bias(i_idx, kv_last, pos_q, pos_k, None, False, valid_k)
    o_ref = _attend_ref(q, k, v, bias, hd ** -0.5)
    np.testing.assert_allclose(np.asarray(f(q, k, v)), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


def test_chunked_composite_kv_len_uses_divisor_without_pad():
    """Gateway-typical Skv = pow2 + small ancestor bucket (here 264 =
    256 + 8): the picker finds a large divisor (132 → two chunks, zero
    padding) instead of padding to the next pow2 multiple (2x scan)."""
    rng = np.random.default_rng(37)
    B, S, H, hd = 1, 64, 2, 8
    A = 200
    Skv = A + S                  # 264 = 2³·3·11
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, H, hd)), jnp.float32)
    kv_last = jnp.concatenate(
        [jnp.full((B, A), 1 << 30, jnp.int32),
         jnp.broadcast_to(jnp.arange(S) + A, (B, S)).astype(jnp.int32)],
        axis=1)
    i_idx = A + jnp.arange(S)
    pos_q = jnp.broadcast_to(A + jnp.arange(S), (B, S)).astype(jnp.int32)
    pos_k = jnp.concatenate(
        [jnp.broadcast_to(jnp.arange(A), (B, A)).astype(jnp.int32),
         pos_q], axis=1)
    valid_k = jnp.ones((B, Skv), bool)

    def f(q_, k_, v_):
        return _attend_chunked(q_, k_, v_, i_idx, kv_last, pos_q, pos_k,
                               None, False, valid_k, hd ** -0.5,
                               kv_chunk=256)

    lengths = _scan_lengths(jax.make_jaxpr(f)(q, k, v))
    assert lengths and max(lengths) == 2, lengths
    bias = _tree_bias(i_idx, kv_last, pos_q, pos_k, None, False, valid_k)
    o_ref = _attend_ref(q, k, v, bias, hd ** -0.5)
    np.testing.assert_allclose(np.asarray(f(q, k, v)), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


def test_chunked_prime_kv_len_windowed():
    """Same prime-ish Skv with a sliding window — padded keys must stay
    invisible under the window term too."""
    rng = np.random.default_rng(31)
    B, S, H, hd = 1, 64, 2, 8
    A = 37
    Skv = A + S
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, H, hd)), jnp.float32)
    kv_last = jnp.concatenate(
        [jnp.full((B, A), 1 << 30, jnp.int32),
         jnp.broadcast_to(jnp.arange(S) + A, (B, S)).astype(jnp.int32)],
        axis=1)
    i_idx = A + jnp.arange(S)
    pos_q = jnp.broadcast_to(A + jnp.arange(S), (B, S)).astype(jnp.int32)
    pos_k = jnp.concatenate(
        [jnp.broadcast_to(jnp.arange(A), (B, A)).astype(jnp.int32),
         pos_q], axis=1)
    valid_k = jnp.ones((B, Skv), bool)
    o = _attend_chunked(q, k, v, i_idx, kv_last, pos_q, pos_k, 16, False,
                        valid_k, hd ** -0.5, kv_chunk=32)
    bias = _tree_bias(i_idx, kv_last, pos_q, pos_k, 16, False, valid_k)
    o_ref = _attend_ref(q, k, v, bias, hd ** -0.5)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)
    assert np.isfinite(np.asarray(o)).all()
