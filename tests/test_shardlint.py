"""shardlint (treelint passes 4–6): every contract catches its seeded
violation, the declared lock discipline holds on the real sources, and
the CLI gates exit clean.

The contract checks are pure functions over parsed collective tables, so
the seeded-violation tests run without devices; the end-to-end lowering
gates run as subprocesses (fake devices need XLA_FLAGS before jax
initializes, which an already-imported test process cannot redo).
"""
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import hlo_comms
from repro.analysis.comms_audit import (check_grad_psum,
                                        check_no_param_allgather,
                                        check_seq_parallel_boundary,
                                        check_zero_data_axis, rule_lint)
from repro.analysis.lock_lint import LockRule, check_source, lock_findings
from repro.analysis.registry import comm_contract_for
from repro.configs import get_config
from repro.core.plan_cost import (CostWeights, score_packing,
                                  wire_bytes_per_step)
from repro.launch.mesh import (host_descriptor, make_host_mesh,
                               production_descriptor)


def _ar(elems, dtype="f32", axes=("data",), op_name="dot_general"):
    return {"op": "all-reduce", "dtype": dtype, "elems": elems,
            "bytes": 4 * elems, "wire_bytes": 8 * elems, "axes": axes,
            "op_name": op_name}


def _ag(elems, axes=("model",), op_name="dot_general"):
    return {"op": "all-gather", "dtype": "bf16", "elems": elems,
            "bytes": 2 * elems, "wire_bytes": 2 * elems, "axes": axes,
            "op_name": op_name}


def _rs(elems, axes=("model",)):
    return {"op": "reduce-scatter", "dtype": "bf16", "elems": elems,
            "bytes": 2 * elems, "wire_bytes": 2 * elems * 16,
            "axes": axes, "op_name": "psum_scatter"}


# ---------------------------------------------------------------------------
# Pass 4 — each contract catches its seeded violation
# ---------------------------------------------------------------------------

def test_seeded_missing_grad_psum_flagged():
    good = [_ar(1000), _ar(1, op_name="reduce_sum")]
    assert check_grad_psum(good, ("data",), 1000) == []
    # seeded: the grad reduction is gone (only the metric scalars remain)
    msgs = check_grad_psum([_ar(1)], ("data",), 1000)
    assert any("missing or short" in m for m in msgs)
    # seeded: a second reduction silently rescales the effective LR
    msgs = check_grad_psum([_ar(1000), _ar(1000)], ("data",), 1000)
    assert any("over-reduction" in m for m in msgs)
    # seeded: grads reduced in bf16 against the fp32 dtype policy
    msgs = check_grad_psum([_ar(1000), _ar(1000, dtype="bf16")],
                           ("data",), 1000)
    assert any("non-fp32" in m for m in msgs)


def test_grad_psum_replicated_reassociation_bound():
    # XLA may reduce a replicated param's grad over (model) then over
    # (data) on a 1/msize slice: grad_min admits it, grad_elems caps it
    colls = [_ar(960), _ar(4, axes=("data", "model"))]
    assert check_grad_psum(colls, ("data",), 1024, grad_min=964) == []
    assert check_grad_psum(colls, ("data",), 1024) != []


def test_seeded_param_allgather_flagged():
    params = {16384, 65536}
    # activation-sized all-gathers on the model axis are fine
    assert check_no_param_allgather([_ag(999)], params) == []
    # backward re-gathers (SP boundary) are fine
    bwd = _ag(16384, op_name="transpose(jvp(f))/dot_general")
    assert check_no_param_allgather([bwd], params) == []
    # seeded: a forward all-gather materializes a full weight
    msgs = check_no_param_allgather([_ag(16384)], params)
    assert any("matches a parameter" in m for m in msgs)


def test_seeded_wrong_axis_collective_flagged():
    # model-axis collectives are the TP contract — allowed in decode
    assert check_zero_data_axis([_ar(64, axes=("model",)), _ag(128)],
                                ("data",)) == []
    # seeded: a collective spans the data axis inside DecodeSession.step
    msgs = check_zero_data_axis([_ag(64, axes=("data",))], ("data",))
    assert any("spans data axis" in m for m in msgs)
    msgs = check_zero_data_axis(
        [_ar(64, axes=("pod", "data", "model"))], ("pod", "data"))
    assert any("spans data axes" in m for m in msgs)


def test_seeded_seq_parallel_regressions_flagged():
    base = [_ar(4096)]
    good_sp = [_rs(256)]
    assert check_seq_parallel_boundary(base, good_sp) == []
    # seeded: GSPMD fell back to all-reduce + slice (no true RS)
    msgs = check_seq_parallel_boundary(base, [_ar(4096)])
    assert any("no true reduce-scatter" in m for m in msgs)
    assert any("still all-reduces" in m for m in msgs)
    assert any("did not drop" in m for m in msgs)
    # seeded: attribution broke — an empty baseline makes the check
    # vacuous and must itself be a finding
    msgs = check_seq_parallel_boundary([], good_sp)
    assert any("vacuous" in m or "attribution" in m for m in msgs)


# ---------------------------------------------------------------------------
# Pass 5 — rule lint seeded violations (host-side, full configs)
# ---------------------------------------------------------------------------

def test_seeded_uncovered_param_flagged():
    msgs = rule_lint(get_config("qwen1p5_0p5b"), rules=[])
    assert any("matches no sharding._RULES entry" in m for m in msgs)


def test_seeded_replicated_fallback_flagged():
    from repro import sharding as sh
    # seeded bug class: an overeager size gate replicates a dim that
    # divides the model axis (probe shape passes the gate, real one not)
    bad = [(r"mlp/wi_gate$",
            lambda s, m: P(None, "M" if s[1] % m == 0 and s[1] > 10**4
                           else None))] + sh._RULES
    msgs = rule_lint(get_config("qwen1p5_0p5b"), rules=bad)
    assert any("silent replicated fallback" in m and "wi_gate" in m
               for m in msgs)
    # the real rules are clean on every registered full config
    assert rule_lint(get_config("qwen1p5_0p5b")) == []


# ---------------------------------------------------------------------------
# Pass 6 — lock lint
# ---------------------------------------------------------------------------

_SEEDED = '''
class Pipe:
    def __init__(self):
        self._cv = object()
        self._results = {}
        self._n = 0

    def ok(self):
        with self._cv:
            self._results[1] = "x"
            self._n += 1

    def racy(self):
        self._results[2] = "y"      # unlocked subscript store
        self._n += 1                # unlocked augassign
        self._results.pop(2)        # unlocked mutator call
'''


def test_lock_lint_seeded_unlocked_write_caught():
    rules = {"Pipe": LockRule(lock="_cv",
                              fields=frozenset({"_results", "_n"}))}
    msgs = check_source(_SEEDED, rules, filename="seeded.py")
    assert len(msgs) == 3
    assert all("racy" in m for m in msgs)
    assert any("_results" in m for m in msgs)
    assert any("_n" in m for m in msgs)


def test_lock_lint_init_and_exempt_fields_skipped():
    rules = {"Pipe": LockRule(lock="_cv", fields=frozenset({"_results"}),
                              exempt={"_n": "single writer"})}
    msgs = check_source(_SEEDED, rules)
    assert len(msgs) == 2           # _n mutations exempt, __init__ free


def test_lock_discipline_holds_on_real_sources():
    assert lock_findings() == []


# ---------------------------------------------------------------------------
# CommContract registry coverage
# ---------------------------------------------------------------------------

def test_comm_contracts_cover_registry_names():
    c = comm_contract_for("qwen1.5-smoke:engine.packed+acc")
    assert c is not None and c.grad_psum and c.no_param_allgather_fwd
    assert c.seq_parallel_boundary
    c = comm_contract_for("qwen1.5-smoke:session.step")
    assert c is not None and c.zero_data_axis_collectives
    assert comm_contract_for("qwen1.5-smoke:rollout.decode_scan") \
        .zero_data_axis_collectives
    assert comm_contract_for("nope:not.an.entrypoint") is None


# ---------------------------------------------------------------------------
# hlo_comms parser — tuple results, iota groups, loop attribution
# ---------------------------------------------------------------------------

_HLO = '''
HloModule jit_f

%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar.1 = f32[64]{0} all-reduce(f32[64]{0} %x), replica_groups=[16,16]<=[16,16]T(1,0), metadata={op_name="jit(f)/while/body/dot_general" source_file="/r/sharding.py" source_line=5}
  ROOT %t = (s32[], f32[64]) tuple(%c, %ar.1)
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %w = (s32[], f32[64]) while((s32[], f32[64]) %init), condition=%cond.1, body=%body.1
  %ar.2 = (f32[100]{0}, f32[28]{0}) all-reduce(f32[100]{0} %g1, f32[28]{0} %g2), replica_groups={{0,1},{2,3}}, metadata={op_name="jit(f)/transpose(jvp(f))/dot_general"}
  %ag = bf16[256]{0} all-gather(bf16[16]{0} %y), replica_groups={}, dimensions={0}
  %rs = bf16[4]{0} reduce-scatter(bf16[64]{0} %z), replica_groups=[16,16]<=[256], to_apply=%add
}
'''


def test_parse_collectives_tuple_iota_and_loops():
    colls = hlo_comms.parse_collectives(_HLO)
    by_op = {c["op"]: c for c in colls}
    ar_tuple = [c for c in colls if c["op"] == "all-reduce"
                and c["elems"] == 128][0]
    assert ar_tuple["bytes"] == 512          # combined (100+28) × f32
    assert not hlo_comms.is_forward(ar_tuple)
    in_loop = [c for c in colls if c["comp"] == "body.1"][0]
    assert in_loop["loop_depth"] >= 1        # while-body attribution
    assert in_loop["source_line"] == 5
    assert by_op["all-gather"]["wire_bytes"] == 512       # result bytes
    assert by_op["reduce-scatter"]["wire_bytes"] == 8 * 16  # shard × group
    # axis attribution on a (16,16) data×model mesh: the transposed iota
    # groups of ar.1 span the data axis only
    hlo_comms.attach_axes(colls, (16, 16), ("data", "model"))
    assert in_loop["axes"] == ("data",)
    assert by_op["all-gather"]["axes"] == ("data", "model")  # all devices


def test_wire_byte_model_conserves_ar_vs_rs_ag():
    # ring all-reduce ≡ reduce-scatter + all-gather: the conservation law
    # the seq-parallel gate leans on (forward edge halves, total doesn't)
    colls = hlo_comms.parse_collectives(_HLO)
    rs = [c for c in colls if c["op"] == "reduce-scatter"][0]
    # bf16[4] result × group size 16 = the full 128-byte tensor on the wire
    assert rs["wire_bytes"] == 128
    full_bytes = rs["bytes"] * 16            # the pre-scatter bf16[64]
    ar_wire = 2 * full_bytes                 # all-reduce of the same tensor
    ag_wire = full_bytes                     # the backward's re-gather
    assert rs["wire_bytes"] + ag_wire == ar_wire


# ---------------------------------------------------------------------------
# Mesh descriptors + cost-model comm term
# ---------------------------------------------------------------------------

def test_mesh_descriptors():
    d = production_descriptor(False)
    assert d.shape == (16, 16) and d.data_axes == ("data",)
    assert d.ici_axes == ("data", "model") and d.dci_axes == ()
    m = production_descriptor(True)
    assert m.shape == (2, 16, 16) and m.data_axes == ("pod", "data")
    assert m.dci_axes == ("pod",) and m.data_axis_size == 32
    assert m.abstract().shape["model"] == 16
    h = host_descriptor(4)
    assert h.shape == (4, 1) and h.data_axis_size == 4
    mesh = make_host_mesh()
    assert tuple(mesh.axis_names) == ("data", "model")


def test_plan_cost_comm_term():
    base = score_packing([[8, 8]], 16)
    assert base.comm_bytes == 0
    w = CostWeights(comm_byte=0.5)
    c = score_packing([[8, 8]], 16, weights=w, comm_bytes=1000)
    assert c.comm_bytes == 1000
    assert c.total == pytest.approx(base.total + 500.0)
    # default weight 0.0 charges nothing even when a table is fed
    c0 = score_packing([[8, 8]], 16, comm_bytes=1000)
    assert c0.total == pytest.approx(base.total)
    table = {"collectives": {
        "all-reduce": {"wire_bytes": 10, "wire_bytes_with_loops": 240},
        "all-gather": {"wire_bytes": 7}}}
    assert wire_bytes_per_step(table) == 247


# ---------------------------------------------------------------------------
# End-to-end CLI gates (subprocess: fake devices + fresh jax)
# ---------------------------------------------------------------------------

def _run(args, timeout):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")
    env.pop("XLA_FLAGS", None)       # the tool must set fake devices itself
    return subprocess.run([sys.executable, "-m", *args],
                          capture_output=True, text=True, timeout=timeout,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))), env=env)


def test_shardlint_fast_gate_exits_clean():
    r = _run(["repro.analysis.lint", "--comms", "--fast", "-q"],
             timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr


@pytest.mark.slow
def test_shardlint_full_production_meshes(tmp_path):
    out = tmp_path / "comms.json"
    r = _run(["repro.analysis.lint", "--comms", "--out", str(out)],
             timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    import json
    rep = json.loads(out.read_text())
    for mesh in ("single_pod", "multi_pod"):
        e = rep["meshes"][mesh]
        assert e["session.step"]["per_axis_wire_bytes"].get("data", 0) == 0
        sp = e["seq_parallel"]["boundary_fwd_wire_bytes"]
        assert sp["seq_parallel"] < sp["all_reduce_baseline"]
        assert wire_bytes_per_step(e["engine.packed"]) > 0


@pytest.mark.slow
def test_shardlint_family_sweep_exits_clean():
    r = _run(["repro.analysis.comms_audit", "--sweep"], timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
