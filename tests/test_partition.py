"""Redundancy-Free Tree Partitioning (paper §3.3, App. B): partitioned
loss/grads equal the whole-tree pass; token accounting matches Fig. 5.

MoE note: router load-balance aux is computed per compute-batch (each
partition), like per-microbatch aux under gradient accumulation — it is
excluded from strict equivalence (router_aux_weight=0 here); the CE part
is exact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.configs.base import MoECfg
from repro.core.gateway import partitioned_value_and_grad
from repro.core.packing import pack_trees
from repro.core.partition import (partition_token_counts, partition_tree,
                                  standard_partition_token_counts)
from repro.core.tree import serialize_tree
from repro.data.synthetic import random_tree
from repro.models.model import init_params, loss_and_metrics, needs_chunks, \
    prepare_batch

pytestmark = pytest.mark.slow  # multi-minute partition equivalences


def get_tree(seed=0, lo=60, hi=120):
    for s in range(seed, seed + 300):
        t = random_tree(np.random.default_rng(s), vocab_size=97,
                        max_depth=5, seg_len_range=(3, 9))
        if t.num_leaves() >= 4 and lo <= t.num_unique_tokens() <= hi:
            return t
    raise RuntimeError


def _whole_tree_ref(cfg, params, tree, chunk):
    ser = serialize_tree(tree, chunk_size=chunk)
    S = ((ser.n + 31) // 32) * 32
    b = prepare_batch(cfg, pack_trees([ser], S, chunk_size=chunk))
    l, _ = loss_and_metrics(cfg, params, b)
    g = jax.grad(lambda p: loss_and_metrics(cfg, p, b)[0])(params)
    return float(l), g


FAMILIES = ["dense", "moe", "ssm_rwkv6", "ssm_mamba2", "ssm_gdn", "hybrid"]


@pytest.mark.parametrize("family", FAMILIES)
def test_partitioned_equals_whole_tree(family):
    cfg = tiny_cfg(family)
    if family == "moe":
        cfg = cfg.replace(moe=MoECfg(num_experts=4, top_k=2, d_expert=32,
                                     capacity_factor=8.0,
                                     router_aux_weight=0.0,
                                     router_z_weight=0.0))
    chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    params = init_params(cfg, jax.random.key(0))
    tree = get_tree()
    l_ref, g_ref = _whole_tree_ref(cfg, params, tree, chunk)
    l_p, g_p, info = partitioned_value_and_grad(cfg, params, tree,
                                                capacity=40)
    assert info["num_partitions"] >= 2, "capacity too large to test cuts"
    np.testing.assert_allclose(l_p, l_ref, rtol=2e-5)
    rels = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-9)),
        g_p, g_ref)
    assert max(jax.tree.leaves(rels)) < 1e-4   # paper App. B.8 f32 bound


def test_partitioned_deep_chain_of_cuts():
    """Gateways must chain across ≥3 partition generations."""
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(1))
    tree = get_tree(7, lo=90, hi=160)
    l_ref, g_ref = _whole_tree_ref(cfg, params, tree, None)
    l_p, g_p, info = partitioned_value_and_grad(cfg, params, tree,
                                                capacity=24)
    assert info["num_partitions"] >= 4
    np.testing.assert_allclose(l_p, l_ref, rtol=2e-5)
    rels = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-9)),
        g_p, g_ref)
    assert max(jax.tree.leaves(rels)) < 1e-4


def test_partition_planner_invariants():
    """Every partition ≤ capacity; unique tokens preserved; parents first;
    differentiable boundaries beat ancestor re-inclusion (Fig. 5)."""
    tree = get_tree(3, lo=100, hi=200)
    C = 48
    parts = partition_tree(tree, C)
    counts = partition_token_counts(parts)
    assert all(p.ser.n <= C for p in parts)
    assert counts["unique_tokens"] == tree.num_unique_tokens()
    for p in parts:
        assert p.parent_pid < p.pid   # topological (parents first)
    std = standard_partition_token_counts(tree, C)
    assert std > counts["unique_tokens"]   # boundary recomputation removed
    flat = tree.flat_tokens()
    assert flat >= std                      # and flattening is worst


def test_partition_memory_bound_is_path():
    """#simultaneously-open vjp closures ≤ partition-tree depth — probe via
    the recursion structure: max cuts chain length."""
    tree = get_tree(11, lo=120, hi=250)
    parts = partition_tree(tree, 32)
    depth = {0: 1}
    for p in parts[1:]:
        depth[p.pid] = depth[p.parent_pid] + 1
    # sanity: a path bound exists and is far below #partitions for bushy trees
    assert max(depth.values()) <= len(parts)
