"""Training substrate: optimizer math, schedule, checkpoint round-trip,
and end-to-end loss descent in both tree and baseline modes."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from conftest import tiny_cfg
from repro.data.loader import LoaderConfig, batches
from repro.models.model import init_params
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   global_norm, init_opt_state, lr_at)
from repro.train.train_step import make_train_step


def test_lr_schedule():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          min_lr_ratio=0.1)
    assert float(lr_at(cfg, 0)) < 0.2
    np.testing.assert_allclose(float(lr_at(cfg, 9)), 1.0, rtol=1e-6)
    assert abs(float(lr_at(cfg, 60)) - 0.55) < 0.02   # mid-cosine
    np.testing.assert_allclose(float(lr_at(cfg, 109)), 0.1, atol=2e-3)


def test_adamw_against_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}
    cfg = OptimizerConfig(lr=1e-2, warmup_steps=1, total_steps=100,
                          grad_clip=1e9, weight_decay=0.1)
    st = init_opt_state(p)
    p2, st2, m = adamw_update(cfg, p, g, st)
    lr = float(lr_at(cfg, 0))
    for k, decay in (("w", True), ("b", False)):
        gk = np.asarray(g[k])
        mu = 0.1 * gk
        nu = 0.05 * gk * gk
        mu_hat = mu / (1 - 0.9)
        nu_hat = nu / (1 - 0.95)
        delta = mu_hat / (np.sqrt(nu_hat) + cfg.eps)
        if decay:
            delta = delta + cfg.weight_decay * np.asarray(p[k])
        ref = np.asarray(p[k]) - lr * delta
        np.testing.assert_allclose(np.asarray(p2[k]), ref, rtol=1e-5)
    assert int(st2["step"]) == 1


def test_grad_clipping():
    p = {"w": jnp.ones((2, 2))}
    g = {"w": jnp.full((2, 2), 100.0)}
    cfg = OptimizerConfig(grad_clip=1.0, warmup_steps=1)
    _, _, m = adamw_update(cfg, p, g, init_opt_state(p))
    assert float(m["grad_norm"]) == 200.0
    assert float(global_norm(g)) == 200.0


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    save_checkpoint(str(tmp_path / "ck"), params, opt, meta={"x": 1})
    p2, o2 = load_checkpoint(str(tmp_path / "ck"), params, opt)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o2["step"]) == 0
    assert os.path.exists(tmp_path / "ck" / "manifest.json")


def _run_mode(mode: str, steps: int = 12, repeat_first_batch: bool = False):
    cfg = tiny_cfg("dense")
    lc = LoaderConfig(seq_len=256, batch_rows=2, trees_per_batch=4,
                      mode=mode, kind="random", seed=3,
                      gen_kwargs=dict(seg_len_range=(2, 6), max_depth=3))
    params = init_params(cfg, jax.random.key(0))
    opt_cfg = OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=steps)
    step = make_train_step(cfg, opt_cfg, donate=False)
    opt = init_opt_state(params)
    if repeat_first_batch:
        inputs, _ = next(iter(batches(cfg, lc, 1)))
        stream = (inputs for _ in range(steps))
    else:
        stream = (b for b, _ in batches(cfg, lc, steps))
    losses = []
    for inputs in stream:
        params, opt, m = step(params, opt, inputs)
        losses.append(float(m["token_nll_mean"]))
    return losses


def test_loss_decreases_tree_mode():
    # fresh random trees every step carry no learnable signal beyond token
    # marginals, so descend on a fixed batch — deterministic, not a coin
    # flip on the sampling noise of the first/last batches.
    losses = _run_mode("tree", repeat_first_batch=True)
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_tree_and_baseline_dynamics_match():
    """Paper Fig. 7 bottom: per-step losses coincide between tree and
    baseline training (same data, same seeds)."""
    lt = _run_mode("tree", steps=6)
    lb = _run_mode("baseline", steps=6)
    np.testing.assert_allclose(lt, lb, rtol=2e-4)
