import numpy as np

from repro.configs.base import (AttnCfg, EncDecCfg, HybridCfg, ModelConfig,
                                MoECfg, SSMCfg)
from repro.data.synthetic import random_tree


def branching_tree(seed: int = 0, min_leaves: int = 3, vocab: int = 89):
    """A random tree guaranteed to branch (otherwise equivalence is trivial)."""
    for s in range(seed, seed + 200):
        t = random_tree(np.random.default_rng(s), vocab_size=vocab)
        if t.num_leaves() >= min_leaves and t.num_unique_tokens() <= 120:
            return t
    raise RuntimeError("no branching tree found")


TINY = dict(n_layers=2, d_model=32, d_ff=64, vocab_size=89,
            dtype="float32", vocab_pad_multiple=8)


def tiny_cfg(family: str, **kw) -> ModelConfig:
    base = dict(TINY)
    if family == "dense":
        base["attn"] = AttnCfg(n_heads=4, n_kv_heads=2, head_dim=8,
                               qk_norm=True, qkv_bias=True)
    elif family == "moe":
        base["attn"] = AttnCfg(n_heads=4, n_kv_heads=2, head_dim=8)
        base["moe"] = MoECfg(num_experts=4, top_k=2, d_expert=32,
                             num_shared_experts=1, capacity_factor=4.0,
                             first_dense_layers=1)
    elif family == "ssm_rwkv6":
        family = "ssm"
        base["ssm"] = SSMCfg(kind="rwkv6", head_dim=8, expand=1, chunk_size=8)
    elif family == "ssm_mamba2":
        family = "ssm"
        base["ssm"] = SSMCfg(kind="mamba2", d_state=8, head_dim=8, expand=2,
                             chunk_size=8)
    elif family == "ssm_gdn":
        family = "ssm"
        base["ssm"] = SSMCfg(kind="gdn", head_dim=8, expand=1, chunk_size=8)
    elif family == "hybrid":
        base["n_layers"] = 4
        base["attn"] = AttnCfg(n_heads=4, n_kv_heads=4, head_dim=8)
        base["ssm"] = SSMCfg(kind="mamba2", d_state=8, head_dim=8,
                             chunk_size=8)
        base["hybrid"] = HybridCfg(attn_every=2)
    elif family == "audio":
        base["attn"] = AttnCfg(n_heads=4, n_kv_heads=4, head_dim=8)
        base["encdec"] = EncDecCfg(enc_layers=2, dec_layers=2, src_len=8)
        base["frontend"] = "audio"
        base["frontend_len"] = 8
    elif family == "vlm":
        base["attn"] = AttnCfg(n_heads=4, n_kv_heads=4, head_dim=8)
        base["frontend"] = "vision"
        base["frontend_len"] = 6
    base.update(kw)
    return ModelConfig(name=f"tiny-{family}", family=family, **base)
