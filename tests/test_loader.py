"""Loader / packing data-fidelity: oversized trees are routed, not
dropped; packing errors are typed; row slicing keeps the loss normalizer;
partition token accounting respects chunked configs."""
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core.packing import (DoesNotFitError, pack_partition_waves,
                                pack_trees)
from repro.core.partition import (partition_tree,
                                  standard_partition_token_counts)
from repro.core.tree import TrajectoryTree, TreeNode, serialize_tree
from repro.data.loader import LoaderConfig
from repro.data.synthetic import random_tree, trees_for_batch
from repro.train.planner import plans


def _step_batches(cfg, lc, steps):
    """The planner's stream, viewed as raw per-step data."""
    return (ps.step_batch() for ps in plans(cfg, lc, steps))


def _chain_tree(seg_lens, vocab=50):
    """Deterministic tree: a root with one child per entry after the
    first, each child a leaf (bushy, easily oversized)."""
    rng = np.random.default_rng(0)
    root = TreeNode(tokens=rng.integers(0, vocab, seg_lens[0]))
    for n in seg_lens[1:]:
        root.children.append(TreeNode(tokens=rng.integers(0, vocab, n)))
    return TrajectoryTree(root=root)


# ---------------------------------------------------------------------------
# DoesNotFitError typing
# ---------------------------------------------------------------------------

def test_pack_trees_raises_typed_overflow():
    t = _chain_tree([40, 20, 20])
    ser = serialize_tree(t)
    with pytest.raises(DoesNotFitError):
        pack_trees([ser], seq_len=32)
    with pytest.raises(DoesNotFitError):
        pack_trees([ser, ser], seq_len=96, batch_size=1)


# ---------------------------------------------------------------------------
# row_slice loss normalizer (was hardcoded to num_trees=1)
# ---------------------------------------------------------------------------

def test_row_slice_tracks_trees_per_row():
    trees = [random_tree(np.random.default_rng(s), vocab_size=50,
                         max_depth=3, seg_len_range=(2, 5))
             for s in range(5)]
    sers = [serialize_tree(t) for t in trees]
    S = max(s.n for s in sers) + sum(sorted(s.n for s in sers)[:2])
    tb = pack_trees(sers, S)
    assert tb.row_trees is not None
    assert int(tb.row_trees.sum()) == len(trees)
    for b in range(tb.shape[0]):
        row = tb.row_slice(b)
        # derived count must agree with the stored per-row count
        roots = int(((tb.prev_idx[b] == -1) & tb.valid[b]).sum())
        assert row.num_trees == int(tb.row_trees[b]) == roots


# ---------------------------------------------------------------------------
# standard partitioning accounting under chunked/SSM configs
# ---------------------------------------------------------------------------

def test_standard_partition_counts_respect_chunking():
    tree = _chain_tree([13, 11, 9, 7, 10, 12])
    C, chunk = 48, 8
    plain = standard_partition_token_counts(tree, C)
    chunked = standard_partition_token_counts(tree, C, chunk_size=chunk)
    # chunk alignment pads every node segment AND the re-included
    # ancestor prefix — the chunked bar must count that padding
    assert chunked > plain
    parts = partition_tree(tree, C, chunk_size=chunk)
    expect = sum(p.ser.n + (-p.anc_len) % chunk + p.anc_len for p in parts)
    assert chunked == expect
    # loss_mode threads through without changing the count
    assert standard_partition_token_counts(
        tree, C, chunk_size=chunk, loss_mode="uniform") == chunked


# ---------------------------------------------------------------------------
# wave packing geometry
# ---------------------------------------------------------------------------

def test_pack_partition_waves_topology():
    trees = []
    s = 0
    while len(trees) < 3:
        t = random_tree(np.random.default_rng(s), vocab_size=97,
                        max_depth=5, seg_len_range=(3, 9))
        s += 1
        if t.num_leaves() >= 3 and t.num_unique_tokens() >= 60:
            trees.append(t)
    forest = [partition_tree(t, 40) for t in trees]
    waves = pack_partition_waves(forest, seq_len=48)
    placed = set()
    loc = {}
    for w, wv in enumerate(waves):
        assert wv.arrays["tokens"].shape[1] == 48
        for sl in wv.slots:
            assert (sl.tree, sl.pid) not in placed
            placed.add((sl.tree, sl.pid))
            loc[(sl.tree, sl.pid)] = w
            part = forest[sl.tree][sl.pid]
            # every partition's parent sits in the previous wave
            if part.parent_pid >= 0:
                assert loc[(sl.tree, part.parent_pid)] == w - 1
            # tokens land where the slot says
            ser = part.ser
            got = wv.arrays["tokens"][sl.row,
                                      sl.offset:sl.offset + ser.n]
            np.testing.assert_array_equal(got, ser.tokens)
        for c in wv.cuts:
            assert 0 <= c.row < wv.num_rows
            assert (c.path_idx >= 0).all()
    assert placed == {(t, p.pid) for t, ps in enumerate(forest)
                      for p in ps}


# ---------------------------------------------------------------------------
# auto-partition loader: zero drops, token conservation
# ---------------------------------------------------------------------------

def test_auto_partition_drops_nothing():
    cfg = tiny_cfg("dense")
    lc = LoaderConfig(seq_len=96, batch_rows=2, trees_per_batch=4,
                      mode="tree", kind="agentic", seed=5,
                      auto_partition=True,
                      gen_kwargs=dict(turn_len_range=(4, 12), num_turns=2))
    steps = 6
    gen_tokens = kept_tokens = 0
    n_oversized = n_packed = 0
    for sb in _step_batches(cfg, lc, steps):
        assert sb.dropped == 0
        n_oversized += len(sb.oversized)
        if sb.tb is not None:
            kept_tokens += int(sb.tb.valid.sum())
            n_packed += sb.tb.num_trees
        kept_tokens += sum(t.num_unique_tokens() for t in sb.oversized)
    for b in range(steps):
        ts = trees_for_batch(lc.seed * 100_003 + b, n_trees=4,
                             kind="agentic", vocab_size=cfg.vocab_size,
                             turn_len_range=(4, 12), num_turns=2)
        gen_tokens += sum(t.num_unique_tokens() for t in ts)
    assert n_oversized > 0, "config produced no oversized trees"
    assert n_packed > 0, "config produced no packable trees"
    assert kept_tokens == gen_tokens   # nothing silently lost


def test_default_mode_counts_drops():
    cfg = tiny_cfg("dense")
    lc = LoaderConfig(seq_len=96, batch_rows=2, trees_per_batch=4,
                      mode="tree", kind="agentic", seed=5,
                      gen_kwargs=dict(turn_len_range=(8, 40), num_turns=4))
    dropped = sum(sb.dropped for sb in _step_batches(cfg, lc, 6))
    assert dropped > 0    # same stream as above: drops are now *visible*


# ---------------------------------------------------------------------------
# single serialization per tree (the size filter used to serialize once to
# size-check and the packer re-serialized — plus once per retry round)
# ---------------------------------------------------------------------------

def test_loader_serializes_each_tree_exactly_once(monkeypatch):
    import repro.train.planner as planner_mod
    from repro.core.tree import serialize_tree as real_ser

    calls = {"ser": 0}

    def counting_ser(*a, **kw):
        calls["ser"] += 1
        return real_ser(*a, **kw)

    monkeypatch.setattr(planner_mod, "serialize_tree", counting_ser)
    cfg = tiny_cfg("dense")
    steps, per_batch = 4, 5
    # tight rows so the planner's eviction loop actually fires
    lc = LoaderConfig(seq_len=96, batch_rows=1, trees_per_batch=per_batch,
                      mode="tree", kind="agentic", seed=5,
                      auto_partition=True,
                      gen_kwargs=dict(turn_len_range=(4, 12), num_turns=2))
    evicted = 0
    for sb in _step_batches(cfg, lc, steps):
        # an oversized tree that individually fits one row can only be
        # there because the planner evicted it to make the step fit
        evicted += sum(serialize_tree(t).n <= lc.seq_len
                       for t in sb.oversized)
    assert evicted > 0, \
        "config never exercised the planner's eviction loop"
    # one serialize_tree call per generated tree, no matter how many
    # candidate packings or eviction retries the planner tried
    # (partitioning oversized trees serializes inside core/partition,
    # not through the scheduler)
    assert calls["ser"] == steps * per_batch
