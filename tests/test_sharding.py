"""Sharding rules: per-tensor PartitionSpecs, divisibility fallbacks,
FSDP second axis, batch specs.  Pure spec logic — no devices needed."""
from jax.sharding import PartitionSpec as P

from repro.sharding import param_spec


def test_attention_rules():
    assert param_spec("layer_stacks/0/attn/wq", (36, 4096, 4096), 16,
                      "model", 1) == P(None, None, "model")
    # qwen2-1.5b: 12 heads don't divide 16, but the q FEATURE dim (1536)
    # does — we shard features (heads split across devices; GSPMD inserts
    # the head-halo collectives; the dry-run proves it lowers)
    assert param_spec("layer_stacks/0/attn/wq", (28, 1536, 1536), 16,
                      "model", 1) == P(None, None, "model")
    # a truly non-divisible feature dim replicates
    assert param_spec("layer_stacks/0/attn/wq", (2, 100, 100), 16,
                      "model", 1) == P(None, None, None)
    assert param_spec("layer_stacks/0/attn/wo", (36, 4096, 4096), 16,
                      "model", 1) == P(None, "model", None)


def test_moe_expert_parallel():
    assert param_spec("layer_stacks/1/moe/wi_up", (60, 384, 7168, 2048),
                      16, "model", 1) == P(None, "model", None, None)
    assert param_spec("layer_stacks/1/moe/router", (60, 7168, 384), 16,
                      "model", 1) == P(None, None, None)


def test_vocab_sharding_and_padding():
    assert param_spec("embed/table", (151936, 4096), 16, "model") == \
        P("model", None)
    # unpadded seamless vocab would not divide — configs pad to 256
    assert 256256 % 16 == 0
    assert param_spec("embed/table", (256206, 1024), 16, "model") == \
        P(None, None)


def test_fsdp_second_axis():
    spec = param_spec("layer_stacks/0/mlp/wi_up", (36, 4096, 12288), 16,
                      "model", 1, fsdp_axis="data", fsdp_size=16)
    assert spec == P(None, "data", "model")
    # fsdp skips non-divisible dims
    spec = param_spec("layer_stacks/0/mlp/wi_up", (24, 1023, 2816), 16,
                      "model", 1, fsdp_axis="data", fsdp_size=16)
    assert spec == P(None, None, "model")


def test_ssm_rules_unfused():
    assert param_spec("layer_stacks/0/ssm/in_x", (38, 2048, 4096), 16,
                      "model", 1) == P(None, None, "model")
    # B/C/dt stay replicated by design (mamba2 split-collective fix)
    assert param_spec("layer_stacks/0/ssm/in_B", (38, 2048, 64), 16,
                      "model", 1) == P(None, None, None)
    assert param_spec("layer_stacks/0/ssm/in_dt", (38, 2048, 64), 16,
                      "model", 1) == P(None, None, None)


def test_norm_scales_replicated():
    assert param_spec("layer_stacks/0/ln1/scale", (36, 4096), 16,
                      "model", 1) == P()
