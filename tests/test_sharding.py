"""Sharding rules: per-tensor PartitionSpecs, divisibility fallbacks,
FSDP second axis, batch specs, and rule coverage over every param family
(so a rule-regex typo fails CI instead of silently replicating a tensor).
Pure spec logic — no devices needed."""
import re

import jax
import pytest
from jax.sharding import PartitionSpec as P

from conftest import tiny_cfg
from repro.models.model import init_params
from repro.sharding import _RULES, _path_str, param_spec


def test_attention_rules():
    assert param_spec("layer_stacks/0/attn/wq", (36, 4096, 4096), 16,
                      "model", 1) == P(None, None, "model")
    # qwen2-1.5b: 12 heads don't divide 16, but the q FEATURE dim (1536)
    # does — we shard features (heads split across devices; GSPMD inserts
    # the head-halo collectives; the dry-run proves it lowers)
    assert param_spec("layer_stacks/0/attn/wq", (28, 1536, 1536), 16,
                      "model", 1) == P(None, None, "model")
    # a truly non-divisible feature dim replicates
    assert param_spec("layer_stacks/0/attn/wq", (2, 100, 100), 16,
                      "model", 1) == P(None, None, None)
    assert param_spec("layer_stacks/0/attn/wo", (36, 4096, 4096), 16,
                      "model", 1) == P(None, "model", None)


def test_moe_expert_parallel():
    assert param_spec("layer_stacks/1/moe/wi_up", (60, 384, 7168, 2048),
                      16, "model", 1) == P(None, "model", None, None)
    assert param_spec("layer_stacks/1/moe/router", (60, 7168, 384), 16,
                      "model", 1) == P(None, None, None)


def test_vocab_sharding_and_padding():
    assert param_spec("embed/table", (151936, 4096), 16, "model") == \
        P("model", None)
    # unpadded seamless vocab would not divide — configs pad to 256
    assert 256256 % 16 == 0
    assert param_spec("embed/table", (256206, 1024), 16, "model") == \
        P(None, None)


def test_fsdp_second_axis():
    spec = param_spec("layer_stacks/0/mlp/wi_up", (36, 4096, 12288), 16,
                      "model", 1, fsdp_axis="data", fsdp_size=16)
    assert spec == P(None, "data", "model")
    # fsdp skips non-divisible dims
    spec = param_spec("layer_stacks/0/mlp/wi_up", (24, 1023, 2816), 16,
                      "model", 1, fsdp_axis="data", fsdp_size=16)
    assert spec == P(None, None, "model")


def test_ssm_rules_unfused():
    assert param_spec("layer_stacks/0/ssm/in_x", (38, 2048, 4096), 16,
                      "model", 1) == P(None, None, "model")
    # B/C/dt stay replicated by design (mamba2 split-collective fix)
    assert param_spec("layer_stacks/0/ssm/in_B", (38, 2048, 64), 16,
                      "model", 1) == P(None, None, None)
    assert param_spec("layer_stacks/0/ssm/in_dt", (38, 2048, 64), 16,
                      "model", 1) == P(None, None, None)


def test_mlp_bias_rule_matches():
    # regression: the rule used to read r"mlp/b i$" (stray space) — the
    # d_ff bias silently fell through to replication
    assert param_spec("layer_stacks/0/mlp/bi", (24, 2816), 16,
                      "model", 1) == P(None, "model")
    assert param_spec("layer_stacks/0/mlp/bi", (24, 100), 16,
                      "model", 1) == P(None, None)


# Params that are *intentionally* replicated: norm scales, d_model-sized
# biases, tiny per-head scalars, conv taps, rwkv6 mix/decay/lora tensors
# (see the per-module init docstrings).  Anything matching neither a
# _RULES entry nor this list is an unreviewed fall-through → test fails.
_REPLICATE_ALLOWLIST = [
    r"(^|/)(ln1|ln2|ln_x|final_norm|enc_norm|norm|ln_out|q_norm|k_norm)"
    r"/scale$",
    r"mlp/bo$",                                    # d_model bias
    r"ssm/(conv_w|conv_b|A_log|D|dt_bias|a_bias)$",
    r"tm/(mix|w0|w_lora_a|w_lora_b|u)$",           # rwkv6 timemix extras
    r"cm/(mix|wr)$",                               # rwkv6 channelmix gate
]

_FAMILIES = ["dense", "moe", "ssm_rwkv6", "ssm_mamba2", "ssm_gdn",
             "hybrid", "vlm", "audio"]


@pytest.mark.parametrize("family", _FAMILIES)
def test_every_param_matches_a_rule_or_allowlist(family):
    """One config per family: every param path either hits a _RULES entry
    or sits on the explicit replicate-allowlist — future rule typos (like
    the mlp/bi one) fail here instead of silently replicating."""
    kw = {"mlp_bias": True} if family == "dense" else {}
    cfg = tiny_cfg(family, **kw)
    params = init_params(cfg, jax.random.key(0))
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    orphans = set()
    for path, _leaf in flat:
        ps = _path_str(path)
        ruled = any(re.search(pat, ps) for pat, _ in _RULES)
        allowed = any(re.search(pat, ps) for pat in _REPLICATE_ALLOWLIST)
        if not ruled and not allowed:
            orphans.add(ps)
    orphans = sorted(orphans)
    assert not orphans, (
        f"{family}: params match no sharding rule and are not on the "
        f"replicate-allowlist: {orphans}")


def test_norm_scales_replicated():
    assert param_spec("layer_stacks/0/ln1/scale", (36, 4096), 16,
                      "model", 1) == P()
