"""Unit tests for the gateway plumbing: the _concat_tail/_route_tail
adjoint pair, and gather_prev's gateway-context slots."""
import jax.numpy as jnp
import numpy as np

from repro.core.gateway import _concat_tail, _route_tail
from repro.models.layers import gather_prev, prev_powers, tree_causal_conv


def test_concat_tail_route_tail_are_adjoint():
    """⟨concat_tail(a,b), c⟩ == ⟨(a,b), route_tail(c)⟩ for random tensors —
    the defining property of a correct transpose."""
    rng = np.random.default_rng(0)
    for T_in, T_c, keep in [(0, 5, 3), (2, 5, 3), (4, 1, 3), (3, 3, 10)]:
        shape = lambda t: (2, 1, t, 4)
        a = None if T_in == 0 else jnp.asarray(
            rng.normal(size=shape(T_in)), jnp.float32)
        b = jnp.asarray(rng.normal(size=shape(T_c)), jnp.float32)
        out = _concat_tail(a, b, keep)
        c = jnp.asarray(rng.normal(size=out.shape), jnp.float32)
        lhs = float(jnp.vdot(out, c))
        ca, cb = _route_tail(None if a is None else a.shape, b.shape, keep,
                             c)
        rhs = float(jnp.vdot(b, cb))
        if a is not None:
            rhs += float(jnp.vdot(a, ca))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-6)


def test_gather_prev_gateway_slots():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 4, 3)), jnp.float32)
    ctx = jnp.asarray(rng.normal(size=(1, 2, 3)), jnp.float32)
    # prev: token0 → gateway slot −2 (= ctx[:, -1]); token1 → 0; token2 →
    # slot −3 (= ctx[:, -2]); token3 → −1 (none)
    prev = jnp.asarray([[-2, 0, -3, -1]], jnp.int32)
    g = gather_prev(x, prev, ctx)
    np.testing.assert_allclose(np.asarray(g[0, 0]), np.asarray(ctx[0, 1]))
    np.testing.assert_allclose(np.asarray(g[0, 1]), np.asarray(x[0, 0]))
    np.testing.assert_allclose(np.asarray(g[0, 2]), np.asarray(ctx[0, 0]))
    np.testing.assert_allclose(np.asarray(g[0, 3]), 0.0)
    # slot beyond ctx → zeros
    prev2 = jnp.asarray([[-5, -1, -1, -1]], jnp.int32)
    g2 = gather_prev(x, prev2, ctx)
    np.testing.assert_allclose(np.asarray(g2[0, 0]), 0.0)


def test_prev_powers_chains_gateway_slots():
    prev = np.asarray([[-2, 0, 1, 2]], np.int32)
    pp = prev_powers(prev, 3)
    # token0: prev=−2, prev²=−3, prev³=−4
    np.testing.assert_array_equal(pp[0, 0], [-2, -3, -4])
    # token3: 2, 1, 0
    np.testing.assert_array_equal(pp[0, 3], [2, 1, 0])
    # token1: 0, then −2 (through token0's gateway), then −3
    np.testing.assert_array_equal(pp[0, 1], [0, -2, -3])


def test_tree_conv_with_ctx_matches_manual():
    """Causal conv across a partition boundary == conv on the glued
    sequence."""
    rng = np.random.default_rng(2)
    K, D = 3, 4
    full = jnp.asarray(rng.normal(size=(1, 6, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, D)), jnp.float32)
    prev_full = np.asarray([[-1, 0, 1, 2, 3, 4]], np.int32)
    ref = tree_causal_conv(full, w, None, jnp.asarray(
        prev_powers(prev_full, K - 1)))
    # split at 4: child sees tokens 4..5 with ctx = tokens 2..3
    child = full[:, 4:]
    ctx = full[:, 2:4]
    prev_child = np.asarray([[-2, 0]], np.int32)
    got = tree_causal_conv(child, w, None, jnp.asarray(
        prev_powers(prev_child, K - 1)), ctx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref[:, 4:]),
                               rtol=1e-6)
