"""treelint self-tests: every pass must catch its seeded violation and
stay silent on the real, proven-clean code paths."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from conftest import tiny_cfg

from repro.analysis.jaxpr_audit import audit_all, audit_target
from repro.analysis.registry import (AuditTarget, Contract,
                                     audit_loader_config, build_targets,
                                     coverage_findings,
                                     host_transfer_sites, repro_src_root)

jax.config.update("jax_platforms", "cpu")


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _target(fn, args, contract, name="seeded"):
    return AuditTarget(name=name, fn=fn, args=args, contract=contract)


# ---------------------------------------------------------------------------
# jaxpr audit: seeded violations
# ---------------------------------------------------------------------------

def test_seeded_callback_flagged():
    def noisy(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2

    fn = jax.jit(noisy)
    args = (_sds((4,)),)
    found = audit_target(_target(fn, args, Contract()))
    assert [f.check for f in found] == ["callback"]
    # ...and an explicit allowance silences it
    assert audit_target(_target(fn, args, Contract(max_callbacks=1))) == []


def test_seeded_missing_donation_flagged():
    def f(acc, g):
        return acc + g, g * 2

    args = (_sds((8,)), _sds((8,)))
    undonated = jax.jit(f)
    found = audit_target(_target(undonated, args, Contract(donate=(0,))))
    assert [f.check for f in found] == ["donation"]
    assert "must be donated" in found[0].message

    donated = jax.jit(f, donate_argnums=(0,))
    assert audit_target(_target(donated, args,
                                Contract(donate=(0,), keep=(1,)))) == []
    # donating a buffer the contract says must stay live is also flagged
    wrong = jax.jit(f, donate_argnums=(1,))
    found = audit_target(_target(wrong, args, Contract(keep=(1,))))
    assert [f.check for f in found] == ["donation"]
    assert "must NOT be donated" in found[0].message


def test_seeded_bf16_accumulator_arg_flagged():
    fn = jax.jit(lambda a: a * 2)
    found = audit_target(_target(fn, (_sds((8,), jnp.bfloat16),),
                                 Contract(fp32_args=(0,))))
    assert [f.check for f in found] == ["dtype"]
    assert "bfloat16" in found[0].message


def test_seeded_low_precision_sum_upcast_flagged():
    # the violation: reduce in bf16, convert the SUM to fp32 at the output
    bad = jax.jit(lambda g: g.sum().astype(jnp.float32))
    found = audit_target(_target(bad, (_sds((64,), jnp.bfloat16),),
                                 Contract(fp32_outs=(0,))))
    assert found and all(f.check == "dtype" for f in found)
    assert any("upcasting" in f.message for f in found)

    # the sanctioned dtype policy: upcast each ADDEND, accumulate in fp32
    good = jax.jit(lambda acc, g: acc + g.astype(jnp.float32))
    assert audit_target(_target(
        good, (_sds((8,)), _sds((8,), jnp.bfloat16)),
        Contract(fp32_outs=(0,)))) == []


def test_seeded_bf16_output_flagged():
    bad = jax.jit(lambda a, b: a + b)
    args = (_sds((4,), jnp.bfloat16), _sds((4,), jnp.bfloat16))
    found = audit_target(_target(bad, args, Contract(fp32_outs=(0,))))
    assert [f.check for f in found] == ["dtype"]
    assert "must be fp32" in found[0].message


# ---------------------------------------------------------------------------
# mask soundness: a broken predicate is caught, the real one is clean
# ---------------------------------------------------------------------------

def test_mask_check_catches_unsound_predicate():
    from repro.analysis.mask_check import check_predicate

    def strict_live(q_start, q_end, kv_start, block_max,
                    qp_min=None, kp_max=None, window=None):
        # seeded bug: strict > wrongly skips blocks with block_max ==
        # q_start, which still hold the visible pair (i=q_start, j≤i)
        live = (kv_start <= q_end) & (block_max > q_start)
        if window is not None:
            live = live & ((qp_min - kp_max) < window)
        return live

    buckets = [(32, 32, 0, None), (32, 32, 8, 63)]
    found, _ = check_predicate(strict_live, buckets=buckets)
    assert found and all(f.check == "mask" for f in found)
    assert "UNSOUND" in found[0].message


def test_mask_check_real_predicate_clean():
    from repro.analysis.mask_check import (check_bwd_shares_predicate,
                                           check_predicate,
                                           empirical_mask_check)
    found, rep = check_predicate(fast=True)
    assert found == []
    assert rep["unsound_skips"] == 0
    assert 0.0 < rep["proven_skip_rate"] < 1.0
    assert check_bwd_shares_predicate() == []
    emp_f, emp_rep = empirical_mask_check(seeds=range(2))
    assert emp_f == []
    assert emp_rep["proven_skip_rate"] > 0.0


# ---------------------------------------------------------------------------
# signature lint: out-of-universe shapes rejected, a real run is clean
# ---------------------------------------------------------------------------

def test_signature_universe_rejects_unbucketed_shapes():
    from repro.analysis.signatures import SignatureUniverse
    from repro.core.plan_cost import packed_signature, wave_signature

    u = SignatureUniverse(seq_len=64, batch_rows=3, num_replicas=2,
                          max_rows=3, capacity=48)
    ok, _ = u.contains(packed_signature(u.packed_rows, 64))
    assert ok
    ok, why = u.contains(packed_signature(5, 64))
    assert not ok and "replica-rounded" in why
    ok, _ = u.contains(wave_signature(2, 64, 8, 2, 16, 0))
    assert ok
    ok, why = u.contains(wave_signature(6, 64, 8, 2, 16, 0))
    assert not ok and "pow2 multiple" in why        # 6 = 2 replicas × 3
    ok, why = u.contains(wave_signature(2, 64, 12, 2, 16, 0))
    assert not ok and "ancestor pad" in why
    ok, why = u.contains(wave_signature(2, 64, 8, 3, 16, 0))
    assert not ok and "cut count" in why
    assert u.count(8, 2, 16, 0) >= 4


def test_signature_lint_real_planner_run_clean():
    from repro.analysis.signatures import lint_signatures, synthetic_source
    from repro.train.planner import PlannerConfig

    cfg = tiny_cfg("dense")
    lc = audit_loader_config(cfg)
    pc = PlannerConfig(lookahead=2, num_replicas=2)
    src = synthetic_source(cfg, n_batches=4, trees_per=lc.trees_per_batch)
    found, rep = lint_signatures(cfg, lc, pc, src)
    assert found == []
    assert rep["out_of_universe"] == 0
    assert rep["steps"] > 0 and rep["signatures_emitted"] > 0
    assert rep["aot_universe_size"] >= rep["signatures_distinct"]


# ---------------------------------------------------------------------------
# AST passes: host-sync funnel + closed jit-site coverage
# ---------------------------------------------------------------------------

def test_engine_host_transfer_funnel():
    path = os.path.join(repro_src_root(), "train", "engine.py")
    assert [q for q, _ in host_transfer_sites(path)] == \
        ["TreeTrainEngine._sync"]
    from repro.analysis.lint import _engine_host_transfer_findings
    assert _engine_host_transfer_findings() == []


def test_host_transfer_ast_detects_sites(tmp_path):
    src = tmp_path / "m.py"
    src.write_text(
        "import numpy as np\nimport jax\n"
        "def pull(x):\n    return np.asarray(x)\n"
        "class C:\n    def get(self, x):\n"
        "        return jax.device_get(x)\n")
    quals = [q for q, _ in host_transfer_sites(str(src))]
    assert quals == ["pull", "C.get"]


def test_coverage_is_closed(tmp_path):
    pkg = tmp_path / "pkg"
    pkg.mkdir()
    (pkg / "mod.py").write_text(
        "import jax\nstep = jax.jit(lambda x: x + 1)\n")
    missing = coverage_findings([], src_root=str(pkg))
    assert len(missing) == 1 and "neither audited" in missing[0]
    claimed = AuditTarget(name="t", fn=None, args=(), contract=Contract(),
                          covers=("pkg/mod.py::<module>",))
    assert coverage_findings([claimed], src_root=str(pkg)) == []


# ---------------------------------------------------------------------------
# registry smoke: the real dense entrypoints audit clean (no false
# positives from the ref-impl oracle) and close the coverage set
# ---------------------------------------------------------------------------

def test_registry_dense_targets_audit_clean():
    cfg = tiny_cfg("dense")
    targets = build_targets(cfg, impl="ref")
    assert len(targets) >= 8
    assert audit_all(targets) == []
    assert coverage_findings(targets) == []
