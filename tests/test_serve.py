"""Serving path: decode-with-cache equals the training forward, for every
family; sliding-window cache; audio enc-dec decode with cross-attention.
All through the DecodeSession API (prefill / fork / step)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core.packing import pack_trees
from repro.core.tree import TrajectoryTree, TreeNode, serialize_tree
from repro.models.attention import project_cross_kv
from repro.models.layers import logits_from_hidden
from repro.models.model import (init_params, needs_chunks, prepare_batch)
from repro.models.transformer import forward
from repro.serve.session import DecodeSession

pytestmark = pytest.mark.slow  # per-family decode loops, ~2 min

FAMILIES = ["dense", "moe", "ssm_rwkv6", "ssm_mamba2", "ssm_gdn", "hybrid"]


def _chain_batch(cfg, toks, chunk):
    tree = TrajectoryTree(TreeNode(tokens=toks))
    ser = serialize_tree(tree, chunk_size=chunk)
    return prepare_batch(cfg, pack_trees([ser], ser.n, chunk_size=chunk))


@pytest.mark.parametrize("family", FAMILIES)
def test_decode_matches_forward(family):
    cfg = tiny_cfg(family)
    chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    S = 16
    toks = rng.integers(0, 89, S).astype(np.int32)
    b = _chain_batch(cfg, toks, chunk)
    h, _ = forward(cfg, params, b)
    ref = logits_from_hidden(params["embed"], params.get("lm_head"), h)[0]
    sess = DecodeSession.create(cfg, params, buf_len=S)
    outs = []
    for t in range(S):
        lg = sess.step(toks[t:t + 1])
        outs.append(lg[0])
    assert sess.t == S and sess.stats.decode_tokens == S
    dec = jnp.stack(outs)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               atol=5e-4, rtol=5e-4)


def test_sliding_window_decode_masks_old_tokens():
    import dataclasses
    cfg = tiny_cfg("dense")
    cfg = cfg.replace(attn=dataclasses.replace(cfg.attn, window=4))
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    S = 12
    toks = rng.integers(0, 89, S).astype(np.int32)
    # full cache vs ring cache of window size must agree (window masking);
    # the session owns the ring-slot arithmetic (t % buf_len)
    sessions = [DecodeSession.create(cfg, params, buf_len=S),
                DecodeSession.create(cfg, params, buf_len=4)]
    outs = [[], []]
    for t in range(S):
        for ci, sess in enumerate(sessions):
            outs[ci].append(sess.step(toks[t:t + 1])[0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs[0])),
                               np.asarray(jnp.stack(outs[1])),
                               atol=1e-5, rtol=1e-5)


def test_audio_encdec_decode():
    cfg = tiny_cfg("audio")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(2)
    B, S, F = 1, 10, cfg.frontend_len
    toks = rng.integers(0, 89, S).astype(np.int32)
    frames = rng.normal(size=(B, F, cfg.d_model)).astype(np.float32)

    # reference: full forward
    tree = TrajectoryTree(TreeNode(tokens=toks))
    ser = serialize_tree(tree)
    b = prepare_batch(cfg, pack_trees([ser], ser.n), frames)
    h, _ = forward(cfg, params, b)
    ref = logits_from_hidden(params["embed"], params.get("lm_head"), h)[0]

    # decode: encoder out → cross cache via load_cross, then token-by-token
    from repro.models.transformer import _scan_group
    from repro.models.layers import rmsnorm
    enc_meta = dict(
        pos_ids=jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F)),
        kv_last=jnp.full((B, F), F - 1, jnp.int32),
        prev_idx=jnp.full((B, F), -1, jnp.int32),
        valid=jnp.ones((B, F), bool))
    enc_x, _ = _scan_group(cfg, params["encoder"], "encoder",
                           jnp.asarray(frames), enc_meta, "ref")
    enc_out = rmsnorm(params["enc_norm"], enc_x, cfg.norm_eps)

    sess = DecodeSession.create(cfg, params, buf_len=S, enc_len=F)
    # per-decoder-layer cross K/V
    dec_stack = params["layer_stacks"][0]
    n_dec = cfg.encdec.dec_layers
    ks, vs = [], []
    for l in range(n_dec):
        lp = jax.tree.map(lambda a, l=l: a[l], dec_stack)
        k, v = project_cross_kv(lp["xattn"], cfg.attn, enc_out)
        ks.append(k)
        vs.append(v)
    sess.load_cross(jnp.stack(ks), jnp.stack(vs))

    outs = []
    for t in range(S):
        outs.append(sess.step(toks[t:t + 1])[0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs)),
                               np.asarray(ref), atol=5e-4, rtol=5e-4)
