"""Fused Pallas backward (tree_attention_bwd) vs dense-oracle gradients.

The custom_vjp in kernels/ops.py must reproduce jax.vjp through the
materialized-mask reference for every tree topology the packer can emit:
branching, row padding, multiple packed trees per row, GQA/MQA head
groups, rectangular blocks.  Also checks the saved-residual plumbing
(no O(S²) tensor in the residuals) and NaN-safety on fully-padded rows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from test_kernels import _gateway_meta, _tree_meta

from repro.core.packing import pack_trees
from repro.core.tree import serialize_tree
from repro.data.synthetic import trees_for_batch
from repro.kernels.ops import tree_attention
from repro.kernels.ref import tree_attention_ref, tree_attention_ref_ext
from repro.kernels.tree_attention import tree_attention as raw_fwd
from repro.kernels.tree_attention_bwd import tree_attention_bwd


def _tree_kv_last(seed: int, B: int, S: int, fill=0.75) -> jnp.ndarray:
    trees = trees_for_batch(seed, n_trees=6 * B, kind="random",
                            seg_len_range=(1, 4), max_depth=3)
    sers, used = [], 0
    for t in trees:
        s = serialize_tree(t)
        if used + s.n <= int(B * S * fill):
            sers.append(s)
            used += s.n
    tb = pack_trees(sers, S, batch_size=B)
    return jnp.asarray(tb.kv_last)


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32), dtype)


def _grads(fn, q, k, v, do):
    _, vjp = jax.vjp(fn, q, k, v)
    return vjp(do)


@pytest.mark.parametrize("B,S,H,Kh,hd,bq,bk", [
    (1, 64, 4, 4, 16, 16, 16),     # MHA
    (2, 128, 4, 2, 16, 32, 32),    # GQA 2:1, multi-row packing
    (1, 128, 8, 1, 32, 32, 64),    # MQA, rectangular blocks
    (2, 128, 4, 2, 64, 64, 32),    # wide head
    (1, 256, 2, 2, 8, 128, 128),   # MXU-aligned blocks
])
def test_bwd_shapes_vs_ref(B, S, H, Kh, hd, bq, bk):
    rng = np.random.default_rng(B * 1000 + S + H)
    kv_last = _tree_kv_last(S + H, B, S)
    q = _rand(rng, (B, S, H, hd))
    k = _rand(rng, (B, S, Kh, hd))
    v = _rand(rng, (B, S, Kh, hd))
    do = _rand(rng, (B, S, H, hd))
    scale = hd ** -0.5
    g = _grads(lambda q_, k_, v_:
               tree_attention(q_, k_, v_, kv_last, scale, bq, bk),
               q, k, v, do)
    gr = _grads(lambda q_, k_, v_:
                tree_attention_ref(q_, k_, v_, kv_last, scale),
                q, k, v, do)
    for name, a, b in zip(("dq", "dk", "dv"), g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4, err_msg=name)


def test_bwd_no_dense_residuals():
    """The vjp residuals stay O(S): no [.., S, S] tensor may be saved."""
    B, S, H, hd = 1, 128, 2, 16
    kv_last = jnp.full((B, S), S - 1, jnp.int32)
    q = k = v = jnp.ones((B, S, H, hd), jnp.float32)
    _, vjp = jax.vjp(lambda q_, k_, v_:
                     tree_attention(q_, k_, v_, kv_last, hd ** -0.5),
                     q, k, v)
    leaves = jax.tree.leaves(vjp)
    assert leaves, "vjp closure saved no residuals?"
    for leaf in leaves:
        assert np.asarray(leaf).shape.count(S) <= 1, (
            f"dense O(S²) residual of shape {np.asarray(leaf).shape}")


def test_bwd_padding_rows_zero_grad_and_finite():
    """Padding keys (kv_last = −1) get zero dk/dv; padded queries zero dq;
    nothing is NaN even when whole rows are masked out."""
    rng = np.random.default_rng(29)
    B, S, H, hd = 1, 64, 2, 16
    kv_last = np.full((B, S), -1, np.int32)
    kv_last[0, :16] = 15
    kv_last = jnp.asarray(kv_last)
    q = _rand(rng, (B, S, H, hd))
    k = _rand(rng, (B, S, H, hd))
    v = _rand(rng, (B, S, H, hd))
    do = _rand(rng, (B, S, H, hd))
    dq, dk, dv = _grads(lambda q_, k_, v_:
                        tree_attention(q_, k_, v_, kv_last, 0.25, 16, 16),
                        q, k, v, do)
    for g in (dq, dk, dv):
        assert np.isfinite(np.asarray(g)).all()
    np.testing.assert_allclose(np.asarray(dq[0, 16:]), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(dk[0, 16:]), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(dv[0, 16:]), 0.0, atol=1e-6)


def test_bwd_pure_causal_matches_plain_flash_grads():
    """Single-chain tree = plain causal attention; gradients must agree
    with jax.grad through vanilla softmax attention."""
    rng = np.random.default_rng(31)
    B, S, H, hd = 1, 128, 4, 16
    kv_last = jnp.full((B, S), S - 1, jnp.int32)
    q = _rand(rng, (B, S, H, hd))
    k = _rand(rng, (B, S, H, hd))
    v = _rand(rng, (B, S, H, hd))
    do = _rand(rng, (B, S, H, hd))

    def plain(q_, k_, v_):
        logits = jnp.einsum("bihd,bjhd->bhij", q_, k_) * hd ** -0.5
        mask = jnp.tril(jnp.ones((S, S), bool))
        w = jax.nn.softmax(jnp.where(mask, logits, -1e30), axis=-1)
        return jnp.einsum("bhij,bjhd->bihd", w, v_)

    g = _grads(lambda q_, k_, v_:
               tree_attention(q_, k_, v_, kv_last, hd ** -0.5, 32, 32),
               q, k, v, do)
    gp = _grads(plain, q, k, v, do)
    for name, a, b in zip(("dq", "dk", "dv"), g, gp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4, err_msg=name)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 5e-2)])
def test_bwd_dtypes(dtype, tol):
    rng = np.random.default_rng(37)
    B, S, H, Kh, hd = 1, 128, 4, 2, 32
    kv_last = _tree_kv_last(3, B, S)
    q = _rand(rng, (B, S, H, hd), dtype)
    k = _rand(rng, (B, S, Kh, hd), dtype)
    v = _rand(rng, (B, S, Kh, hd), dtype)
    do = _rand(rng, (B, S, H, hd), dtype)
    g = _grads(lambda q_, k_, v_:
               tree_attention(q_, k_, v_, kv_last, hd ** -0.5, 32, 32),
               q, k, v, do)
    gr = _grads(lambda q_, k_, v_:
                tree_attention_ref(q_, k_, v_, kv_last, hd ** -0.5),
                q, k, v, do)
    for name, a, b in zip(("dq", "dk", "dv"), g, gr):
        assert a.dtype == dtype, name
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=tol, rtol=tol, err_msg=name)


@pytest.mark.parametrize("A,pad_rows,window", [
    (32, (0, 7), None),    # aligned ancestors, row-1 front padding
    (20, (5, 0), None),    # awkward depth → KV back-pad path
    (32, (4, 11), 12),     # ancestors + sliding window combined
])
def test_bwd_gateway_ancestors_vs_ref(A, pad_rows, window):
    """Fused backward through the gateway layout: dq AND the ancestor
    rows of dk/dv (d_extra_k/d_extra_v, rows [0, A)) match the oracle."""
    rng = np.random.default_rng(200 + A + (window or 0))
    B, S, H, Kh, hd = 2, 64, 4, 2, 16
    kl_all, pos_q, pos_k, _ = _gateway_meta(5, B, S, A, pad_rows)
    q = _rand(rng, (B, S, H, hd))
    k = _rand(rng, (B, A + S, Kh, hd))
    v = _rand(rng, (B, A + S, Kh, hd))
    do = _rand(rng, (B, S, H, hd))
    scale = hd ** -0.5
    g = _grads(lambda q_, k_, v_:
               tree_attention(q_, k_, v_, kl_all, scale, 32, 32, q_off=A,
                              window=window, pos_q=pos_q, pos_k=pos_k),
               q, k, v, do)
    gr = _grads(lambda q_, k_, v_:
                tree_attention_ref_ext(q_, k_, v_, kl_all, scale, q_off=A,
                                       window=window, pos_q=pos_q,
                                       pos_k=pos_k),
                q, k, v, do)
    for name, a, b in zip(("dq", "dk", "dv"), g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4, err_msg=name)
    # ancestor cotangents are real (nonzero) — the routing has something
    # to carry back to the parent partition
    assert float(jnp.abs(g[1][:, :A]).max()) > 1e-3
    assert float(jnp.abs(g[2][:, :A]).max()) > 1e-3


def test_bwd_window_with_tree_branching_vs_ref():
    rng = np.random.default_rng(211)
    B, S, H, hd = 2, 128, 4, 16
    kv_last, pos_ids = _tree_meta(11, B, S)
    q = _rand(rng, (B, S, H, hd))
    k = _rand(rng, (B, S, H, hd))
    v = _rand(rng, (B, S, H, hd))
    do = _rand(rng, (B, S, H, hd))
    scale = hd ** -0.5
    g = _grads(lambda q_, k_, v_:
               tree_attention(q_, k_, v_, kv_last, scale, 32, 32, window=8,
                              pos_q=pos_ids, pos_k=pos_ids),
               q, k, v, do)
    gr = _grads(lambda q_, k_, v_:
                tree_attention_ref_ext(q_, k_, v_, kv_last, scale,
                                       window=8, pos_q=pos_ids,
                                       pos_k=pos_ids),
                q, k, v, do)
    for name, a, b in zip(("dq", "dk", "dv"), g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4, err_msg=name)


def test_bwd_bf16_gqa_with_ancestors():
    rng = np.random.default_rng(223)
    B, S, A, H, Kh, hd = 1, 128, 32, 4, 2, 32
    kl_all, pos_q, pos_k, _ = _gateway_meta(7, B, S, A, pad_rows=(9,))
    q = _rand(rng, (B, S, H, hd), jnp.bfloat16)
    k = _rand(rng, (B, A + S, Kh, hd), jnp.bfloat16)
    v = _rand(rng, (B, A + S, Kh, hd), jnp.bfloat16)
    do = _rand(rng, (B, S, H, hd), jnp.bfloat16)
    scale = hd ** -0.5
    g = _grads(lambda q_, k_, v_:
               tree_attention(q_, k_, v_, kl_all, scale, 32, 32, q_off=A),
               q, k, v, do)
    gr = _grads(lambda q_, k_, v_:
                tree_attention_ref_ext(q_, k_, v_, kl_all, scale, q_off=A),
                q, k, v, do)
    for name, a, b in zip(("dq", "dk", "dv"), g, gr):
        assert a.dtype == jnp.bfloat16, name
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-2, rtol=5e-2, err_msg=name)


def test_bwd_direct_entry_point_matches_custom_vjp():
    """tree_attention_bwd called directly (as a library op) agrees with
    the custom_vjp wiring — catches residual-layout drift."""
    rng = np.random.default_rng(41)
    B, S, H, Kh, hd = 2, 128, 4, 2, 16
    kv_last = _tree_kv_last(11, B, S)
    q = _rand(rng, (B, S, H, hd))
    k = _rand(rng, (B, S, Kh, hd))
    v = _rand(rng, (B, S, Kh, hd))
    do = _rand(rng, (B, S, H, hd))
    scale = hd ** -0.5
    o, lse = raw_fwd(q, k, v, kv_last, scale, block_q=32, block_k=32,
                     save_residuals=True, interpret=True)
    dq, dk, dv = tree_attention_bwd(q, k, v, kv_last, o, lse, do, scale,
                                    block_q=32, block_k=32, interpret=True)
    g = _grads(lambda q_, k_, v_:
               tree_attention(q_, k_, v_, kv_last, scale, 32, 32),
               q, k, v, do)
    for name, a, b in zip(("dq", "dk", "dv"), (dq, dk, dv), g):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5, err_msg=name)
