"""End-to-end system behaviour.

The paper's headline loop at test scale: agentic trees → packed tree
batches → train steps → identical dynamics to the per-branch baseline,
with fewer token slots computed (the speedup source).
"""
import jax
import numpy as np
import pytest


from conftest import tiny_cfg
from repro.core.packing import pack_linear_paths, pack_trees
from repro.core.tree import serialize_tree
from repro.data.loader import LoaderConfig, batches, dataset_por
from repro.data.synthetic import trees_for_batch
from repro.models.model import init_params, loss_and_metrics, prepare_batch
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_step import make_train_step


@pytest.mark.slow
def test_end_to_end_tree_training_runs_and_learns():
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    opt_cfg = OptimizerConfig(lr=5e-3, warmup_steps=2, total_steps=15)
    step = make_train_step(cfg, opt_cfg, donate=False)
    opt = init_opt_state(params)
    lc = LoaderConfig(seq_len=256, batch_rows=2, trees_per_batch=5,
                      mode="tree", kind="agentic", seed=1,
                      gen_kwargs=dict(num_turns=3,
                                      turn_len_range=(4, 16)))
    losses = []
    for inputs, _tb in batches(cfg, lc, 15):
        params, opt, m = step(params, opt, inputs)
        losses.append(float(m["token_nll_mean"]))
    assert len(losses) >= 10
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_token_slot_savings_match_por():
    """The packed tree batch uses ≈(1−POR)× the slots of the baseline
    packing — the computation-savings bookkeeping behind Fig. 8."""
    trees = trees_for_batch(5, n_trees=8, kind="agentic", num_turns=4,
                            turn_len_range=(8, 32), vocab_size=97)
    por = dataset_por(trees)
    uniq = sum(t.num_unique_tokens() for t in trees)
    flat = sum(t.flat_tokens() for t in trees)
    assert uniq == round((1 - por) * flat)
    # packing preserves the counts exactly (valid slots = real tokens)
    sers = [serialize_tree(t) for t in trees]
    S = max(max(s.n for s in sers),
            max(len(p["tokens"]) for t in trees
                for p in t.linearize_paths()))
    S = ((S + 63) // 64) * 64
    tb = pack_trees(sers, S)
    lb = pack_linear_paths([t.linearize_paths() for t in trees], S)
    assert int(tb.valid.sum()) == uniq
    assert int(lb.valid.sum()) == flat


def test_pallas_impl_matches_ref_in_model():
    """Full model forward with the Pallas kernel (interpret) == ref impl."""
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    trees = trees_for_batch(2, n_trees=2, kind="random", vocab_size=89)
    sers = [serialize_tree(t) for t in trees]
    b = prepare_batch(cfg, pack_trees(sers, 128))
    l_ref, _ = loss_and_metrics(cfg, params, b, impl="ref")
    l_pal, _ = loss_and_metrics(cfg, params, b, impl="pallas")
    np.testing.assert_allclose(float(l_pal), float(l_ref), rtol=1e-5)
    l_chk, _ = loss_and_metrics(cfg, params, b, impl="chunked")
    np.testing.assert_allclose(float(l_chk), float(l_ref), rtol=1e-5)
