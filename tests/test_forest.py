"""Cross-tree forest grafting (core/forest + planner --graft) and
planner-chosen partition capacity (core/partition.choose_capacity).

The load-bearing claims:

  - grafting is pure dedup: token conservation (grafted unique + saved
    == summed source unique), bit-exact λ on every reused source node,
    λ summed over members on shared spine nodes, path-count additivity;
  - a grafting-enabled planner schedule is gradient-equal (≤ 1e-6
    max-rel) to independent per-tree training of the same stream —
    compared per-window with each step weighted by its tree count,
    because per-step losses are means over that step's trees and graft
    on/off distribute trees across steps differently;
  - on template-heavy streams the grafted schedule computes measurably
    fewer unique tokens (the paper's cross-tree shared-prefix motivation).

MoE caveat: the router's load-balance/z losses are means over the
batch's *valid tokens*, so token multiplicity is semantic — a prefix
shared by k trees contributes k times ungrafted but once grafted.  The
strict bar therefore zeroes the aux weights for MoE (the main CE loss
plus routing itself are packing-independent: pads never queue and
capacity_factor=4 never binds); with aux on, the divergence is the
regularizer seeing the deduped token distribution, not a grafting bug.
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core.forest import graft_trees
from repro.core.partition import choose_capacity
from repro.core.tree import serialize_tree, tree_lam_map
from repro.data.loader import LoaderConfig
from repro.data.synthetic import (template_stream, template_tokens,
                                  trees_for_batch)
from repro.models.model import init_params
from repro.train.engine import TreeTrainEngine
from repro.train.planner import PlannerConfig, plan_stream


def _template_window(seed, batches=3, trees=5, **kw):
    gen = dict(vocab_size=500, num_templates=2, template_len=48,
               num_turns=2, turn_len_range=(4, 16))
    gen.update(kw)
    out = []
    for b in range(batches):
        out += trees_for_batch(seed * 100_003 + b, n_trees=trees,
                               kind="template", **gen)
    return out


# ---------------------------------------------------------------------------
# pure-dedup invariants (host-only)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loss_mode", ["sep_avg", "uniform", "rl"])
def test_graft_conservation_seeded(loss_mode):
    for seed in range(4):
        trees = _template_window(seed)
        if loss_mode == "rl":
            from repro.data.synthetic import assign_branch_advantages
            rng = np.random.default_rng(seed)
            for t in trees:
                assign_branch_advantages(
                    t, rng.normal(size=t.num_leaves()))
        grafts, passthrough = graft_trees(trees, loss_mode=loss_mode,
                                          min_graft=16)
        assert grafts, "template window must produce at least one graft"
        # srcs ∪ passthrough partitions the input indices
        covered = sorted(i for g in grafts for i in g.srcs) + passthrough
        assert sorted(covered) == list(range(len(trees)))
        for g in grafts:
            assert len(g.srcs) >= 2
            src_unique = sum(trees[i].num_unique_tokens() for i in g.srcs)
            # token conservation: dedup only, nothing dropped or invented
            assert g.tree.num_unique_tokens() + g.saved_tokens == src_unique
            assert g.saved_tokens > 0
            assert g.shared_tokens >= 16
            # path-count additivity: every source branch survives
            assert g.tree.num_leaves() == sum(
                trees[i].num_leaves() for i in g.srcs)
            # λ conservation: serialized weight mass equals the sources'
            ser = serialize_tree(g.tree, lam_map=g.lam_map)
            w_src = sum(
                serialize_tree(trees[i], loss_mode=loss_mode)
                .weight.astype(np.float64).sum() for i in g.srcs)
            # rl weights nearly cancel (± advantages), so tolerance is
            # relative to the total weight MASS, not the near-zero sum
            tol = 1e-6 * max(np.abs(ser.weight).sum(), 1.0)
            np.testing.assert_allclose(
                ser.weight.astype(np.float64).sum(), w_src, atol=tol)
            # reused source nodes keep their λ BIT-exactly
            for i in g.srcs:
                lam_src = tree_lam_map(trees[i].root, loss_mode)
                for node in g.tree.nodes():
                    if id(node) in lam_src:
                        assert g.lam_map[id(node)] == lam_src[id(node)]


def test_graft_property():
    """Hypothesis variant of the conservation invariants over arbitrary
    trees — shared prefixes arise from the tiny vocab (skips when
    hypothesis is absent, like the other property suites)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.core.tree import TrajectoryTree, TreeNode

    @st.composite
    def trees(draw, max_depth=3, max_children=3, max_seg=5):
        def node(depth):
            L = draw(st.integers(1, max_seg))
            toks = draw(st.lists(st.integers(0, 2), min_size=L,
                                 max_size=L))
            n = TreeNode(tokens=np.asarray(toks, np.int32))
            if depth < max_depth:
                k = draw(st.integers(0, max_children))
                if k >= 2 or (k == 1 and draw(st.booleans())):
                    n.children = [node(depth + 1) for _ in range(k)]
            return n

        return TrajectoryTree(root=node(0))

    @given(st.lists(trees(), min_size=2, max_size=6),
           st.integers(1, 4))
    @settings(max_examples=40, deadline=None)
    def check(forest, min_graft):
        grafts, passthrough = graft_trees(forest, min_graft=min_graft)
        covered = sorted(i for g in grafts for i in g.srcs) + passthrough
        assert sorted(covered) == list(range(len(forest)))
        for g in grafts:
            src_unique = sum(forest[i].num_unique_tokens()
                             for i in g.srcs)
            assert (g.tree.num_unique_tokens() + g.saved_tokens
                    == src_unique)
            assert g.saved_tokens >= min_graft
            assert g.tree.num_leaves() == sum(
                forest[i].num_leaves() for i in g.srcs)
            ser = serialize_tree(g.tree, lam_map=g.lam_map)
            w_src = sum(serialize_tree(forest[i]).weight.sum()
                        for i in g.srcs)
            np.testing.assert_allclose(ser.weight.sum(), w_src,
                                       rtol=1e-6)

    check()


# ---------------------------------------------------------------------------
# schedule-level: grafted planner ≡ independent per-tree training
# ---------------------------------------------------------------------------

def _lc(loss_mode="sep_avg", **kw):
    base = dict(seq_len=192, batch_rows=3, trees_per_batch=6, mode="tree",
                kind="template", seed=3, loss_mode=loss_mode,
                auto_partition=True,
                gen_kwargs=dict(num_templates=2, template_len=96,
                                num_turns=1, turn_len_range=(4, 12)))
    base.update(kw)
    return LoaderConfig(**base)


def _window_grads(cfg, params, lc, pc, impl, steps=4):
    """Tree-count-weighted loss/grads over the stream: Σ n·(per-step
    mean) / Σ n — invariant to how a schedule distributes trees across
    steps, which is exactly what graft on/off changes."""
    eng = TreeTrainEngine(cfg, impl=impl, donate=False)
    tot_n, tot_l, tot_g, uniq = 0, 0.0, None, 0
    for ps in plan_stream(cfg, lc, steps, pc):
        plan = ps.execution_plan()
        g, scal = eng.accumulate(params, plan)
        n = plan.num_trees
        tot_l += n * float(np.asarray(scal)[0])
        # float64 host accumulation: the weighted combine must not add
        # noise of its own on top of the per-step fp32 engine math
        g = jax.tree.map(lambda a: n * np.asarray(a, np.float64), g)
        tot_g = g if tot_g is None else jax.tree.map(np.add, tot_g, g)
        tot_n += n
        uniq += plan.unique_tokens
    return (tot_l / tot_n,
            jax.tree.map(lambda a: a / tot_n, tot_g), uniq, tot_n)


def _max_rel(g, g_ref):
    rels = jax.tree.map(
        lambda a, b: float(np.abs(a - b).max() /
                           (np.abs(b).max() + 1e-9)), g, g_ref)
    return max(jax.tree.leaves(rels))


def _per_tree_reference(cfg, params, lc, impl, steps=4):
    """Independent per-tree training: every tree serialized alone in its
    own row, per-tree grads averaged in float64 — the ungrafted ground
    truth the ISSUE bar compares against."""
    from repro.core.packing import pack_trees
    from repro.data.loader import tree_stream
    from repro.models.model import prepare_batch
    from repro.train.train_step import make_grad_fn

    fn = make_grad_fn(cfg, impl=impl)
    tot_l, tot_g, n = 0.0, None, 0
    for batch in tree_stream(cfg, lc, steps):
        for t in batch:
            ser = serialize_tree(t, loss_mode=lc.loss_mode)
            assert ser.n <= lc.seq_len
            inputs = prepare_batch(cfg, pack_trees([ser], lc.seq_len),
                                   num_trees=1)
            loss, grads, _ = fn(params, inputs)
            tot_l += float(loss)
            grads = jax.tree.map(lambda a: np.asarray(a, np.float64),
                                 grads)
            tot_g = grads if tot_g is None else jax.tree.map(
                np.add, tot_g, grads)
            n += 1
    return tot_l / n, jax.tree.map(lambda a: a / n, tot_g), n


def _check_graft_grad_equivalence(cfg, impl, loss_mode):
    params = init_params(cfg, jax.random.key(0))
    lc = _lc(loss_mode)
    l_ref, g_ref, n_ref = _per_tree_reference(cfg, params, lc, impl)
    l1, g1, u1, n1 = _window_grads(
        cfg, params, lc,
        PlannerConfig(lookahead=4, graft=True, min_graft=8), impl)
    assert n1 == n_ref                   # every source tree accounted
    assert abs(l1 - l_ref) / max(abs(l_ref), 1e-9) <= 1e-6
    assert _max_rel(g1, g_ref) <= 1e-6


def test_graft_grad_equivalence_dense_ref():
    _check_graft_grad_equivalence(tiny_cfg("dense"), "ref", "rl")


@pytest.mark.slow
@pytest.mark.parametrize("family,impl", [
    ("dense", "chunked"), ("dense", "pallas"),
    ("moe", "chunked"), ("moe", "pallas")])
def test_graft_grad_equivalence_grid(family, impl):
    cfg = tiny_cfg(family)
    if family == "moe":
        # aux router losses are means over valid tokens — multiplicity-
        # sensitive by definition, so the strict bar turns them off (see
        # module docstring); everything else in the MoE path is exact
        cfg = replace(cfg, moe=replace(cfg.moe, router_aux_weight=0.0,
                                       router_z_weight=0.0))
    _check_graft_grad_equivalence(cfg, impl, "sep_avg")


# ---------------------------------------------------------------------------
# saved-token fraction on a template-heavy stream (host-only)
# ---------------------------------------------------------------------------

def test_graft_saves_quarter_on_template_stream():
    cfg = tiny_cfg("dense")
    lc = _lc()

    def stats(pc):
        uniq = trees = dropped = 0
        for ps in plan_stream(cfg, lc, 4, pc):
            sb = ps.step_batch()
            dropped += sb.dropped
            trees += sb.num_trees
            if sb.tb is not None:
                uniq += int(sb.tb.valid.sum())
            uniq += sum(t.num_unique_tokens() for t in sb.oversized)
        return uniq, trees, dropped

    u0, t0, d0 = stats(PlannerConfig(lookahead=4))
    u1, t1, d1 = stats(PlannerConfig(lookahead=4, graft=True,
                                     min_graft=16))
    assert t1 + d1 == t0 + d0            # source-tree accounting intact
    assert d1 == 0
    assert u1 <= 0.75 * u0, (u0, u1)     # ≥ 25% unique tokens saved


# ---------------------------------------------------------------------------
# planner-chosen partition capacity
# ---------------------------------------------------------------------------

def test_choose_capacity_bounds_and_chunk():
    rng = np.random.default_rng(0)
    from repro.data.synthetic import agentic_tree
    trees = [agentic_tree(rng, vocab_size=300, num_turns=3,
                          turn_len_range=(16, 48)) for _ in range(3)]
    for chunk in (None, 8, 16):
        cap = choose_capacity(trees, 256, chunk_size=chunk)
        assert 0 < cap <= 256
        if chunk:
            assert cap % chunk == 0
        # pow2 fraction of seq_len (signature buckets stay enumerable)
        assert 256 % cap == 0


def test_auto_capacity_flows_through_planner():
    cfg = tiny_cfg("dense")
    lc = _lc(seq_len=96, auto_capacity=True,
             gen_kwargs=dict(num_templates=2, template_len=48,
                             num_turns=3, turn_len_range=(8, 32)))
    pc = PlannerConfig(lookahead=2)
    saw_oversized = False
    for ps in plan_stream(cfg, lc, 4, pc):
        sb = ps.step_batch()
        if sb.oversized:
            saw_oversized = True
            assert ps.capacity is not None
            assert 0 < ps.capacity <= lc.seq_len
            assert lc.seq_len % ps.capacity == 0
            plan = ps.execution_plan()      # materializes at that cap
            assert plan.partition is not None
    assert saw_oversized
    # an explicit capacity always wins over auto
    lc2 = replace(lc, capacity=96)
    for ps in plan_stream(cfg, lc2, 2, pc):
        if ps.step_batch().oversized:
            assert ps.capacity in (None, 96)


# ---------------------------------------------------------------------------
# template generator determinism
# ---------------------------------------------------------------------------

def test_template_tokens_deterministic_across_batches():
    a = template_tokens(7, 1, 64, 1000)
    b = template_tokens(7, 1, 64, 1000)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, template_tokens(7, 2, 64, 1000))
    assert not np.array_equal(a, template_tokens(8, 1, 64, 1000))
    # every stream batch opens trees with one of the SAME template heads
    heads = set()
    for batch in template_stream(5, num_batches=3, trees_per_batch=4,
                                 vocab_size=1000, num_templates=2,
                                 template_len=32, num_turns=1,
                                 turn_len_range=(4, 8)):
        for t in batch:
            heads.add(tuple(t.root.tokens[:32].tolist()))
    assert len(heads) == 2
    expect = {tuple(template_tokens(7, tid, 32, 1000).tolist())
              for tid in range(2)}
    assert heads == expect
