"""Pallas tree-attention kernel vs pure-jnp oracle (interpret mode on CPU).

Sweeps shapes, dtypes, GQA ratios, block sizes and tree topologies per the
kernel-validation contract (every kernel: sweep + assert_allclose vs ref).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import pack_trees
from repro.core.tree import serialize_tree
from repro.data.synthetic import trees_for_batch
from repro.kernels.ops import tree_attention
from repro.kernels.ref import tree_attention_ref, tree_attention_ref_ext

BIG = 1 << 30


def _tree_meta(seed: int, B: int, S: int):
    """(kv_last, pos_ids) of a packed random-tree batch."""
    trees = trees_for_batch(seed, n_trees=6 * B, kind="random",
                            seg_len_range=(1, 4), max_depth=3)
    sers, used = [], 0
    for t in trees:
        s = serialize_tree(t)
        if used + s.n <= B * S * 3 // 4:   # keep some padding in rows
            sers.append(s)
            used += s.n
    tb = pack_trees(sers, S, batch_size=B)
    return jnp.asarray(tb.kv_last), jnp.asarray(tb.pos_ids)


def _tree_kv_last(seed: int, B: int, S: int) -> jnp.ndarray:
    return _tree_meta(seed, B, S)[0]


def _gateway_meta(seed: int, B: int, S: int, A: int, pad_rows=()):
    """The exact gateway KV layout models/attention.py assembles: A
    ancestor slots front-concatenated (always-visible kv_last = BIG,
    front padding = −1 on selected rows), DFS indices offset by A, and
    positions continuing the path (ancestors precede the local root)."""
    kv_main, pos_main = _tree_meta(seed, B, S)
    anc_kl = np.full((B, A), BIG, np.int64)
    anc_valid = np.ones((B, A), bool)
    for r, p in zip(range(B), pad_rows):
        anc_kl[r, :p] = -1
        anc_valid[r, :p] = False
    kl_all = jnp.concatenate(
        [jnp.asarray(anc_kl, jnp.int32),
         jnp.where(kv_main >= 0, kv_main + A, -1)], axis=1)
    anc_pos = jnp.broadcast_to(jnp.arange(A, dtype=jnp.int32), (B, A))
    pos_q = (pos_main + A).astype(jnp.int32)
    pos_k = jnp.concatenate([anc_pos, pos_q], axis=1)
    return kl_all, pos_q, pos_k, jnp.asarray(anc_valid)


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,S,H,Kh,hd,bq,bk", [
    (1, 64, 4, 4, 16, 16, 16),     # MHA
    (2, 128, 4, 2, 16, 32, 32),    # GQA 2:1
    (1, 128, 8, 1, 32, 32, 64),    # MQA, rectangular blocks
    (2, 128, 4, 2, 64, 64, 32),    # wide head
    (1, 256, 2, 2, 8, 128, 128),   # MXU-aligned blocks
])
def test_kernel_shapes_vs_ref(B, S, H, Kh, hd, bq, bk):
    rng = np.random.default_rng(B * 1000 + S)
    kv_last = _tree_kv_last(S, B, S)
    q = _rand(rng, (B, S, H, hd), jnp.float32)
    k = _rand(rng, (B, S, Kh, hd), jnp.float32)
    v = _rand(rng, (B, S, Kh, hd), jnp.float32)
    scale = hd ** -0.5
    o = tree_attention(q, k, v, kv_last, scale, bq, bk)
    o_ref = tree_attention_ref(q, k, v, kv_last, scale)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    rng = np.random.default_rng(7)
    B, S, H, Kh, hd = 1, 128, 4, 2, 32
    kv_last = _tree_kv_last(3, B, S)
    q = _rand(rng, (B, S, H, hd), dtype)
    k = _rand(rng, (B, S, Kh, hd), dtype)
    v = _rand(rng, (B, S, Kh, hd), dtype)
    o = tree_attention(q, k, v, kv_last, hd ** -0.5, 32, 32)
    o_ref = tree_attention_ref(q, k, v, kv_last, hd ** -0.5)
    tol = TOLS[dtype]
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=tol, rtol=tol)


def test_kernel_pure_causal_degenerates_to_flash():
    """A single chain tree = plain causal attention."""
    rng = np.random.default_rng(11)
    B, S, H, hd = 2, 128, 4, 16
    kv_last = jnp.full((B, S), S - 1, jnp.int32)
    q = _rand(rng, (B, S, H, hd), jnp.float32)
    k = _rand(rng, (B, S, H, hd), jnp.float32)
    v = _rand(rng, (B, S, H, hd), jnp.float32)
    o = tree_attention(q, k, v, kv_last, hd ** -0.5, 32, 32)
    # plain causal reference
    logits = jnp.einsum("bihd,bjhd->bhij", q, k) * hd ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    w = jax.nn.softmax(jnp.where(mask, logits, -1e30), axis=-1)
    o_ref = jnp.einsum("bhij,bjhd->bihd", w, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


def test_kernel_invalid_keys_never_attended():
    """kv_last = −1 keys (padding) are invisible; fully-masked queries → 0."""
    rng = np.random.default_rng(13)
    B, S, H, hd = 1, 64, 2, 16
    kv_last = np.full((B, S), -1, np.int32)
    kv_last[0, :16] = 15          # one 16-token segment; rest padding
    q = _rand(rng, (B, S, H, hd), jnp.float32)
    k = _rand(rng, (B, S, H, hd), jnp.float32)
    v = _rand(rng, (B, S, H, hd), jnp.float32)
    o = tree_attention(q, k, v, jnp.asarray(kv_last), hd ** -0.5, 16, 16)
    assert np.isfinite(np.asarray(o)).all()
    np.testing.assert_allclose(np.asarray(o[0, 16:]), 0.0, atol=1e-6)


@pytest.mark.parametrize("A,pad_rows", [
    (32, (0, 7)),     # MXU-aligned ancestor block, row 1 front-padded
    (20, (5, 0)),     # awkward depth → ops.py back-pads KV to sublane 8
])
def test_kernel_gateway_ancestors_vs_ref(A, pad_rows):
    """Front-concatenated ancestor KV (partition gateway) with per-row
    front-padding valid masks matches the dense oracle."""
    rng = np.random.default_rng(100 + A)
    B, S, H, Kh, hd = 2, 64, 4, 2, 16
    kl_all, _, _, _ = _gateway_meta(5, B, S, A, pad_rows)
    q = _rand(rng, (B, S, H, hd), jnp.float32)
    k = _rand(rng, (B, A + S, Kh, hd), jnp.float32)
    v = _rand(rng, (B, A + S, Kh, hd), jnp.float32)
    scale = hd ** -0.5
    o = tree_attention(q, k, v, kl_all, scale, 32, 32, q_off=A)
    o_ref = tree_attention_ref_ext(q, k, v, kl_all, scale, q_off=A)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_window_with_tree_branching_vs_ref():
    """Sliding window (positions, not DFS indices) combined with tree
    branching: the pallas path must apply the window term, and the result
    must genuinely differ from the un-windowed one (mask has teeth)."""
    rng = np.random.default_rng(23)
    B, S, H, hd = 2, 128, 4, 16
    kv_last, pos_ids = _tree_meta(11, B, S)
    q = _rand(rng, (B, S, H, hd), jnp.float32)
    k = _rand(rng, (B, S, H, hd), jnp.float32)
    v = _rand(rng, (B, S, H, hd), jnp.float32)
    scale = hd ** -0.5
    o = tree_attention(q, k, v, kv_last, scale, 32, 32, window=8,
                       pos_q=pos_ids, pos_k=pos_ids)
    o_ref = tree_attention_ref_ext(q, k, v, kv_last, scale, window=8,
                                   pos_q=pos_ids, pos_k=pos_ids)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)
    o_full = tree_attention_ref_ext(q, k, v, kv_last, scale)
    assert float(jnp.abs(o_ref - o_full).max()) > 1e-3


def test_kernel_bf16_gqa_with_ancestors():
    rng = np.random.default_rng(31)
    B, S, A, H, Kh, hd = 1, 128, 32, 4, 2, 32
    kl_all, pos_q, pos_k, _ = _gateway_meta(7, B, S, A, pad_rows=(9,))
    q = _rand(rng, (B, S, H, hd), jnp.bfloat16)
    k = _rand(rng, (B, A + S, Kh, hd), jnp.bfloat16)
    v = _rand(rng, (B, A + S, Kh, hd), jnp.bfloat16)
    scale = hd ** -0.5
    o = tree_attention(q, k, v, kl_all, scale, 32, 32, q_off=A,
                       window=16, pos_q=pos_q, pos_k=pos_k)
    o_ref = tree_attention_ref_ext(q, k, v, kl_all, scale, q_off=A,
                                   window=16, pos_q=pos_q, pos_k=pos_k)
    tol = TOLS[jnp.bfloat16]
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=tol, rtol=tol)


def test_kernel_grads_vs_ref():
    rng = np.random.default_rng(17)
    B, S, H, Kh, hd = 1, 128, 4, 2, 16
    kv_last = _tree_kv_last(5, B, S)
    q = _rand(rng, (B, S, H, hd), jnp.float32)
    k = _rand(rng, (B, S, Kh, hd), jnp.float32)
    v = _rand(rng, (B, S, Kh, hd), jnp.float32)
    f = lambda q, k, v: (tree_attention(q, k, v, kv_last, 0.25, 32, 32)
                         ** 2).sum()
    fr = lambda q, k, v: (tree_attention_ref(q, k, v, kv_last, 0.25)
                          ** 2).sum()
    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-4)
