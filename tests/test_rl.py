"""RL model-update phase (loss_mode="rl"): per-branch GRPO advantages
scale λ_t.  Guarantees:

  - advantages ≡ 1 reduce BIT-EXACTLY to SFT sep_avg (weights and grads);
  - non-uniform per-branch advantages match the dense per-path oracle
    (each branch replicated as an independent sequence scaled by its
    advantage), including through the partition-wave path;
  - serve-side rollouts (token sequences + rewards) convert into
    advantage-carrying trajectory trees the engine natively ingests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import branching_tree, tiny_cfg
from repro.core.gateway import packed_partitioned_value_and_grad
from repro.core.packing import pack_linear_paths, pack_trees
from repro.core.tree import serialize_tree
from repro.data.synthetic import (assign_branch_advantages, grpo_tree,
                                  random_tree)
from repro.models.model import init_params, prepare_batch
from repro.serve.decode import rollouts_to_tree
from repro.train.train_step import make_grad_fn


def _set_branch_advs(tree, advs=None, rng=None):
    leaves = [p[-1] for p in tree.paths()]
    if advs is None:
        advs = rng.normal(size=len(leaves)) + 1.0
    for leaf, a in zip(leaves, np.broadcast_to(advs, (len(leaves),))):
        leaf.branch_adv = float(a)
    return tree


def _max_rel(g, g_ref):
    rels = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max() /
                           (jnp.abs(b).max() + 1e-9)), g, g_ref)
    return max(jax.tree.leaves(rels))


# ---------------------------------------------------------------------------
# advantages ≡ 1  ⇒  bit-exact SFT
# ---------------------------------------------------------------------------

def test_rl_unit_advantages_weights_bitexact_sep_avg():
    tree = branching_tree(0, min_leaves=3)
    _set_branch_advs(tree, advs=1.0)
    s_sft = serialize_tree(tree, loss_mode="sep_avg")
    s_rl = serialize_tree(tree, loss_mode="rl")
    assert np.array_equal(s_sft.weight, s_rl.weight)
    # unset advantages (None) are 1.0 too
    tree2 = branching_tree(0, min_leaves=3)
    s_rl2 = serialize_tree(tree2, loss_mode="rl")
    assert np.array_equal(s_sft.weight, s_rl2.weight)


def test_rl_unit_advantages_grads_bitexact_sep_avg():
    """The acceptance bar: loss_mode="rl" with A≡1 reproduces the SFT
    gradients bit for bit (identical weights → identical jitted call)."""
    cfg = tiny_cfg("dense")
    tree = branching_tree(1, min_leaves=3)
    params = init_params(cfg, jax.random.key(0))
    gfn = make_grad_fn(cfg)
    b_sft = prepare_batch(cfg, pack_trees(
        [serialize_tree(tree, loss_mode="sep_avg")], 128))
    b_rl = prepare_batch(cfg, pack_trees(
        [serialize_tree(tree, loss_mode="rl")], 128))
    l_s, g_s, _ = gfn(params, b_sft)
    l_r, g_r, _ = gfn(params, b_rl)
    assert float(l_s) == float(l_r)
    for a, b in zip(jax.tree.leaves(g_s), jax.tree.leaves(g_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# non-uniform advantages: host-side weight oracle
# ---------------------------------------------------------------------------

def test_rl_weights_match_path_sum_oracle():
    """λ_t = Σ_{paths through t} A_path / K, token by token."""
    tree = branching_tree(2, min_leaves=3)
    rng = np.random.default_rng(0)
    _set_branch_advs(tree, rng=rng)
    ser = serialize_tree(tree, loss_mode="rl")
    paths = tree.paths()
    K = len(paths)
    # brute-force: walk each path, add its leaf advantage to every node
    adv_of = {}
    for p in paths:
        a = p[-1].branch_adv
        for n in p:
            adv_of[id(n)] = adv_of.get(id(n), 0.0) + a
    # reconstruct per-token weights node by node (DFS order == ser order)
    order = []

    def dfs(n):
        order.append(n)
        for c in n.children:
            dfs(c)

    dfs(tree.root)
    off = 0
    for node in order:
        lam = adv_of[id(node)] / K
        exp = np.where(node.trained, lam, 0.0).astype(np.float32)
        got = ser.weight[off:off + node.size]
        np.testing.assert_allclose(got, exp, rtol=1e-6)
        off += node.size
    assert off == ser.n  # no chunk padding in this config


# ---------------------------------------------------------------------------
# non-uniform advantages: dense per-path gradient oracle
# ---------------------------------------------------------------------------

def test_rl_grads_match_dense_per_path_oracle():
    """Tree-packed RL loss/grads == every branch replicated as an
    independent sequence scaled by its advantage (Gradient Restoration
    under per-branch weights)."""
    cfg = tiny_cfg("dense")
    tree = branching_tree(3, min_leaves=3)
    rng = np.random.default_rng(1)
    _set_branch_advs(tree, rng=rng)
    params = init_params(cfg, jax.random.key(0))
    gfn = make_grad_fn(cfg)
    bt = prepare_batch(cfg, pack_trees(
        [serialize_tree(tree, loss_mode="rl")], 128))
    bl = prepare_batch(cfg, pack_linear_paths(
        [tree.linearize_paths()], 256, loss_mode="rl"))
    lt, gt, _ = gfn(params, bt)
    ll, gl, _ = gfn(params, bl)
    np.testing.assert_allclose(float(lt), float(ll), rtol=5e-6)
    assert _max_rel(gt, gl) < 1e-4


@pytest.mark.slow
def test_rl_grads_through_partition_wave_path():
    """The RL objective survives Redundancy-Free Tree Partitioning: the
    wave-scheduled driver with loss_mode="rl" equals the whole-tree pass
    on the rl-serialized batch — advantages thread through full-tree
    lam_map, boundary weights and gateway cotangents."""
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(2)
    tree = None
    for s in range(300):
        t = random_tree(np.random.default_rng(s), vocab_size=89,
                        max_depth=5, seg_len_range=(3, 9))
        if t.num_leaves() >= 4 and 90 <= t.num_unique_tokens() <= 160:
            tree = t
            break
    _set_branch_advs(tree, rng=rng)

    ser = serialize_tree(tree, loss_mode="rl")
    S = ((ser.n + 31) // 32) * 32
    b = prepare_batch(cfg, pack_trees([ser], S))
    gfn = make_grad_fn(cfg)
    l_ref, g_ref, _ = gfn(params, b)

    l_p, g_p, info = packed_partitioned_value_and_grad(
        cfg, params, [tree], capacity=32, seq_len=32, loss_mode="rl")
    assert info["num_partitions"] > 1
    np.testing.assert_allclose(l_p, float(l_ref), rtol=2e-5)
    assert _max_rel(g_p, g_ref) < 1e-4


# ---------------------------------------------------------------------------
# serve-side rollouts → advantage trees
# ---------------------------------------------------------------------------

def test_rollouts_to_tree_merges_prefixes_and_normalizes():
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 50, 6).astype(np.int32)
    shared = rng.integers(0, 50, 4).astype(np.int32)
    tails = [rng.integers(0, 50, n).astype(np.int32) for n in (5, 3, 7)]
    seqs = [np.concatenate([prompt, shared, t]) for t in tails]
    # one rollout that is a strict prefix of rollout 0, one duplicate of 1
    seqs.append(seqs[0][:len(prompt) + 6])
    seqs.append(seqs[1].copy())
    rewards = [1.0, -1.0, 0.5, 2.0, -1.0]
    tree = rollouts_to_tree(seqs, rewards, prompt_len=len(prompt))

    # every rollout is reproduced by exactly one root-to-leaf path
    got = sorted(tuple(np.concatenate([n.tokens for n in p]).tolist())
                 for p in tree.paths())
    want = sorted(tuple(s.tolist()) for s in seqs)
    assert got == want
    # prompt tokens carry no loss; completions do
    ser = serialize_tree(tree, loss_mode="rl")
    assert tree.num_leaves() == len(seqs)
    # leaf advantages are the group-normalized rewards, matched by value
    r = np.asarray(rewards)
    expect = np.sort((r - r.mean()) / (r.std() + 1e-6))
    leaf_advs = np.sort([p[-1].branch_adv for p in tree.paths()])
    np.testing.assert_allclose(leaf_advs, expect, rtol=1e-6)
    # prompt segment untrained → first prompt tokens have zero weight
    assert ser.weight[:len(prompt)].sum() == 0.0
    assert ser.weight.sum() != 0.0
    # shared prefixes were actually merged (fewer unique than flat tokens)
    assert tree.num_unique_tokens() < sum(len(s) for s in seqs)


def test_rollouts_to_tree_identical_rollouts():
    """K identical rollouts: one shared chain, every leaf a duplicate
    (empty) branch, all advantages zero (zero reward variance) — the tree
    still trains, it just contributes a zero RL gradient."""
    rng = np.random.default_rng(4)
    seq = rng.integers(0, 50, 12).astype(np.int32)
    K = 4
    tree = rollouts_to_tree([seq.copy() for _ in range(K)], [0.7] * K,
                            prompt_len=5)
    assert tree.num_leaves() == K
    # the token content is stored once — full sharing
    assert tree.num_unique_tokens() == len(seq)
    for p in tree.paths():
        np.testing.assert_array_equal(np.concatenate(
            [n.tokens for n in p]), seq)
        assert p[-1].branch_adv == 0.0
    ser = serialize_tree(tree, loss_mode="rl")
    assert np.isfinite(ser.weight).all()
    assert ser.weight.sum() == 0.0          # zero advantage ⇒ zero loss


def test_rollouts_to_tree_zero_variance_rewards():
    """Distinct rollouts with equal rewards: normalized advantages are
    all zero; normalize=False keeps the raw rewards."""
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, 50, 4).astype(np.int32)
    seqs = [np.concatenate([prompt, rng.integers(0, 50, n)
                            .astype(np.int32)]) for n in (3, 5, 6)]
    tree = rollouts_to_tree(seqs, [2.5] * 3, prompt_len=len(prompt))
    assert all(p[-1].branch_adv == 0.0 for p in tree.paths())
    raw = rollouts_to_tree(seqs, [2.5] * 3, prompt_len=len(prompt),
                           normalize=False)
    assert all(p[-1].branch_adv == 2.5 for p in raw.paths())


def test_rollouts_to_tree_token_multiset_property():
    """Property, over many random rollout groups: the tree's root-to-leaf
    paths reproduce EXACTLY the input sequences (as a multiset), prompt
    tokens never carry loss, and merging only ever shrinks the token
    count."""
    for seed in range(10):
        rng = np.random.default_rng(100 + seed)
        P = int(rng.integers(1, 8))
        prompt = rng.integers(0, 30, P).astype(np.int32)
        K = int(rng.integers(2, 7))
        seqs = []
        for _ in range(K):
            # low vocab + short tails → frequent shared prefixes/dupes
            tail = rng.integers(0, 5, rng.integers(0, 7)).astype(np.int32)
            seqs.append(np.concatenate([prompt, tail]))
        rewards = rng.normal(size=K).tolist()
        tree = rollouts_to_tree(seqs, rewards, prompt_len=P)
        got = sorted(tuple(np.concatenate([n.tokens for n in p]).tolist())
                     for p in tree.paths())
        want = sorted(tuple(s.tolist()) for s in seqs)
        assert got == want, seed
        assert tree.num_leaves() == K
        assert tree.num_unique_tokens() <= sum(len(s) for s in seqs)
        ser = serialize_tree(tree, loss_mode="rl")
        assert np.isfinite(ser.weight).all()
        assert ser.weight[:P].sum() == 0.0   # prompt is never trained


def test_grpo_tree_generator():
    t = grpo_tree(np.random.default_rng(0), vocab_size=97, num_turns=3,
                  turn_len_range=(4, 10))
    advs = [p[-1].branch_adv for p in t.paths()]
    assert all(a is not None for a in advs)
    if len(advs) > 1:
        np.testing.assert_allclose(np.mean(advs), 0.0, atol=1e-3)
    # serialization accepts it in rl mode
    ser = serialize_tree(t, loss_mode="rl")
    assert np.isfinite(ser.weight).all()


def test_assign_branch_advantages_roundtrip():
    t = branching_tree(5, min_leaves=3)
    K = t.num_leaves()
    adv = assign_branch_advantages(t, np.arange(K, dtype=np.float64))
    assert adv.shape == (K,)
    np.testing.assert_allclose(
        [p[-1].branch_adv for p in t.paths()], adv, rtol=1e-6)
