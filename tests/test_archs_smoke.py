"""Per-architecture smoke tests: reduced same-family variant, one
forward + train step on CPU; output shapes + no NaNs.  Decode smoke for
decode-capable archs.  (Full configs are exercised only via the dry-run.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.packing import pack_trees
from repro.core.tree import serialize_tree
from repro.data.synthetic import trees_for_batch
from repro.models.model import (init_params, loss_and_metrics, needs_chunks,
                                prepare_batch)
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_step import make_train_step

pytestmark = pytest.mark.slow  # every registered arch config, ~2 min


def _smoke_batch(cfg, seed=0, S=64):
    chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    trees = trees_for_batch(seed, n_trees=3, kind="random",
                            vocab_size=cfg.vocab_size,
                            seg_len_range=(2, 5), max_depth=3)
    sers = [serialize_tree(t, chunk_size=chunk) for t in trees]
    sers = [s for s in sers if s.n <= S][:2] or \
        [serialize_tree(trees_for_batch(seed + 1, n_trees=1, kind="chain",
                                        vocab_size=cfg.vocab_size)[0],
                        chunk_size=chunk)]
    tb = pack_trees(sers, S, chunk_size=chunk)
    extra = None
    if cfg.frontend is not None:
        rng = np.random.default_rng(seed)
        extra = rng.normal(size=(tb.tokens.shape[0], cfg.frontend_len,
                                 cfg.d_model)).astype(np.float32)
    return prepare_batch(cfg, tb, extra)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.key(0))
    batch = _smoke_batch(cfg)
    loss, metrics = loss_and_metrics(cfg, params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))

    opt = OptimizerConfig(total_steps=10, warmup_steps=2)
    step = make_train_step(cfg, opt, donate=False)
    params2, opt_state, m = step(params, init_opt_state(params), batch)
    assert np.isfinite(float(m["total"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.family == "audio":
        pytest.skip("audio decode smoke covered in test_serve.py")
    from repro.serve.session import DecodeSession
    params = init_params(cfg, jax.random.key(0))
    B, T = 2, 8
    sess = DecodeSession.create(cfg, params, batch=B, buf_len=T)
    rng = np.random.default_rng(0)
    for _ in range(3):
        toks = rng.integers(0, cfg.vocab_size, B).astype(np.int32)
        logits = sess.step(toks)
        assert logits.shape == (B, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits)).all()
    assert sess.t == 3 and sess.stats.decode_tokens == 3 * B


def test_all_full_configs_construct():
    """Full (paper-scale) configs build + param counts are in the right
    ballpark (ShapeDtypeStruct only — no allocation)."""
    import repro.models.transformer as tf
    expected = {
        "qwen3-8b": (6e9, 11e9),
        "kimi-k2-1t-a32b": (0.8e12, 1.4e12),
        "nemotron-4-340b": (3.0e11, 4.2e11),
        "qwen3-32b": (2.6e10, 4.0e10),
        "llama4-scout-17b-a16e": (0.9e11, 1.4e11),
    }
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda key: tf.init_params(cfg, key), jax.random.key(0))
        n = sum(np.prod(l.shape) for l in jax.tree.leaves(shapes))
        assert n > 1e8, (arch, n)
        if cfg.name in expected:
            lo, hi = expected[cfg.name]
            assert lo <= n <= hi, (arch, f"{n:.3e}")
