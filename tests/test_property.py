"""Property-based tests (hypothesis) on the tree-serialization invariants.

These are the system's load-bearing invariants: every model-layer
adaptation (mask, positions, state routing, λ weights) is derived from the
serialization arrays, so if these hold for arbitrary trees, the layer
equivalences reduce to the (separately tested) layer math.
"""
import numpy as np
import pytest

from repro.core.packing import pack_linear_paths, pack_trees
from repro.core.tree import (TrajectoryTree, TreeNode, serialize_tree,
                             visibility_mask)
from repro.models.layers import prev_powers

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


@st.composite
def trees(draw, max_depth=4, max_children=3, max_seg=6):
    def node(depth):
        L = draw(st.integers(1, max_seg))
        toks = draw(st.lists(st.integers(0, 255), min_size=L, max_size=L))
        n = TreeNode(tokens=np.asarray(toks, np.int32))
        if depth < max_depth:
            k = draw(st.integers(0, max_children))
            if k >= 2 or (k == 1 and draw(st.booleans())):
                n.children = [node(depth + 1) for _ in range(k)]
        return n

    return TrajectoryTree(root=node(0))


@given(trees())
@settings(max_examples=40, deadline=None)
def test_serialization_counts_and_weights(tree):
    ser = serialize_tree(tree)
    # every token exactly once
    assert ser.n == tree.num_unique_tokens()
    assert ser.valid.all()
    # λ sums: Σ_t λ_t  ==  Σ_paths (len(path)·1/K) == flat/K for all-trained
    K = tree.num_leaves()
    assert ser.num_paths == K
    flat = tree.flat_tokens()
    np.testing.assert_allclose(ser.weight.sum(), flat / K, rtol=1e-5)
    # POR consistency (Eq. 12)
    por = tree.por()
    assert 0 <= por < 1
    np.testing.assert_allclose(por, 1 - ser.n / flat, rtol=1e-9)


@given(trees())
@settings(max_examples=30, deadline=None)
def test_mask_is_tree_partial_order(tree):
    ser = serialize_tree(tree)
    m = visibility_mask(ser)
    n = ser.n
    # diagonal visible, causal
    assert np.diag(m).all()
    assert not np.triu(m, 1).any()
    # transitivity: visible(i,j) ∧ visible(j,k) ⇒ visible(i,k)
    # (m is a partial order restricted to ancestor chains)
    m_int = m.astype(np.int32)
    two_step = (m_int @ m_int) > 0
    assert not (two_step & ~m).any()
    # each token's visible set is exactly its path prefix: count equals
    # depth position + 1
    np.testing.assert_array_equal(m.sum(1), ser.pos_ids + 1)


@given(trees())
@settings(max_examples=30, deadline=None)
def test_prev_chain_matches_positions(tree):
    ser = serialize_tree(tree)
    # following prev_idx from any token walks positions down by exactly 1
    prev = ser.prev_idx
    pos = ser.pos_ids
    has_prev = prev >= 0
    np.testing.assert_array_equal(pos[has_prev] - 1, pos[prev[has_prev]])
    # prev^k power chains agree with k applications
    pp = prev_powers(prev[None], 3)[0]
    for t in range(ser.n):
        cur = t
        for j in range(3):
            cur = prev[cur] if cur >= 0 else -1
            assert pp[t, j] == cur


@given(trees(), st.integers(2, 8))
@settings(max_examples=25, deadline=None)
def test_chunk_alignment_and_parent_map(tree, chunk):
    ser = serialize_tree(tree, chunk_size=chunk)
    assert ser.n % chunk == 0
    cp = ser.chunk_parent_map(chunk)
    C = ser.n // chunk
    assert cp.shape == (C,)
    # parents always precede children (DFS pre-order property the SSM
    # routing depends on)
    for c in range(C):
        assert cp[c] < c
    # padding is inert
    assert (ser.kv_last[~ser.valid] == -1).all()
    assert (ser.weight[~ser.valid] == 0).all()


@given(trees())
@settings(max_examples=20, deadline=None)
def test_pack_weight_conservation(tree):
    """Packing preserves Σλ (Eq. 2/3: tree and path serializations carry
    identical total loss weight)."""
    ser = serialize_tree(tree)
    S = max(64, ((ser.n + 63) // 64) * 64)
    tb = pack_trees([ser], S)
    lb = pack_linear_paths([tree.linearize_paths()],
                           max(S, ((tree.max_path_tokens() + 63) // 64)
                               * 64))
    w_tree = tb.weight[tb.prev_idx >= 0].sum()
    w_lin = lb.weight[lb.prev_idx >= 0].sum()
    np.testing.assert_allclose(w_tree, w_lin, rtol=1e-5)
