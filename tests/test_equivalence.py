"""Core paper claim (§3.1–3.2): tree-training loss and gradients equal the
per-branch sep-avg baseline, for every architecture family.

Baseline = linearize every root-to-leaf path, pack, standard causal masks.
Tree     = DFS serialization + tree attention mask + depth positions +
           (for SSM) tree state routing + path-predecessor conv/shift +
           λ_t = g_t/K loss weights.
Both are fed through the *same* model code; only the metadata differs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import branching_tree, tiny_cfg
from repro.core.packing import pack_linear_paths, pack_trees
from repro.core.tree import serialize_tree
from repro.data.synthetic import trees_for_batch
from repro.models.model import (init_params, loss_and_metrics, needs_chunks,
                                prepare_batch)

FAMILIES = ["dense", "moe", "ssm_rwkv6", "ssm_mamba2", "ssm_gdn", "hybrid"]


def _batches(cfg, trees, chunk):
    tb = pack_trees([serialize_tree(t, chunk_size=chunk) for t in trees],
                    512, chunk_size=chunk)
    lb = pack_linear_paths([t.linearize_paths() for t in trees], 1024,
                           chunk_size=chunk)
    return prepare_batch(cfg, tb), prepare_batch(cfg, lb)


@pytest.mark.parametrize("family", FAMILIES)
def test_loss_equivalence(family):
    cfg = tiny_cfg(family)
    chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    params = init_params(cfg, jax.random.key(0))
    trees = trees_for_batch(2, n_trees=3, kind="random", vocab_size=89)
    assert any(t.num_leaves() > 1 for t in trees)
    bt, bl = _batches(cfg, trees, chunk)
    lt, _ = loss_and_metrics(cfg, params, bt)
    ll, _ = loss_and_metrics(cfg, params, bl)
    np.testing.assert_allclose(float(lt), float(ll), rtol=5e-6)


@pytest.mark.parametrize("family", ["dense", "ssm_mamba2", "ssm_rwkv6"])
def test_grad_equivalence(family):
    """Eq. (5): ∂L_tree/∂θ = ∂L_sep_avg/∂θ (float32, App. B.8 tolerance)."""
    cfg = tiny_cfg(family)
    chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    params = init_params(cfg, jax.random.key(0))
    trees = trees_for_batch(2, n_trees=2, kind="random", vocab_size=89)
    bt, bl = _batches(cfg, trees, chunk)
    gt = jax.grad(lambda p: loss_and_metrics(cfg, p, bt)[0])(params)
    gl = jax.grad(lambda p: loss_and_metrics(cfg, p, bl)[0])(params)

    def rel(a, b):
        denom = jnp.abs(b).max() + 1e-9
        return float(jnp.abs(a - b).max() / denom)

    max_rel = max(jax.tree.leaves(jax.tree.map(rel, gt, gl)))
    assert max_rel < 1e-4, max_rel   # paper App. B.8: < 1e-4 in f32


@pytest.mark.parametrize("family", ["audio", "vlm"])
def test_multimodal_equivalence(family):
    cfg = tiny_cfg(family)
    tree = branching_tree(5, min_leaves=4)
    params = init_params(cfg, jax.random.key(1))
    tb = pack_trees([serialize_tree(tree)], 128)
    lb = pack_linear_paths([tree.linearize_paths()], 128)
    rng = np.random.default_rng(0)
    ext = rng.normal(size=(1, cfg.frontend_len, cfg.d_model)).astype(
        np.float32)
    bt = prepare_batch(cfg, tb, ext)
    bl = prepare_batch(cfg, lb, np.repeat(ext, lb.tokens.shape[0], 0))
    lt, _ = loss_and_metrics(cfg, params, bt)
    ll, _ = loss_and_metrics(cfg, params, bl)
    np.testing.assert_allclose(float(lt), float(ll), rtol=5e-6)


def test_rl_advantage_weighting():
    """λ_t with per-token advantages (policy-gradient objective, §3.1)."""
    cfg = tiny_cfg("dense")
    tree = branching_tree(0, min_leaves=3)
    rng = np.random.default_rng(1)
    for n in tree.nodes():
        n.advantage = rng.normal(size=n.size).astype(np.float32)
    params = init_params(cfg, jax.random.key(0))
    bt = prepare_batch(cfg, pack_trees([serialize_tree(tree)], 128))
    bl = prepare_batch(cfg, pack_linear_paths([tree.linearize_paths()], 256))
    lt, _ = loss_and_metrics(cfg, params, bt)
    ll, _ = loss_and_metrics(cfg, params, bl)
    np.testing.assert_allclose(float(lt), float(ll), rtol=5e-6)


def test_uniform_loss_mode_differs_but_finite():
    """§3.1: λ_t = 1 is a *different* objective — valid, not equal to
    sep-avg on branching trees."""
    cfg = tiny_cfg("dense")
    tree = branching_tree(0, min_leaves=3)
    params = init_params(cfg, jax.random.key(0))
    b_sep = prepare_batch(cfg, pack_trees([serialize_tree(tree)], 128))
    b_uni = prepare_batch(cfg, pack_trees(
        [serialize_tree(tree, loss_mode="uniform")], 128))
    l_sep, _ = loss_and_metrics(cfg, params, b_sep)
    l_uni, _ = loss_and_metrics(cfg, params, b_uni)
    assert np.isfinite(float(l_uni))
    assert abs(float(l_sep) - float(l_uni)) > 1e-3


def test_gradient_restoration_shared_prefix_sums_branches():
    """Gradient Restoration (paper §3.3) at the kernel level: with the
    fused Pallas vjp, dk/dv on a shared prefix equal the *sum* of the
    gradients that prefix receives in each standalone branch pass, and
    dq on every branch equals its standalone dq."""
    from repro.kernels.ops import tree_attention

    rng = np.random.default_rng(43)
    P, L, H, hd = 16, 24, 4, 16          # prefix len, branch len
    S = P + 2 * L                        # DFS: [prefix, branch1, branch2]
    scale = hd ** -0.5

    def rand(*shape):
        return jnp.asarray(rng.normal(size=shape).astype(np.float32))

    q = rand(1, S, H, hd)
    k = rand(1, S, H, hd)
    v = rand(1, S, H, hd)
    # gradient flows in from branch tokens only — a per-branch run counts
    # its own prefix-query loss once, so summing two runs would double it;
    # restoration is about what the *branches* send back to the prefix.
    do = rand(1, S, H, hd).at[:, :P].set(0.0)

    # tree mask: prefix visible to everything, each branch only to itself
    kv_last = np.zeros((1, S), np.int32)
    kv_last[0, :P] = S - 1
    kv_last[0, P:P + L] = P + L - 1
    kv_last[0, P + L:] = S - 1
    kv_last = jnp.asarray(kv_last)
    _, vjp = jax.vjp(lambda q_, k_, v_:
                     tree_attention(q_, k_, v_, kv_last, scale, 8, 8),
                     q, k, v)
    dq_t, dk_t, dv_t = vjp(do)

    # standalone branch passes: rows [prefix + branch_b], plain causal
    acc_dk = np.zeros((P, H, hd), np.float32)
    acc_dv = np.zeros((P, H, hd), np.float32)
    for b0 in (P, P + L):
        sel = np.r_[0:P, b0:b0 + L]
        qb, kb, vb, dob = (x[:, sel] for x in (q, k, v, do))
        kl_b = jnp.full((1, P + L), P + L - 1, jnp.int32)
        _, vjp_b = jax.vjp(lambda q_, k_, v_:
                           tree_attention(q_, k_, v_, kl_b, scale, 8, 8),
                           qb, kb, vb)
        dqb, dkb, dvb = vjp_b(dob)
        acc_dk += np.asarray(dkb[0, :P])
        acc_dv += np.asarray(dvb[0, :P])
        np.testing.assert_allclose(np.asarray(dq_t[0, b0:b0 + L]),
                                   np.asarray(dqb[0, P:]),
                                   atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(dk_t[0, :P]), acc_dk,
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(dv_t[0, :P]), acc_dv,
                               atol=1e-5, rtol=1e-5)


def test_tree_forward_equals_each_branch_forward():
    """Forward equivalence (Eq. 6): per-token log-prob in the DFS pass
    matches the token's log-prob in its standalone branch pass."""
    from repro.models.model import forward
    cfg = tiny_cfg("dense")
    tree = branching_tree(3, min_leaves=3)
    params = init_params(cfg, jax.random.key(0))
    ser = serialize_tree(tree)
    bt = prepare_batch(cfg, pack_trees([ser], 128))
    h_tree, _ = forward(cfg, params, bt)

    # DFS index of every token per path, mapped against standalone runs
    paths = tree.linearize_paths()
    # reconstruct each path's DFS indices by walking nodes
    node_tok_ranges = [(int(s), int(e)) for s, e in
                       zip(ser.node_start, ser.node_end)]
    # walk tree collecting node ids per path
    ids_per_path = []

    def rec(node, nid_counter, acc):
        nid = nid_counter[0]
        nid_counter[0] += 1
        acc = acc + [nid]
        if not node.children:
            ids_per_path.append(acc)
        for c in node.children:
            rec(c, nid_counter, acc)

    rec(tree.root, [0], [])
    for path_nodes, lin in zip(ids_per_path, paths):
        lb = pack_linear_paths([[lin]], 128)
        bl = prepare_batch(cfg, lb)
        h_lin, _ = forward(cfg, params, bl)
        off = 0
        for nid in path_nodes:
            s, e = node_tok_ranges[nid]
            n = e - s
            np.testing.assert_allclose(
                np.asarray(h_tree[0, s:e]), np.asarray(h_lin[0, off:off + n]),
                atol=2e-5, rtol=2e-5)
            off += n
