"""Core paper claim (§3.1–3.2): tree-training loss and gradients equal the
per-branch sep-avg baseline, for every architecture family.

Baseline = linearize every root-to-leaf path, pack, standard causal masks.
Tree     = DFS serialization + tree attention mask + depth positions +
           (for SSM) tree state routing + path-predecessor conv/shift +
           λ_t = g_t/K loss weights.
Both are fed through the *same* model code; only the metadata differs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import branching_tree, tiny_cfg
from repro.core.packing import pack_linear_paths, pack_trees
from repro.core.tree import serialize_tree
from repro.data.synthetic import trees_for_batch
from repro.models.model import (init_params, loss_and_metrics, needs_chunks,
                                prepare_batch)

FAMILIES = ["dense", "moe", "ssm_rwkv6", "ssm_mamba2", "ssm_gdn", "hybrid"]


def _batches(cfg, trees, chunk):
    tb = pack_trees([serialize_tree(t, chunk_size=chunk) for t in trees],
                    512, chunk_size=chunk)
    lb = pack_linear_paths([t.linearize_paths() for t in trees], 1024,
                           chunk_size=chunk)
    return prepare_batch(cfg, tb), prepare_batch(cfg, lb)


@pytest.mark.parametrize("family", FAMILIES)
def test_loss_equivalence(family):
    cfg = tiny_cfg(family)
    chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    params = init_params(cfg, jax.random.key(0))
    trees = trees_for_batch(2, n_trees=3, kind="random", vocab_size=89)
    assert any(t.num_leaves() > 1 for t in trees)
    bt, bl = _batches(cfg, trees, chunk)
    lt, _ = loss_and_metrics(cfg, params, bt)
    ll, _ = loss_and_metrics(cfg, params, bl)
    np.testing.assert_allclose(float(lt), float(ll), rtol=5e-6)


@pytest.mark.parametrize("family", ["dense", "ssm_mamba2", "ssm_rwkv6"])
def test_grad_equivalence(family):
    """Eq. (5): ∂L_tree/∂θ = ∂L_sep_avg/∂θ (float32, App. B.8 tolerance)."""
    cfg = tiny_cfg(family)
    chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    params = init_params(cfg, jax.random.key(0))
    trees = trees_for_batch(2, n_trees=2, kind="random", vocab_size=89)
    bt, bl = _batches(cfg, trees, chunk)
    gt = jax.grad(lambda p: loss_and_metrics(cfg, p, bt)[0])(params)
    gl = jax.grad(lambda p: loss_and_metrics(cfg, p, bl)[0])(params)

    def rel(a, b):
        denom = jnp.abs(b).max() + 1e-9
        return float(jnp.abs(a - b).max() / denom)

    max_rel = max(jax.tree.leaves(jax.tree.map(rel, gt, gl)))
    assert max_rel < 1e-4, max_rel   # paper App. B.8: < 1e-4 in f32


@pytest.mark.parametrize("family", ["audio", "vlm"])
def test_multimodal_equivalence(family):
    cfg = tiny_cfg(family)
    tree = branching_tree(5, min_leaves=4)
    params = init_params(cfg, jax.random.key(1))
    tb = pack_trees([serialize_tree(tree)], 128)
    lb = pack_linear_paths([tree.linearize_paths()], 128)
    rng = np.random.default_rng(0)
    ext = rng.normal(size=(1, cfg.frontend_len, cfg.d_model)).astype(
        np.float32)
    bt = prepare_batch(cfg, tb, ext)
    bl = prepare_batch(cfg, lb, np.repeat(ext, lb.tokens.shape[0], 0))
    lt, _ = loss_and_metrics(cfg, params, bt)
    ll, _ = loss_and_metrics(cfg, params, bl)
    np.testing.assert_allclose(float(lt), float(ll), rtol=5e-6)


def test_rl_advantage_weighting():
    """λ_t with per-token advantages (policy-gradient objective, §3.1)."""
    cfg = tiny_cfg("dense")
    tree = branching_tree(0, min_leaves=3)
    rng = np.random.default_rng(1)
    for n in tree.nodes():
        n.advantage = rng.normal(size=n.size).astype(np.float32)
    params = init_params(cfg, jax.random.key(0))
    bt = prepare_batch(cfg, pack_trees([serialize_tree(tree)], 128))
    bl = prepare_batch(cfg, pack_linear_paths([tree.linearize_paths()], 256))
    lt, _ = loss_and_metrics(cfg, params, bt)
    ll, _ = loss_and_metrics(cfg, params, bl)
    np.testing.assert_allclose(float(lt), float(ll), rtol=5e-6)


def test_uniform_loss_mode_differs_but_finite():
    """§3.1: λ_t = 1 is a *different* objective — valid, not equal to
    sep-avg on branching trees."""
    cfg = tiny_cfg("dense")
    tree = branching_tree(0, min_leaves=3)
    params = init_params(cfg, jax.random.key(0))
    b_sep = prepare_batch(cfg, pack_trees([serialize_tree(tree)], 128))
    b_uni = prepare_batch(cfg, pack_trees(
        [serialize_tree(tree, loss_mode="uniform")], 128))
    l_sep, _ = loss_and_metrics(cfg, params, b_sep)
    l_uni, _ = loss_and_metrics(cfg, params, b_uni)
    assert np.isfinite(float(l_uni))
    assert abs(float(l_sep) - float(l_uni)) > 1e-3


def test_tree_forward_equals_each_branch_forward():
    """Forward equivalence (Eq. 6): per-token log-prob in the DFS pass
    matches the token's log-prob in its standalone branch pass."""
    from repro.models.model import forward
    cfg = tiny_cfg("dense")
    tree = branching_tree(3, min_leaves=3)
    params = init_params(cfg, jax.random.key(0))
    ser = serialize_tree(tree)
    bt = prepare_batch(cfg, pack_trees([ser], 128))
    h_tree, _ = forward(cfg, params, bt)

    # DFS index of every token per path, mapped against standalone runs
    paths = tree.linearize_paths()
    # reconstruct each path's DFS indices by walking nodes
    node_tok_ranges = [(int(s), int(e)) for s, e in
                       zip(ser.node_start, ser.node_end)]
    # walk tree collecting node ids per path
    ids_per_path = []

    def rec(node, nid_counter, acc):
        nid = nid_counter[0]
        nid_counter[0] += 1
        acc = acc + [nid]
        if not node.children:
            ids_per_path.append(acc)
        for c in node.children:
            rec(c, nid_counter, acc)

    rec(tree.root, [0], [])
    for path_nodes, lin in zip(ids_per_path, paths):
        lb = pack_linear_paths([[lin]], 128)
        bl = prepare_batch(cfg, lb)
        h_lin, _ = forward(cfg, params, bl)
        off = 0
        for nid in path_nodes:
            s, e = node_tok_ranges[nid]
            n = e - s
            np.testing.assert_allclose(
                np.asarray(h_tree[0, s:e]), np.asarray(h_lin[0, off:off + n]),
                atol=2e-5, rtol=2e-5)
            off += n
