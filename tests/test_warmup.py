"""AOT warmup engine (train/warmup): the precompiled-executable story.

  - planner pre-warm on a real stream → the engine replays with ZERO
    retraces and grads BIT-IDENTICAL to the uncached dispatch path;
  - the pipeline compiles a step's signatures before the engine
    consumes the step (prewarm overlap);
  - out-of-universe signatures take the honest slow path: a logged
    warning and a synchronous compile, never a crash;
  - the warmup compile list is exactly the enumerable signature
    universe, ordered packed-first then by simulated hit frequency;
  - the persistent jax compilation cache round-trips across fresh
    processes: the second process writes 0 new cache modules;
  - satellite: the cost model charges wave signatures at
    ``wave_compile`` (not ``compile_miss``) and the planner's shared
    ``CompileCacheSim`` counts per-signature hit frequency.
"""
import json
import logging
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.analysis.signatures import SignatureUniverse, step_signatures
from repro.core.plan_cost import (CompileCacheSim, CostWeights,
                                  packed_signature, score_packing,
                                  wave_signature)
from repro.data.loader import LoaderConfig
from repro.models.model import init_params
from repro.train.engine import TreeTrainEngine
from repro.train.exec_cache import ExecutableCache, arg_fingerprint, exec_key
from repro.train.optimizer import OptimizerConfig
from repro.train.planner import PlannerConfig, plans
from repro.train.warmup import (AOTWarmupService, compile_cache_files,
                                universe_signatures)


def _lc(**kw):
    base = dict(seq_len=64, batch_rows=2, trees_per_batch=2, mode="tree",
                kind="agentic", seed=11, auto_partition=True, capacity=32,
                gen_kwargs=dict(turn_len_range=(6, 14), num_turns=2))
    base.update(kw)
    return LoaderConfig(**base)


# ---------------------------------------------------------------------------
# Host-only: enumeration, ordering, cost model (no compiles)
# ---------------------------------------------------------------------------

def test_universe_signatures_match_enumeration():
    """The warmup compile list and SignatureUniverse.enumerate_signatures
    are independent implementations — they must agree exactly, and every
    entry must pass the runtime ``contains`` check the engine applies on
    a cache miss (the treelint warmup pass proves the same invariant on
    the lint configs; this pins it at unit scope)."""
    lc = _lc()
    pc = PlannerConfig()
    caps = (16, 2, 16, 2)
    universe = SignatureUniverse(
        seq_len=lc.seq_len, batch_rows=lc.batch_rows,
        num_replicas=pc.num_replicas, max_rows=lc.batch_rows,
        capacity=lc.capacity)
    warm = universe_signatures(lc, pc, caps)
    enum = universe.enumerate_signatures(*caps)
    assert set(warm) == set(enum)
    assert len(warm) == len(set(warm)), "duplicate signatures in list"
    for sig in warm:
        ok, why = universe.contains(sig)
        assert ok, f"{sig}: {why}"
    # the enumeration is a strict subset of the loose bounding box
    assert len(enum) <= universe.count(*caps)


def test_signature_list_priority():
    """Packed compiles first (every step needs it), then wave buckets in
    descending simulated-hit-frequency order — the hottest bucket is warm
    soonest when warmup runs on a background thread."""
    lc = _lc()
    cfg = tiny_cfg("dense")
    params = init_params(cfg, jax.random.key(0))
    sim = CompileCacheSim()
    hot = wave_signature(2, lc.seq_len, 8, 1, 8, 1)
    cold = wave_signature(2, lc.seq_len, 8, 2, 8, 1)
    for _ in range(5):
        sim.commit([hot])
    sim.commit([cold])
    svc = AOTWarmupService(cfg, lc, params=params, sim=sim,
                           caps=(16, 2, 16, 2))
    sigs = svc.signature_list()
    assert sigs[0][0] == "packed"
    waves = [s for s in sigs if s[0] == "wave"]
    assert waves.index(hot) < waves.index(cold)
    # budget keeps the hottest buckets: each signature costs two
    # executables (fwd+bwd), so max_compiles=4 admits two signatures
    svc.max_compiles = 4
    kept = list(svc._budgeted(sigs))
    assert len(kept) == 2 and sigs[0] in kept and hot in kept


def test_cost_model_charges_wave_compile():
    """score_packing bills a NEW wave signature at ``wave_compile`` and a
    new packed signature at ``compile_miss`` — a second scoring against
    a cache that has seen them charges neither."""
    w = CostWeights(pad=0.0, compile_miss=100.0, wave_compile=7.0,
                    live_block=0.0, comm_byte=0.0)
    psig = packed_signature(2, 64)
    wsig = wave_signature(2, 64, 8, 1, 8, 1)
    cache = CompileCacheSim()
    cost = score_packing([], 64, signatures=[psig, wsig], cache=cache,
                         weights=w)
    assert cost.total == pytest.approx(107.0)
    assert cost.new_signatures == 2
    cache.commit([psig, wsig])
    again = score_packing([], 64, signatures=[psig, wsig], cache=cache,
                          weights=w)
    assert again.total == pytest.approx(0.0)
    assert again.new_signatures == 0
    assert cache.freq[psig] == 1 and cache.freq[wsig] == 1
    cache.commit([wsig])
    assert cache.freq[wsig] == 2


def test_exec_key_fingerprints_shapes_not_values():
    """Python-int leaves fingerprint by TYPE (weak-typed scalars: one
    executable serves every value) while array leaves fingerprint by
    shape+dtype — a changed shape is a different executable."""
    sig = packed_signature(2, 64)
    a = {"tokens": np.zeros((2, 64), np.int32), "num_trees": 3}
    b = {"tokens": np.zeros((2, 64), np.int32), "num_trees": 7}
    c = {"tokens": np.zeros((2, 128), np.int32), "num_trees": 3}
    assert exec_key("packed", sig, (a,)) == exec_key("packed", sig, (b,))
    assert exec_key("packed", sig, (a,)) != exec_key("packed", sig, (c,))
    assert arg_fingerprint((a,)) == arg_fingerprint((b,))


# ---------------------------------------------------------------------------
# Compiled: prewarm overlap, zero retraces, bit-identical grads
# ---------------------------------------------------------------------------

def test_prewarm_stream_zero_retraces_bitident_grads():
    """Planner pipeline with ``warmup=svc``: every step's signatures are
    compiled BEFORE the engine consumes the step (prewarm overlap), the
    replay takes 0 retraces with 0 exposed compile wait, and the grads
    are bit-identical to an engine running the plain jit dispatch path
    (no executable cache) — AOT compilation is a pure latency move."""
    cfg = tiny_cfg("dense")
    lc = _lc()
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=2)
    params = init_params(cfg, jax.random.key(1))
    svc = AOTWarmupService(cfg, lc, params=params, opt_cfg=opt_cfg,
                           donate=False)
    eng = TreeTrainEngine(cfg, opt_cfg, donate=False,
                          exec_cache=svc.cache, universe=svc.universe)
    ref = TreeTrainEngine(cfg, opt_cfg, donate=False)
    steps = 0
    for ps in plans(cfg, lc, 2, warmup=svc):
        plan = ps.execution_plan()
        # prewarm overlap: the pipeline's build thread already compiled
        # this step's signatures before handing the plan over
        missing = set(step_signatures(ps)) - svc.cache.signatures()
        assert not missing, f"not prewarmed: {missing}"
        g_aot, s_aot = eng.accumulate(params, plan)
        g_ref, s_ref = ref.accumulate(params, plan)
        for a, b in zip(jax.tree.leaves(g_aot), jax.tree.leaves(g_ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(s_aot),
                                      np.asarray(s_ref))
        steps += 1
    assert steps == 2
    assert eng.retraces == 0, f"{eng.retraces} retraces after prewarm"
    assert eng.compile_wait_s == 0.0
    assert not svc.errors, svc.errors[:3]
    assert svc.prewarmed == len(svc.cache) > 0


def test_out_of_universe_sig_warns_not_crashes(caplog):
    """A signature outside the enumerable universe compiles on the
    honest synchronous slow path with a logged warning naming why the
    planner escaped — never an exception."""
    cfg = tiny_cfg("dense")
    # packed-only stream (no partitioning) keeps this to two compiles
    lc = _lc(auto_partition=False, capacity=None, mode="tree",
             gen_kwargs=dict(turn_len_range=(4, 8), num_turns=1))
    # a universe whose caps exclude the real batch: batch_rows=1 makes
    # the actual packed (2, 64) signature out-of-universe
    universe = SignatureUniverse(seq_len=lc.seq_len, batch_rows=1,
                                 num_replicas=1, max_rows=1, capacity=1)
    ok, _ = universe.contains(packed_signature(2, lc.seq_len))
    assert not ok
    eng = TreeTrainEngine(cfg, donate=False, exec_cache=ExecutableCache(),
                          universe=universe)
    params = init_params(cfg, jax.random.key(2))
    ps = next(iter(plans(cfg, lc, 1)))
    with caplog.at_level(logging.WARNING, logger="repro.train.engine"):
        grads, scal = eng.accumulate(params, ps.execution_plan())
    assert eng.retraces >= 1
    assert any("out-of-universe" in r.message for r in caplog.records)
    assert np.isfinite(float(np.asarray(scal)[0]))
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(grads))


# ---------------------------------------------------------------------------
# Persistent compilation cache across fresh processes
# ---------------------------------------------------------------------------

_PERSIST_SNIPPET = """
import json, sys
import jax, jax.numpy as jnp
from repro.train.warmup import configure_compile_cache, compile_cache_files
d = configure_compile_cache(sys.argv[1])
before = compile_cache_files(d)
f = jax.jit(lambda x: (x @ x.T).sum() + 1.0)
out = float(f(jnp.arange(48.0 * 16).reshape(48, 16)))
print(json.dumps({"new": compile_cache_files(d) - before, "out": out}))
"""


def test_persistent_cache_roundtrip(tmp_path):
    """configure_compile_cache wires jax's persistent compilation cache:
    a second FRESH process compiling the same computation writes zero
    new cache modules and reproduces the same value."""
    cache_dir = str(tmp_path / "jax-cache")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")
    env.pop("XLA_FLAGS", None)
    outs = []
    for _ in range(2):
        r = subprocess.run([sys.executable, "-c", _PERSIST_SNIPPET,
                            cache_dir], env=env, capture_output=True,
                           text=True, timeout=300)
        assert r.returncode == 0, r.stderr[-2000:]
        outs.append(json.loads(r.stdout.strip().splitlines()[-1]))
    assert outs[0]["new"] > 0, "first process persisted nothing"
    assert outs[1]["new"] == 0, \
        f"warm restart recompiled {outs[1]['new']} modules"
    assert outs[1]["out"] == outs[0]["out"]
    assert compile_cache_files(cache_dir) == outs[0]["new"]
