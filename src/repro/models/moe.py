"""Mixture-of-Experts layer: top-k router, capacity-based scatter dispatch,
optional shared experts, load-balance + z auxiliary losses.

Expert-parallel: every expert-indexed tensor ([E, ...]) is sharded over the
"model" mesh axis; the dispatch/combine reshards ([N,D]→[E,C,D] and back)
are where GSPMD inserts the all-to-all — the same communication pattern as
Megatron/DeepSeek expert parallelism, derived instead of hand-written.

Scatter/gather dispatch is O(N) memory (no [N,E,C] one-hots), which is what
makes kimi-k2's 384 experts lowerable.  On real TPU the expert GEMMs would
use a megablox/ragged-dot kernel; the dispatch math is identical.

Tree Training interaction (paper §5): routing is per-token, so computing
each unique token once routes it once — identical to what every per-branch
pass would compute for the shared prefix.  No adaptation needed beyond the
attention/SSM fixes; the router sees DFS rows transparently.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import MoECfg
from repro.models.layers import _dense_init


def init_moe(key, cfg: MoECfg, d_model: int, activation: str,
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    E, F = cfg.num_experts, cfg.d_expert
    p = {
        "router": _dense_init(ks[0], (d_model, E), scale=0.02,
                              dtype=jnp.float32),
        "wo": _dense_init(ks[3], (E, F, d_model), dtype=dtype),
    }
    if activation == "swiglu":
        p["wi_gate"] = _dense_init(ks[1], (E, d_model, F), dtype=dtype)
        p["wi_up"] = _dense_init(ks[2], (E, d_model, F), dtype=dtype)
    else:
        p["wi_up"] = _dense_init(ks[2], (E, d_model, F), dtype=dtype)
    if cfg.num_shared_experts:
        Fs = F * cfg.num_shared_experts
        if activation == "swiglu":
            p["shared_wi_gate"] = _dense_init(ks[4], (d_model, Fs),
                                              dtype=dtype)
        p["shared_wi_up"] = _dense_init(ks[4], (d_model, Fs), dtype=dtype)
        p["shared_wo"] = _dense_init(ks[5], (Fs, d_model), dtype=dtype)
    return p


def _act(p: dict, x: jax.Array, activation: str, prefix: str = "") -> jax.Array:
    if activation == "swiglu":
        return jax.nn.silu(x @ p[prefix + "wi_gate"]) * (x @ p[prefix + "wi_up"])
    h = x @ p[prefix + "wi_up"]
    if activation == "squared_relu":
        r = jax.nn.relu(h)
        return r * r
    return jax.nn.relu(h)


def moe(params: dict, cfg: MoECfg, x: jax.Array, valid: jax.Array,
        activation: str) -> tuple[jax.Array, dict]:
    """x: [B, S, D]; valid: [B, S] bool.  Returns (y, aux_losses)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    N = B * S
    xf = x.reshape(N, D)
    vmask = valid.reshape(N)

    logits = (xf @ params["router"]).astype(jnp.float32)      # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                    # [N, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    C = int(max(1, round(N * K / E * cfg.capacity_factor)))
    # position of each (token, slot) within its expert queue
    oh = jax.nn.one_hot(top_e, E, dtype=jnp.int32)            # [N, K, E]
    oh = oh * vmask[:, None, None].astype(jnp.int32)          # pads don't queue
    pos = jnp.cumsum(oh.reshape(N * K, E), axis=0) - 1        # [N·K, E]
    pos = jnp.take_along_axis(pos, top_e.reshape(N * K, 1), axis=1)[:, 0]
    e_flat = top_e.reshape(N * K)
    keep = (pos >= 0) & (pos < C) & jnp.repeat(vmask, K)
    pos_c = jnp.where(keep, pos, C)                           # C = drop slot

    # dispatch: [E, C+1, D] (last row is the spill bucket)
    xb = jnp.zeros((E, C + 1, D), x.dtype)
    src = jnp.repeat(xf, K, axis=0)                           # [N·K, D]
    xb = xb.at[e_flat, pos_c].add(src, mode="drop")
    xb = xb[:, :C]

    # expert FFN (einsum over stacked experts)
    if "wi_gate" in params:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, params["wi_gate"])) \
            * jnp.einsum("ecd,edf->ecf", xb, params["wi_up"])
    else:
        h = jnp.einsum("ecd,edf->ecf", xb, params["wi_up"])
        if activation == "squared_relu":
            r = jax.nn.relu(h)
            h = r * r
        else:
            h = jax.nn.relu(h)
    yb = jnp.einsum("ecf,efd->ecd", h, params["wo"])          # [E, C, D]

    # combine
    yb = jnp.concatenate([yb, jnp.zeros((E, 1, D), yb.dtype)], axis=1)
    gathered = yb[e_flat, pos_c]                              # [N·K, D]
    w = jnp.where(keep, top_p.reshape(N * K), 0.0).astype(x.dtype)
    y = (gathered * w[:, None]).reshape(N, K, D).sum(axis=1)

    if "shared_wi_up" in params:
        y = y + _act(params, xf, activation, "shared_") @ params["shared_wo"]

    # aux losses (over valid tokens)
    nv = jnp.maximum(vmask.sum(), 1).astype(jnp.float32)
    frac = (oh.sum(1).astype(jnp.float32) * vmask[:, None]).sum(0) / (nv * K)
    pmean = (probs * vmask[:, None]).sum(0) / nv
    aux = {
        "load_balance": E * jnp.sum(frac * pmean) * cfg.router_aux_weight,
        "router_z": (jnp.where(vmask,
                               jax.nn.logsumexp(logits, -1) ** 2, 0.0).sum()
                     / nv) * cfg.router_z_weight,
    }
    return y.reshape(B, S, D), aux
