"""Tree-aware GQA attention.

The tree attention mask (paper §3.2, Fig. 3) is driven entirely by the
per-key bound ``kv_last``:   visible(i, j) ⇔ j ≤ i ∧ kv_last[j] ≥ i.
Plain causal batches are the special case ``kv_last[j] = end-of-segment``,
so baseline and tree mode share one code path.

Implementations:
  - 'ref'     : materialized mask (oracle; small shapes / tests)
  - 'chunked' : lax.scan over KV blocks with online softmax — bounded
                memory; the XLA path used for dry-runs and large shapes.
  - 'pallas'  : kernels/ops.py fused forward+backward (TPU target;
                FlashMask-style block skipping).  Falls back to interpret
                mode on CPU.  Covers partition gateways (extra_kv
                ancestors, q_off index offset, per-row front-padding
                masks) and sliding windows natively — no XLA downgrade.

Sliding-window attention restricts additionally to pos_i − pos_j < window
(positions, not DFS indices — window applies along the *path* and across
partition gateways: ancestor positions travel in extra_kv["pos"]).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttnCfg
from repro.models.layers import _dense_init, init_rmsnorm, rmsnorm, rope
from repro.sharding import tp_out_proj

NEG_INF = -1e30


def init_attention(key, cfg: AttnCfg, d_model: int, dtype=jnp.float32,
                   cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d_model, cfg.q_dim), dtype=dtype),
        "wk": _dense_init(ks[1], (d_model, cfg.kv_dim), dtype=dtype),
        "wv": _dense_init(ks[2], (d_model, cfg.kv_dim), dtype=dtype),
        "wo": _dense_init(ks[3], (cfg.q_dim, d_model), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = init_rmsnorm(cfg.head_dim, dtype)
        p["k_norm"] = init_rmsnorm(cfg.head_dim, dtype)
    return p


def _project_qkv(params: dict, cfg: AttnCfg, x: jax.Array,
                 x_kv: Optional[jax.Array] = None):
    B, S, _ = x.shape
    x_kv = x if x_kv is None else x_kv
    Skv = x_kv.shape[1]
    q = x @ params["wq"]
    k = x_kv @ params["wk"]
    v = x_kv @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    return q, k, v


def _scale(cfg: AttnCfg) -> float:
    return cfg.softmax_scale or cfg.head_dim ** -0.5


def _tree_bias(i_idx, kv_last, pos_q, pos_k, window, bidirectional, valid_k):
    """Additive mask bias [B, 1, 1, Sq, Sk] from tree metadata."""
    if bidirectional:
        vis = valid_k[:, None, :]
    else:
        j_idx = jnp.arange(kv_last.shape[-1])
        vis = (j_idx[None, None, :] <= i_idx[None, :, None]) & \
              (kv_last[:, None, :] >= i_idx[None, :, None])
        if window is not None:
            d = pos_q[:, :, None] - pos_k[:, None, :]
            vis = vis & (d < window)
    return jnp.where(vis, 0.0, NEG_INF)[:, None, None]  # [B,1,1,Sq,Sk]


def _attend_ref(q, k, v, bias, scale):
    B, S, H, hd = q.shape
    Kh = k.shape[2]
    G = H // Kh
    qg = q.reshape(B, S, Kh, G, hd)
    logits = jnp.einsum("bikgd,bjkd->bkgij", qg, k).astype(jnp.float32)
    logits = logits * scale + bias
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgij,bjkd->bikgd", w.astype(v.dtype), v)
    return o.reshape(B, S, H, hd)


def _attend_chunked(q, k, v, i_idx, kv_last, pos_q, pos_k, window,
                    bidirectional, valid_k, scale, kv_chunk=1024):
    """Online-softmax over KV chunks — memory O(S·kv_chunk)."""
    B, S, H, hd = q.shape
    Skv, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    kv_chunk = min(kv_chunk, Skv)
    if Skv % kv_chunk:
        # awkward KV lengths (e.g. gateway-extended, or prime-ish): the
        # old decrement loop degraded to chunk 1 (an Skv-step scan)
        # whenever Skv had no large divisor.  Prefer the largest divisor
        # within 4x of the requested chunk (no padding, e.g. 1032 → 516);
        # failing that, a power-of-two chunk minimizing the padded length,
        # back-padding with invisible keys.
        lo = max(kv_chunk // 4, 1)
        div = next((d for d in range(kv_chunk, lo - 1, -1)
                    if Skv % d == 0), None)
        if div is not None:
            kv_chunk = div
        else:
            cands = [c for c in (1 << i for i in
                                 range(3, kv_chunk.bit_length()))
                     if 4 * c >= kv_chunk] or [8]
            kv_chunk = min(cands, key=lambda c: (-(-Skv // c) * c, -c))
            pad = -Skv % kv_chunk
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kv_last = jnp.pad(kv_last, ((0, 0), (0, pad)),
                              constant_values=-1)
            pos_k = jnp.pad(pos_k, ((0, 0), (0, pad)))
            valid_k = jnp.pad(valid_k, ((0, 0), (0, pad)))
            Skv += pad
    n_chunks = Skv // kv_chunk
    qg = q.reshape(B, S, Kh, G, hd)

    kc = k.reshape(B, n_chunks, kv_chunk, Kh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, Kh, hd).transpose(1, 0, 2, 3, 4)
    klc = kv_last.reshape(B, n_chunks, kv_chunk).transpose(1, 0, 2)
    pkc = pos_k.reshape(B, n_chunks, kv_chunk).transpose(1, 0, 2)
    vkc = valid_k.reshape(B, n_chunks, kv_chunk).transpose(1, 0, 2)
    j_base = jnp.arange(n_chunks) * kv_chunk

    def body(carry, inp):
        m, l, acc = carry
        kj, vj, klj, pkj, vkj, j0 = inp
        logits = jnp.einsum("bikgd,bjkd->bkgij", qg, kj).astype(jnp.float32)
        logits = logits * scale
        if bidirectional:
            vis = vkj[:, None, :]
        else:
            jj = j0 + jnp.arange(kv_chunk)
            vis = (jj[None, None, :] <= i_idx[None, :, None]) & \
                  (klj[:, None, :] >= i_idx[None, :, None])
            if window is not None:
                d = pos_q[:, :, None] - pkj[:, None, :]
                vis = vis & (d < window)
        logits = logits + jnp.where(vis, 0.0, NEG_INF)[:, None, None]
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgij,bjkd->bkgid", p.astype(vj.dtype), vj).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Kh, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kh, G, S), jnp.float32)
    a0 = jnp.zeros((B, Kh, G, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, klc, pkc, vkc, j_base))
    o = acc / jnp.maximum(l[..., None], 1e-37)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd).astype(q.dtype)


BIG = 1 << 30


def attention(
    params: dict,
    cfg: AttnCfg,
    x: jax.Array,
    *,
    pos_ids: jax.Array,
    kv_last: jax.Array,
    valid: jax.Array,
    impl: str = "ref",
    bidirectional: bool = False,
    cross_kv: Optional[tuple[jax.Array, jax.Array]] = None,
    cross_valid: Optional[jax.Array] = None,
    extra_kv: Optional[dict] = None,
    capture_idx: Optional[dict] = None,
) -> jax.Array | tuple[jax.Array, dict]:
    """Full-sequence (train/prefill) attention.

    cross_kv: pre-projected (k, v) from an encoder → cross-attention
    (mask = cross_valid only; branch-independent, paper §5 table).
    extra_kv: partition-gateway ancestor KV — dict(k, v, pos) with
    k/v [B, A, Kh, hd] *already roped* in the parent partition; ancestors
    are visible to every query (they precede the partition root).  An
    optional boolean ``valid`` [B, A] masks per-row front padding (wave
    batching pads rows to a shared ancestor length).
    capture_idx: dict name → static index array; returns per-cut
    (k, v) slices at those DFS positions (relayed to child partitions).
    """
    B, S, _ = x.shape
    if cross_kv is not None:
        q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        k, v = cross_kv
        bias = jnp.where(cross_valid[:, None, :], 0.0,
                         NEG_INF)[:, None, None]
        o = _attend_ref(q, k, v, bias, _scale(cfg))
        return o.reshape(B, S, -1) @ params["wo"]

    q, k, v = _project_qkv(params, cfg, x)
    if not bidirectional:
        q = rope(q, pos_ids, cfg.rope_theta)
        k = rope(k, pos_ids, cfg.rope_theta)

    caps = None
    if capture_idx is not None:
        caps = {name: {"k": k[:, idx], "v": v[:, idx]}
                for name, idx in capture_idx.items()}

    kq_off = 0
    k_all, v_all, kl_all, pos_k = k, v, kv_last, pos_ids
    if extra_kv is not None:
        A = extra_kv["k"].shape[1]
        kq_off = A
        k_all = jnp.concatenate([extra_kv["k"].astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([extra_kv["v"].astype(v.dtype), v], axis=1)
        anc_kl = jnp.full((B, A), BIG, jnp.int32)
        if extra_kv.get("valid") is not None:
            anc_kl = jnp.where(extra_kv["valid"], BIG, -1)
        kl_all = jnp.concatenate(
            [anc_kl, jnp.where(kv_last >= 0, kv_last + A, -1)], axis=1)
        pos_k = jnp.concatenate([extra_kv["pos"], pos_ids], axis=1)

    i_idx = kq_off + jnp.arange(S)
    if impl == "ref":
        bias = _tree_bias(i_idx, kl_all, pos_ids, pos_k, cfg.window,
                          bidirectional, valid)
        o = _attend_ref(q, k_all, v_all, bias, _scale(cfg))
    elif impl == "chunked":
        anc_ok = (jnp.ones((B, kq_off), bool)
                  if extra_kv is None or extra_kv.get("valid") is None
                  else extra_kv["valid"])
        valid_k = valid if extra_kv is None else jnp.concatenate(
            [anc_ok, valid], axis=1)
        o = _attend_chunked(q, k_all, v_all, i_idx, kl_all, pos_ids, pos_k,
                            cfg.window, bidirectional, valid_k, _scale(cfg))
    elif impl == "pallas":
        from repro.kernels.ops import tree_attention as pallas_attn
        if bidirectional:
            # encoder-style validity masks have no fused kernel (tiny
            # prefix shapes, never the hot path) — use the oracle bias
            bias = _tree_bias(i_idx, kl_all, pos_ids, pos_k, cfg.window,
                              bidirectional, valid)
            o = _attend_ref(q, k_all, v_all, bias, _scale(cfg))
        else:
            o = pallas_attn(q, k_all, v_all, kl_all, _scale(cfg),
                            q_off=kq_off, window=cfg.window,
                            pos_q=pos_ids, pos_k=pos_k)
    else:
        raise ValueError(impl)
    y = tp_out_proj(o.reshape(B, S, -1), params["wo"])
    if capture_idx is not None:
        return y, caps
    return y


def project_cross_kv(params: dict, cfg: AttnCfg, enc_out: jax.Array):
    B, T, _ = enc_out.shape
    k = (enc_out @ params["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ params["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    if "bk" in params:
        k = k + params["bk"].reshape(1, 1, cfg.n_kv_heads, cfg.head_dim)
        v = v + params["bv"].reshape(1, 1, cfg.n_kv_heads, cfg.head_dim)
    return k, v


# --------------------------------------------------------------------------
# Decode path — ring-buffer KV cache (full or sliding window)
# --------------------------------------------------------------------------

def init_kv_cache(batch: int, buf_len: int, cfg: AttnCfg,
                  dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, buf_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, buf_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((batch, buf_len), -1, jnp.int32),
    }


def decode_attention(params: dict, cfg: AttnCfg, x: jax.Array,
                     cache: dict, pos: jax.Array, write_idx: jax.Array,
                     cross_cache: Optional[dict] = None
                     ) -> tuple[jax.Array, dict]:
    """One-token decode.  x: [B, 1, D]; pos: [B] position ids of the new
    token; write_idx: scalar ring-buffer slot.  Returns (y, new_cache)."""
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(params, cfg, x)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k_new = rope(k_new, pos[:, None], cfg.rope_theta)

    k = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                            k_new.astype(cache["k"].dtype),
                                            write_idx, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                            v_new.astype(cache["v"].dtype),
                                            write_idx, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], pos[:, None], write_idx, axis=1)

    vis = (cpos >= 0) & (cpos <= pos[:, None])
    if cfg.window is not None:
        vis = vis & (pos[:, None] - cpos < cfg.window)
    bias = jnp.where(vis, 0.0, NEG_INF)[:, None, None]  # [B,1,1,T]
    B_, T = cpos.shape
    Kh, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, 1, Kh, G, cfg.head_dim)
    logits = jnp.einsum("bikgd,bjkd->bkgij", qg,
                        k.astype(q.dtype)).astype(jnp.float32)
    logits = logits * _scale(cfg) + bias[..., None, :]
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgij,bjkd->bikgd", w.astype(v.dtype), v)
    o = o.reshape(B, 1, cfg.q_dim)

    if cross_cache is not None:
        qc = (x @ params["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        cb = jnp.where(cross_cache["valid"][:, None, :], 0.0,
                       NEG_INF)[:, None, None]
        oc = _attend_ref(qc, cross_cache["k"], cross_cache["v"], cb,
                         _scale(cfg))
        o = o + oc.reshape(B, 1, cfg.q_dim)

    y = o @ params["wo"]
    return y, {"k": k, "v": v, "pos": cpos}
