"""RWKV6 ("Finch") time-mix / channel-mix with tree-aware chunked scan.

Signature feature: **data-dependent per-channel decay** — w_t is produced
from the token (via a low-rank MLP), and the recurrent state decays
per key-channel:

    S_t = diag(w_t) S_{t−1} + k_tᵀ v_t
    o_t = r_t · (S_{t−1} + diag(u) k_tᵀ v_t)        (u = per-channel bonus)

Tree adaptations (paper §3.2 applied to this family):
  - chunk-level *tree state routing* for S (parent chunk, not DFS neighbor);
  - the RWKV "token shift" (every projection mixes x_t with x_{t−1}) is the
    K=2 analogue of the causal conv — we gather the *path predecessor*
    (prev_idx) instead of the DFS predecessor, which is exact across
    branch points.

Within-chunk the per-channel decay forbids the usual rank-factored
(A = r̃ k̃ᵀ) trick from overflowing-safe computation, so the intra term
materializes the [L, L, d_k] decay difference — all exponents are ≤ 0
(differences of a non-increasing cumsum), so only benign underflow can
occur.  Keep chunk_size modest (32) for this layer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SSMCfg
from repro.models.layers import _dense_init, gather_prev, init_rmsnorm, rmsnorm
from repro.models.ssm.common import chunkify, tree_chunk_scan, unchunkify

LOGW_MIN = -8.0  # per-token decay clamp (exp(−8) ≈ 3e-4), FLA-style


def init_rwkv6_timemix(key, cfg: SSMCfg, d_model: int,
                       dtype=jnp.float32) -> dict:
    H = cfg.n_heads(d_model)
    d_attn = H * cfg.head_dim
    lora_r = max(32, d_model // 32)
    ks = jax.random.split(key, 10)
    return {
        "mix": 0.5 * jnp.ones((5, d_model), dtype),   # r,k,v,w,g lerp coeffs
        "wr": _dense_init(ks[0], (d_model, d_attn), dtype=dtype),
        "wk": _dense_init(ks[1], (d_model, d_attn), dtype=dtype),
        "wv": _dense_init(ks[2], (d_model, d_attn), dtype=dtype),
        "wg": _dense_init(ks[3], (d_model, d_attn), dtype=dtype),
        "wo": _dense_init(ks[4], (d_attn, d_model), dtype=dtype),
        "w0": jnp.full((d_attn,), -2.0, jnp.float32), # base log-log decay
        "w_lora_a": _dense_init(ks[5], (d_model, lora_r), dtype=dtype),
        "w_lora_b": _dense_init(ks[6], (lora_r, d_attn), scale=0.01,
                                dtype=dtype),
        "u": _dense_init(ks[7], (H, cfg.head_dim), scale=1.0,
                         dtype=jnp.float32),
        "ln_out": init_rmsnorm(d_attn, dtype),
    }


def _wkv_chunk_step(s_in, xs):
    """s_in: S [B,H,dk,dv]; xs: (r,k,v [B,L,H,hd], logw [B,L,H,hd])."""
    r, k, v, logw = xs
    B, L, H, hd = r.shape
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    lw = logw.astype(jnp.float32)
    cw = jnp.cumsum(lw, axis=1)                       # [B,L,H,hd] inclusive
    ecw = cw - lw                                     # exclusive
    # intra: A_ij = Σ_d r_i,d k_j,d exp(ecw_i,d − cw_j,d),  j < i
    diff = ecw[:, :, None] - cw[:, None]              # [B,i,j,H,hd] ≤ 0 f. j<i
    strict = jnp.tril(jnp.ones((L, L), bool), k=-1)
    A = jnp.einsum("bihd,bjhd,bijhd->bhij", rf, kf,
                   jnp.exp(jnp.where(strict[None, :, :, None, None],
                                     diff, -jnp.inf)))
    y = jnp.einsum("bhij,bjhd->bihd", A, vf)
    # bonus (current token): (r_i ⊙ u ⊙ k_i) · v_i — u baked into k via xs? no:
    # handled by caller adding bonus term (needs u); here append via closure.
    # inter: o_i += (r_i ⊙ exp(ecw_i)) · S_in
    y = y + jnp.einsum("bihd,bhde->bihe", rf * jnp.exp(ecw),
                       s_in.astype(jnp.float32))
    # state: S_out = diag(exp(cw_L)) S_in + Σ_j diag(exp(cw_L − cw_j)) k_jᵀ v_j
    wL = cw[:, -1]                                    # [B,H,hd]
    S_out = jnp.exp(wL)[..., None] * s_in.astype(jnp.float32) + jnp.einsum(
        "bjhd,bjhe->bhde", kf * jnp.exp(wL[:, None] - cw), vf)
    return y, S_out


def rwkv6_timemix(
    params: dict,
    cfg: SSMCfg,
    x: jax.Array,
    *,
    chunk_parent: jax.Array,
    prev_idx: jax.Array,
    valid: jax.Array,
    initial_state: Optional[dict] = None,
    shift_ctx: Optional[jax.Array] = None,
    capture: Optional[dict] = None,
    return_states: bool = False,
):
    B, S, D = x.shape
    H, hd = cfg.n_heads(D), cfg.head_dim
    x_prev = gather_prev(x, prev_idx, shift_ctx)      # tree-correct shift
    mix = params["mix"]

    def lerp(i):
        return x + (x_prev - x) * mix[i]

    r = (lerp(0) @ params["wr"]).reshape(B, S, H, hd)
    k = (lerp(1) @ params["wk"]).reshape(B, S, H, hd)
    v = (lerp(2) @ params["wv"]).reshape(B, S, H, hd)
    wx = jnp.tanh(lerp(3) @ params["w_lora_a"]) @ params["w_lora_b"]
    logw = -jnp.exp(params["w0"] + wx.astype(jnp.float32))  # ≤ 0
    logw = jnp.maximum(logw, LOGW_MIN).reshape(B, S, H, hd)
    g = jax.nn.silu(lerp(4) @ params["wg"])

    vm = valid[..., None, None].astype(jnp.float32)
    k = k * vm                                        # pads: no contribution,
    logw = logw * vm                                  # no decay

    cs = cfg.chunk_size
    xs = tuple(chunkify(t, cs) for t in (r, k, v, logw))
    zero = {"S": jnp.zeros((B, H, hd, hd), jnp.float32)}

    def step(s, x_c):
        y, S = _wkv_chunk_step(s["S"], x_c)
        return y, {"S": S}

    ys, states = tree_chunk_scan(step, zero, xs, chunk_parent, initial_state)
    y = unchunkify(ys)
    # bonus term: (r_i ⊙ u ⊙ k_i) · v_i
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    bonus = jnp.einsum("bihd,bihd,bihe->bihe",
                       rf, params["u"][None, None] * kf, vf)
    y = (y + bonus).reshape(B, S, H * hd).astype(x.dtype)
    y = rmsnorm(params["ln_out"], y) * g
    out = y @ params["wo"]
    if capture is not None:
        caps = {name: {"state": {"S": states["S"][:, c["chunk"] + 1]},
                       "shift": x[:, c["shift_pos"]]}
                for name, c in capture.items()}
        return out, caps
    if return_states:
        return out, states
    return out


def init_rwkv6_channelmix(key, d_model: int, d_ff: int,
                          dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "mix": 0.5 * jnp.ones((2, d_model), dtype),   # k, r
        "wk": _dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "wv": _dense_init(ks[1], (d_ff, d_model), dtype=dtype),
        "wr": _dense_init(ks[2], (d_model, d_model), dtype=dtype),
    }


def rwkv6_channelmix(params: dict, x: jax.Array, prev_idx: jax.Array,
                     shift_ctx: Optional[jax.Array] = None,
                     capture: Optional[dict] = None):
    x_prev = gather_prev(x, prev_idx, shift_ctx)
    mix = params["mix"]
    xk = x + (x_prev - x) * mix[0]
    xr = x + (x_prev - x) * mix[1]
    kk = jax.nn.relu(xk @ params["wk"])
    kk = kk * kk
    out = jax.nn.sigmoid(xr @ params["wr"]) * (kk @ params["wv"])
    if capture is not None:
        caps = {name: {"shift": x[:, c["shift_pos"]]}
                for name, c in capture.items()}
        return out, caps
    return out


# ---------------------------------------------------------------------------
# Decode: per-token recurrence; cache = {S, x_prev_tm, x_prev_cm}
# ---------------------------------------------------------------------------

def init_rwkv6_cache(batch: int, cfg: SSMCfg, d_model: int,
                     dtype=jnp.float32) -> dict:
    H, hd = cfg.n_heads(d_model), cfg.head_dim
    return {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "x_tm": jnp.zeros((batch, 1, d_model), dtype),
        "x_cm": jnp.zeros((batch, 1, d_model), dtype),
    }


def rwkv6_timemix_decode(params: dict, cfg: SSMCfg, x: jax.Array,
                         cache: dict) -> tuple[jax.Array, dict]:
    B, _, D = x.shape
    H, hd = cfg.n_heads(D), cfg.head_dim
    x_prev = cache["x_tm"]
    mix = params["mix"]

    def lerp(i):
        return x + (x_prev - x) * mix[i]

    r = (lerp(0) @ params["wr"]).reshape(B, H, hd)
    k = (lerp(1) @ params["wk"]).reshape(B, H, hd)
    v = (lerp(2) @ params["wv"]).reshape(B, H, hd)
    wx = jnp.tanh(lerp(3) @ params["w_lora_a"]) @ params["w_lora_b"]
    logw = jnp.maximum(-jnp.exp(params["w0"] + wx.astype(jnp.float32)),
                       LOGW_MIN).reshape(B, H, hd)
    g = jax.nn.silu(lerp(4) @ params["wg"])

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    S = cache["S"]
    kv = jnp.einsum("bhd,bhe->bhde", kf, vf)
    o = jnp.einsum("bhd,bhde->bhe", rf,
                   S + params["u"][None, ..., None] * kv)
    S = jnp.exp(logw)[..., None] * S + kv
    y = o.reshape(B, 1, H * hd).astype(x.dtype)
    y = rmsnorm(params["ln_out"], y) * g
    out = y @ params["wo"]
    return out, {**cache, "S": S, "x_tm": x}


def rwkv6_channelmix_decode(params: dict, x: jax.Array, cache: dict
                            ) -> tuple[jax.Array, dict]:
    x_prev = cache["x_cm"]
    mix = params["mix"]
    xk = x + (x_prev - x) * mix[0]
    xr = x + (x_prev - x) * mix[1]
    kk = jax.nn.relu(xk @ params["wk"])
    kk = kk * kk
    y = jax.nn.sigmoid(xr @ params["wr"]) * (kk @ params["wv"])
    return y, {**cache, "x_cm": x}
