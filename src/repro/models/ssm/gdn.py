"""Gated Delta Net (GDN) — the paper's hybrid-model SSM (App. A.2/A.3).

Per-token recurrence (defines the semantics; the chunked form below is the
paper's Appendix-A algorithm ported from PyTorch to JAX):

    S_t  = exp(g_t) · S_{t−1}
    err  = k_t · S_t − v_t                       (delta rule)
    S_t  = S_t − β_t · k_tᵀ ⊗ err
    o_t  = q_t · S_t                             (post-update read)

The within-chunk correction matrix T = (I − A)⁻¹ with A = strict-lower
(β k kᵀ ⊙ decay) is computed with a unit-lower-triangular solve instead of
the paper's Python row recursion — identical result, MXU-friendly.

Tree adaptation = chunk-level parent state routing (tree_chunk_scan) plus
the path-predecessor causal conv, exactly as the paper's
``torch_chunk_gated_delta_rule_tree_varlen`` + ``_tree_correct_conv_varlen``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SSMCfg
from repro.models.layers import (_dense_init, init_rmsnorm, rmsnorm,
                                 tree_causal_conv)
from repro.models.ssm.common import chunkify, tree_chunk_scan, unchunkify


def init_gdn(key, cfg: SSMCfg, d_model: int, dtype=jnp.float32) -> dict:
    di = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    hd, K = cfg.head_dim, cfg.conv_kernel
    ks = jax.random.split(key, 8)
    # q, k, v of head_dim each; z gate; per-head a (decay), beta
    return {
        "wq": _dense_init(ks[0], (d_model, di), dtype=dtype),
        "wk": _dense_init(ks[1], (d_model, di), dtype=dtype),
        "wv": _dense_init(ks[2], (d_model, di), dtype=dtype),
        "wz": _dense_init(ks[3], (d_model, di), dtype=dtype),
        "wa": _dense_init(ks[4], (d_model, H), scale=0.1, dtype=dtype),
        "wb": _dense_init(ks[5], (d_model, H), scale=0.1, dtype=dtype),
        "a_bias": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "conv_w": _dense_init(ks[6], (K, 3 * di), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((3 * di,), dtype),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": _dense_init(ks[7], (di, d_model), dtype=dtype),
    }


def _gdn_chunk_step(s_in, xs):
    """s_in: [B,H,hd,hd] (k-dim × v-dim).
    xs: (q,k,v [B,L,H,hd], g [B,L,H] log-decay ≤ 0, beta [B,L,H])."""
    q, k, v, g, beta = xs
    B, L, H, hd = q.shape
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    gf, bf = g.astype(jnp.float32), beta.astype(jnp.float32)
    cw = jnp.cumsum(gf, axis=1)                          # [B,L,H] inclusive
    v_beta = vf * bf[..., None]
    k_beta = kf * bf[..., None]
    tri_inc = jnp.tril(jnp.ones((L, L), bool))           # incl. diag
    tri_exc = jnp.tril(jnp.ones((L, L), bool), k=-1)
    decay = jnp.exp(cw[:, :, None] - cw[:, None])        # [B,i,j,H]
    # A = strict-lower −(k_beta kᵀ ⊙ decay)  (paper's attn_raw);
    # T = (I − A)⁻¹ via unit-lower-triangular solve
    kk = jnp.einsum("bihd,bjhd->bhij", k_beta, kf)
    A = jnp.where(tri_exc[None, None], -kk * decay.transpose(0, 3, 1, 2), 0.0)
    eye = jnp.eye(L, dtype=jnp.float32)
    T = jax.scipy.linalg.solve_triangular(
        eye[None, None] - A, jnp.broadcast_to(eye, A.shape), lower=True,
        unit_diagonal=True)
    value_corr = jnp.einsum("bhij,bjhd->bihd", T, v_beta)
    k_cumdecay = jnp.einsum("bhij,bjhd->bihd", T,
                            k_beta * jnp.exp(cw)[..., None])
    v_prime = jnp.einsum("bihd,bhde->bihe", k_cumdecay,
                         s_in.astype(jnp.float32))
    v_new = value_corr - v_prime
    attn_within = jnp.where(tri_inc[None, None],
                            jnp.einsum("bihd,bjhd->bhij", qf, kf)
                            * decay.transpose(0, 3, 1, 2), 0.0)
    attn_inter = jnp.einsum("bihd,bhde->bihe", qf * jnp.exp(cw)[..., None],
                            s_in.astype(jnp.float32))
    out = attn_inter + jnp.einsum("bhij,bjhe->bihe", attn_within, v_new)
    # state update
    wL = cw[:, -1]                                       # [B,H]
    s_out = (s_in.astype(jnp.float32) * jnp.exp(wL)[..., None, None]
             + jnp.einsum("bjhd,bjhe->bhde",
                          kf * jnp.exp(wL[:, None] - cw)[..., None], v_new))
    return out, s_out


def gdn(
    params: dict,
    cfg: SSMCfg,
    x: jax.Array,
    *,
    chunk_parent: jax.Array,
    prev_pows: jax.Array,
    valid: jax.Array,
    initial_state: Optional[dict] = None,
    conv_ctx: Optional[jax.Array] = None,
    capture: Optional[dict] = None,
    return_states: bool = False,
):
    B, S, D = x.shape
    di = cfg.d_inner(D)
    H, hd, K = cfg.n_heads(D), cfg.head_dim, cfg.conv_kernel

    qkv_pre = jnp.concatenate(
        [x @ params["wq"], x @ params["wk"], x @ params["wv"]], axis=-1)
    qkv = jax.nn.silu(tree_causal_conv(
        qkv_pre, params["conv_w"], params["conv_b"], prev_pows[..., :K - 1],
        conv_ctx))
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, H, hd)
    k = k / jnp.maximum(jnp.linalg.norm(k.astype(jnp.float32), axis=-1,
                                        keepdims=True), 1e-6).astype(k.dtype)
    v = v.reshape(B, S, H, hd)
    g = -jnp.exp(params["a_bias"]) * jax.nn.softplus(
        (x @ params["wa"]).astype(jnp.float32) + params["dt_bias"])
    beta = jax.nn.sigmoid((x @ params["wb"]).astype(jnp.float32))
    vm = valid.astype(jnp.float32)[..., None]
    beta = beta * vm                                     # pads: no delta write
    g = g * vm                                           # and no decay

    cs = cfg.chunk_size
    xs = (chunkify(q, cs), chunkify(k, cs), chunkify(v, cs),
          chunkify(g, cs), chunkify(beta, cs))
    zero = {"S": jnp.zeros((B, H, hd, hd), jnp.float32)}

    def step(s, x_c):
        y, S = _gdn_chunk_step(s["S"], x_c)
        return y, {"S": S}

    ys, states = tree_chunk_scan(step, zero, xs, chunk_parent, initial_state)
    y = unchunkify(ys).reshape(B, S, di).astype(x.dtype)
    z = x @ params["wz"]
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"]
    if capture is not None:
        caps = {name: {"state": {"S": states["S"][:, c["chunk"] + 1]},
                       "conv": qkv_pre[:, c["conv_pos"]]}
                for name, c in capture.items()}
        return out, caps
    if return_states:
        return out, states
    return out


def init_gdn_cache(batch: int, cfg: SSMCfg, d_model: int,
                   dtype=jnp.float32) -> dict:
    di = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    return {
        "S": jnp.zeros((batch, H, cfg.head_dim, cfg.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, 3 * di), dtype),
    }


def gdn_decode(params: dict, cfg: SSMCfg, x: jax.Array, cache: dict
               ) -> tuple[jax.Array, dict]:
    B, _, D = x.shape
    di = cfg.d_inner(D)
    H, hd = cfg.n_heads(D), cfg.head_dim
    qkv = jnp.concatenate(
        [x @ params["wq"], x @ params["wk"], x @ params["wv"]], axis=-1)
    window = jnp.concatenate(
        [cache["conv"], qkv.astype(cache["conv"].dtype)], axis=1)
    conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32))
    qkv = jax.nn.silu(conv + params["conv_b"])[:, None].astype(x.dtype)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, H, hd).astype(jnp.float32)
    k = k.reshape(B, H, hd).astype(jnp.float32)
    k = k / jnp.maximum(jnp.linalg.norm(k, axis=-1, keepdims=True), 1e-6)
    v = v.reshape(B, H, hd).astype(jnp.float32)
    g = -jnp.exp(params["a_bias"]) * jax.nn.softplus(
        (x[:, 0] @ params["wa"]).astype(jnp.float32) + params["dt_bias"])
    beta = jax.nn.sigmoid((x[:, 0] @ params["wb"]).astype(jnp.float32))
    S = cache["S"] * jnp.exp(g)[..., None, None]
    err = jnp.einsum("bhd,bhde->bhe", k, S) - v
    S = S - beta[..., None, None] * jnp.einsum("bhd,bhe->bhde", k, err)
    o = jnp.einsum("bhd,bhde->bhe", q, S)
    y = o.reshape(B, 1, di).astype(x.dtype)
    z = x @ params["wz"]
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"]
    return out, {"S": S, "conv": window[:, 1:]}
