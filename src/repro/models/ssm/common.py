"""Tree-aware chunked scan for recurrent (SSM / linear-attention) layers.

Paper §3.2 (SSM Layers): under DFS serialization the *sequential* chunk
state flow is wrong — after a leaf the next chunk is a sibling, not a
descendant.  Tree routing fixes it: chunk c reads its initial state from
``chunk_parent[c]`` (−1 = zero/initial state).  DFS pre-order guarantees
the parent state is already computed; sibling chunks read the *same*
parent state tensor, so their gradient contributions accumulate there
automatically (here: through the gather's transpose — a scatter-add).

The harness is layer-agnostic: mamba2 / rwkv6 / gdn supply a
``chunk_step(state, xs_c) -> (y_c, state_out)`` and get tree routing,
state capture (for partition gateways) and the all-states buffer for free.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


def _gather_state(buf: Any, idx: jax.Array, one_hot: bool = True) -> Any:
    """buf leaf: [B, C+1, ...]; idx: [B] → state pytree [B, ...].

    Default = one-hot contraction rather than a gather: under pjit the
    dynamic gather made GSPMD emit an all-gather + all-reduce *inside the
    chunk scan* (×layers×chunks — §Perf rwkv6 iter 5); the one-hot einsum
    is sharding-transparent (contraction over the local, replicated C+1
    dim) at negligible FLOPs ((C+1)·|state| per step)."""
    if one_hot:
        C1 = jax.tree.leaves(buf)[0].shape[1]
        oh = jax.nn.one_hot(idx, C1, dtype=jnp.float32)      # [B, C+1]

        def g(b):
            return jnp.einsum("bc,bc...->b...", oh.astype(b.dtype), b)
        return jax.tree.map(g, buf)

    def g(b):
        ix = idx.reshape((-1,) + (1,) * (b.ndim - 1))
        return jnp.take_along_axis(b, ix, axis=1).squeeze(1)
    return jax.tree.map(g, buf)


def tree_chunk_scan(
    chunk_step: Callable[[Any, Any], tuple[Any, Any]],
    zero_state: Any,
    xs: Any,
    chunk_parent: jax.Array,
    initial_state: Optional[Any] = None,
) -> tuple[Any, Any]:
    """Run ``chunk_step`` over chunks with tree state routing.

    zero_state: pytree of [B, ...] zeros (dtype/shape template).
    xs: pytree of [B, C, L, ...] per-chunk inputs.
    chunk_parent: [B, C] int32; −1 reads the initial state.
    initial_state: optional pytree [B, ...] injected at slot 0 — the SSM
      partition-gateway injection point (paper App. B.7): root chunks of a
      child partition read the parent partition's relayed state here.

    Returns (ys [B, C, L, ...], all_states buffer [B, C+1, ...]) — the
    buffer is differentiable and slots can be captured for gateways.
    """
    C = chunk_parent.shape[1]
    init = zero_state if initial_state is None else initial_state

    def mkbuf(z):
        buf = jnp.zeros((z.shape[0], C + 1) + z.shape[1:], z.dtype)
        return buf.at[:, 0].set(z)

    buf0 = jax.tree.map(mkbuf, init)

    xs_t = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), xs)  # [C, B, L, ...]
    cp_t = jnp.moveaxis(chunk_parent, 1, 0)                   # [C, B]

    def body(carry, inp):
        buf, c = carry
        x_c, parent = inp
        s_in = _gather_state(buf, parent + 1)
        y_c, s_out = chunk_step(s_in, x_c)
        buf = jax.tree.map(
            lambda b, s: jax.lax.dynamic_update_slice_in_dim(
                b, s[:, None].astype(b.dtype), c + 1, axis=1),
            buf, s_out)
        return (buf, c + 1), y_c

    (buf, _), ys = jax.lax.scan(body, (buf0, 0), (xs_t, cp_t))
    ys = jax.tree.map(lambda a: jnp.moveaxis(a, 0, 1), ys)    # [B, C, L, ...]
    return ys, buf


def chunkify(x: jax.Array, chunk: int) -> jax.Array:
    """[B, S, ...] → [B, C, chunk, ...]."""
    B, S = x.shape[:2]
    assert S % chunk == 0, (S, chunk)
    return x.reshape(B, S // chunk, chunk, *x.shape[2:])


def unchunkify(x: jax.Array) -> jax.Array:
    B, C, L = x.shape[:3]
    return x.reshape(B, C * L, *x.shape[3:])
