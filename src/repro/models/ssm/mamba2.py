"""Mamba2 (SSD) layer with tree-aware chunked scan (paper §3.2 adapted).

Chunked state-space duality: within-chunk quadratic term + cross-chunk
recurrent state, with the state routed along the *tree* (parent chunk)
instead of DFS-sequentially.  The causal conv uses path-predecessor
gathers (models/layers.tree_causal_conv) — exact per-branch semantics.

State per layer: h [B, H, d_state, head_dim]  (+ conv tail for decode).
Decays are scalar-per-head-per-token: g_t = dt_t · (−exp(A_log)) ≤ 0.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SSMCfg
from repro.models.layers import (_dense_init, init_rmsnorm, rmsnorm,
                                 tree_causal_conv)
from repro.models.ssm.common import (chunkify, tree_chunk_scan, unchunkify)

def init_mamba2(key, cfg: SSMCfg, d_model: int, dtype=jnp.float32) -> dict:
    """Projections are kept UNFUSED (separate z/x/B/C/dt matmuls) — a
    deliberate sharding decision: a fused [D, 2di+2ds+H] projection has its
    output dim model-sharded, and the later `split` at non-shard-aligned
    boundaries makes GSPMD emit per-chunk halo collective-permutes inside
    the scan (observed: 784 permutes on zamba2 train_4k, §Perf iter 2).
    Separate matmuls let z/x shard on 'model' while the small B/C/dt stay
    replicated.  Same math, same FLOPs."""
    di = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    ds, K = cfg.d_state, cfg.conv_kernel
    ks = jax.random.split(key, 8)
    return {
        "in_z": _dense_init(ks[0], (d_model, di), dtype=dtype),
        "in_x": _dense_init(ks[1], (d_model, di), dtype=dtype),
        "in_B": _dense_init(ks[2], (d_model, ds), dtype=dtype),
        "in_C": _dense_init(ks[3], (d_model, ds), dtype=dtype),
        "in_dt": _dense_init(ks[4], (d_model, H), dtype=dtype),
        "conv_w": _dense_init(ks[5], (K, di + 2 * ds), scale=0.5,
                              dtype=dtype),
        "conv_b": jnp.zeros((di + 2 * ds,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),      # A = −exp(0) = −1
        "dt_bias": jnp.full((H,), -1.0, jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": _dense_init(ks[6], (di, d_model), dtype=dtype),
    }


def _ssd_chunk_step(s_in, xs):
    """One chunk of SSD.  s_in: h [B,H,ds,hd].
    xs: (xh [B,L,H,hd], Bm [B,L,ds], Cm [B,L,ds], dt [B,L,H], g [B,L,H])."""
    xh, Bm, Cm, dt, g = xs
    L = xh.shape[1]
    gf = g.astype(jnp.float32)
    # All exponents below are differences ≤ 0 (cw is non-increasing), so
    # exp() can only underflow to 0 — which is the correct limit.
    cw = jnp.cumsum(gf, axis=1)                       # [B,L,H] inclusive
    tri = jnp.tril(jnp.ones((L, L), bool))
    # intra: y_i = Σ_{j<=i} (C_i·B_j) exp(cw_i − cw_j) dt_j x_j
    CB = jnp.einsum("bis,bjs->bij", Cm.astype(jnp.float32),
                    Bm.astype(jnp.float32))
    D_ij = jnp.exp(cw[:, :, None] - cw[:, None])      # [B,i,j,H]
    W = CB[..., None] * D_ij * dt.astype(jnp.float32)[:, None]
    W = jnp.where(tri[None, :, :, None], W, 0.0)
    y_intra = jnp.einsum("bijh,bjhd->bihd", W, xh.astype(jnp.float32))
    # inter: y_i += exp(cw_i) C_i · h_in
    Ch = jnp.einsum("bis,bhsd->bihd", Cm.astype(jnp.float32),
                    s_in.astype(jnp.float32))
    y = y_intra + jnp.exp(cw)[..., None] * Ch
    # state: h_out = exp(cw_L) h_in + Σ_j exp(cw_L − cw_j) dt_j B_j ⊗ x_j
    wL = cw[:, -1]                                    # [B,H]
    dec = jnp.exp(wL[:, None] - cw) * dt.astype(jnp.float32)   # [B,L,H]
    h_new = jnp.einsum("bjs,bjh,bjhd->bhsd", Bm.astype(jnp.float32), dec,
                       xh.astype(jnp.float32))
    h_out = jnp.exp(wL)[..., None, None] * s_in.astype(jnp.float32) + h_new
    return y.astype(xh.dtype), h_out


def mamba2(
    params: dict,
    cfg: SSMCfg,
    x: jax.Array,
    *,
    chunk_parent: jax.Array,
    prev_pows: jax.Array,
    valid: jax.Array,
    initial_state: Optional[dict] = None,
    conv_ctx: Optional[jax.Array] = None,
    capture: Optional[dict] = None,
    return_states: bool = False,
):
    """x: [B, S, D] (pre-normed); returns [B, S, D] (+ states / captures).

    Partition gateway (paper App. B.7): ``initial_state`` seeds root chunks
    (chunk_parent = −1); ``conv_ctx`` [B, ≥K−1, conv_dim] supplies the conv
    inputs of relayed ancestor tokens (prev slots −2…); ``capture`` maps
    cut-name → dict(chunk=int, conv_pos=idx array) and returns the state at
    the cut chunk + conv inputs at the path tail, with grad_fn intact.
    """
    B, S, D = x.shape
    di = cfg.d_inner(D)
    H = cfg.n_heads(D)
    ds, hd, K = cfg.d_state, cfg.head_dim, cfg.conv_kernel

    z = x @ params["in_z"]
    xc0 = x @ params["in_x"]
    Bm0 = x @ params["in_B"]
    Cm0 = x @ params["in_C"]
    dt = x @ params["in_dt"]
    # depthwise causal conv applied per stream (identical math to conv over
    # the concatenation; avoids any sharded-dim concat/split)
    cw, cb = params["conv_w"], params["conv_b"]
    pp = prev_pows[..., :K - 1]

    def cx(s, e):
        return None if conv_ctx is None else conv_ctx[..., s:e]

    xc = jax.nn.silu(tree_causal_conv(xc0, cw[:, :di], cb[:di], pp,
                                      cx(0, di)))
    Bm = jax.nn.silu(tree_causal_conv(Bm0, cw[:, di:di + ds],
                                      cb[di:di + ds], pp, cx(di, di + ds)))
    Cm = jax.nn.silu(tree_causal_conv(Cm0, cw[:, di + ds:], cb[di + ds:],
                                      pp, cx(di + ds, di + 2 * ds)))

    dtf = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    vm = valid.astype(jnp.float32)[..., None]
    dtf = dtf * vm                                    # pads contribute nothing
    g = dtf * a                                       # and don't decay state

    xh = xc.reshape(B, S, H, hd)
    ch = chunkify
    xs = (ch(xh, cfg.chunk_size), ch(Bm, cfg.chunk_size),
          ch(Cm, cfg.chunk_size), ch(dtf, cfg.chunk_size),
          ch(g, cfg.chunk_size))
    zero = {"h": jnp.zeros((B, H, ds, hd), jnp.float32)}
    init = None if initial_state is None else initial_state

    def step(s, x_c):
        y, h = _ssd_chunk_step(s["h"], x_c)
        return y, {"h": h}

    ys, states = tree_chunk_scan(step, zero, xs, chunk_parent, init)
    y = (unchunkify(ys) + params["D"][:, None] * xh).astype(x.dtype)
    y = y.reshape(B, S, di)                           # (+ skip connection)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"]
    if capture is not None:
        conv_in = jnp.concatenate([xc0, Bm0, Cm0], axis=-1)  # pre-conv vals
        caps = {name: {"state": {"h": states["h"][:, c["chunk"] + 1]},
                       "conv": conv_in[:, c["conv_pos"]]}
                for name, c in capture.items()}
        return out, caps
    if return_states:
        return out, states
    return out


# ---------------------------------------------------------------------------
# Decode: single-token recurrence + conv ring
# ---------------------------------------------------------------------------

def init_mamba2_cache(batch: int, cfg: SSMCfg, d_model: int,
                      dtype=jnp.float32) -> dict:
    di = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    conv_dim = di + 2 * cfg.d_state
    return {
        "h": jnp.zeros((batch, H, cfg.d_state, cfg.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
    }


def mamba2_decode(params: dict, cfg: SSMCfg, x: jax.Array, cache: dict
                  ) -> tuple[jax.Array, dict]:
    """x: [B, 1, D]."""
    B, _, D = x.shape
    di = cfg.d_inner(D)
    H, ds, hd, K = cfg.n_heads(D), cfg.d_state, cfg.head_dim, cfg.conv_kernel
    z = x @ params["in_z"]
    xc = x @ params["in_x"]
    Bm = x @ params["in_B"]
    Cm = x @ params["in_C"]
    dt = x @ params["in_dt"]
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)  # [B,1,convdim]
    window = jnp.concatenate([cache["conv"], conv_in.astype(cache["conv"].dtype)],
                             axis=1)                  # [B,K,convdim]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + params["conv_b"])[:, None]
    xc, Bm, Cm = jnp.split(conv_out.astype(x.dtype), [di, di + ds], axis=-1)

    dtf = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    g = dtf * (-jnp.exp(params["A_log"]))             # [B,H]
    xh = xc.reshape(B, H, hd)
    h = cache["h"] * jnp.exp(g)[..., None, None] + jnp.einsum(
        "bs,bh,bhd->bhsd", Bm[:, 0].astype(jnp.float32), dtf,
        xh.astype(jnp.float32))
    y = jnp.einsum("bs,bhsd->bhd", Cm[:, 0].astype(jnp.float32), h)
    y = (y + params["D"][:, None] * xh.astype(jnp.float32)).astype(x.dtype)
    y = y.reshape(B, 1, di)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"]
    return out, {"h": h, "conv": window[:, 1:]}
