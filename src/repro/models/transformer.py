"""Model assembly: decoder-only (dense / MoE / SSM / hybrid), enc-dec, and
multimodal-prefix variants — all tree-aware, layers stacked for lax.scan.

``forward(cfg, params, batch, impl)`` returns per-token hidden states for
the *text* positions; ``loss_and_metrics`` turns them into the tree loss
(Eq. 4): gather each token's path-predecessor hidden row (prev_idx), apply
the LM head, weighted CE with λ_t.  Branching nodes' children gather the
same parent row, so gradients aggregate there exactly like the per-branch
baseline (Eq. 5).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (attention, init_attention,
                                    project_cross_kv)
from repro.models.layers import (embed, init_embedding, init_lm_head,
                                 init_mlp, init_rmsnorm, logits_from_hidden,
                                 mlp, rmsnorm, _dense_init)
from repro.models.moe import init_moe, moe
from repro.models.ssm.gdn import gdn, init_gdn
from repro.models.ssm.mamba2 import init_mamba2, mamba2
from repro.models.ssm.rwkv6 import (init_rwkv6_channelmix,
                                    init_rwkv6_timemix, rwkv6_channelmix,
                                    rwkv6_timemix)
from repro.sharding import shard_activation, shard_logits


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, key, kind: str) -> dict:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    p: dict = {}
    if kind == "dense":
        p["ln1"] = init_rmsnorm(D, dt)
        p["attn"] = init_attention(ks[0], cfg.attn, D, dt)
        p["ln2"] = init_rmsnorm(D, dt)
        p["mlp"] = init_mlp(ks[1], D, cfg.d_ff, cfg.mlp_activation,
                            cfg.mlp_bias, dt)
    elif kind == "moe":
        p["ln1"] = init_rmsnorm(D, dt)
        p["attn"] = init_attention(ks[0], cfg.attn, D, dt)
        p["ln2"] = init_rmsnorm(D, dt)
        p["moe"] = init_moe(ks[1], cfg.moe, D, cfg.mlp_activation, dt)
    elif kind == "rwkv6":
        p["ln1"] = init_rmsnorm(D, dt)
        p["tm"] = init_rwkv6_timemix(ks[0], cfg.ssm, D, dt)
        p["ln2"] = init_rmsnorm(D, dt)
        p["cm"] = init_rwkv6_channelmix(ks[1], D, cfg.d_ff, dt)
    elif kind == "mamba2":
        p["ln1"] = init_rmsnorm(D, dt)
        p["ssm"] = init_mamba2(ks[0], cfg.ssm, D, dt)
    elif kind == "gdn":
        p["ln1"] = init_rmsnorm(D, dt)
        p["ssm"] = init_gdn(ks[0], cfg.ssm, D, dt)
        p["ln2"] = init_rmsnorm(D, dt)
        p["mlp"] = init_mlp(ks[1], D, cfg.d_ff, cfg.mlp_activation,
                            cfg.mlp_bias, dt)
    elif kind == "encoder":
        p["ln1"] = init_rmsnorm(D, dt)
        p["attn"] = init_attention(ks[0], cfg.attn, D, dt)
        p["ln2"] = init_rmsnorm(D, dt)
        p["mlp"] = init_mlp(ks[1], D, cfg.d_ff, cfg.mlp_activation,
                            cfg.mlp_bias, dt)
    elif kind == "decoder_cross":
        p["ln1"] = init_rmsnorm(D, dt)
        p["attn"] = init_attention(ks[0], cfg.attn, D, dt)
        p["ln_x"] = init_rmsnorm(D, dt)
        p["xattn"] = init_attention(ks[1], cfg.attn, D, dt, cross=True)
        p["ln2"] = init_rmsnorm(D, dt)
        p["mlp"] = init_mlp(ks[2], D, cfg.d_ff, cfg.mlp_activation,
                            cfg.mlp_bias, dt)
    else:
        raise ValueError(kind)
    return p


def _apply_layer(cfg: ModelConfig, p: dict, kind: str, x: jax.Array,
                 meta: dict, impl: str, gw=None, capspec=None
                 ) -> tuple[jax.Array, jax.Array, dict]:
    """Returns (x, aux_loss_scalar, captures).

    gw: per-layer partition-gateway inputs (ancestor KV / SSM state / conv
    and shift contexts) — None outside partition mode.
    capspec: static per-cut capture plan — dict cut_name →
    {path_idx, cut_chunk, conv_pos, shift_pos} (numpy index arrays).
    """
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    caps: dict = {}
    gw = gw or {}
    if kind in ("dense", "moe", "encoder"):
        bidir = kind == "encoder"
        cap_idx = None if capspec is None else \
            {n: s["path_idx"] for n, s in capspec.items()}
        egw = gw.get("attn")
        if egw is not None:
            egw = {**egw, "pos": meta["anc_pos"],
                   "valid": meta.get("anc_valid")}
        a = attention(p["attn"], cfg.attn, rmsnorm(p["ln1"], x, eps),
                      pos_ids=meta["pos_ids"], kv_last=meta["kv_last"],
                      valid=meta["valid"], impl=impl, bidirectional=bidir,
                      extra_kv=egw, capture_idx=cap_idx)
        if cap_idx is not None:
            a, caps_a = a
            caps["attn"] = caps_a
        x = shard_activation(x + a)
        h = rmsnorm(p["ln2"], x, eps)
        if kind == "moe":
            m, auxd = moe(p["moe"], cfg.moe, h, meta["valid"],
                          cfg.mlp_activation)
            aux = aux + sum(auxd.values())
        else:
            m = mlp(p["mlp"], h, cfg.mlp_activation)
        x = shard_activation(x + m)
    elif kind == "rwkv6":
        gtm, gcm = gw.get("tm", {}), gw.get("cm", {})
        cap_tm = None if capspec is None else \
            {n: {"chunk": s["cut_chunk"], "shift_pos": s["shift_pos"]}
             for n, s in capspec.items()}
        t = rwkv6_timemix(p["tm"], cfg.ssm, rmsnorm(p["ln1"], x, eps),
                          chunk_parent=meta["chunk_parent"],
                          prev_idx=meta["prev_idx"], valid=meta["valid"],
                          initial_state=gtm.get("state"),
                          shift_ctx=gtm.get("shift"), capture=cap_tm)
        if cap_tm is not None:
            t, caps_tm = t
            caps["tm"] = caps_tm
        x = shard_activation(x + t)
        cap_cm = None if capspec is None else \
            {n: {"shift_pos": s["shift_pos"]} for n, s in capspec.items()}
        c = rwkv6_channelmix(p["cm"], rmsnorm(p["ln2"], x, eps),
                             meta["prev_idx"], gcm.get("shift"), cap_cm)
        if cap_cm is not None:
            c, caps_cm = c
            caps["cm"] = caps_cm
        x = shard_activation(x + c)
    elif kind == "mamba2":
        gs = gw.get("ssm", {})
        cap = None if capspec is None else \
            {n: {"chunk": s["cut_chunk"], "conv_pos": s["conv_pos"]}
             for n, s in capspec.items()}
        s = mamba2(p["ssm"], cfg.ssm, rmsnorm(p["ln1"], x, eps),
                   chunk_parent=meta["chunk_parent"],
                   prev_pows=meta["prev_pows"], valid=meta["valid"],
                   initial_state=gs.get("state"), conv_ctx=gs.get("conv"),
                   capture=cap)
        if cap is not None:
            s, caps_s = s
            caps["ssm"] = caps_s
        x = shard_activation(x + s)
    elif kind == "gdn":
        gs = gw.get("ssm", {})
        cap = None if capspec is None else \
            {n: {"chunk": s["cut_chunk"], "conv_pos": s["conv_pos"]}
             for n, s in capspec.items()}
        s = gdn(p["ssm"], cfg.ssm, rmsnorm(p["ln1"], x, eps),
                chunk_parent=meta["chunk_parent"],
                prev_pows=meta["prev_pows"], valid=meta["valid"],
                initial_state=gs.get("state"), conv_ctx=gs.get("conv"),
                capture=cap)
        if cap is not None:
            s, caps_s = s
            caps["ssm"] = caps_s
        x = shard_activation(x + s)
        m = mlp(p["mlp"], rmsnorm(p["ln2"], x, eps), cfg.mlp_activation)
        x = shard_activation(x + m)
    elif kind == "decoder_cross":
        a = attention(p["attn"], cfg.attn, rmsnorm(p["ln1"], x, eps),
                      pos_ids=meta["pos_ids"], kv_last=meta["kv_last"],
                      valid=meta["valid"], impl=impl)
        x = x + a
        kv = project_cross_kv(p["xattn"], cfg.attn, meta["enc_out"])
        c = attention(p["xattn"], cfg.attn, rmsnorm(p["ln_x"], x, eps),
                      pos_ids=meta["pos_ids"], kv_last=meta["kv_last"],
                      valid=meta["valid"], cross_kv=kv,
                      cross_valid=meta["enc_valid"])
        x = x + c
        m = mlp(p["mlp"], rmsnorm(p["ln2"], x, eps), cfg.mlp_activation)
        x = shard_activation(x + m)
    else:
        raise ValueError(kind)
    return x, aux, caps


def _layer_kinds(cfg: ModelConfig) -> list[str]:
    """Kind of every decoder layer, in order."""
    if cfg.family in ("dense", "vlm"):
        return ["dense"] * cfg.n_layers
    if cfg.family == "moe":
        fd = cfg.moe.first_dense_layers
        return ["dense"] * fd + ["moe"] * (cfg.n_layers - fd)
    if cfg.family == "ssm":
        if cfg.ssm.kind == "rwkv6":
            return ["rwkv6"] * cfg.n_layers
        if cfg.ssm.kind == "gdn":
            return ["gdn"] * cfg.n_layers
        return ["mamba2"] * cfg.n_layers
    if cfg.family == "hybrid":
        return ["mamba2"] * cfg.n_layers      # shared attn handled separately
    if cfg.family == "audio":
        return ["decoder_cross"] * cfg.encdec.dec_layers
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: dict = {
        "embed": init_embedding(keys[0], cfg.padded_vocab, cfg.d_model, dt),
        "final_norm": init_rmsnorm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_lm_head(keys[1], cfg.d_model,
                                         cfg.padded_vocab, dt)

    groups = layer_groups(cfg)
    gkeys = jax.random.split(keys[2], len(groups))
    stacks = []
    for (kind, n), gk in zip(groups, gkeys):
        lkeys = jax.random.split(gk, n)
        stacked = jax.vmap(
            lambda k, kind=kind: _init_layer(cfg, k, kind))(lkeys)
        stacks.append(stacked)
    params["layer_stacks"] = stacks

    if cfg.family == "hybrid":
        params["shared_attn"] = _init_layer(cfg, keys[3], "dense")
        if cfg.hybrid.concat_embed:
            params["shared_in"] = _dense_init(
                keys[4], (2 * cfg.d_model, cfg.d_model), dtype=dt)
    if cfg.family == "audio":
        e = cfg.encdec
        ekeys = jax.random.split(keys[5], e.enc_layers)
        params["encoder"] = jax.vmap(
            lambda k: _init_layer(cfg, k, "encoder"))(ekeys)
        params["enc_norm"] = init_rmsnorm(cfg.d_model, dt)
    return params


def layer_groups(cfg: ModelConfig) -> list[tuple[str, int]]:
    """Consecutive same-kind layer runs → (kind, count) scan groups."""
    groups: list[tuple[str, int]] = []
    for k in _layer_kinds(cfg):
        if groups and groups[-1][0] == k:
            groups[-1] = (k, groups[-1][1] + 1)
        else:
            groups.append((k, 1))
    return groups


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _scan_group(cfg: ModelConfig, stacked: dict, kind: str, x: jax.Array,
                meta: dict, impl: str, gw=None, capspec=None):
    """Scan a stacked layer group.  gw leaves have a leading per-layer dim
    (scan xs); captured tensors come back stacked the same way."""
    def body(carry, inp):
        x, aux = carry
        lp, gw_l = inp
        x, a, caps = _apply_layer(cfg, lp, kind, x, meta, impl, gw_l,
                                  capspec)
        return (x, aux + a), caps

    if cfg.remat == "full":
        # activation checkpointing: recompute the layer in the backward
        # pass instead of saving its internals (per-chunk attention
        # probabilities etc. dominate temp memory otherwise — §Perf)
        body = jax.checkpoint(body)

    (x, aux), caps = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stacked, gw or {}))
    if capspec is None:
        return x, aux
    return x, aux, caps


def _mm_prefix_meta(cfg: ModelConfig, batch: dict) -> dict:
    """Combine a multimodal embedding prefix with the text metadata."""
    F = batch["extra_embeds"].shape[1]
    B, S = batch["tokens"].shape
    tot = F + S
    pos = jnp.concatenate(
        [jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F)),
         batch["pos_ids"] + F], axis=1)
    kv_last = jnp.concatenate(
        [jnp.full((B, F), tot - 1, jnp.int32),
         jnp.where(batch["kv_last"] >= 0, batch["kv_last"] + F, -1)], axis=1)
    prev = jnp.concatenate(
        [jnp.full((B, F), -1, jnp.int32),
         jnp.where(batch["prev_idx"] >= 0, batch["prev_idx"] + F, -1)],
        axis=1)
    valid = jnp.concatenate(
        [jnp.ones((B, F), bool), batch["valid"]], axis=1)
    return dict(pos_ids=pos, kv_last=kv_last, prev_idx=prev, valid=valid,
                prefix_len=F)


def forward(cfg: ModelConfig, params: dict, batch: dict,
            impl: str = "ref") -> tuple[jax.Array, jax.Array]:
    """Returns (hidden [B, S_text, D] post-final-norm, aux_loss)."""
    group_kinds = [g[0] for g in layer_groups(cfg)]
    dt = _dtype(cfg)
    F = 0
    if cfg.family == "vlm" or (cfg.frontend and cfg.family != "audio"):
        meta = _mm_prefix_meta(cfg, batch)
        F = meta.pop("prefix_len")
        x = jnp.concatenate(
            [batch["extra_embeds"].astype(dt),
             embed(params["embed"], batch["tokens"])], axis=1)
    else:
        meta = dict(pos_ids=batch["pos_ids"], kv_last=batch["kv_last"],
                    prev_idx=batch["prev_idx"], valid=batch["valid"])
        x = embed(params["embed"], batch["tokens"])
    for k in ("chunk_parent", "prev_pows"):
        if k in batch:
            meta[k] = batch[k]
    x = shard_activation(x)

    if cfg.family == "audio":
        B, Fr = batch["extra_embeds"].shape[:2]
        enc_valid = batch.get("extra_valid",
                              jnp.ones((B, Fr), bool))
        enc_meta = dict(pos_ids=jnp.broadcast_to(
            jnp.arange(Fr, dtype=jnp.int32), (B, Fr)),
            kv_last=jnp.full((B, Fr), Fr - 1, jnp.int32),
            prev_idx=jnp.full((B, Fr), -1, jnp.int32), valid=enc_valid)
        enc_x = batch["extra_embeds"].astype(dt)
        enc_x, _ = _scan_group(cfg, params["encoder"], "encoder", enc_x,
                               enc_meta, impl)
        meta["enc_out"] = rmsnorm(params["enc_norm"], enc_x, cfg.norm_eps)
        meta["enc_valid"] = enc_valid

    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "hybrid":
        x, aux = _hybrid_forward(cfg, params, x, meta, impl)
    else:
        for stacked, kind in zip(params["layer_stacks"], group_kinds):
            x, a = _scan_group(cfg, stacked, kind, x, meta, impl)
            aux = aux + a

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if F:
        x = x[:, F:]
    return x, aux


def _hybrid_forward(cfg: ModelConfig, params: dict, x: jax.Array,
                    meta: dict, impl: str) -> tuple[jax.Array, jax.Array]:
    """Zamba2-style: scan mamba2 stages, shared attn block every k layers."""
    emb0 = x
    stacked = params["layer_stacks"][0]
    L = cfg.n_layers
    k = cfg.hybrid.attn_every
    aux = jnp.zeros((), jnp.float32)
    i = 0
    while i < L:
        j = min(i + k, L)
        stage = jax.tree.map(lambda a: a[i:j], stacked)
        x, a = _scan_group(cfg, stage, "mamba2", x, meta, impl)
        aux = aux + a
        # shared attention block after each stage (same params every time);
        # input optionally [x ; embed0] down-projected (Zamba2), output
        # contributes its block *delta* to the residual stream.
        if cfg.hybrid.concat_embed:
            h_in = jnp.concatenate([x, emb0], axis=-1) @ params["shared_in"]
        else:
            h_in = x
        h_out, a2, _ = _apply_layer(cfg, params["shared_attn"], "dense",
                                    h_in, meta, impl)
        x = x + (h_out - h_in)
        aux = aux + a2
        i = j
    return x, aux


# ---------------------------------------------------------------------------
# Partition mode (Redundancy-Free Tree Partitioning, paper §3.3 / App. B)
# ---------------------------------------------------------------------------

def partition_forward(cfg: ModelConfig, params: dict, batch: dict,
                      gw_in, capspecs: dict, impl: str):
    """One partition's DFS forward with gateway inputs and captures.

    gw_in: None (root partition) or dict:
      "g{i}"      → per-scan-group gateway (leaves with leading layer dim):
                    attention {"attn": {k, v, pos}}, SSM {"ssm": {state,
                    conv}}, rwkv6 {"tm": {state, shift}, "cm": {shift}}.
      "shared{s}" → hybrid shared-block application s (single-layer gw).
    capspecs: static dict cut_name → {path_idx, cut_chunk, conv_pos,
      shift_pos} (host-planned, core/partition.py).

    Returns (hidden, aux_loss, captures) — captures mirror gw structure and
    retain grad_fn: the orchestrator (core/gateway.py) relays them to child
    partitions and chains their cotangents back (paper App. B.6).
    """
    if cfg.family in ("vlm", "audio"):
        raise NotImplementedError(
            "partitioned training currently covers dense/moe/ssm/hybrid")
    groups = layer_groups(cfg)
    meta = dict(pos_ids=batch["pos_ids"], kv_last=batch["kv_last"],
                prev_idx=batch["prev_idx"], valid=batch["valid"])
    for k in ("chunk_parent", "prev_pows", "anc_pos", "anc_valid"):
        if k in batch:
            meta[k] = batch[k]
    x = shard_activation(embed(params["embed"], batch["tokens"]))

    aux = jnp.zeros((), jnp.float32)
    caps_all: dict = {}
    gw_in = gw_in or {}
    if cfg.family == "hybrid":
        emb0 = x
        stacked = params["layer_stacks"][0]
        gw0 = gw_in.get("g0")
        L, step = cfg.n_layers, cfg.hybrid.attn_every
        i = si = 0
        caps_stages = []
        while i < L:
            j = min(i + step, L)
            stage = jax.tree.map(lambda a: a[i:j], stacked)
            gws = None if gw0 is None else \
                jax.tree.map(lambda a: a[i:j], gw0)
            x, a, caps = _scan_group(cfg, stage, "mamba2", x, meta, impl,
                                     gw=gws, capspec=capspecs)
            caps_stages.append(caps)
            aux = aux + a
            if cfg.hybrid.concat_embed:
                h_in = jnp.concatenate([x, emb0], axis=-1) \
                    @ params["shared_in"]
            else:
                h_in = x
            gw_sh = gw_in.get(f"shared{si}")
            if gw_sh is not None:           # stored with leading layer axis
                gw_sh = jax.tree.map(lambda a: a[0], gw_sh)
            h_out, a2, caps_sh = _apply_layer(
                cfg, params["shared_attn"], "dense", h_in, meta, impl,
                gw_sh, capspecs)
            caps_all[f"shared{si}"] = jax.tree.map(lambda a: a[None],
                                                   caps_sh)
            x = x + (h_out - h_in)
            aux = aux + a2
            i = j
            si += 1
        # stitch stage captures back into one [L, ...] stack per leaf
        caps_all["g0"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *caps_stages)
    else:
        for gi, (stacked, (kind, _)) in enumerate(
                zip(params["layer_stacks"], groups)):
            x, a, caps = _scan_group(cfg, stacked, kind, x, meta, impl,
                                     gw=gw_in.get(f"g{gi}"),
                                     capspec=capspecs)
            caps_all[f"g{gi}"] = caps
            aux = aux + a

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux, caps_all


def partition_loss(cfg: ModelConfig, params: dict, batch: dict, gw_in,
                   capspecs: dict, impl: str = "ref"):
    """Loss *sum* for one partition (λ already full-tree) + boundary
    losses: child partitions' first tokens are predicted by this
    partition's hidden states at the cut nodes (extra_pos/label/weight).

    Returns ((loss, captures), metrics)."""
    hidden, aux, caps = partition_forward(cfg, params, batch, gw_in,
                                          capspecs, impl)
    head = params.get("lm_head")

    prev = batch["prev_idx"]
    w = jnp.where(prev >= 0, batch["weight"], 0.0)
    h_prev = jnp.take_along_axis(hidden, jnp.maximum(prev, 0)[..., None],
                                 axis=1)
    logits = shard_logits(logits_from_hidden(params["embed"], head, h_prev))
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, batch["tokens"][..., None], axis=-1)
    nll = lse - lab[..., 0]
    loss = jnp.sum(w * nll)

    if "extra_pos" in batch and batch["extra_pos"].shape[-1] > 0:
        h_b = jnp.take_along_axis(hidden, batch["extra_pos"][..., None],
                                  axis=1)
        lg = logits_from_hidden(params["embed"], head, h_b)
        lse_b = jax.nn.logsumexp(lg, axis=-1)
        lab_b = jnp.take_along_axis(lg, batch["extra_label"][..., None],
                                    axis=-1)[..., 0]
        loss = loss + jnp.sum(batch["extra_weight"] * (lse_b - lab_b))

    metrics = {"weight_sum": jnp.sum(w)
               + (jnp.sum(batch["extra_weight"])
                  if "extra_pos" in batch else 0.0),
               # token CE only (no router/z aux) — the drivers aggregate
               # this into a per-token nll comparable to token_nll_mean
               "nll_sum": loss}
    return (loss + aux, caps), metrics


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def loss_and_metrics(cfg: ModelConfig, params: dict, batch: dict,
                     impl: str = "ref") -> tuple[jax.Array, dict]:
    """Tree loss (Eq. 4): Σ_t λ_t · CE(logits[prev(t)], token_t) / #trees."""
    hidden, aux = forward(cfg, params, batch, impl)
    prev = batch["prev_idx"]
    w = jnp.where(prev >= 0, batch["weight"], 0.0)
    h_prev = jnp.take_along_axis(hidden, jnp.maximum(prev, 0)[..., None],
                                 axis=1)
    head = params.get("lm_head")
    logits = logits_from_hidden(params["embed"], head, h_prev)
    logits = shard_logits(logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, batch["tokens"][..., None].astype(
        jnp.int32), axis=-1)[..., 0]
    nll = lse - lab
    denom = jnp.asarray(batch.get("num_trees", 1), jnp.float32)
    loss = jnp.sum(w * nll) / denom
    total = loss + aux
    metrics = {"loss": loss, "aux_loss": aux,
               "weight_sum": jnp.sum(w),
               # un-normalized token CE (no MoE aux, no tree denominator):
               # the engine accumulates this on-device across microbatch
               # executions and divides by weight_sum once at logging time
               "nll_sum": jnp.sum(w * nll),
               "token_nll_mean": jnp.sum(w * nll) / jnp.maximum(
                   jnp.sum(w), 1e-9)}
    return total, metrics
