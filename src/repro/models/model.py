"""Public model API: init / loss / forward + host-side batch preparation."""
from __future__ import annotations

from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.packing import TreeBatch
from repro.models.layers import prev_powers
from repro.models.transformer import (forward, init_params, layer_groups,
                                      loss_and_metrics)

__all__ = ["init_params", "forward", "loss_and_metrics", "prepare_batch",
           "needs_chunks", "max_conv_taps", "layer_groups"]


def needs_chunks(cfg: ModelConfig) -> bool:
    return cfg.ssm is not None


def max_conv_taps(cfg: ModelConfig) -> int:
    """How many path-predecessor gathers the model needs (conv K−1)."""
    if cfg.ssm is None:
        return 0
    if cfg.ssm.kind == "rwkv6":
        return 1                      # token shift only (uses prev_idx)
    return cfg.ssm.conv_kernel - 1


def prepare_batch(cfg: ModelConfig, tb: TreeBatch,
                  extra_embeds: Optional[np.ndarray] = None, *,
                  num_trees: Optional[int] = None) -> dict:
    """TreeBatch (host numpy) → jnp input dict for forward/loss.

    ``num_trees`` overrides the loss normalizer (mean over trees): when a
    step trains more trees than the packed batch holds — oversized trees
    riding the partition waves — the packed loss must divide by the
    step's FULL tree count so both shares sum to one mean-over-trees
    objective."""
    d: dict[str, Any] = {
        "tokens": jnp.asarray(tb.tokens),
        "pos_ids": jnp.asarray(tb.pos_ids),
        "kv_last": jnp.asarray(tb.kv_last),
        "weight": jnp.asarray(tb.weight),
        "prev_idx": jnp.asarray(tb.prev_idx),
        "valid": jnp.asarray(tb.valid),
        "num_trees": tb.num_trees if num_trees is None else num_trees,
    }
    if needs_chunks(cfg):
        assert tb.chunk_parent is not None, \
            f"{cfg.name} needs chunk-aligned serialization (SSM family)"
        d["chunk_parent"] = jnp.asarray(tb.chunk_parent)
        k = max(1, max_conv_taps(cfg))
        d["prev_pows"] = jnp.asarray(prev_powers(tb.prev_idx, k))
    if extra_embeds is not None:
        d["extra_embeds"] = jnp.asarray(extra_embeds)
    elif tb.extra_embeds is not None:
        d["extra_embeds"] = jnp.asarray(tb.extra_embeds)
    elif cfg.frontend is not None:
        # stub frontend: zeros of the configured prefix length
        B = tb.tokens.shape[0]
        d["extra_embeds"] = jnp.zeros(
            (B, cfg.frontend_len, cfg.d_model),
            jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    return d
