"""Shared layer primitives: norms, RoPE, MLPs, embeddings.

Functional style: every layer is ``init_*(key, ...) -> params`` plus an
apply function taking ``(params, x, ...)``.  Params are plain dicts of
jnp arrays so they stack cleanly for ``lax.scan`` over layers and map
directly onto sharding rules (repro/sharding.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import tp_out_proj


def _dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# RoPE — applied per explicit position id (depth positions for tree mode)
# --------------------------------------------------------------------------

def rope(x: jax.Array, pos_ids: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; pos_ids: [..., S] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = pos_ids[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]                      # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, activation: str,
             bias: bool = False, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict = {}
    if activation == "swiglu":
        p["wi_gate"] = _dense_init(k1, (d_model, d_ff), dtype=dtype)
        p["wi_up"] = _dense_init(k2, (d_model, d_ff), dtype=dtype)
    else:
        p["wi_up"] = _dense_init(k2, (d_model, d_ff), dtype=dtype)
    p["wo"] = _dense_init(k3, (d_ff, d_model), dtype=dtype)
    if bias:
        p["bi"] = jnp.zeros((d_ff,), dtype)
        p["bo"] = jnp.zeros((d_model,), dtype)
    return p


def mlp(params: dict, x: jax.Array, activation: str) -> jax.Array:
    if activation == "swiglu":
        g = x @ params["wi_gate"]
        u = x @ params["wi_up"]
        h = jax.nn.silu(g) * u
    elif activation == "squared_relu":
        h = x @ params["wi_up"]
        if "bi" in params:
            h = h + params["bi"]
        r = jax.nn.relu(h)
        h = r * r
    elif activation == "relu":
        h = jax.nn.relu(x @ params["wi_up"])
    else:
        raise ValueError(activation)
    y = tp_out_proj(h, params["wo"])
    if "bo" in params:
        y = y + params["bo"]
    return y


# --------------------------------------------------------------------------
# Embedding / LM head
# --------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32) -> dict:
    return {"table": _dense_init(key, (vocab, d_model), scale=1.0, dtype=dtype)}


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return params["table"][tokens]


def logits_from_hidden(emb_params: dict, head_params: Optional[dict],
                       h: jax.Array) -> jax.Array:
    """LM head; tied embeddings when head_params is None."""
    w = emb_params["table"].T if head_params is None else head_params["w"]
    return (h @ w.astype(h.dtype)).astype(jnp.float32)


def init_lm_head(key, d_model: int, vocab: int, dtype=jnp.float32) -> dict:
    return {"w": _dense_init(key, (d_model, vocab), dtype=dtype)}


# --------------------------------------------------------------------------
# Tree-aware gathers (token shift / causal conv by path predecessor)
# --------------------------------------------------------------------------

def gather_prev(x: jax.Array, prev_idx: jax.Array,
                ctx: Optional[jax.Array] = None) -> jax.Array:
    """x: [B, S, D]; prev_idx: [B, S].

    Index semantics: ≥0 → x row; −1 → no predecessor (zeros);
    −(2+j) → gateway context ``ctx[:, Tc−1−j]`` (partition boundaries:
    slot −2 is the immediate relayed ancestor, −3 the one before, …).

    Returns x at each token's *path predecessor* — the tree-correct
    replacement for `roll(x, 1)` token-shift, exact across branch *and
    partition* boundaries because prev_idx follows the tree, not DFS order.
    """
    safe = jnp.maximum(prev_idx, 0)
    # (A vmap-over-batch formulation was tried for pjit friendliness and
    # lowers to the *identical* partitioned HLO — §Perf rwkv6 iter log.)
    g = jnp.take_along_axis(x, safe[..., None], axis=1)
    out = jnp.where((prev_idx >= 0)[..., None], g, 0.0)
    if ctx is not None:
        Tc = ctx.shape[1]
        ci = Tc + prev_idx + 1                 # −2 → Tc−1, −3 → Tc−2, …
        in_ctx = (prev_idx <= -2) & (ci >= 0)
        gc = jnp.take_along_axis(ctx.astype(x.dtype),
                                 jnp.clip(ci, 0, Tc - 1)[..., None], axis=1)
        out = jnp.where(in_ctx[..., None], gc, out)
    return out.astype(x.dtype)


def prev_powers(prev_idx: np.ndarray, k: int) -> np.ndarray:
    """Host-side: indices of the 1..k-th path-predecessors. [B, S, k].

    conv window for token t = x[prev^k(t)], ..., x[prev^1(t)], x[t] — the
    tree-correct causal-conv context (paper §3.2(ii)) as pure gathers.
    Gateway slots chain: prev(−(2+j)) = −(3+j); prev(−1) = −1.
    """
    B, S = prev_idx.shape
    out = np.full((B, S, k), -1, dtype=np.int32)
    cur = prev_idx.copy()
    for j in range(k):
        out[:, :, j] = cur
        nxt = np.where(cur <= -2, cur - 1, -1).astype(np.int32)
        valid = cur >= 0
        rows = np.broadcast_to(np.arange(B)[:, None], cur.shape)
        nxt[valid] = prev_idx[rows[valid], cur[valid]]
        cur = nxt
    return out


def tree_causal_conv(x: jax.Array, conv_w: jax.Array,
                     conv_b: Optional[jax.Array],
                     prev_pows: jax.Array,
                     ctx: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv along the *tree path* via predecessor gathers.

    x: [B, S, D]; conv_w: [K, D] (tap K−1 is the current token);
    prev_pows: [B, S, K−1] int32 (prev^1 ... prev^(K−1));
    ctx: optional [B, ≥K−1, D] relayed ancestor values for gateway slots.
    Equivalent to causal_conv1d on each root-to-leaf path independently.
    """
    K = conv_w.shape[0]
    acc = x * conv_w[K - 1]
    for j in range(K - 1):
        # tap K-2-j multiplies prev^{j+1}
        xs = gather_prev(x, prev_pows[..., j], ctx)
        acc = acc + xs * conv_w[K - 2 - j]
    if conv_b is not None:
        acc = acc + conv_b
    return acc
