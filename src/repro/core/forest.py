"""Cross-tree forest grafting: schedule-level shared-prefix reuse.

The paper's Gradient Restoration computes each shared prefix once
*within* a tree; at the schedule level the dominant remaining redundancy
is *between* trees — the same system prompt / task template heads many
trajectories in one lookahead window, and each tree re-computes it
("Schedule-Level Shared-Prefix Reuse for LLM RL Training", PAPERS.md).

This module merges trees whose token-level heads share a long-enough
prefix into one grafted :class:`~repro.core.tree.TreeNode` forest, so
the cross-tree prefix is tokenized / forwarded / backwarded exactly once
per window and Gradient Restoration sums cotangents over *all* grafted
branches:

  head        a tree's shareable region is its maximal unary root chain
              (every token on it is a prefix of every path, so it can
              become an ancestor of foreign branches without changing any
              path's visibility or depth positions);
  trie        heads are sorted lexicographically by per-token
              ``(token, trained, advantage)`` keys and grouped into
              maximal runs whose consecutive longest-common-prefix is
              ≥ ``min_graft`` — the threshold keeps tiny overlaps from
              fragmenting nodes (each split costs chunk padding under
              SSM serialization and packing granularity everywhere);
  graft       each group becomes a radix tree of shared spine nodes with
              the members' remainders hanging below; remainders reuse the
              original node objects (a chain node containing a divergence
              offset is split, exactly like ``partition.split_long_nodes``
              — both pieces keep the node's λ since a unary chain has all
              K leaves beneath every node);
  weights     per-branch loss weights / advantages are preserved via a
              ``lam_map`` for ``serialize_tree``: unshared nodes keep
              their source tree's full-tree λ bit-exactly
              (``tree_lam_map``), a shared spine node gets
              λ = Σ_members λ_root — along a unary root chain λ is
              constant and equals the root's, so summing the member
              roots' λ reproduces the independent-training gradient for
              every shared token (all three loss modes, including
              per-branch RL advantages across formerly-separate trees).

The loss normalizer must then count SOURCE trees, not grafted roots —
the planner carries ``n_src`` through FitTree/OversizedTree.  Whether a
graft actually wins (saved unique tokens vs. chunk-padding growth, row
fragmentation and gateway fan-out when the merged forest goes oversized)
is the cost model's call: ``core/plan_cost.graft_gain``.

Pure numpy/host code — no jax imports, safe on planner worker threads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .tree import TrajectoryTree, TreeNode, tree_lam_map


@dataclass
class Graft:
    """≥2 source trees merged under a shared-prefix spine."""
    tree: TrajectoryTree
    lam_map: dict[int, float]     # id(node) → λ for serialize_tree
    srcs: list[int]               # indices into the input tree list
    saved_tokens: int             # Σ source unique − grafted unique
    shared_tokens: int            # tokens on multi-source spine nodes


@dataclass
class _Member:
    idx: int
    chain: list[TreeNode]         # maximal unary root chain
    cum: np.ndarray               # cum[j] = head tokens before chain[j]
    tok: np.ndarray               # head token ids
    trn: np.ndarray               # head trained mask
    adv: np.ndarray               # head advantages (None ≡ 1.0)
    key: tuple                    # lexicographic sort key
    lam_root: float               # λ of the root (constant on the chain)


def _member(idx: int, tree: TrajectoryTree, loss_mode: str) -> _Member:
    chain = [tree.root]
    while len(chain[-1].children) == 1:
        chain.append(chain[-1].children[0])
    cum = np.cumsum([0] + [n.size for n in chain])
    if cum[-1]:
        tok = np.concatenate([n.tokens for n in chain])
        trn = np.concatenate([n.trained for n in chain])
        adv = np.concatenate([n.advantage if n.advantage is not None
                              else np.ones(n.size, np.float32)
                              for n in chain])
    else:
        tok = np.zeros(0, np.int32)
        trn = np.zeros(0, bool)
        adv = np.zeros(0, np.float32)
    key = tuple(zip(tok.tolist(), trn.tolist(), adv.tolist()))
    lam_root = tree_lam_map(tree.root, loss_mode)[id(tree.root)]
    return _Member(idx=idx, chain=chain, cum=cum, tok=tok, trn=trn,
                   adv=adv, key=key, lam_root=lam_root)


def _lcp_from(a: _Member, b: _Member, off: int) -> int:
    """Length of the common (token, trained, advantage) prefix of two
    heads beyond offset ``off``."""
    n = min(a.tok.size, b.tok.size)
    if n <= off:
        return 0
    eq = ((a.tok[off:n] == b.tok[off:n])
          & (a.trn[off:n] == b.trn[off:n])
          & (a.adv[off:n] == b.adv[off:n]))
    bad = np.flatnonzero(~eq)
    return int(bad[0]) if bad.size else n - off


def _runs(members: list[_Member], off: int, min_graft: int
          ) -> list[list[_Member]]:
    """Maximal runs of (lexicographically sorted) members whose
    consecutive LCP beyond ``off`` is ≥ min_graft.  Sortedness makes the
    run's set-LCP = LCP(first, last) ≥ min_graft."""
    groups: list[list[_Member]] = [[members[0]]]
    for prev, m in zip(members, members[1:]):
        if _lcp_from(prev, m, off) >= min_graft:
            groups[-1].append(m)
        else:
            groups.append([m])
    return groups


def _remainder(m: _Member, q: int, lam: dict[int, float]) -> list[TreeNode]:
    """The member's tree from head offset ``q`` onward, as subtree roots
    to hang under a shared spine node.  Reuses original node objects
    (their λ entries are already in ``lam``); only a chain node split at
    a mid-node offset allocates a new piece, which inherits the node's λ
    (unary chain ⇒ identical leaf set beneath both pieces)."""
    last = m.chain[-1]
    if q == m.tok.size:
        if last.children:
            return list(last.children)
        # whole tree consumed by the shared prefix: an empty leaf keeps
        # the branch (and its RL advantage) alive — K and λ are exact
        leaf = TreeNode(tokens=np.zeros(0, np.int32),
                        trained=np.zeros(0, bool),
                        branch_adv=last.branch_adv)
        lam[id(leaf)] = lam[id(last)]
        return [leaf]
    j = int(np.searchsorted(m.cum, q, side="right")) - 1
    node = m.chain[j]
    r = q - int(m.cum[j])
    if r == 0:
        return [node]
    piece = TreeNode(tokens=node.tokens[r:], trained=node.trained[r:],
                     advantage=None if node.advantage is None
                     else node.advantage[r:],
                     branch_adv=node.branch_adv)
    piece.children = list(node.children)
    lam[id(piece)] = lam[id(node)]
    return [piece]


def _build(group: list[_Member], off: int, lam: dict[int, float],
           min_graft: int, stats: dict) -> TreeNode:
    """Radix-merge a sorted group (set-LCP beyond ``off`` ≥ min_graft)
    into a shared spine node with member remainders below."""
    p = _lcp_from(group[0], group[-1], off)
    m0 = group[0]
    shared = TreeNode(tokens=m0.tok[off:off + p].copy(),
                      trained=m0.trn[off:off + p].copy(),
                      advantage=m0.adv[off:off + p].copy())
    lam[id(shared)] = float(sum(m.lam_root for m in group))
    stats["shared"] += p
    stats["saved"] += (len(group) - 1) * p
    nxt = off + p
    children: list[TreeNode] = []
    for m in group:
        if m.tok.size == nxt:
            children.extend(_remainder(m, nxt, lam))
    rest = [m for m in group if m.tok.size > nxt]
    if rest:
        for sub in _runs(rest, nxt, min_graft):
            if len(sub) >= 2:
                children.append(_build(sub, nxt, lam, min_graft, stats))
            else:
                children.extend(_remainder(sub[0], nxt, lam))
    shared.children = children
    return shared


def graft_trees(trees: Sequence[TrajectoryTree], *,
                loss_mode: str = "sep_avg", min_graft: int = 16
                ) -> tuple[list[Graft], list[int]]:
    """Detect shared heads across ``trees`` and merge them.

    Returns ``(grafts, passthrough)``: each graft merges ≥2 source trees
    (disjoint ``srcs``); ``passthrough`` lists the indices left alone.
    Source trees are never mutated — grafted structures reuse their node
    objects below the divergence points, so serializing a graft with its
    ``lam_map`` reproduces every source branch's weights bit-exactly on
    unshared nodes and sums λ over members on shared spine nodes.
    """
    min_graft = max(1, int(min_graft))
    members = sorted((_member(i, t, loss_mode)
                      for i, t in enumerate(trees)),
                     key=lambda m: m.key)
    grafts: list[Graft] = []
    passthrough: list[int] = []
    if not members:
        return grafts, passthrough
    for grp in _runs(members, 0, min_graft):
        if len(grp) < 2:
            passthrough.append(grp[0].idx)
            continue
        lam: dict[int, float] = {}
        for m in grp:
            lam.update(tree_lam_map(trees[m.idx].root, loss_mode))
        stats = {"shared": 0, "saved": 0}
        root = _build(grp, 0, lam, min_graft, stats)
        gt = TrajectoryTree(root=root)
        src_unique = sum(trees[m.idx].num_unique_tokens() for m in grp)
        grafts.append(Graft(tree=gt, lam_map=lam,
                            srcs=sorted(m.idx for m in grp),
                            saved_tokens=src_unique
                            - gt.num_unique_tokens(),
                            shared_tokens=stats["shared"]))
    return grafts, sorted(passthrough)
