# Tree Training core: DFS serialization (tree.py), packing (packing.py),
# Redundancy-Free Tree Partitioning (partition.py) and the differentiable
# partition-boundary runtime (gateway.py).
