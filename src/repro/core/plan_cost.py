"""Schedule-level cost model for Tree Packing plans.

The paper's Tree Packing fixes *what* shares a row (whole serialized
trees, mutually invisible under ``kv_last``); the remaining degrees of
freedom live at the schedule level — which trees share a step, how many
rows a step materializes, and which jit signatures the stream exercises.
This module scores candidate packings so the planner
(``train/planner.py``) can choose among placement heuristics and
lookahead windows instead of committing to per-step first-fit.

Three cost components, all in **token-cell units** (one token slot of one
row) so they add meaningfully:

  padded tokens      every materialized row cell that holds no valid
                     token still costs HBM traffic and (partially) MXU
                     work — the paper's padded-vs-unique overhead;
  compile-cache miss a shape signature the jit cache has not seen
                     triggers a trace+lower+compile stall, amortized here
                     as ``compile_miss`` token-cells per new packed
                     signature and ``wave_compile`` per new *wave* bucket
                     (pow2 rows × ancestor × cut × path — waves dominate
                     compile misses on oversized-heavy streams); with an
                     AOT warmup service filling the executable cache
                     ahead of time (``train/warmup``) the stall is hidden,
                     so runs with ``--aot-warmup`` may calibrate these
                     weights down (see ``benchmarks/run.py --calibrate``);
  live blocks        the tree-attention kernels skip KV blocks wholly
                     invisible to a query block (App. A.1), so attention
                     compute scales with the number of *live* blocks, not
                     rows×tri(S/b).  Packing many small trees into a row
                     keeps blocks near the diagonal and raises the skip
                     fraction; one long tree lights up its whole
                     lower-triangle;
  comm bytes         audited per-step collective wire bytes (shardlint's
                     ``comms.json`` byte table → ``wire_bytes_per_step``)
                     converted at ``comm_byte`` token-cells per byte.
                     Default weight 0.0: the packed step's collective
                     traffic is shape-independent (grad psum dominates),
                     so it only differentiates candidates on meshes where
                     rows change the boundary traffic — flip the weight
                     on when feeding a measured table.

Pure numpy/host code — no jax imports, safe to call from the planner's
background build threads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Hashable, Iterable, Sequence


def pow2(n: int, lo: int = 1) -> int:
    """Smallest power of two ≥ n (and ≥ lo) — THE shape-bucket rule, shared
    with the wave planner (core/gateway) so cost-model signature estimates
    match the buckets the engine actually compiles."""
    b = lo
    while b < n:
        b *= 2
    return b


def round_to_multiple(n: int, m: int) -> int:
    """Smallest multiple of m ≥ n (replica-balanced row counts)."""
    if m <= 1:
        return n
    return ((n + m - 1) // m) * m


def _tri(n: int) -> int:
    return n * (n + 1) // 2


def row_live_blocks(sizes: Sequence[int], block: int) -> int:
    """Estimated live (non-skipped) attention blocks for ONE row packed
    with trees of the given serialized lengths.

    Each tree of n tokens spans ``c = ceil(n/block)`` query blocks and its
    visible KV is confined to its own span, giving ~tri(c) live blocks;
    straddling a block boundary can light up at most one extra diagonal
    per tree, which we fold in when the tree is not block-aligned."""
    live = 0
    for n in sizes:
        if n <= 0:
            continue
        c = -(-n // block)
        live += _tri(c)
        if n % block:
            live += c - 1       # boundary straddle with the next resident
    return live


def _packing_live_blocks(row_sizes: Sequence[Sequence[int]], seq_len: int,
                         block: int) -> tuple[int, int]:
    """(live, causal) block counts for a candidate packing — the single
    definition both ``est_block_skip`` and ``score_packing`` share."""
    nq = max(seq_len // block, 1)
    causal = _tri(nq) * len(row_sizes)
    live = sum(min(row_live_blocks(s, block), _tri(nq)) for s in row_sizes)
    return live, causal


def est_block_skip(row_sizes: Sequence[Sequence[int]], seq_len: int,
                   block: int) -> float:
    """Estimated fraction of causal-schedule blocks the kernel skips for a
    candidate packing (rows → serialized tree lengths).  Empty rows are
    fully skipped (kv_last = −1 everywhere)."""
    live, causal = _packing_live_blocks(row_sizes, seq_len, block)
    return 1.0 - live / causal if causal else 0.0


class CompileCacheSim:
    """Host-side mirror of the jit signature cache: the planner charges a
    candidate only for signatures the stream has not already compiled.

    ``freq`` counts every commit per signature — the simulated hit
    frequency the AOT warmup service (``train/warmup``) uses to order its
    background compiles (hot buckets first)."""

    def __init__(self) -> None:
        self.seen: set[Hashable] = set()
        self.freq: dict[Hashable, int] = {}

    def misses(self, sigs: Iterable[Hashable]) -> int:
        return len({s for s in sigs if s not in self.seen})

    def commit(self, sigs: Iterable[Hashable]) -> None:
        for s in sigs:
            self.seen.add(s)
            self.freq[s] = self.freq.get(s, 0) + 1


def packed_signature(n_rows: int, seq_len: int) -> Hashable:
    return ("packed", n_rows, seq_len)


def wave_signature(n_rows: int, seq_len: int, anc: int, n_cuts: int,
                   path_len: int, n_extra: int) -> Hashable:
    """Jit signature of one partition wave: bucketed rows × ancestor
    length × cut count × capture-path length × boundary-extra count.
    Mirrors exactly what keys ``train/engine._wave_exec_fns`` retraces —
    the wave half of the compile-cache model (ROADMAP item 4) and the
    shape the analysis layer (``repro.analysis.signatures``) audits
    against the pow2 bucket universe."""
    return ("wave", n_rows, seq_len, anc, n_cuts, path_len, n_extra)


def wave_signature_of(wp, seq_len: int) -> Hashable:
    """The jit signature one ``core/gateway.WavePlan`` dispatches: every
    field is a shape the engine's ``_wave_exec_fns`` cache keys on
    (bucketed rows, ancestor pad, capspec count/path pad, boundary-extra
    pad).  Shared by the engine's executable-cache lookup, the planner's
    wave-aware compile charging and the signature lint — one definition,
    three consumers."""
    ncut = len(wp.capspecs)
    plen = (len(next(iter(wp.capspecs.values()))["path_idx"])
            if ncut else 0)
    n_extra = (wp.batch["extra_pos"].shape[1]
               if "extra_pos" in wp.batch else 0)
    return wave_signature(wp.batch["tokens"].shape[0], seq_len,
                          wp.anc_A_max, ncut, plen, n_extra)


@dataclass(frozen=True)
class CostWeights:
    """All weights are token-cells per unit of the component."""
    pad: float = 1.0             # per padded (invalid) token cell
    compile_miss: float = 4096.0  # per new packed jit signature
    wave_compile: float = 2048.0  # per new WAVE shape bucket (the wave
    #                               fwd+bwd pair is a shorter trace than
    #                               the fused packed step, but a miss
    #                               still stalls the step it lands in)
    live_block: float = 0.25      # per live block, scaled by block²
    comm_byte: float = 0.0        # per audited collective wire byte
    graft_saved: float = 1.0      # credit per cross-tree deduped cell
    graft_cut: float = 64.0       # per extra gateway boundary a graft adds


@dataclass
class PackingCost:
    """Score breakdown for one candidate packing (lower total = better)."""
    padded_tokens: int
    used_tokens: int
    est_skip: float              # estimated block-skip fraction
    live_blocks: int
    new_signatures: int
    total: float
    comm_bytes: int = 0          # audited wire bytes charged (0 = off)

    @property
    def pad_per_unique(self) -> float:
        return self.padded_tokens / max(self.used_tokens, 1)


DEFAULT_WEIGHTS = CostWeights()


def score_packing(
    row_sizes: Sequence[Sequence[int]],
    seq_len: int,
    *,
    block: int = 64,
    signatures: Iterable[Hashable] = (),
    cache: CompileCacheSim | None = None,
    weights: CostWeights = DEFAULT_WEIGHTS,
    comm_bytes: int = 0,
) -> PackingCost:
    """Score a candidate packing: ``row_sizes[r]`` lists the serialized
    token counts sharing materialized row r (include empty rows — their
    padding is real).  ``signatures`` are the jit signatures the candidate
    would execute; with a ``cache`` only unseen ones are charged.
    ``comm_bytes``: the candidate's audited per-step collective wire
    bytes (``wire_bytes_per_step`` over shardlint's byte table)."""
    used = sum(sum(s) for s in row_sizes)
    padded = len(row_sizes) * seq_len - used
    live, causal = _packing_live_blocks(row_sizes, seq_len, block)
    skip = 1.0 - live / causal if causal else 0.0
    new = ({s for s in signatures if s not in cache.seen}
           if cache is not None else set(signatures))
    miss = len(new)
    compile_cost = sum(
        weights.wave_compile if (isinstance(s, tuple) and s
                                 and s[0] == "wave")
        else weights.compile_miss
        for s in new)
    total = (weights.pad * padded
             + compile_cost
             + weights.live_block * live * block * block
             + weights.comm_byte * comm_bytes)
    return PackingCost(padded_tokens=padded, used_tokens=used,
                       est_skip=skip, live_blocks=live,
                       new_signatures=miss, total=total,
                       comm_bytes=comm_bytes)


def graft_gain(src_cells: int, merged_cells: int, seq_len: int,
               capacity: int,
               weights: CostWeights = DEFAULT_WEIGHTS, *,
               parts: int | None = None) -> float:
    """Net token-cell gain of one cross-tree graft (``core/forest``) —
    the schedule-level dedup term: graft iff the result is > 0.

    ``src_cells`` is the summed *serialized* length of the source trees
    (chunk padding included) and ``merged_cells`` the grafted tree's, so
    the credit already nets out the node fragmentation the merge adds
    under SSM chunk alignment.  When the merged forest no longer fits a
    packed row it partitions like any oversized tree — charge the wave
    rows' fragmentation (each partition materializes a full ``seq_len``
    row slot) plus ``graft_cut`` per extra gateway boundary the wider
    fan-out relays cotangents across.  Pass ``parts`` (the REAL
    partition count from ``core.partition.partition_tree``) when known:
    tree partitions cut at subtree boundaries, so the capacity quotient
    badly underestimates the wave rows a branchy forest materializes —
    the planner supplies the real count so losing grafts (padding out-
    weighing dedup) are rejected or bisected instead of shipped."""
    gain = weights.graft_saved * (src_cells - merged_cells)
    if merged_cells > seq_len:
        if parts is None:
            parts = -(-merged_cells // max(capacity, 1))
        frag = parts * seq_len - merged_cells
        gain -= weights.pad * max(frag, 0)
        gain -= weights.graft_cut * (parts - 1)
    return gain


def wire_bytes_per_step(comms_entry: dict) -> int:
    """One engine step's audited collective wire bytes, summed from a
    shardlint ``comms.json`` entrypoint entry (``engine.packed`` /
    ``session.step``): per-op ``wire_bytes_with_loops`` from the
    ``collectives`` summary.  Feed the result to ``score_packing``'s
    ``comm_bytes`` with a non-zero ``CostWeights.comm_byte``."""
    total = 0
    for s in comms_entry.get("collectives", {}).values():
        total += int(s.get("wire_bytes_with_loops",
                           s.get("wire_bytes", 0)))
    return total


def balanced_row_order(row_loads: Sequence[int], num_replicas: int
                       ) -> list[int]:
    """Permutation of rows such that splitting the reordered rows into
    ``num_replicas`` contiguous shards (how the data axis slices the
    leading dim) balances both the non-empty-row count (≤1 apart) and the
    token load: rows are dealt snake-wise, heaviest first.

    ``len(row_loads)`` must be a multiple of ``num_replicas`` (the planner
    rounds row counts up first)."""
    B = len(row_loads)
    if num_replicas <= 1 or B % num_replicas:
        return list(range(B))
    order = sorted(range(B), key=lambda r: (-row_loads[r], r))
    shards: list[list[int]] = [[] for _ in range(num_replicas)]
    for i, r in enumerate(order):
        rnd, j = divmod(i, num_replicas)
        if rnd % 2:
            j = num_replicas - 1 - j
        shards[j].append(r)
    out: list[int] = []
    for s in shards:
        out.extend(s)
    return out
