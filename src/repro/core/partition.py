"""Redundancy-Free Tree Partitioning (paper §3.3) — host-side planning.

When a tree exceeds the per-step token budget C, split it into connected
subtrees with cuts at node boundaries (so the partition dependency graph
is itself a tree → peak memory bounded by one root-to-leaf partition
path), sized to maximize per-partition token utilization.

The paper solves the bin-packing with OR-Tools; offline here we use a
deterministic greedy: bottom-up accumulation, closing the largest child
subtrees first when a node's accumulated open subtree exceeds C.  The
objective (minimize #partitions s.t. ≤C tokens each) is identical; the
optimality gap is measured in benchmarks/bench_partition.py.

Each partition gets:
  - its own DFS serialization (full-tree λ weights, depth-position offset,
    gateway prev slots −2.. for conv/token-shift context);
  - per-cut capture plans: which of its token positions lie on the path
    root→cut (their KV is relayed to the child partition), which chunk
    index holds the cut state (SSM), and the child's boundary first-token
    labels (their loss belongs to the parent — its hidden states predict
    them).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .tree import (SerializedTree, TrajectoryTree, TreeNode,
                   _branch_adv_sums, _leaf_counts, serialize_tree)


def split_long_nodes(tree: TrajectoryTree, max_len: int,
                     origin: Optional[dict] = None) -> TrajectoryTree:
    """Pre-split node segments longer than max_len into chains (semantics
    unchanged — a chain of nodes spells the same paths).  ``origin``, if
    given, is filled with id(new node) → id(source node) so id-keyed
    metadata (an external λ map) can be remapped onto the copy: every
    piece of a split chain has the same leaf set beneath it, hence the
    same λ, as the node it came from."""

    def rec(n: TreeNode) -> TreeNode:
        children = [rec(c) for c in n.children]
        if n.size <= max_len:
            m = TreeNode(tokens=n.tokens, trained=n.trained,
                         advantage=n.advantage, branch_adv=n.branch_adv)
            m.children = children
            if origin is not None:
                origin[id(m)] = id(n)
            return m
        head: Optional[TreeNode] = None
        cur: Optional[TreeNode] = None
        for s in range(0, n.size, max_len):
            e = min(s + max_len, n.size)
            piece = TreeNode(tokens=n.tokens[s:e], trained=n.trained[s:e],
                             advantage=None if n.advantage is None
                             else n.advantage[s:e],
                             branch_adv=n.branch_adv)
            if origin is not None:
                origin[id(piece)] = id(n)
            if head is None:
                head = piece
            else:
                cur.children = [piece]
            cur = piece
        cur.children = children
        return head

    return TrajectoryTree(root=rec(tree.root))


@dataclass
class CutPlan:
    """One cut node inside a partition → one child partition."""
    child_pid: int
    # indices (into this partition's DFS serialization) of *valid* tokens on
    # the path partition-root → cut node, in path order:
    path_token_idx: np.ndarray
    # chunk index (this partition's chunk grid) holding the SSM state at the
    # cut (= last chunk of the cut node); −1 when no SSM:
    cut_chunk: int
    # boundary loss: the child-partition root's first token is predicted by
    # this partition's hidden state at the cut node's last valid token:
    boundary_pos: int          # DFS index (here) of the predicting token
    boundary_label: int        # child's first token id
    boundary_weight: float     # λ of the child's first token


@dataclass
class TreePartition:
    pid: int
    parent_pid: int            # −1 for the root partition
    ser: SerializedTree
    anc_len: int               # #ancestor tokens (= depth-pos offset)
    cuts: list[CutPlan] = field(default_factory=list)
    num_paths_total: int = 1   # K of the full tree (loss normalizer)


def _chunk_pad(n: int, chunk: Optional[int]) -> int:
    if not chunk:
        return n
    return ((n + chunk - 1) // chunk) * chunk


def partition_tree(
    tree: TrajectoryTree,
    capacity: int,
    *,
    chunk_size: Optional[int] = None,
    loss_mode: str = "sep_avg",
    lam_map: Optional[dict] = None,
) -> list[TreePartition]:
    """Plan partitions for one tree.  Returns them in DFS (topological)
    order: parents precede children.

    ``lam_map`` (id(node) → λ on the *input* tree) overrides the
    loss_mode-derived weights — a grafted cross-tree forest
    (``core/forest``) carries its summed/preserved per-branch λ this way
    when the merged tree exceeds capacity and partitions like any
    oversized tree."""
    unit = chunk_size or 1
    assert capacity % unit == 0 or chunk_size is None
    origin: dict[int, int] = {}
    tree = split_long_nodes(tree, max(1, capacity - (unit - 1))
                            if chunk_size else capacity, origin)

    # full-tree weights
    g = _leaf_counts(tree.root)
    K = g[id(tree.root)]
    if lam_map is not None:
        ext = lam_map
        lam_map = {id(n): ext[origin[id(n)]] for n in tree.nodes()}
    elif loss_mode == "uniform":
        lam_map = {nid: 1.0 for nid in g}
    elif loss_mode == "rl":
        lam_map = {nid: a / K
                   for nid, a in _branch_adv_sums(tree.root).items()}
    elif loss_mode == "sep_avg":
        lam_map = {nid: gn / K for nid, gn in g.items()}
    else:
        raise ValueError(loss_mode)

    padded = {id(n): _chunk_pad(n.size, chunk_size)
              for n in tree.nodes()}

    # --- greedy bottom-up packing: decide the set of cut nodes ------------
    cut: set[int] = set()          # id(node) → starts a new partition
    open_size: dict[int, int] = {}

    def pack(n: TreeNode) -> int:
        for c in n.children:
            pack(c)
        total = padded[id(n)] + sum(open_size[id(c)] for c in n.children)
        if total > capacity:
            kids = sorted(n.children, key=lambda c: -open_size[id(c)])
            for c in kids:
                cut.add(id(c))
                total -= open_size[id(c)]
                if total <= capacity:
                    break
        assert total <= capacity, \
            f"node of {padded[id(n)]} tokens exceeds capacity {capacity}"
        open_size[id(n)] = total
        return total

    pack(tree.root)

    # --- materialize partitions in DFS order ------------------------------
    parts: list[TreePartition] = []

    def depth_tokens(path_nodes: list[TreeNode]) -> int:
        return sum(n.size for n in path_nodes)

    def build(root: TreeNode, parent_pid: int, anc_len: int) -> None:
        pid = len(parts)
        # pruned copy: descend until cut nodes; record cut children
        cut_children: list[tuple[TreeNode, TreeNode]] = []  # (pruned_anc, orig_child)
        lam_local: dict[int, float] = {}

        def prune(n: TreeNode) -> TreeNode:
            m = TreeNode(tokens=n.tokens, trained=n.trained,
                         advantage=n.advantage, branch_adv=n.branch_adv)
            lam_local[id(m)] = lam_map[id(n)]
            for c in n.children:
                if id(c) in cut:
                    cut_children.append((m, c))
                else:
                    m.children.append(prune(c))
            return m

        proot = prune(root)
        psub = TrajectoryTree(root=proot)
        ser = serialize_tree(psub, chunk_size=chunk_size, lam_map=lam_local,
                             depth_pos0=anc_len,
                             root_prev=-2 if parent_pid >= 0 or anc_len > 0
                             else -1)
        part = TreePartition(pid=pid, parent_pid=parent_pid, ser=ser,
                             anc_len=anc_len, num_paths_total=K)
        parts.append(part)

        # map pruned nodes → serialization node ids (DFS order coincides)
        order: list[TreeNode] = []

        def dfs(m: TreeNode):
            order.append(m)
            for c in m.children:
                dfs(c)

        dfs(proot)
        nid_of = {id(m): i for i, m in enumerate(order)}
        parent_of = {id(m): None for m in order}
        for m in order:
            for c in m.children:
                parent_of[id(c)] = m

        for anc_node, child in cut_children:
            # path partition-root → anc_node (inclusive): valid token idx
            chain = []
            cur = anc_node
            while cur is not None:
                chain.append(cur)
                cur = parent_of[id(cur)]
            chain.reverse()
            idxs = []
            for m in chain:
                nid = nid_of[id(m)]
                s, e = int(ser.node_start[nid]), int(ser.node_end[nid])
                idxs.extend(i for i in range(s, e) if ser.valid[i])
            nid = nid_of[id(anc_node)]
            e = int(ser.node_end[nid])
            cut_chunk = -1 if not chunk_size else (e - 1) // chunk_size
            # boundary loss: child's first token predicted from anc's last
            last_valid = idxs[-1]
            child_pid_placeholder = -1  # fixed after recursion ordering
            part.cuts.append(CutPlan(
                child_pid=child_pid_placeholder,
                path_token_idx=np.asarray(idxs, np.int32),
                cut_chunk=cut_chunk,
                boundary_pos=int(last_valid),
                boundary_label=int(child.tokens[0]),
                boundary_weight=float(lam_map[id(child)]
                                      * (1.0 if child.trained[0] else 0.0)
                                      * (child.advantage[0]
                                         if child.advantage is not None
                                         else 1.0)),
            ))

        # recurse into children partitions (DFS): anc_len grows by the path
        for cp, (_anc, child) in zip(part.cuts, cut_children):
            cp.child_pid = len(parts)
            build(child, pid, anc_len + len(cp.path_token_idx))

    build(tree.root, -1, 0)
    return parts


def partition_schedule_load(parts: list[TreePartition]) -> dict:
    """Schedule-level load summary of ONE partitioned tree, for the
    planner's cross-step balancing (train/planner): ``tokens`` is the row
    cells its waves must materialize (serialized, chunk-padded), ``depth``
    the number of waves it forces — the step's partitioned critical
    path — and ``width`` the widest single depth level (row pressure)."""
    depth: dict[int, int] = {}
    width: dict[int, int] = {}
    for p in parts:
        d = 0 if p.parent_pid < 0 else depth[p.parent_pid] + 1
        depth[p.pid] = d
        width[d] = width.get(d, 0) + 1
    return dict(tokens=sum(p.ser.n for p in parts),
                num_partitions=len(parts),
                depth=1 + max(depth.values()) if depth else 0,
                width=max(width.values()) if width else 0)


def choose_capacity(trees: list[TrajectoryTree], seq_len: int, *,
                    chunk_size: Optional[int] = None,
                    max_candidates: int = 4) -> int:
    """Planner-chosen partition capacity (the carried ROADMAP item): pick
    the per-partition token cap for a window's oversized trees from
    ``partition_schedule_load`` instead of a user-fixed ``--capacity``.

    Candidates are pow2 fractions of ``seq_len`` (so capture-path pads
    stay inside the pow2 signature buckets the engine compiles), scored
    in token-cell units: every partition materializes a full ``seq_len``
    wave-row slot, and each extra wave depth level is another dispatch
    on the step's critical path.  Ties keep the larger cap.  Partition
    *structure* depends only on token counts, so the probe partitions
    under ``sep_avg`` regardless of the training loss mode."""
    unit = chunk_size or 1
    cands: list[int] = []
    c = seq_len
    while c >= max(2 * unit, 32) and len(cands) < max_candidates:
        if c % unit == 0:
            cands.append(c)
        c //= 2
    if not cands:
        return seq_len
    best: Optional[tuple[float, int]] = None
    for cap in cands:                      # descending: ties keep larger
        cells = depth = 0
        for t in trees:
            load = partition_schedule_load(
                partition_tree(t, cap, chunk_size=chunk_size))
            cells += load["num_partitions"] * seq_len
            depth += load["depth"]
        score = cells + 0.25 * depth * seq_len
        if best is None or score < best[0]:
            best = (score, cap)
    return best[1]


def partition_token_counts(parts: list[TreePartition]) -> dict:
    """Accounting for the Fig.-5 benchmark."""
    unique = sum(int(p.ser.valid.sum()) for p in parts)
    with_pad = sum(p.ser.n for p in parts)
    return dict(num_partitions=len(parts), unique_tokens=unique,
                padded_tokens=with_pad)


def standard_partition_token_counts(
    tree: TrajectoryTree,
    capacity: int,
    *,
    chunk_size: Optional[int] = None,
    loss_mode: str = "sep_avg",
) -> int:
    """Token count of *standard* tree partitioning (no differentiable
    boundaries): each child partition re-includes all ancestor tokens
    (recomputed) — the paper's Fig.-5 middle bar.

    ``chunk_size``/``loss_mode`` must match the config being measured:
    chunked (SSM) serializations pad every node segment to the chunk grid
    and the re-included ancestor prefix pads the same way, so ignoring them
    under-counts the standard-partitioning bar."""
    parts = partition_tree(tree, capacity, chunk_size=chunk_size,
                           loss_mode=loss_mode)
    total = 0
    for p in parts:
        total += p.ser.n + _chunk_pad(p.anc_len, chunk_size)
    return total
