"""Packing serialized trees into fixed-shape training rows.

Generalizes sequence packing (Krell et al. 2021) to prefix trees (paper §2):
a row holds one or more whole DFS-serialized trees back to back.  Because
``kv_last`` already bounds visibility to the token's own subtree, packed
trees are mutually invisible with **no extra mask machinery** — the same
two-comparison predicate covers causality, branch separation and packing.

Produces ``TreeBatch`` — plain numpy arrays with static shapes, ready to be
fed to the jitted model (and sharded over the data axes).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from .tree import SerializedTree
from .partition import TreePartition


class DoesNotFitError(ValueError):
    """An item (tree / path / row set) exceeds the fixed packing budget.

    Raised explicitly so callers can distinguish "this tree is too big for
    the row" (recoverable: partition it or drop it) from genuine packer
    bugs, which should propagate."""


@dataclass
class TreeBatch:
    """Fixed-shape batch of packed DFS rows (+ per-token metadata)."""

    tokens: np.ndarray        # i32 [B, S]
    pos_ids: np.ndarray       # i32 [B, S]
    kv_last: np.ndarray       # i32 [B, S]   (−1 = invisible key)
    weight: np.ndarray        # f32 [B, S]   λ_t
    prev_idx: np.ndarray      # i32 [B, S]   (−1 = no loss for this token)
    valid: np.ndarray         # bool [B, S]
    chunk_parent: Optional[np.ndarray] = None  # i32 [B, C] (−1 = init state)
    num_trees: int = 1        # loss normalizer (mean over trees)
    extra_embeds: Optional[np.ndarray] = None  # f32 [B, T_src, D] frontend stub
    row_trees: Optional[np.ndarray] = None     # i32 [B] trees per row

    @property
    def shape(self) -> tuple[int, int]:
        return self.tokens.shape  # type: ignore[return-value]

    def row_slice(self, b: int) -> "TreeBatch":
        sl = lambda a: None if a is None else a[b:b + 1]
        if self.row_trees is not None:
            n = int(self.row_trees[b])
        else:
            # a tree root is the only valid token with no path predecessor
            n = int(((self.prev_idx[b] == -1) & self.valid[b]).sum())
        return TreeBatch(self.tokens[b:b + 1], self.pos_ids[b:b + 1],
                         self.kv_last[b:b + 1], self.weight[b:b + 1],
                         self.prev_idx[b:b + 1], self.valid[b:b + 1],
                         sl(self.chunk_parent), max(n, 1),
                         sl(self.extra_embeds), sl(self.row_trees))


def _empty_row(S: int) -> dict[str, np.ndarray]:
    return dict(
        tokens=np.zeros(S, np.int32),
        pos_ids=np.zeros(S, np.int32),
        kv_last=np.full(S, -1, np.int32),
        weight=np.zeros(S, np.float32),
        prev_idx=np.full(S, -1, np.int32),
        valid=np.zeros(S, bool),
    )


def plan_tree_rows(
    sizes: Sequence[int],
    seq_len: int,
    *,
    batch_size: Optional[int] = None,
    heuristic: str = "ffd",
) -> list[list[int]]:
    """Row *assignment* only — no arrays touched.  Returns rows as lists
    of item indices (items sorted and placed largest-first).

    heuristic 'ffd': first-fit decreasing (the historical packer);
    'bfd': best-fit decreasing (tightest row that still fits — fewer
    stranded holes on mixed-size streams).  The planner scores both with
    the cost model (core/plan_cost) and materializes the winner."""
    order = sorted(range(len(sizes)), key=lambda i: (-sizes[i], i))
    rows: list[list[int]] = []
    row_used: list[int] = []
    for i in order:
        n = sizes[i]
        if n > seq_len:
            raise DoesNotFitError(
                f"tree of {n} tokens does not fit row of {seq_len}; "
                "partition it first (core/partition.py)")
        fit = [r for r, used in enumerate(row_used) if used + n <= seq_len]
        if fit:
            r = fit[0] if heuristic == "ffd" else \
                min(fit, key=lambda r_: seq_len - row_used[r_] - n)
            rows[r].append(i)
            row_used[r] += n
        else:
            rows.append([i])
            row_used.append(n)

    if batch_size is not None:
        if len(rows) > batch_size:
            raise DoesNotFitError(
                f"{len(rows)} rows > batch_size {batch_size}")
        while len(rows) < batch_size:
            rows.append([])
    return rows


def materialize_tree_rows(
    trees: Sequence[SerializedTree],
    rows: Sequence[Sequence[int]],
    seq_len: int,
    *,
    chunk_size: Optional[int] = None,
    tree_counts: Optional[Sequence[int]] = None,
) -> TreeBatch:
    """Materialize a planned row assignment (``rows[r]`` = tree indices
    sharing row r, in placement order) into a fixed-shape TreeBatch.  If
    ``chunk_size`` is given the serializations must be chunk-aligned and
    rows carry a chunk_parent map.  ``tree_counts[i]`` is how many SOURCE
    trees serialization i represents (a grafted cross-tree forest counts
    all its members — the loss normalizer and ``row_trees`` accounting
    must see source trees, not grafted roots); default 1 each."""
    for r in rows:
        if sum(trees[i].n for i in r) > seq_len:
            raise DoesNotFitError(
                f"planned row of {sum(trees[i].n for i in r)} tokens "
                f"exceeds seq_len {seq_len}")
    B, S = len(rows), seq_len
    count = (lambda i: 1) if tree_counts is None \
        else (lambda i: int(tree_counts[i]))
    cols = {k: [] for k in
            ("tokens", "pos_ids", "kv_last", "weight", "prev_idx", "valid")}
    chunk_rows: list[np.ndarray] = []
    C = None if chunk_size is None else S // chunk_size

    for r in rows:
        row = _empty_row(S)
        cp = None if C is None else np.full(C, -1, np.int32)
        off = 0
        for i in r:
            t = trees[i]
            sl = slice(off, off + t.n)
            row["tokens"][sl] = t.tokens
            row["pos_ids"][sl] = t.pos_ids
            row["kv_last"][sl] = np.where(t.kv_last < 0, -1, t.kv_last + off)
            row["weight"][sl] = t.weight
            row["prev_idx"][sl] = np.where(t.prev_idx < 0, -1,
                                           t.prev_idx + off)
            row["valid"][sl] = t.valid
            if C is not None:
                assert off % chunk_size == 0 and t.n % chunk_size == 0, \
                    "SSM packing requires chunk-aligned trees"
                tc = t.chunk_parent_map(chunk_size)
                coff = off // chunk_size
                cp[coff:coff + len(tc)] = np.where(tc < 0, -1, tc + coff)
            off += t.n
        for k in cols:
            cols[k].append(row[k])
        if cp is not None:
            chunk_rows.append(cp)

    return TreeBatch(
        tokens=np.stack(cols["tokens"]),
        pos_ids=np.stack(cols["pos_ids"]),
        kv_last=np.stack(cols["kv_last"]),
        weight=np.stack(cols["weight"]),
        prev_idx=np.stack(cols["prev_idx"]),
        valid=np.stack(cols["valid"]),
        chunk_parent=np.stack(chunk_rows) if chunk_rows else None,
        num_trees=sum(count(i) for r in rows for i in r),
        row_trees=np.asarray([sum(count(i) for i in r) for r in rows],
                             np.int32),
    )


def pack_trees(
    trees: Sequence[SerializedTree],
    seq_len: int,
    *,
    batch_size: Optional[int] = None,
    chunk_size: Optional[int] = None,
) -> TreeBatch:
    """First-fit-decreasing pack of whole serialized trees into rows
    (plan + materialize in one call — the planner calls the two halves
    separately so it can score candidate assignments first).

    Every tree must fit in one row (use Redundancy-Free Tree Partitioning
    for larger trees — core/partition.py)."""
    rows = plan_tree_rows([t.n for t in trees], seq_len,
                          batch_size=batch_size)
    return materialize_tree_rows(trees, rows, seq_len,
                                 chunk_size=chunk_size)


def pack_linear_paths(
    trees_paths: Sequence[Sequence[dict[str, np.ndarray]]],
    seq_len: int,
    *,
    batch_size: Optional[int] = None,
    chunk_size: Optional[int] = None,
    loss_mode: str = "sep_avg",
) -> TreeBatch:
    """Baseline: pack *linearized per-branch sequences* (Eq. 7 serialization
    + standard sequence packing).  ``trees_paths[k]`` is the list of path
    dicts of tree k (from ``TrajectoryTree.linearize_paths``).  Loss weights
    are 1/K_k per trained token so the packed loss equals mean-over-trees of
    sep-avg — directly comparable with the tree-packed loss.

    loss_mode 'rl' additionally scales every path by its per-branch GRPO
    advantage (``branch_adv`` from ``linearize_paths``) — the dense
    per-path form of the RL model-update objective; 'uniform' drops the
    1/K normalizer (each replicated trained token weighs 1).
    """
    flat: list[dict[str, np.ndarray]] = []
    for ti, paths in enumerate(trees_paths):
        K = len(paths)
        for p in paths:
            q = dict(p)
            if loss_mode == "sep_avg":
                w = p["advantage"] / K
            elif loss_mode == "uniform":
                w = p["advantage"]
            elif loss_mode == "rl":
                w = p["advantage"] * p.get("branch_adv", 1.0) / K
            else:
                raise ValueError(loss_mode)
            q["_w"] = np.where(p["trained"], w, 0.0).astype(np.float32)
            q["_tree"] = ti
            flat.append(q)

    def aligned_len(n: int) -> int:
        if chunk_size is None:
            return n
        return ((n + chunk_size - 1) // chunk_size) * chunk_size

    order = sorted(range(len(flat)), key=lambda i: -len(flat[i]["tokens"]))
    rows: list[list[int]] = []
    row_used: list[int] = []
    for i in order:
        n = aligned_len(len(flat[i]["tokens"]))
        if n > seq_len:
            raise DoesNotFitError("path longer than row")
        for r, used in enumerate(row_used):
            if used + n <= seq_len:
                rows[r].append(i)
                row_used[r] += n
                break
        else:
            rows.append([i])
            row_used.append(n)
    if batch_size is not None:
        if len(rows) > batch_size:
            raise DoesNotFitError(
                f"{len(rows)} rows > batch_size {batch_size}")
        while len(rows) < batch_size:
            rows.append([])

    S = seq_len
    C = None if chunk_size is None else S // chunk_size
    out = {k: [] for k in
           ("tokens", "pos_ids", "kv_last", "weight", "prev_idx", "valid")}
    chunk_rows = []
    for r in rows:
        row = _empty_row(S)
        cp = None if C is None else np.full(C, -1, np.int32)
        off = 0
        for i in r:
            p = flat[i]
            n = len(p["tokens"])
            na = aligned_len(n)
            sl = slice(off, off + n)
            row["tokens"][sl] = p["tokens"]
            row["pos_ids"][sl] = p["pos_ids"]
            row["kv_last"][sl] = off + n - 1
            row["weight"][sl] = p["_w"]
            pv = np.arange(off - 1, off + n - 1, dtype=np.int32)
            pv[0] = -1
            row["prev_idx"][sl] = pv
            row["valid"][sl] = True
            if C is not None:
                c0, c1 = off // chunk_size, (off + na) // chunk_size
                for c in range(c0, c1):
                    cp[c] = -1 if c == c0 else c - 1
            off += na
        for k in out:
            out[k].append(row[k])
        if cp is not None:
            chunk_rows.append(cp)

    return TreeBatch(
        tokens=np.stack(out["tokens"]),
        pos_ids=np.stack(out["pos_ids"]),
        kv_last=np.stack(out["kv_last"]),
        weight=np.stack(out["weight"]),
        prev_idx=np.stack(out["prev_idx"]),
        valid=np.stack(out["valid"]),
        chunk_parent=np.stack(chunk_rows) if chunk_rows else None,
        num_trees=len(trees_paths),
        row_trees=np.asarray(
            [len({flat[i]["_tree"] for i in r}) for r in rows], np.int32),
    )


# ---------------------------------------------------------------------------
# Tree Packing over partitions (paper §3.3–3.4): pack the partition
# serializations of MANY trees into fixed-shape [B, S] rows, grouped by
# topological wave so every partition's parent lands in a strictly earlier
# wave (its gateway captures exist before the child runs).  Waves follow
# depth order in the partition tree; a depth level wider than ``max_rows``
# splits into several consecutive waves, all still after their parents'.
#
# Row discipline: wave-0 fragments carry no gateway, so any number can
# share a row (kv_last separates them, as with whole trees).  Wave ≥1
# fragments each own a row — their ancestor KV (extra_kv) is row-global.
# ---------------------------------------------------------------------------

@dataclass
class PackedPartition:
    """Placement of one partition fragment inside a wave batch."""
    tree: int                  # index into the forest's tree list
    pid: int                   # partition id within that tree
    row: int
    offset: int                # token offset inside the row


@dataclass
class PackedCut:
    """One cut of a placed partition, with row-absolute indices."""
    tree: int
    pid: int                   # parent partition (lives in this wave)
    child_pid: int
    row: int                   # parent's row
    path_idx: np.ndarray       # i32, absolute positions in the parent row
    cut_chunk: int             # absolute chunk index in the parent row
    boundary_pos: int          # absolute position of the predicting token
    boundary_label: int
    boundary_weight: float


@dataclass
class PackedWave:
    """One topological wave: fixed-shape rows + placement metadata."""
    arrays: dict[str, np.ndarray]          # [B, S] serialization columns
    slots: list[PackedPartition] = field(default_factory=list)
    cuts: list[PackedCut] = field(default_factory=list)

    @property
    def num_rows(self) -> int:
        return self.arrays["tokens"].shape[0]


def pack_partition_waves(
    forest: Sequence[Sequence[TreePartition]],
    seq_len: int,
    *,
    chunk_size: Optional[int] = None,
    max_rows: Optional[int] = None,
) -> list[PackedWave]:
    """Pack every partition of every tree into per-wave [B, S] rows.

    forest[t] is ``partition_tree(trees[t], capacity, ...)`` with
    capacity ≤ seq_len.  Returns waves in topological order; root waves'
    rows may hold several fragments, gateway-bearing waves one fragment
    per row.  ``max_rows`` bounds every wave's row count (same-depth
    fragments are independent, so a too-wide wave splits into several
    consecutive waves) — the partitioned path then never exceeds the
    activation footprint of a ``max_rows × seq_len`` packed step."""
    # wave index per partition (parent wave + 1; parents precede children)
    waves: list[list[tuple[int, int]]] = []
    for t, parts in enumerate(forest):
        wv: dict[int, int] = {}
        for p in parts:
            w = 0 if p.parent_pid < 0 else wv[p.parent_pid] + 1
            wv[p.pid] = w
            while len(waves) <= w:
                waves.append([])
            waves[w].append((t, p.pid))

    def materialize(placements: list[PackedPartition], B: int
                    ) -> PackedWave:
        S = seq_len
        C = None if chunk_size is None else S // chunk_size
        cols = {k: np.stack([_empty_row(S)[k] for _ in range(B)])
                for k in ("tokens", "pos_ids", "kv_last", "weight",
                          "prev_idx", "valid")}
        cp = None if C is None else np.full((B, C), -1, np.int32)
        cuts: list[PackedCut] = []
        for pl in placements:
            ser = forest[pl.tree][pl.pid].ser
            off = pl.offset
            sl = slice(off, off + ser.n)
            cols["tokens"][pl.row, sl] = ser.tokens
            cols["pos_ids"][pl.row, sl] = ser.pos_ids
            cols["kv_last"][pl.row, sl] = np.where(
                ser.kv_last < 0, -1, ser.kv_last + off)
            cols["weight"][pl.row, sl] = ser.weight
            # negative prev slots (−1 none, −2.. gateway) are offset-free
            cols["prev_idx"][pl.row, sl] = np.where(
                ser.prev_idx < 0, ser.prev_idx, ser.prev_idx + off)
            cols["valid"][pl.row, sl] = ser.valid
            if C is not None:
                assert off % chunk_size == 0 and ser.n % chunk_size == 0, \
                    "SSM wave packing requires chunk-aligned partitions"
                pc = ser.chunk_parent_map(chunk_size)
                coff = off // chunk_size
                cp[pl.row, coff:coff + len(pc)] = np.where(
                    pc < 0, pc, pc + coff)
            for c in forest[pl.tree][pl.pid].cuts:
                coff = 0 if chunk_size is None else off // chunk_size
                cuts.append(PackedCut(
                    tree=pl.tree, pid=pl.pid, child_pid=c.child_pid,
                    row=pl.row,
                    path_idx=c.path_token_idx + off,
                    cut_chunk=(-1 if c.cut_chunk < 0
                               else c.cut_chunk + coff),
                    boundary_pos=c.boundary_pos + off,
                    boundary_label=c.boundary_label,
                    boundary_weight=c.boundary_weight))
        arrays = dict(cols)
        if cp is not None:
            arrays["chunk_parent"] = cp
        return PackedWave(arrays=arrays, slots=placements, cuts=cuts)

    out: list[PackedWave] = []
    for w, members in enumerate(waves):
        # --- row assignment -------------------------------------------------
        placements: list[PackedPartition] = []
        if w == 0:
            order = sorted(members,
                           key=lambda m: -forest[m[0]][m[1]].ser.n)
            row_used: list[int] = []
            for t, pid in order:
                n = forest[t][pid].ser.n
                if n > seq_len:
                    raise DoesNotFitError(
                        f"partition of {n} tokens > row of {seq_len}; "
                        "lower the partition capacity")
                for r, used in enumerate(row_used):
                    if used + n <= seq_len:
                        placements.append(PackedPartition(t, pid, r, used))
                        row_used[r] += n
                        break
                else:
                    placements.append(PackedPartition(t, pid,
                                                      len(row_used), 0))
                    row_used.append(n)
            B = len(row_used)
        else:
            for r, (t, pid) in enumerate(members):
                if forest[t][pid].ser.n > seq_len:
                    raise DoesNotFitError(
                        f"partition of {forest[t][pid].ser.n} tokens > row "
                        f"of {seq_len}; lower the partition capacity")
                placements.append(PackedPartition(t, pid, r, 0))
            B = len(members)

        # --- materialize rows (splitting too-wide waves) --------------------
        if max_rows is not None and B > max_rows:
            for base in range(0, B, max_rows):
                chunk = [PackedPartition(p.tree, p.pid, p.row - base,
                                         p.offset)
                         for p in placements
                         if base <= p.row < base + max_rows]
                out.append(materialize(chunk, min(max_rows, B - base)))
        else:
            out.append(materialize(placements, B))
    return out
