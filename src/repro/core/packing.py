"""Packing serialized trees into fixed-shape training rows.

Generalizes sequence packing (Krell et al. 2021) to prefix trees (paper §2):
a row holds one or more whole DFS-serialized trees back to back.  Because
``kv_last`` already bounds visibility to the token's own subtree, packed
trees are mutually invisible with **no extra mask machinery** — the same
two-comparison predicate covers causality, branch separation and packing.

Produces ``TreeBatch`` — plain numpy arrays with static shapes, ready to be
fed to the jitted model (and sharded over the data axes).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from .tree import SerializedTree


@dataclass
class TreeBatch:
    """Fixed-shape batch of packed DFS rows (+ per-token metadata)."""

    tokens: np.ndarray        # i32 [B, S]
    pos_ids: np.ndarray       # i32 [B, S]
    kv_last: np.ndarray       # i32 [B, S]   (−1 = invisible key)
    weight: np.ndarray        # f32 [B, S]   λ_t
    prev_idx: np.ndarray      # i32 [B, S]   (−1 = no loss for this token)
    valid: np.ndarray         # bool [B, S]
    chunk_parent: Optional[np.ndarray] = None  # i32 [B, C] (−1 = init state)
    num_trees: int = 1        # loss normalizer (mean over trees)
    extra_embeds: Optional[np.ndarray] = None  # f32 [B, T_src, D] frontend stub

    @property
    def shape(self) -> tuple[int, int]:
        return self.tokens.shape  # type: ignore[return-value]

    def row_slice(self, b: int) -> "TreeBatch":
        sl = lambda a: None if a is None else a[b:b + 1]
        return TreeBatch(self.tokens[b:b + 1], self.pos_ids[b:b + 1],
                         self.kv_last[b:b + 1], self.weight[b:b + 1],
                         self.prev_idx[b:b + 1], self.valid[b:b + 1],
                         sl(self.chunk_parent), 1, sl(self.extra_embeds))


def _empty_row(S: int) -> dict[str, np.ndarray]:
    return dict(
        tokens=np.zeros(S, np.int32),
        pos_ids=np.zeros(S, np.int32),
        kv_last=np.full(S, -1, np.int32),
        weight=np.zeros(S, np.float32),
        prev_idx=np.full(S, -1, np.int32),
        valid=np.zeros(S, bool),
    )


def pack_trees(
    trees: Sequence[SerializedTree],
    seq_len: int,
    *,
    batch_size: Optional[int] = None,
    chunk_size: Optional[int] = None,
) -> TreeBatch:
    """First-fit-decreasing pack of whole serialized trees into rows.

    Every tree must fit in one row (use Redundancy-Free Tree Partitioning
    for larger trees — core/partition.py).  If ``chunk_size`` is given the
    serializations must be chunk-aligned and rows carry a chunk_parent map.
    """
    order = sorted(range(len(trees)), key=lambda i: -trees[i].n)
    rows: list[list[int]] = []
    row_used: list[int] = []
    for i in order:
        n = trees[i].n
        if n > seq_len:
            raise ValueError(
                f"tree of {n} tokens does not fit row of {seq_len}; "
                "partition it first (core/partition.py)")
        for r, used in enumerate(row_used):
            if used + n <= seq_len:
                rows[r].append(i)
                row_used[r] += n
                break
        else:
            rows.append([i])
            row_used.append(n)

    if batch_size is not None:
        if len(rows) > batch_size:
            raise ValueError(f"{len(rows)} rows > batch_size {batch_size}")
        while len(rows) < batch_size:
            rows.append([])

    B, S = len(rows), seq_len
    cols = {k: [] for k in
            ("tokens", "pos_ids", "kv_last", "weight", "prev_idx", "valid")}
    chunk_rows: list[np.ndarray] = []
    C = None if chunk_size is None else S // chunk_size

    for r in rows:
        row = _empty_row(S)
        cp = None if C is None else np.full(C, -1, np.int32)
        off = 0
        for i in r:
            t = trees[i]
            sl = slice(off, off + t.n)
            row["tokens"][sl] = t.tokens
            row["pos_ids"][sl] = t.pos_ids
            row["kv_last"][sl] = np.where(t.kv_last < 0, -1, t.kv_last + off)
            row["weight"][sl] = t.weight
            row["prev_idx"][sl] = np.where(t.prev_idx < 0, -1,
                                           t.prev_idx + off)
            row["valid"][sl] = t.valid
            if C is not None:
                assert off % chunk_size == 0 and t.n % chunk_size == 0, \
                    "SSM packing requires chunk-aligned trees"
                tc = t.chunk_parent_map(chunk_size)
                coff = off // chunk_size
                cp[coff:coff + len(tc)] = np.where(tc < 0, -1, tc + coff)
            off += t.n
        for k in cols:
            cols[k].append(row[k])
        if cp is not None:
            chunk_rows.append(cp)

    return TreeBatch(
        tokens=np.stack(cols["tokens"]),
        pos_ids=np.stack(cols["pos_ids"]),
        kv_last=np.stack(cols["kv_last"]),
        weight=np.stack(cols["weight"]),
        prev_idx=np.stack(cols["prev_idx"]),
        valid=np.stack(cols["valid"]),
        chunk_parent=np.stack(chunk_rows) if chunk_rows else None,
        num_trees=len(trees),
    )


def pack_linear_paths(
    trees_paths: Sequence[Sequence[dict[str, np.ndarray]]],
    seq_len: int,
    *,
    batch_size: Optional[int] = None,
    chunk_size: Optional[int] = None,
) -> TreeBatch:
    """Baseline: pack *linearized per-branch sequences* (Eq. 7 serialization
    + standard sequence packing).  ``trees_paths[k]`` is the list of path
    dicts of tree k (from ``TrajectoryTree.linearize_paths``).  Loss weights
    are 1/K_k per trained token so the packed loss equals mean-over-trees of
    sep-avg — directly comparable with the tree-packed loss.
    """
    flat: list[dict[str, np.ndarray]] = []
    for paths in trees_paths:
        K = len(paths)
        for p in paths:
            q = dict(p)
            q["_w"] = np.where(p["trained"], p["advantage"] / K,
                               0.0).astype(np.float32)
            flat.append(q)

    def aligned_len(n: int) -> int:
        if chunk_size is None:
            return n
        return ((n + chunk_size - 1) // chunk_size) * chunk_size

    order = sorted(range(len(flat)), key=lambda i: -len(flat[i]["tokens"]))
    rows: list[list[int]] = []
    row_used: list[int] = []
    for i in order:
        n = aligned_len(len(flat[i]["tokens"]))
        if n > seq_len:
            raise ValueError("path longer than row")
        for r, used in enumerate(row_used):
            if used + n <= seq_len:
                rows[r].append(i)
                row_used[r] += n
                break
        else:
            rows.append([i])
            row_used.append(n)
    if batch_size is not None:
        if len(rows) > batch_size:
            raise ValueError(f"{len(rows)} rows > batch_size {batch_size}")
        while len(rows) < batch_size:
            rows.append([])

    S = seq_len
    C = None if chunk_size is None else S // chunk_size
    out = {k: [] for k in
           ("tokens", "pos_ids", "kv_last", "weight", "prev_idx", "valid")}
    chunk_rows = []
    for r in rows:
        row = _empty_row(S)
        cp = None if C is None else np.full(C, -1, np.int32)
        off = 0
        for i in r:
            p = flat[i]
            n = len(p["tokens"])
            na = aligned_len(n)
            sl = slice(off, off + n)
            row["tokens"][sl] = p["tokens"]
            row["pos_ids"][sl] = p["pos_ids"]
            row["kv_last"][sl] = off + n - 1
            row["weight"][sl] = p["_w"]
            pv = np.arange(off - 1, off + n - 1, dtype=np.int32)
            pv[0] = -1
            row["prev_idx"][sl] = pv
            row["valid"][sl] = True
            if C is not None:
                c0, c1 = off // chunk_size, (off + na) // chunk_size
                for c in range(c0, c1):
                    cp[c] = -1 if c == c0 else c - 1
            off += na
        for k in out:
            out[k].append(row[k])
        if cp is not None:
            chunk_rows.append(cp)

    return TreeBatch(
        tokens=np.stack(out["tokens"]),
        pos_ids=np.stack(out["pos_ids"]),
        kv_last=np.stack(out["kv_last"]),
        weight=np.stack(out["weight"]),
        prev_idx=np.stack(out["prev_idx"]),
        valid=np.stack(out["valid"]),
        chunk_parent=np.stack(chunk_rows) if chunk_rows else None,
        num_trees=len(trees_paths),
    )
