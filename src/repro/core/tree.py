"""Trajectory trees and DFS serialization (paper §3.1–3.2).

A trajectory tree is a rooted tree whose nodes hold token segments; each
root-to-leaf path is one complete trajectory.  DFS serialization lays every
token out exactly once; per-token metadata arrays make the serialized
sequence *equivalent* to running every path independently:

  - ``kv_last[j]``  : DFS index of the last token in node(j)'s subtree.
    Token i may attend to token j  iff  ``j <= i and kv_last[j] >= i`` —
    this single int per key encodes causality + same-path visibility, and
    also separates multiple packed trees in one row for free.
  - ``pos_ids[t]``  : depth-based position (position the token would have in
    its standalone root-to-leaf sequence) — Eq. (9); makes RoPE exact.
  - ``weight[t]``   : λ_t = g_t / K  for trained tokens, 0 otherwise — Eq. (4).
  - ``prev_idx[t]`` : DFS index of the token whose *logits* predict token t
    (the preceding token on t's path).  Within a node this is t−1; at a node
    start it is the parent node's last token.  Several children of a
    branching node gather the same parent row — their losses (and gradients)
    accumulate there exactly as the per-branch baseline would.
  - ``node_id[t]``, ``chunk_parent`` : SSM chunk-grid metadata (§3.2 SSM).

All host-side, numpy only; the jitted model consumes the arrays.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional

import numpy as np


@dataclass
class TreeNode:
    """One node: a token segment plus children."""

    tokens: np.ndarray                      # int32 [len]
    trained: Optional[np.ndarray] = None    # bool  [len]; True = model output (gets loss)
    advantage: Optional[np.ndarray] = None  # f32   [len]; RL per-token advantage
    children: list["TreeNode"] = field(default_factory=list)
    # GRPO-style per-*branch* advantage: meaningful on leaves (a branch is
    # one root-to-leaf trajectory); None = 1.0.  Under loss_mode="rl" a
    # shared token's weight is Σ_{branches through it} A_b / K, which with
    # A≡1 reduces bit-exactly to sep_avg (g_t / K).
    branch_adv: Optional[float] = None

    def __post_init__(self) -> None:
        self.tokens = np.asarray(self.tokens, dtype=np.int32)
        if self.trained is None:
            self.trained = np.ones_like(self.tokens, dtype=bool)
        else:
            self.trained = np.asarray(self.trained, dtype=bool)
        if self.advantage is not None:
            self.advantage = np.asarray(self.advantage, dtype=np.float32)

    @property
    def size(self) -> int:
        return int(self.tokens.shape[0])


@dataclass
class TrajectoryTree:
    root: TreeNode

    # ---- basic structure ----------------------------------------------
    def nodes(self) -> Iterator[TreeNode]:
        stack = [self.root]
        while stack:
            n = stack.pop()
            yield n
            stack.extend(reversed(n.children))

    def num_unique_tokens(self) -> int:
        return sum(n.size for n in self.nodes())

    def num_leaves(self) -> int:
        return sum(1 for n in self.nodes() if not n.children)

    def num_nodes(self) -> int:
        return sum(1 for _ in self.nodes())

    def flat_tokens(self) -> int:
        """Token count of the baseline serialization X_base (every path,

        prefixes repeated) — Eq. (7) / denominator of POR (Eq. 12)."""
        total = 0
        for path in self.paths():
            total += sum(n.size for n in path)
        return total

    def max_path_tokens(self) -> int:
        def rec(n: TreeNode) -> int:
            return n.size + (max((rec(c) for c in n.children), default=0))
        return rec(self.root)

    def por(self) -> float:
        """Potential Overlap Ratio — Eq. (12)."""
        flat = self.flat_tokens()
        return 1.0 - self.num_unique_tokens() / flat if flat else 0.0

    def paths(self) -> list[list[TreeNode]]:
        """All root-to-leaf node paths (one per leaf), in DFS leaf order."""
        out: list[list[TreeNode]] = []

        def rec(n: TreeNode, prefix: list[TreeNode]) -> None:
            prefix = prefix + [n]
            if not n.children:
                out.append(prefix)
            for c in n.children:
                rec(c, prefix)

        rec(self.root, [])
        return out

    def linearize_paths(self) -> list[dict[str, np.ndarray]]:
        """Per-branch baseline: one linear sequence per root-to-leaf path.

        Each path dict also carries ``branch_adv`` — the leaf's per-branch
        RL advantage (1.0 when unset) — so baseline packers can reproduce
        the GRPO-weighted objective per replicated branch."""
        seqs = []
        for path in self.paths():
            toks = np.concatenate([n.tokens for n in path])
            trained = np.concatenate([n.trained for n in path])
            adv = (np.concatenate([
                n.advantage if n.advantage is not None
                else np.ones(n.size, np.float32) for n in path]))
            leaf = path[-1]
            seqs.append(dict(tokens=toks, trained=trained, advantage=adv,
                             pos_ids=np.arange(toks.shape[0],
                                               dtype=np.int32),
                             branch_adv=float(leaf.branch_adv)
                             if leaf.branch_adv is not None else 1.0))
        return seqs


@dataclass
class SerializedTree:
    """DFS serialization of one tree (paper Eq. (8)) + equivalence metadata."""

    tokens: np.ndarray        # i32 [N]
    pos_ids: np.ndarray       # i32 [N] depth-based positions (Eq. 9)
    kv_last: np.ndarray       # i32 [N] last DFS index visible-to bound
    weight: np.ndarray        # f32 [N] λ_t (Eq. 4), already ×advantage for RL
    prev_idx: np.ndarray      # i32 [N] logits row predicting token t (−1: none)
    valid: np.ndarray         # bool [N] False = chunk-alignment padding
    node_id: np.ndarray       # i32 [N] DFS node index per token
    node_parent: np.ndarray   # i32 [num_nodes] parent node index (−1 for root)
    node_start: np.ndarray    # i32 [num_nodes] DFS start offset of node segment
    node_end: np.ndarray      # i32 [num_nodes] end offset (exclusive, incl. pad)
    num_paths: int            # K

    @property
    def n(self) -> int:
        return int(self.tokens.shape[0])

    def chunk_parent_map(self, chunk_size: int) -> np.ndarray:
        """Per-chunk parent chunk index for tree SSM state routing (§3.2).

        Requires the serialization to be chunk-aligned (every node starts on
        a chunk boundary).  Chunk c's parent is the previous chunk of the
        same node, or the *last chunk of the parent node*; −1 = zero state.
        """
        assert self.n % chunk_size == 0, "serialization not chunk-aligned"
        num_chunks = self.n // chunk_size
        cp = np.full(num_chunks, -1, dtype=np.int32)
        for nid in range(len(self.node_parent)):
            s, e = int(self.node_start[nid]), int(self.node_end[nid])
            if s == e:
                continue
            assert s % chunk_size == 0, "node not chunk-aligned"
            c0 = s // chunk_size
            c1 = (e + chunk_size - 1) // chunk_size
            p = int(self.node_parent[nid])
            if p < 0:
                cp[c0] = -1
            else:
                # last chunk of the parent node
                pe = int(self.node_end[p])
                cp[c0] = (pe - 1) // chunk_size
            for c in range(c0 + 1, c1):
                cp[c] = c - 1
        return cp


def _leaf_counts(root: TreeNode) -> dict[int, int]:
    """g_n = number of root-to-leaf paths through node n (post-order)."""
    g: dict[int, int] = {}

    def rec(n: TreeNode) -> int:
        if not n.children:
            g[id(n)] = 1
            return 1
        tot = sum(rec(c) for c in n.children)
        g[id(n)] = tot
        return tot

    rec(root)
    return g


def _branch_adv_sums(root: TreeNode) -> dict[int, float]:
    """Σ of per-branch advantages over the leaves under each node.

    The RL analogue of ``_leaf_counts``: the GRPO objective
    (1/K) Σ_k A_k Σ_{t∈path k} nll_t gives a shared token the coefficient
    Σ_{branches through it} A_b / K.  A leaf with ``branch_adv=None``
    counts as 1.0, so a tree with no advantages sums to exactly g_n."""
    s: dict[int, float] = {}

    def rec(n: TreeNode) -> float:
        if not n.children:
            a = 1.0 if n.branch_adv is None else float(n.branch_adv)
            s[id(n)] = a
            return a
        tot = sum(rec(c) for c in n.children)
        s[id(n)] = tot
        return tot

    rec(root)
    return s


def tree_lam_map(root: TreeNode, loss_mode: str) -> dict[int, float]:
    """id(node) → λ for every node of the tree rooted at ``root``, under
    ``loss_mode`` — exactly the per-node weight ``serialize_tree`` would
    assign.  The single definition shared by the partitioner (pruned
    subtrees keep full-tree weights) and the cross-tree grafter
    (``core/forest``: unshared nodes keep their source tree's weights
    bit-exactly)."""
    g = _leaf_counts(root)
    K = g[id(root)]
    if loss_mode == "uniform":
        return {nid: 1.0 for nid in g}
    if loss_mode == "rl":
        return {nid: a / K for nid, a in _branch_adv_sums(root).items()}
    if loss_mode == "sep_avg":
        return {nid: gn / K for nid, gn in g.items()}
    raise ValueError(loss_mode)


def serialize_tree(
    tree: TrajectoryTree,
    *,
    chunk_size: Optional[int] = None,
    loss_mode: str = "sep_avg",
    lam_map: Optional[dict[int, float]] = None,
    depth_pos0: int = 0,
    root_prev: int = -1,
) -> SerializedTree:
    """DFS-serialize ``tree``; every token appears exactly once (Eq. 8).

    chunk_size: if given, each node segment is padded to a multiple of
      chunk_size so SSM chunk boundaries coincide with node boundaries
      (pad tokens are ``valid=False`` and inert everywhere).
    loss_mode: 'sep_avg' → λ_t = g_t/K (Eq. 4); 'uniform' → λ_t = 1 for
      every unique trained token (§3.1's alternative objective);
      'rl' → λ_t = Σ_{branches through t} A_b / K — the GRPO model-update
      objective with per-branch advantages (``TreeNode.branch_adv`` on
      leaves).  With A≡1 the branch sum equals g_t exactly, so 'rl'
      reduces bit-for-bit to 'sep_avg'.

    Partition-mode extras (core/partition.py):
      lam_map    : id(node) → λ computed on the *full* tree (a pruned
                   partition subtree must keep full-tree weights);
      depth_pos0 : depth position of the first token (= #ancestor tokens);
      root_prev  : prev_idx of the very first token; −2 means "gateway
                   context slot 0" (the immediate ancestor relayed through
                   the partition boundary — see models/layers.gather_prev).
    """
    g = _leaf_counts(tree.root)
    K = g[id(tree.root)]
    adv_sum = _branch_adv_sums(tree.root) if loss_mode == "rl" else None

    toks: list[np.ndarray] = []
    pos: list[np.ndarray] = []
    kvl: list[np.ndarray] = []
    wgt: list[np.ndarray] = []
    prv: list[np.ndarray] = []
    vld: list[np.ndarray] = []
    nid: list[np.ndarray] = []
    node_parent: list[int] = []
    node_start: list[int] = []
    node_end: list[int] = []

    cursor = 0  # DFS token offset

    def pad_len(n_tokens: int) -> int:
        if chunk_size is None:
            return 0
        rem = n_tokens % chunk_size
        return 0 if rem == 0 else chunk_size - rem

    def rec(node: TreeNode, depth_pos: int, parent_nid: int,
            parent_last_tok: int) -> int:
        """Returns the DFS index one past the last token of node's subtree
        (including padding)."""
        nonlocal cursor
        my_nid = len(node_parent)
        node_parent.append(parent_nid)
        L = node.size
        P = pad_len(L)
        start = cursor
        node_start.append(start)
        node_end.append(start + L + P)

        toks.append(np.concatenate([node.tokens,
                                    np.zeros(P, np.int32)]))
        pos.append(np.concatenate([
            np.arange(depth_pos, depth_pos + L, dtype=np.int32),
            np.zeros(P, np.int32)]))
        if lam_map is not None:
            lam = lam_map[id(node)]
        elif loss_mode == "sep_avg":
            lam = g[id(node)] / K
        elif loss_mode == "uniform":
            lam = 1.0
        elif loss_mode == "rl":
            lam = adv_sum[id(node)] / K
        else:
            raise ValueError(loss_mode)
        adv = (node.advantage if node.advantage is not None
               else np.ones(L, np.float32))
        w = np.where(node.trained, lam * adv, 0.0).astype(np.float32)
        wgt.append(np.concatenate([w, np.zeros(P, np.float32)]))
        # prev index: within node = previous DFS slot; first token looks at
        # the parent node's last *real* token.  Empty nodes (L=0, e.g. the
        # empty leaf of a duplicated/prefix rollout branch) contribute no
        # tokens but still count as a leaf for λ.
        p = np.arange(start - 1, start + L - 1, dtype=np.int32)
        if L > 0:
            p[0] = parent_last_tok
        prv.append(np.concatenate([p, np.full(P, -1, np.int32)]))
        vld.append(np.concatenate([np.ones(L, bool), np.zeros(P, bool)]))
        nid.append(np.full(L + P, my_nid, np.int32))
        cursor += L + P

        my_last_tok = start + L - 1 if L > 0 else parent_last_tok
        for c in node.children:
            rec(c, depth_pos + L, my_nid, my_last_tok)
        subtree_end = cursor
        # kv_last for this node's tokens = last index of its subtree (pads
        # are invisible: kv_last = −1 so no query can ever see them).
        k = np.full(L + P, -1, np.int32)
        k[:L] = subtree_end - 1
        kvl.append(k)
        return subtree_end

    rec(tree.root, depth_pos0, -1, root_prev)

    # kv_last lists were appended post-order; rebuild in DFS token order.
    # Easier: recompute from node table.
    n_total = cursor
    kv_last = np.full(n_total, -1, np.int32)
    node_sub_end = np.zeros(len(node_parent), np.int64)
    # subtree end per node: max of node_end over descendants — compute by
    # iterating nodes in reverse DFS order (children appear after parents).
    for i in range(len(node_parent) - 1, -1, -1):
        node_sub_end[i] = max(node_sub_end[i], node_end[i])
        p = node_parent[i]
        if p >= 0:
            node_sub_end[p] = max(node_sub_end[p], node_sub_end[i])
    node_id_arr = np.concatenate(nid) if nid else np.zeros(0, np.int32)
    valid_arr = np.concatenate(vld) if vld else np.zeros(0, bool)
    for i in range(len(node_parent)):
        s, e = node_start[i], node_end[i]
        seg = slice(s, e)
        k = np.full(e - s, -1, np.int32)
        real = valid_arr[seg]
        k[real] = node_sub_end[i] - 1
        kv_last[seg] = k

    return SerializedTree(
        tokens=np.concatenate(toks),
        pos_ids=np.concatenate(pos),
        kv_last=kv_last,
        weight=np.concatenate(wgt),
        prev_idx=np.concatenate(prv),
        valid=valid_arr,
        node_id=node_id_arr,
        node_parent=np.asarray(node_parent, np.int32),
        node_start=np.asarray(node_start, np.int32),
        node_end=np.asarray(node_end, np.int32),
        num_paths=K,
    )


def visibility_mask(ser: SerializedTree) -> np.ndarray:
    """Dense [N, N] boolean tree-attention mask (test oracle; Fig. 3)."""
    n = ser.n
    i = np.arange(n)[:, None]
    j = np.arange(n)[None, :]
    return (j <= i) & (ser.kv_last[None, :] >= i) & ser.valid[None, :] \
        & ser.valid[:, None]


def subtree_token_count(tree: TrajectoryTree) -> dict[int, int]:
    """id(node) → token count of its subtree (used by the partitioner)."""
    out: dict[int, int] = {}

    def rec(n: TreeNode) -> int:
        t = n.size + sum(rec(c) for c in n.children)
        out[id(n)] = t
        return t

    rec(tree.root)
    return out
