"""Differentiable partition boundaries — the runtime half of
Redundancy-Free Tree Partitioning (paper §3.3 + App. B), in JAX.

PyTorch version: detached leaf KV tensors + retain_graph + float32
accumulator hooks.  JAX version: each partition is a pure function
``(params, gw_in) → ((loss, captures), metrics)``; we take ``jax.vjp`` per
partition, recurse into child partitions (relaying captured KV / SSM state
/ conv & token-shift context), then invoke the parent's vjp with the loss
cotangent AND the children's gateway cotangents — the same gradient relay
as pipeline parallelism (paper's own analogy).  Peak residency = vjp
closures along one root-to-leaf partition path (the paper's memory
bound).  Gateway cotangents are accumulated in float32 before the parent
vjp call (App. B.5's accumulator, the natural JAX idiom).

The gateway is *ancestor-compacted*: we gather exactly the ancestor-token
rows host-side instead of slicing ``[:past_len+e]`` + a −∞ bias
(App. B.3) — smaller tensors, no bias mask.  Ancestor RoPE positions
(App. B.4) travel as static per-partition data, not differentiable leaves.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.partition import TreePartition, partition_tree
from repro.core.tree import TrajectoryTree
from repro.models.layers import prev_powers
from repro.models.model import max_conv_taps, needs_chunks
from repro.models.transformer import partition_loss


# ---------------------------------------------------------------------------
# Host-side batch / capture planning per partition
# ---------------------------------------------------------------------------

def make_part_batch(cfg: ModelConfig, part: TreePartition,
                    chunk_size: Optional[int],
                    anc_pos: np.ndarray) -> dict:
    ser = part.ser
    b: dict[str, Any] = {
        "tokens": jnp.asarray(ser.tokens[None]),
        "pos_ids": jnp.asarray(ser.pos_ids[None]),
        "kv_last": jnp.asarray(ser.kv_last[None]),
        "weight": jnp.asarray(ser.weight[None]),
        "prev_idx": jnp.asarray(ser.prev_idx[None]),
        "valid": jnp.asarray(ser.valid[None]),
        "anc_pos": jnp.asarray(anc_pos[None].astype(np.int32)),
    }
    if needs_chunks(cfg):
        b["chunk_parent"] = jnp.asarray(
            ser.chunk_parent_map(chunk_size)[None])
        k = max(1, max_conv_taps(cfg))
        b["prev_pows"] = jnp.asarray(prev_powers(ser.prev_idx[None], k))
    if part.cuts:
        b["extra_pos"] = jnp.asarray(
            [[c.boundary_pos for c in part.cuts]], jnp.int32)
        b["extra_label"] = jnp.asarray(
            [[c.boundary_label for c in part.cuts]], jnp.int32)
        b["extra_weight"] = jnp.asarray(
            [[c.boundary_weight for c in part.cuts]], jnp.float32)
    return b


def make_capspecs(cfg: ModelConfig, part: TreePartition) -> dict:
    taps = max(1, max_conv_taps(cfg))
    specs = {}
    for i, c in enumerate(part.cuts):
        idx = c.path_token_idx
        specs[f"c{i}"] = {
            "path_idx": idx,
            "cut_chunk": c.cut_chunk,
            "conv_pos": idx[-taps:],
            "shift_pos": idx[-1:],
        }
    return specs


# ---------------------------------------------------------------------------
# Gateway assembly (parent → child) and cotangent routing (child → parent)
# ---------------------------------------------------------------------------

def _concat_tail(gw_arr: Optional[jax.Array], cap_arr: jax.Array,
                 keep: int) -> jax.Array:
    """Concat along the token axis (2), keep the last ``keep`` entries."""
    z = cap_arr if gw_arr is None else jnp.concatenate(
        [gw_arr, cap_arr], axis=2)
    return z[:, :, -keep:] if z.shape[2] > keep else z


def _route_tail(gw_shape, cap_shape, keep: int, cot_child: jax.Array):
    """Transpose of _concat_tail → cotangents for (gw_arr, cap_arr)."""
    T_in = 0 if gw_shape is None else gw_shape[2]
    T_c = cap_shape[2]
    T = T_in + T_c
    kept = min(keep, T)
    cz = jnp.zeros(cap_shape[:2] + (T,) + cap_shape[3:], cot_child.dtype)
    cz = cz.at[:, :, T - kept:].set(cot_child[:, :, -kept:])
    return (None if T_in == 0 else cz[:, :, :T_in]), cz[:, :, T_in:]


def assemble_child_gw(cfg: ModelConfig, gw_in: Optional[dict], caps: dict,
                      cut_name: str) -> dict:
    taps = max(1, max_conv_taps(cfg))
    child: dict = {}
    for gkey, group_caps in caps.items():
        if not group_caps:
            continue
        gw_g = (gw_in or {}).get(gkey, {})
        cg: dict = {}
        if "attn" in group_caps:
            cap = group_caps["attn"][cut_name]
            prev = gw_g.get("attn")
            cg["attn"] = {
                t: (cap[t] if prev is None else
                    jnp.concatenate([prev[t], cap[t]], axis=2))
                for t in ("k", "v")}
        if "ssm" in group_caps:
            cap = group_caps["ssm"][cut_name]
            prev = gw_g.get("ssm")
            cg["ssm"] = {
                "state": cap["state"],
                "conv": _concat_tail(None if prev is None else prev["conv"],
                                     cap["conv"], taps)}
        if "tm" in group_caps:
            cap = group_caps["tm"][cut_name]
            prev = gw_g.get("tm")
            cg["tm"] = {
                "state": cap["state"],
                "shift": _concat_tail(None if prev is None
                                      else prev["shift"], cap["shift"], 1)}
        if "cm" in group_caps:
            cap = group_caps["cm"][cut_name]
            prev = gw_g.get("cm")
            cg["cm"] = {
                "shift": _concat_tail(None if prev is None
                                      else prev["shift"], cap["shift"], 1)}
        if cg:
            child[gkey] = cg
    return child


def route_child_cot(cfg: ModelConfig, gw_in: Optional[dict], caps: dict,
                    cut_name: str, cot_child: dict,
                    cot_gw_acc: Optional[dict], cot_caps: dict):
    """Split child's gateway cotangent into pass-through ancestors (adds to
    this partition's gw_in cotangent, float32) and this partition's capture
    cotangents.  Mutates cot_caps in place; returns cot_gw_acc."""
    taps = max(1, max_conv_taps(cfg))
    for gkey, cg in cot_child.items():
        group_caps = caps[gkey]
        gw_g = (gw_in or {}).get(gkey, {})
        if "attn" in cg:
            prev = gw_g.get("attn")
            A_in = 0 if prev is None else prev["k"].shape[2]
            for t in ("k", "v"):
                cot = cg["attn"][t]
                if A_in:
                    cot_gw_acc[gkey]["attn"][t] = (
                        cot_gw_acc[gkey]["attn"][t]
                        + cot[:, :, :A_in].astype(jnp.float32))
                cc = cot_caps[gkey]["attn"][cut_name][t]
                cot_caps[gkey]["attn"][cut_name][t] = cc + cot[:, :, A_in:]
        if "ssm" in cg:
            cap = group_caps["ssm"][cut_name]
            prev = gw_g.get("ssm")
            cot_caps[gkey]["ssm"][cut_name]["state"] = jax.tree.map(
                lambda a, b: a + b.astype(a.dtype),
                cot_caps[gkey]["ssm"][cut_name]["state"],
                cg["ssm"]["state"])
            cgw, cc = _route_tail(None if prev is None
                                  else prev["conv"].shape,
                                  cap["conv"].shape, taps, cg["ssm"]["conv"])
            if cgw is not None:
                cot_gw_acc[gkey]["ssm"]["conv"] = (
                    cot_gw_acc[gkey]["ssm"]["conv"]
                    + cgw.astype(jnp.float32))
            cot_caps[gkey]["ssm"][cut_name]["conv"] = (
                cot_caps[gkey]["ssm"][cut_name]["conv"] + cc)
        for tkey in ("tm", "cm"):
            if tkey not in cg:
                continue
            cap = group_caps[tkey][cut_name]
            prev = gw_g.get(tkey)
            if "state" in cg[tkey]:
                cot_caps[gkey][tkey][cut_name]["state"] = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype),
                    cot_caps[gkey][tkey][cut_name]["state"],
                    cg[tkey]["state"])
            cgw, cc = _route_tail(None if prev is None
                                  else prev["shift"].shape,
                                  cap["shift"].shape, 1, cg[tkey]["shift"])
            if cgw is not None:
                cot_gw_acc[gkey][tkey]["shift"] = (
                    cot_gw_acc[gkey][tkey]["shift"]
                    + cgw.astype(jnp.float32))
            cot_caps[gkey][tkey][cut_name]["shift"] = (
                cot_caps[gkey][tkey][cut_name]["shift"] + cc)
    return cot_gw_acc


# ---------------------------------------------------------------------------
# Cached jitted per-partition forward / backward
#
# jax.vjp re-traces on every call; across training steps (and across
# same-shaped partitions) that tracing dominates host time.  We instead
# cache two jitted callables per (cfg, capture-plan, gw-structure)
# signature:
#   fwd(params, batch, gw)            → ((loss, caps), metrics)
#   bwd(params, batch, gw, cots)      → (g_params, g_gw)   [rematerialized]
# The backward *recomputes* the partition forward inside jit (activation
# remat) — so no residuals are held between the two phases at all, which
# strictly improves on the paper's peak-memory bound at ~1/3 extra FLOPs
# (standard remat trade-off), and lets XLA cache the executable.
# ---------------------------------------------------------------------------

def _capspec_sig(capspecs: dict):
    return tuple(sorted(
        (n, tuple(map(int, s["path_idx"])), int(s["cut_chunk"]),
         tuple(map(int, s["conv_pos"])), tuple(map(int, s["shift_pos"])))
        for n, s in capspecs.items()))


def _capspecs_from_sig(sig) -> dict:
    return {n: {"path_idx": np.asarray(p, np.int32), "cut_chunk": c,
                "conv_pos": np.asarray(cv, np.int32),
                "shift_pos": np.asarray(sh, np.int32)}
            for n, p, c, cv, sh in sig}


from functools import lru_cache  # noqa: E402


@lru_cache(maxsize=512)
def _part_fns(cfg: ModelConfig, sig, impl: str, has_gw: bool):
    capspecs = _capspecs_from_sig(sig)

    if has_gw:
        def fwd(params, batch, gw):
            return partition_loss(cfg, params, batch, gw, capspecs, impl)

        def bwd(params, batch, gw, cot):
            return _vjp2(cfg, params, batch, gw, capspecs, impl, cot)
    else:
        def fwd(params, batch, gw):
            return partition_loss(cfg, params, batch, None, capspecs, impl)

        def bwd(params, batch, gw, cot):
            return _vjp1(cfg, params, batch, capspecs, impl, cot)

    return jax.jit(fwd), jax.jit(bwd)


def _vjp1(cfg, params, batch, capspecs, impl, cot):
    _, vjp, _ = jax.vjp(
        lambda p: partition_loss(cfg, p, batch, None, capspecs, impl),
        params, has_aux=True)
    (g_params,) = vjp(cot)
    return g_params, None


def _vjp2(cfg, params, batch, gw, capspecs, impl, cot):
    _, vjp, _ = jax.vjp(
        lambda p, g: partition_loss(cfg, p, batch, g, capspecs, impl),
        params, gw, has_aux=True)
    return vjp(cot)


# ---------------------------------------------------------------------------
# The partitioned train-step driver
# ---------------------------------------------------------------------------

def partitioned_value_and_grad(
    cfg: ModelConfig,
    params: dict,
    tree: TrajectoryTree,
    capacity: int,
    *,
    impl: str = "ref",
    loss_mode: str = "sep_avg",
) -> tuple[float, dict, dict]:
    """Loss + grads for ONE tree with ≤capacity tokens resident per
    partition — every token computed exactly once (paper Fig. 5, right).

    Returns (loss, grads (float32), info)."""
    chunk_size = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    parts = partition_tree(tree, capacity, chunk_size=chunk_size,
                           loss_mode=loss_mode)
    grads_acc = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32),
                             params)
    total_loss = 0.0
    info = {"num_partitions": len(parts),
            "tokens": sum(p.ser.n for p in parts)}

    def process(pid: int, gw_in: Optional[dict], anc_pos: np.ndarray):
        nonlocal grads_acc, total_loss
        part = parts[pid]
        batch = make_part_batch(cfg, part, chunk_size, anc_pos)
        capspecs = make_capspecs(cfg, part)
        fwd, bwd = _part_fns(cfg, _capspec_sig(capspecs), impl,
                             gw_in is not None)

        (loss, caps), _metrics = fwd(params, batch, gw_in)
        total_loss += float(loss)

        cot_gw_acc = None if gw_in is None else jax.tree.map(
            lambda a: jnp.zeros(a.shape, jnp.float32), gw_in)
        cot_caps = jax.tree.map(jnp.zeros_like, caps)

        for i, cut in enumerate(part.cuts):
            cut_name = f"c{i}"
            child_gw = assemble_child_gw(cfg, gw_in, caps, cut_name)
            child_anc_pos = np.concatenate(
                [anc_pos, part.ser.pos_ids[cut.path_token_idx]])
            cot_child = process(cut.child_pid, child_gw, child_anc_pos)
            cot_gw_acc = route_child_cot(cfg, gw_in, caps, cut_name,
                                         cot_child, cot_gw_acc, cot_caps)

        g_params, g_gw = bwd(params, batch, gw_in,
                             (jnp.ones((), loss.dtype), cot_caps))
        grads_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                 grads_acc, g_params)
        if gw_in is None:
            return None
        return jax.tree.map(
            lambda own, acc: (own.astype(jnp.float32) + acc
                              ).astype(own.dtype),
            g_gw, cot_gw_acc)

    process(0, None, np.zeros((0,), np.int32))
    return total_loss, grads_acc, info
