"""Differentiable partition boundaries — the runtime half of
Redundancy-Free Tree Partitioning (paper §3.3 + App. B), in JAX.

PyTorch version: detached leaf KV tensors + retain_graph + float32
accumulator hooks.  JAX version: each partition is a pure function
``(params, gw_in) → ((loss, captures), metrics)``; we take ``jax.vjp`` per
partition, recurse into child partitions (relaying captured KV / SSM state
/ conv & token-shift context), then invoke the parent's vjp with the loss
cotangent AND the children's gateway cotangents — the same gradient relay
as pipeline parallelism (paper's own analogy).  Peak residency = vjp
closures along one root-to-leaf partition path (the paper's memory
bound).  Gateway cotangents are accumulated in float32 before the parent
vjp call (App. B.5's accumulator, the natural JAX idiom).

This module is the host-side *planner* plus the per-partition device
primitives; three entry points share the plumbing:
  ``partitioned_value_and_grad``        one tree, depth-first B=1
                                        recursion (strict path bound);
  ``build_partition_plan``              many trees → a ``PartitionPlan``
                                        (per-wave numpy batches, capture
                                        plans, gateway topology) that the
                                        unified engine executes
                                        (train/engine.run_partition_plan);
  ``packed_partitioned_value_and_grad`` thin compatibility wrapper:
                                        build the plan, run it through
                                        the engine executor.

The gateway is *ancestor-compacted*: we gather exactly the ancestor-token
rows host-side instead of slicing ``[:past_len+e]`` + a −∞ bias
(App. B.3) — smaller tensors, no bias mask.  Ancestor RoPE positions
(App. B.4) travel as static per-partition data, not differentiable leaves.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.packing import pack_partition_waves
from repro.core.partition import TreePartition, partition_tree
from repro.core.plan_cost import balanced_row_order, pow2
from repro.core.tree import TrajectoryTree
from repro.models.layers import prev_powers
from repro.models.model import max_conv_taps, needs_chunks
from repro.models.transformer import partition_loss


# ---------------------------------------------------------------------------
# Host-side batch / capture planning per partition
# ---------------------------------------------------------------------------

def make_part_batch(cfg: ModelConfig, part: TreePartition,
                    chunk_size: Optional[int],
                    anc_pos: np.ndarray) -> dict:
    ser = part.ser
    b: dict[str, Any] = {
        "tokens": jnp.asarray(ser.tokens[None]),
        "pos_ids": jnp.asarray(ser.pos_ids[None]),
        "kv_last": jnp.asarray(ser.kv_last[None]),
        "weight": jnp.asarray(ser.weight[None]),
        "prev_idx": jnp.asarray(ser.prev_idx[None]),
        "valid": jnp.asarray(ser.valid[None]),
        "anc_pos": jnp.asarray(anc_pos[None].astype(np.int32)),
    }
    if needs_chunks(cfg):
        b["chunk_parent"] = jnp.asarray(
            ser.chunk_parent_map(chunk_size)[None])
        k = max(1, max_conv_taps(cfg))
        b["prev_pows"] = jnp.asarray(prev_powers(ser.prev_idx[None], k))
    if part.cuts:
        b["extra_pos"] = jnp.asarray(
            [[c.boundary_pos for c in part.cuts]], jnp.int32)
        b["extra_label"] = jnp.asarray(
            [[c.boundary_label for c in part.cuts]], jnp.int32)
        b["extra_weight"] = jnp.asarray(
            [[c.boundary_weight for c in part.cuts]], jnp.float32)
    return b


def make_capspecs(cfg: ModelConfig, part: TreePartition) -> dict:
    taps = max(1, max_conv_taps(cfg))
    specs = {}
    for i, c in enumerate(part.cuts):
        idx = np.asarray(c.path_token_idx, np.int32)
        specs[f"c{i}"] = {
            "path_idx": idx,
            "cut_chunk": np.int32(max(c.cut_chunk, 0)),
            "conv_pos": idx[-taps:],
            "shift_pos": idx[-1:],
        }
    return specs


# ---------------------------------------------------------------------------
# Gateway assembly (parent → child) and cotangent routing (child → parent)
# ---------------------------------------------------------------------------

def _concat_tail(gw_arr: Optional[jax.Array], cap_arr: jax.Array,
                 keep: int) -> jax.Array:
    """Concat along the token axis (2), keep the last ``keep`` entries."""
    z = cap_arr if gw_arr is None else jnp.concatenate(
        [gw_arr, cap_arr], axis=2)
    return z[:, :, -keep:] if z.shape[2] > keep else z


def _route_tail(gw_shape, cap_shape, keep: int, cot_child: jax.Array):
    """Transpose of _concat_tail → cotangents for (gw_arr, cap_arr)."""
    T_in = 0 if gw_shape is None else gw_shape[2]
    T_c = cap_shape[2]
    T = T_in + T_c
    kept = min(keep, T)
    cz = jnp.zeros(cap_shape[:2] + (T,) + cap_shape[3:], cot_child.dtype)
    cz = cz.at[:, :, T - kept:].set(cot_child[:, :, -kept:])
    return (None if T_in == 0 else cz[:, :, :T_in]), cz[:, :, T_in:]


def assemble_child_gw(cfg: ModelConfig, gw_in: Optional[dict], caps: dict,
                      cut_name: str) -> dict:
    taps = max(1, max_conv_taps(cfg))
    child: dict = {}
    for gkey, group_caps in caps.items():
        if not group_caps:
            continue
        gw_g = (gw_in or {}).get(gkey, {})
        cg: dict = {}
        if "attn" in group_caps:
            cap = group_caps["attn"][cut_name]
            prev = gw_g.get("attn")
            cg["attn"] = {
                t: (cap[t] if prev is None else
                    jnp.concatenate([prev[t], cap[t]], axis=2))
                for t in ("k", "v")}
        if "ssm" in group_caps:
            cap = group_caps["ssm"][cut_name]
            prev = gw_g.get("ssm")
            cg["ssm"] = {
                "state": cap["state"],
                "conv": _concat_tail(None if prev is None else prev["conv"],
                                     cap["conv"], taps)}
        if "tm" in group_caps:
            cap = group_caps["tm"][cut_name]
            prev = gw_g.get("tm")
            cg["tm"] = {
                "state": cap["state"],
                "shift": _concat_tail(None if prev is None
                                      else prev["shift"], cap["shift"], 1)}
        if "cm" in group_caps:
            cap = group_caps["cm"][cut_name]
            prev = gw_g.get("cm")
            cg["cm"] = {
                "shift": _concat_tail(None if prev is None
                                      else prev["shift"], cap["shift"], 1)}
        if cg:
            child[gkey] = cg
    return child


def route_child_cot(cfg: ModelConfig, gw_in: Optional[dict], caps: dict,
                    cut_name: str, cot_child: dict,
                    cot_gw_acc: Optional[dict], cot_caps: dict):
    """Split child's gateway cotangent into pass-through ancestors (adds to
    this partition's gw_in cotangent, float32) and this partition's capture
    cotangents.  Mutates cot_caps in place; returns cot_gw_acc."""
    taps = max(1, max_conv_taps(cfg))
    for gkey, cg in cot_child.items():
        group_caps = caps[gkey]
        gw_g = (gw_in or {}).get(gkey, {})
        if "attn" in cg:
            prev = gw_g.get("attn")
            A_in = 0 if prev is None else prev["k"].shape[2]
            for t in ("k", "v"):
                cot = cg["attn"][t]
                if A_in:
                    cot_gw_acc[gkey]["attn"][t] = (
                        cot_gw_acc[gkey]["attn"][t]
                        + cot[:, :, :A_in].astype(jnp.float32))
                cc = cot_caps[gkey]["attn"][cut_name][t]
                cot_caps[gkey]["attn"][cut_name][t] = cc + cot[:, :, A_in:]
        if "ssm" in cg:
            cap = group_caps["ssm"][cut_name]
            prev = gw_g.get("ssm")
            cot_caps[gkey]["ssm"][cut_name]["state"] = jax.tree.map(
                lambda a, b: a + b.astype(a.dtype),
                cot_caps[gkey]["ssm"][cut_name]["state"],
                cg["ssm"]["state"])
            cgw, cc = _route_tail(None if prev is None
                                  else prev["conv"].shape,
                                  cap["conv"].shape, taps, cg["ssm"]["conv"])
            if cgw is not None:
                cot_gw_acc[gkey]["ssm"]["conv"] = (
                    cot_gw_acc[gkey]["ssm"]["conv"]
                    + cgw.astype(jnp.float32))
            cot_caps[gkey]["ssm"][cut_name]["conv"] = (
                cot_caps[gkey]["ssm"][cut_name]["conv"] + cc)
        for tkey in ("tm", "cm"):
            if tkey not in cg:
                continue
            cap = group_caps[tkey][cut_name]
            prev = gw_g.get(tkey)
            if "state" in cg[tkey]:
                cot_caps[gkey][tkey][cut_name]["state"] = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype),
                    cot_caps[gkey][tkey][cut_name]["state"],
                    cg[tkey]["state"])
            cgw, cc = _route_tail(None if prev is None
                                  else prev["shift"].shape,
                                  cap["shift"].shape, 1, cg[tkey]["shift"])
            if cgw is not None:
                cot_gw_acc[gkey][tkey]["shift"] = (
                    cot_gw_acc[gkey][tkey]["shift"]
                    + cgw.astype(jnp.float32))
            cot_caps[gkey][tkey][cut_name]["shift"] = (
                cot_caps[gkey][tkey][cut_name]["shift"] + cc)
    return cot_gw_acc


# ---------------------------------------------------------------------------
# Cached jitted per-partition forward / backward
#
# jax.vjp re-traces on every call; across training steps (and across
# same-shaped partitions) that tracing dominates host time.  We instead
# cache two jitted callables per (cfg, cut-name structure, gw-structure)
# signature:
#   fwd(params, batch, gw, capspecs)       → ((loss, caps), metrics)
#   bwd(params, batch, gw, capspecs, cot)  → (g_params, g_gw) [rematerialized]
# Capture plans travel as *runtime* index arrays (dynamic gathers), not
# static constants, so partitions that merely differ in where their cuts
# sit reuse one executable — only the array *shapes* (bucketed by the wave
# scheduler below) key the jit cache.  The backward *recomputes* the
# partition forward inside jit (activation remat) — so no residuals are
# held between the two phases at all, which strictly improves on the
# paper's peak-memory bound at ~1/3 extra FLOPs (standard remat
# trade-off), and lets XLA cache the executable.
# ---------------------------------------------------------------------------

from functools import lru_cache  # noqa: E402


def _names_sig(capspecs: dict) -> tuple:
    return tuple(sorted(capspecs))


@lru_cache(maxsize=64)
def _part_fns(cfg: ModelConfig, names: tuple, impl: str, has_gw: bool):
    if has_gw:
        def fwd(params, batch, gw, capspecs):
            return partition_loss(cfg, params, batch, gw, capspecs, impl)

        def bwd(params, batch, gw, capspecs, cot):
            return _vjp2(cfg, params, batch, gw, capspecs, impl, cot)
    else:
        def fwd(params, batch, gw, capspecs):
            return partition_loss(cfg, params, batch, None, capspecs, impl)

        def bwd(params, batch, gw, capspecs, cot):
            return _vjp1(cfg, params, batch, capspecs, impl, cot)

    return jax.jit(fwd), jax.jit(bwd)


def _vjp1(cfg, params, batch, capspecs, impl, cot):
    _, vjp, _ = jax.vjp(
        lambda p: partition_loss(cfg, p, batch, None, capspecs, impl),
        params, has_aux=True)
    (g_params,) = vjp(cot)
    return g_params, None


def _vjp2(cfg, params, batch, gw, capspecs, impl, cot):
    _, vjp, _ = jax.vjp(
        lambda p, g: partition_loss(cfg, p, batch, g, capspecs, impl),
        params, gw, has_aux=True)
    return vjp(cot)


# ---------------------------------------------------------------------------
# The partitioned train-step driver
# ---------------------------------------------------------------------------

def partitioned_value_and_grad(
    cfg: ModelConfig,
    params: dict,
    tree: TrajectoryTree,
    capacity: int,
    *,
    impl: str = "ref",
    loss_mode: str = "sep_avg",
) -> tuple[float, dict, dict]:
    """Loss + grads for ONE tree with ≤capacity tokens resident per
    partition — every token computed exactly once (paper Fig. 5, right).

    Returns (loss, grads (float32), info)."""
    chunk_size = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    parts = partition_tree(tree, capacity, chunk_size=chunk_size,
                           loss_mode=loss_mode)
    grads_acc = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32),
                             params)
    # loss accumulates as a device array; float() once after the recursion
    # so partition dispatch pipelines instead of host-syncing per partition
    total_loss = jnp.zeros((), jnp.float32)
    total_weight = jnp.zeros((), jnp.float32)
    total_nll = jnp.zeros((), jnp.float32)
    info = {"num_partitions": len(parts),
            "tokens": sum(p.ser.n for p in parts)}

    def process(pid: int, gw_in: Optional[dict], anc_pos: np.ndarray):
        nonlocal grads_acc, total_loss, total_weight, total_nll
        part = parts[pid]
        batch = make_part_batch(cfg, part, chunk_size, anc_pos)
        capspecs = make_capspecs(cfg, part)
        fwd, bwd = _part_fns(cfg, _names_sig(capspecs), impl,
                             gw_in is not None)

        (loss, caps), metrics = fwd(params, batch, gw_in, capspecs)
        total_loss = total_loss + loss.astype(jnp.float32)
        total_weight = total_weight + \
            metrics["weight_sum"].astype(jnp.float32)
        total_nll = total_nll + metrics["nll_sum"].astype(jnp.float32)

        cot_gw_acc = None if gw_in is None else jax.tree.map(
            lambda a: jnp.zeros(a.shape, jnp.float32), gw_in)
        cot_caps = jax.tree.map(jnp.zeros_like, caps)

        for i, cut in enumerate(part.cuts):
            cut_name = f"c{i}"
            child_gw = assemble_child_gw(cfg, gw_in, caps, cut_name)
            child_anc_pos = np.concatenate(
                [anc_pos, part.ser.pos_ids[cut.path_token_idx]])
            cot_child = process(cut.child_pid, child_gw, child_anc_pos)
            cot_gw_acc = route_child_cot(cfg, gw_in, caps, cut_name,
                                         cot_child, cot_gw_acc, cot_caps)

        g_params, g_gw = bwd(params, batch, gw_in, capspecs,
                             (jnp.ones((), loss.dtype), cot_caps))
        grads_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                 grads_acc, g_params)
        if gw_in is None:
            return None
        return jax.tree.map(
            lambda own, acc: (own.astype(jnp.float32) + acc
                              ).astype(own.dtype),
            g_gw, cot_gw_acc)

    process(0, None, np.zeros((0,), np.int32))
    info["weight_sum"] = float(total_weight)
    info["nll_sum"] = float(total_nll)
    return float(total_loss), grads_acc, info


# ---------------------------------------------------------------------------
# Batched wave-scheduled planning (Tree Packing over partitions, §3.3–3.4)
#
# The recursive driver above runs one partition at a time (B=1).  Training
# needs the transpose: MANY trees' partitions per step, batched.  The wave
# scheduler packs every partition of every tree into per-wave [B, S] rows
# (core/packing.pack_partition_waves); ``build_partition_plan`` turns that
# into a pure-host PartitionPlan, and the engine's executor
# (train/engine.run_partition_plan) runs
#
#   forward  waves 0..W−1: each wave is ONE jitted call; a child's gateway
#            is assembled per row from its parent's captures (the parent is
#            always in the previous wave);
#   backward waves W−1..0: children's gateway cotangents are routed to
#            their parents' capture cotangents per row, then the wave's
#            remat backward runs as one jitted call.
#
# Rows in a wave have different ancestor depths and cut plans, so gateway
# tensors are front-padded to a shared (bucketed) ancestor length — padded
# slots are masked invisible (attention anc_valid; conv front-zeros are
# exactly the out-of-range-reads-zero semantics) — and capture plans are
# front-padded index arrays whose padded entries are trimmed host-side
# before any use.  Shape buckets (powers of two for B, ancestor length,
# cut count, path length) keep the jit cache small across steps.
#
# Memory: unlike the depth-first recursion (peak = one root-to-leaf
# partition path), wave scheduling keeps each wave's gateway inputs and
# captures resident between the two sweeps — the usual
# throughput-for-memory trade of pipelined schedules; each wave's
# *activations* are still rematerialized inside the jitted backward.
# ---------------------------------------------------------------------------


# THE shape-bucket rule, shared with the schedule cost model so planner
# signature estimates match the buckets the engine actually compiles
_pow2 = pow2


def _pad_rows(a: np.ndarray, Bb: int, fill) -> np.ndarray:
    if a.shape[0] == Bb:
        return a
    pad = np.full((Bb - a.shape[0],) + a.shape[1:], fill, a.dtype)
    return np.concatenate([a, pad], axis=0)


def _pad_tok(a: jax.Array, T: int) -> jax.Array:
    """Front-pad the token axis (2) with zeros to length T."""
    t = a.shape[2]
    if t >= T:
        return a[:, :, -T:]
    z = jnp.zeros(a.shape[:2] + (T - t,) + a.shape[3:], a.dtype)
    return jnp.concatenate([z, a], axis=2)


def _slice_gw_row(gw: dict, r: int, A_real: int) -> dict:
    """Row r of a stacked wave gateway, stripped of front padding."""
    def attn_sl(a):
        return a[:, r:r + 1, a.shape[2] - A_real:]

    out = {}
    for gkey, g in gw.items():
        h = {}
        for kind, sub in g.items():
            if kind == "attn":
                h[kind] = {t: attn_sl(sub[t]) for t in ("k", "v")}
            else:
                h[kind] = jax.tree.map(lambda a: a[:, r:r + 1], sub)
        out[gkey] = h
    return out


def _stack_gw_rows(rows: list[dict], A_max: int, Bb: int,
                   rows_idx: Optional[list[int]] = None) -> dict:
    """Stack per-row (B=1) gateways along the row axis, front-padding
    token axes (attention ancestors to A_max; conv/shift tails to their
    wave max) and adding zero rows up to Bb.  ``rows_idx`` scatters entry
    i to row ``rows_idx[i]`` (the wave's load-balance permutation);
    omitted, entry i lands at row i."""
    perm = None
    if rows_idx is not None and list(rows_idx) != list(range(len(rows))):
        src = np.full(Bb, -1, np.int64)
        for i, r in enumerate(rows_idx):
            src[r] = i
        pads = iter(range(len(rows), Bb))
        perm = jnp.asarray([p if p >= 0 else next(pads) for p in src])

    def catB(xs):
        x = jnp.concatenate(xs, axis=1)
        if Bb > len(xs):
            z = jnp.zeros((x.shape[0], Bb - len(xs)) + x.shape[2:],
                          x.dtype)
            x = jnp.concatenate([x, z], axis=1)
        if perm is not None:
            x = jnp.take(x, perm, axis=1)
        return x

    out: dict = {}
    for gkey in rows[0]:
        g: dict = {}
        for kind in rows[0][gkey]:
            sub: dict = {}
            for leaf in rows[0][gkey][kind]:
                vals = [r[gkey][kind][leaf] for r in rows]
                if kind == "attn" or leaf in ("conv", "shift"):
                    T = A_max if kind == "attn" else \
                        max(v.shape[2] for v in vals)
                    sub[leaf] = catB([_pad_tok(v, T) for v in vals])
                else:       # "state": nested pytree, no token axis
                    sub[leaf] = jax.tree.map(
                        lambda *xs: catB(list(xs)), *vals)
            g[kind] = sub
        out[gkey] = g
    return out


def _wave_capspecs(cfg: ModelConfig, cuts: list, taps: int) -> dict:
    """Bucketed, front-padded capture plans for one wave (runtime arrays).

    Padded entries index position 0; their captures are trimmed before any
    use and receive zero cotangents, so they are inert."""
    if not cuts:
        return {}
    plen_b = _pow2(max(len(c.path_idx) for c in cuts))
    ncut_b = _pow2(len(cuts))
    specs = {}
    for i in range(ncut_b):
        if i < len(cuts):
            idx = np.asarray(cuts[i].path_idx, np.int32)
            pad = np.concatenate(
                [np.zeros(plen_b - len(idx), np.int32), idx])
            cc = np.int32(max(cuts[i].cut_chunk, 0))
        else:
            pad = np.zeros(plen_b, np.int32)
            cc = np.int32(0)
        specs[f"c{i}"] = {"path_idx": pad, "cut_chunk": cc,
                          "conv_pos": pad[-taps:], "shift_pos": pad[-1:]}
    return specs


def _cut_caps_view(cfg: ModelConfig, caps: dict, cname: str, r: int,
                   true_len: int) -> dict:
    """Row r's capture for one cut, trimmed to its real (unpadded) token
    entries — the exact tensors a child partition's gateway glues in."""
    taps = max(1, max_conv_taps(cfg))
    creal = min(taps, true_len)
    out: dict = {}
    for gkey, g in caps.items():
        h: dict = {}
        for kind, cuts_d in g.items():
            if cname not in cuts_d:
                continue
            c = cuts_d[cname]
            if kind == "attn":
                h[kind] = {cname: {
                    t: c[t][:, r:r + 1, c[t].shape[2] - true_len:]
                    for t in ("k", "v")}}
            elif kind == "ssm":
                h[kind] = {cname: {
                    "state": jax.tree.map(lambda a: a[:, r:r + 1],
                                          c["state"]),
                    "conv": c["conv"][:, r:r + 1,
                                      c["conv"].shape[2] - creal:]}}
            elif kind == "tm":
                h[kind] = {cname: {
                    "state": jax.tree.map(lambda a: a[:, r:r + 1],
                                          c["state"]),
                    "shift": c["shift"][:, r:r + 1]}}
            elif kind == "cm":
                h[kind] = {cname: {"shift": c["shift"][:, r:r + 1]}}
        if h:
            out[gkey] = h
    return out


def _embed_cut_cot(cot_caps: dict, cot_view: dict, cname: str, r: int
                   ) -> None:
    """Scatter a trimmed per-cut cotangent (mirror of _cut_caps_view) back
    into the wave-level capture cotangent, in place."""
    def emb_tok(full, part):
        t = part.shape[2]
        return full.at[:, r, full.shape[2] - t:].add(
            part[:, 0].astype(full.dtype))

    def emb_row(full, part):
        return full.at[:, r].add(part[:, 0].astype(full.dtype))

    for gkey, g in cot_view.items():
        for kind, cuts_d in g.items():
            c = cuts_d[cname]
            tgt = cot_caps[gkey][kind][cname]
            if kind == "attn":
                for t in ("k", "v"):
                    tgt[t] = emb_tok(tgt[t], c[t])
            else:
                if "state" in c:
                    tgt["state"] = jax.tree.map(emb_row, tgt["state"],
                                                c["state"])
                for leaf in ("conv", "shift"):
                    if leaf in c:
                        tgt[leaf] = emb_tok(tgt[leaf], c[leaf])


@dataclass
class GatewayRef:
    """Where one gateway-bearing fragment's parent captures live: wave
    index, cut index within that wave (cname = f"c{cut}"), the parent's
    row, and the real (unpadded) captured path length."""
    wave: int
    cut: int
    row: int
    path_len: int


@dataclass
class WavePlan:
    """Host-side plan for ONE wave: fixed-shape numpy batch columns (rows
    already padded to the pow2 bucket), bucketed capture plans, and the
    gateway topology — everything the executor needs except the runtime
    capture tensors themselves."""
    batch: dict[str, np.ndarray]          # [Bb, S] columns (+anc_*, extra_*)
    capspecs: dict                        # bucketed runtime index arrays
    has_gw: bool
    num_rows: int                         # real rows (before pow2 padding)
    parents: list[GatewayRef] = field(default_factory=list)  # per slot
    slot_rows: list[int] = field(default_factory=list)       # slot → row
    A_real: list[int] = field(default_factory=list)          # per row [Bb]
    anc_A_max: int = 0                    # bucketed ancestor length
    anc_pos_rows: list[np.ndarray] = field(default_factory=list)  # per row


@dataclass
class PartitionPlan:
    """Plan for the partitioned share of one step: waves in topological
    order (parents strictly earlier), ready for the engine's forward and
    backward sweeps (train/engine.run_partition_plan)."""
    waves: list[WavePlan]
    num_trees: int
    info: dict


def build_partition_plan(
    cfg: ModelConfig,
    trees: list[TrajectoryTree],
    capacity: int,
    *,
    seq_len: Optional[int] = None,
    loss_mode: str = "sep_avg",
    max_rows: Optional[int] = None,
    row_multiple: int = 1,
    forest: Optional[list[list[TreePartition]]] = None,
) -> PartitionPlan:
    """Plan (host-side only) the wave-scheduled partitioned execution of
    MANY oversized trees: partition each tree, pack every partition into
    per-wave [B, S] rows, pad/bucket every shape, precompute ancestor
    positions and capture plans, and record the gateway topology.

    No device work happens here — the plan is pure numpy + static
    metadata.  ``train/engine.py`` executes it (one jitted forward and one
    jitted remat-backward per wave, gradients accumulated on-device).

    ``row_multiple`` rounds every wave's bucketed row count up to a
    multiple (the mesh's data-axis size) so wave batches shard evenly
    across replicas; ``forest`` passes precomputed partitions (the
    scheduler partitions each tree exactly once and reuses the result
    here — must match ``partition_tree`` on the same args)."""
    chunk_size = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    seq_len = capacity if seq_len is None else seq_len
    assert capacity <= seq_len, (capacity, seq_len)
    taps = max(1, max_conv_taps(cfg))
    info: dict[str, Any] = {"num_trees": len(trees)}
    if not trees:
        return PartitionPlan(waves=[], num_trees=0, info=info)

    if forest is None:
        forest = [partition_tree(t, capacity, chunk_size=chunk_size,
                                 loss_mode=loss_mode) for t in trees]
    assert len(forest) == len(trees)
    waves = pack_partition_waves(forest, seq_len, chunk_size=chunk_size,
                                 max_rows=max_rows)
    cut_of_child: dict[tuple[int, int], tuple[int, int]] = {}
    for w, wv in enumerate(waves):
        for ci, c in enumerate(wv.cuts):
            cut_of_child[(c.tree, c.child_pid)] = (w, ci)

    info.update(num_partitions=sum(len(p) for p in forest),
                num_waves=len(waves),
                rows=sum(wv.num_rows for wv in waves),
                max_wave_rows=max(wv.num_rows for wv in waves),
                tokens=sum(p.ser.n for ps in forest for p in ps),
                unique_tokens=sum(int(p.ser.valid.sum())
                                  for ps in forest for p in ps))

    plans: list[WavePlan] = []
    rowmaps: list[np.ndarray] = []     # per wave: packer row → balanced row
    cells = 0
    for wv in waves:
        B = wv.num_rows
        # bucket in per-replica units: identical to pow2 for power-of-two
        # replica counts, but never inflates past ~the max_rows budget the
        # way rounding pow2(B) up to an odd multiple would (e.g. B=6,
        # R=6 → 6, not round_to_multiple(8, 6)=12)
        Bb = row_multiple * _pow2(-(-B // row_multiple))
        cells += Bb * seq_len
        a = wv.arrays
        prev_np = _pad_rows(a["prev_idx"], Bb, -1)
        batch = {
            "tokens": _pad_rows(a["tokens"], Bb, 0),
            "pos_ids": _pad_rows(a["pos_ids"], Bb, 0),
            "kv_last": _pad_rows(a["kv_last"], Bb, -1),
            "weight": _pad_rows(a["weight"], Bb, 0),
            "prev_idx": prev_np,
            "valid": _pad_rows(a["valid"], Bb, False),
        }
        if chunk_size is not None:
            batch["chunk_parent"] = _pad_rows(a["chunk_parent"], Bb, -1)
            batch["prev_pows"] = prev_powers(prev_np, taps)
        if wv.cuts:
            Eb = _pow2(max(sum(1 for c in wv.cuts if c.row == r)
                           for r in range(B)))
            pos = np.zeros((Bb, Eb), np.int32)
            lab = np.zeros((Bb, Eb), np.int32)
            wgt = np.zeros((Bb, Eb), np.float32)
            cnt = [0] * B
            for c in wv.cuts:
                j = cnt[c.row]
                cnt[c.row] += 1
                pos[c.row, j] = c.boundary_pos
                lab[c.row, j] = c.boundary_label
                wgt[c.row, j] = c.boundary_weight
            batch["extra_pos"] = pos
            batch["extra_label"] = lab
            batch["extra_weight"] = wgt
        capspecs = _wave_capspecs(cfg, wv.cuts, taps)

        # waves are depth-homogeneous: either all root fragments (no
        # gateway) or all gateway-bearing; parents may sit several waves
        # back once a too-wide depth level is split under max_rows
        has_gw = forest[wv.slots[0].tree][wv.slots[0].pid].parent_pid >= 0
        parents: list[GatewayRef] = []
        A_max = 0
        anc_pos_rows: list[np.ndarray] = \
            [np.zeros((0,), np.int32) for _ in range(Bb)]
        if has_gw:
            # wave ≥ 1: one fragment per row, slot i at packer row i
            for sl in wv.slots:
                wp, ci = cut_of_child[(sl.tree, sl.pid)]
                c = waves[wp].cuts[ci]
                prow = int(rowmaps[wp][c.row])
                parents.append(GatewayRef(wave=wp, cut=ci, row=prow,
                                          path_len=len(c.path_idx)))
                anc_pos_rows[sl.row] = np.concatenate(
                    [plans[wp].anc_pos_rows[prow],
                     waves[wp].arrays["pos_ids"][c.row, c.path_idx]]
                ).astype(np.int32)
                assert len(anc_pos_rows[sl.row]) == \
                    forest[sl.tree][sl.pid].anc_len
            # lo=8: ancestor buckets stay TPU-sublane-aligned so the fused
            # pallas kernels get an MXU-friendly front-padded KV extension
            # (the chunked path is indifferent; padded slots are masked)
            A_max = _pow2(max(len(p) for p in anc_pos_rows), lo=8)
            anc_pos = np.zeros((Bb, A_max), np.int32)
            anc_valid = np.zeros((Bb, A_max), bool)
            for r, p in enumerate(anc_pos_rows):
                anc_pos[r, A_max - len(p):] = p
                anc_valid[r, A_max - len(p):] = True
            batch["anc_pos"] = anc_pos
            batch["anc_valid"] = anc_valid

        # wave-level replica balance: permute rows by gateway + token
        # load the way packed rows are snake-dealt (train/planner), so
        # contiguous per-replica shards carry non-empty-row counts within
        # 1 of each other.  Pure row permutation — identity when
        # row_multiple ≤ 1, and gradient-neutral always (row metadata is
        # row-local; the gateway topology is remapped alongside).
        loads = [int(batch["valid"][r].sum()) + len(anc_pos_rows[r])
                 for r in range(Bb)]
        order = balanced_row_order(loads, row_multiple)
        new_of = np.empty(Bb, np.int64)
        new_of[np.asarray(order)] = np.arange(Bb)
        if order != list(range(Bb)):
            batch = {k: v[np.asarray(order)] for k, v in batch.items()}
            anc_pos_rows = [anc_pos_rows[r] for r in order]
        rowmaps.append(new_of)

        plans.append(WavePlan(batch=batch, capspecs=capspecs,
                              has_gw=has_gw, num_rows=B, parents=parents,
                              slot_rows=[int(new_of[sl.row])
                                         for sl in wv.slots],
                              A_real=[len(p) for p in anc_pos_rows],
                              anc_A_max=A_max,
                              anc_pos_rows=anc_pos_rows))

    info["cells"] = cells     # materialized row cells (bucketed rows × S)
    return PartitionPlan(waves=plans, num_trees=len(trees), info=info)


def packed_partitioned_value_and_grad(
    cfg: ModelConfig,
    params: dict,
    trees: list[TrajectoryTree],
    capacity: int,
    *,
    seq_len: Optional[int] = None,
    impl: str = "ref",
    loss_mode: str = "sep_avg",
    max_rows: Optional[int] = None,
) -> tuple[float, dict, dict]:
    """Loss-*sum* + grads for MANY trees via wave-scheduled Tree Packing
    over partitions — thin compatibility wrapper: builds a PartitionPlan
    and executes it through the unified engine's wave executor
    (``train/engine.run_partition_plan``).  Every token of every tree is
    computed exactly once, with ≤ ``seq_len`` tokens per row and one
    jitted fwd / one jitted bwd call per wave.  ``max_rows`` caps every
    wave's row count (too-wide waves split), bounding per-wave activation
    residency to a ``max_rows × seq_len`` step like the packed path's
    row budget.

    Returns ``(loss_sum, grads (float32), info)``; divide by the number of
    trees to match ``loss_and_metrics``'s mean-over-trees normalizer."""
    from repro.train.engine import run_partition_plan

    plan = build_partition_plan(cfg, trees, capacity, seq_len=seq_len,
                                loss_mode=loss_mode, max_rows=max_rows)
    grads_acc = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32),
                             params)
    if not plan.waves:
        return 0.0, grads_acc, plan.info
    scal = jnp.zeros((3,), jnp.float32)
    grads_acc, scal = run_partition_plan(
        cfg, params, plan, grads_acc, scal, impl=impl,
        loss_scale=jnp.ones((), jnp.float32), donate=False)
    # one host sync point for the scalars (loss reporting + per-token nll)
    total_loss, nll_sum, weight_sum = np.asarray(scal)
    info = dict(plan.info)
    info["weight_sum"] = float(weight_sum)
    info["nll_sum"] = float(nll_sum)
    return float(total_loss), grads_acc, info


def _embed_gw_row_cot(acc: dict, row_cot: dict, r: int) -> dict:
    """Add a per-row gateway cotangent (stripped shapes) into the stacked
    wave accumulator at row r (front-padded axes)."""
    out: dict = {}
    for gkey, g in acc.items():
        h: dict = {}
        for kind, sub in g.items():
            src = row_cot[gkey][kind]
            if kind == "attn":
                h[kind] = {t: sub[t].at[:, r, sub[t].shape[2]
                                        - src[t].shape[2]:].add(
                    src[t][:, 0].astype(sub[t].dtype))
                    for t in ("k", "v")}
            else:
                hh: dict = {}
                for leaf in sub:
                    if leaf in ("conv", "shift"):
                        hh[leaf] = sub[leaf].at[
                            :, r, sub[leaf].shape[2]
                            - src[leaf].shape[2]:].add(
                            src[leaf][:, 0].astype(sub[leaf].dtype))
                    else:
                        hh[leaf] = jax.tree.map(
                            lambda a, b: a.at[:, r].add(
                                b[:, 0].astype(a.dtype)),
                            sub[leaf], src[leaf])
                h[kind] = hh
        out[gkey] = h
    return out
