"""shardlint — treelint passes 4–5: abstract-mesh SPMD & collective audit.

Pass 4 (comms audit): lower the registered entrypoints under the
production mesh descriptors (``launch/mesh``) with zero real accelerators
(``--xla_force_host_platform_device_count`` fakes), walk the post-SPMD
HLO for collectives (``analysis/hlo_comms``), attribute per-axis/per-
dtype wire bytes, and check each entrypoint's declared
:class:`~repro.analysis.registry.CommContract`:

  * ``engine.packed+acc`` — exactly one fp32 grad psum over the data
    axes (reduced element count == grad element count), zero forward
    all-gathers materializing a parameter, and with ``seq_parallel=True``
    the block-boundary forward reduction lowers as a true reduce-scatter
    with strictly fewer forward wire bytes than the all-reduce baseline
    (total fwd+bwd boundary bytes are conserved — see ``hlo_comms``'s
    byte model — so the gate is the forward edge, which is exactly what
    the ``sharding.use_mesh`` docstring claims);
  * ``session.step`` — zero data-axis collectives: decode replicas own
    disjoint cache rows.

Pass 5 (sharding-propagation lint): every ≥2-D param must match a
``sharding._RULES`` entry and must not silently lower fully replicated
when a dim divides the model axis; ``shard_activation`` annotations must
survive into the lowered StableHLO (``@Sharding`` custom calls with the
expected tile factors); every requested non-replicated param sharding
must appear in the lowering.  Coverage is closed: every registered
entrypoint needs a ``CommContract`` or a ``COMM_ALLOWED`` reason.

Pass 6 (``analysis/lock_lint``) rides along under ``lint --comms``.

The boundary attribution trick: ``sharding.tp_out_proj`` owns a known
source-line range; collectives whose HLO metadata points into that range
are the block-boundary reduction, and backward ops are split off by the
``transpose(...)`` marker in ``op_name`` (the VJP inherits the forward's
source line).

Run as ``python -m repro.analysis.lint --comms [--fast]``, or
``python -m repro.analysis.comms_audit --sweep`` for the per-family
lowering sweep (the "can't run on one host" configs become statically
verified).
"""
from __future__ import annotations

import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count"
                                 "=512")
# GSPMD's advisory "involuntary full rematerialization" messages log at
# ERROR level and flood the audit output; nothing here executes anyway
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")

import inspect       # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
from typing import Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import sharding as sh                  # noqa: E402
from repro.analysis import hlo_comms              # noqa: E402
from repro.analysis.jaxpr_audit import Finding    # noqa: E402
from repro.analysis.registry import (_forest,     # noqa: E402
                                     audit_loader_config, build_targets,
                                     comm_coverage_findings,
                                     params_abstract)
from repro.configs import (ARCH_IDS,              # noqa: E402
                           SHARDLINT_SWEEP_ARCHS, get_config)
from repro.launch.mesh import (MeshDescriptor,    # noqa: E402
                               host_descriptor, production_descriptor)
from repro.models.model import needs_chunks       # noqa: E402

SDS = jax.ShapeDtypeStruct

# the dense config the acceptance invariants are proven on
DENSE_ARCH = "qwen1p5_0p5b"

# elements below this are metric scalars (loss/nll/weight sums), not
# grads; also the slack the grad-psum equality tolerates for them when
# XLA's all-reduce combiner folds scalars into a grad tuple-reduce
SCALAR_SLACK = 64


# ---------------------------------------------------------------------------
# Pure contract checks — unit-testable on synthetic collective tables.
# Each collective dict needs: op, dtype, elems, bytes, wire_bytes, axes,
# op_name (and optionally elems_eff / wire_eff with loop multipliers).
# ---------------------------------------------------------------------------

def _eff(c: dict, key: str, base: str) -> int:
    return c.get(key, c[base])


def check_grad_psum(colls: list[dict], data_axes: tuple[str, ...],
                    grad_elems: int,
                    grad_min: Optional[int] = None) -> list[str]:
    """Exactly-once fp32 grad reduction over the data axes.

    ``grad_elems`` is the per-device ledger (Σ local shard elements);
    ``grad_min`` relaxes the lower bound for replicated params, whose
    reduction XLA may legally re-associate across mesh axes (model-axis
    AR of partials + data-axis AR of a 1/msize slice — same sum, fewer
    data-axis elements).  Sharded params have no such freedom: their
    data-axis psum must appear in full."""
    da = set(data_axes)
    grad_min = grad_elems if grad_min is None else grad_min
    total = 0
    msgs: list[str] = []
    for c in colls:
        if c["op"] != "all-reduce" or not da.issubset(set(c["axes"])):
            continue
        if c["dtype"] == "f32":
            total += _eff(c, "elems_eff", "elems")
        elif _eff(c, "elems_eff", "elems") > SCALAR_SLACK:
            msgs.append(
                f"non-fp32 ({c['dtype']}) data-axis all-reduce of "
                f"{c['elems']} elements — the grad psum must run in fp32 "
                f"(dtype policy)")
    if total < grad_min:
        msgs.append(
            f"grad psum missing or short: {total} fp32 elements "
            f"all-reduced over the data axes, expected at least "
            f"{grad_min} (each grad shard reduced exactly once)")
    elif total > grad_elems + SCALAR_SLACK:
        msgs.append(
            f"grad over-reduction: {total} fp32 elements all-reduced "
            f"over the data axes vs {grad_elems} grad shard elements "
            f"(+{SCALAR_SLACK} scalar slack) — something reduces twice, "
            f"silently scaling the effective LR")
    return msgs


def check_no_param_allgather(colls: list[dict],
                             param_elems: set[int]) -> list[str]:
    """No forward all-gather materializes a full parameter."""
    msgs = []
    for c in colls:
        if (c["op"] == "all-gather" and hlo_comms.is_forward(c)
                and c["elems"] in param_elems):
            msgs.append(
                f"forward all-gather of {c['elems']} elements matches a "
                f"parameter's full size (axes {c['axes']}, "
                f"op_name '{c['op_name'][:80]}') — params must stay "
                f"resident on the packed forward, not be re-gathered per "
                f"microbatch")
    return msgs


def check_zero_data_axis(colls: list[dict],
                         data_axes: tuple[str, ...]) -> list[str]:
    """Decode-style entrypoints: no collective may span a data axis."""
    msgs = []
    for c in colls:
        hit = set(c["axes"]) & set(data_axes)
        if hit:
            msgs.append(
                f"{c['op']} of {c['elems']} elements spans data "
                f"ax{'es' if len(hit) > 1 else 'is'} {sorted(hit)} — "
                f"decode replicas own disjoint rows; this serializes "
                f"every serving step")
    return msgs


def check_seq_parallel_boundary(base_fwd: list[dict],
                                sp_fwd: list[dict]) -> list[str]:
    """SP must replace the boundary forward all-reduce with a true
    reduce-scatter and strictly shrink forward boundary wire bytes."""
    msgs = []
    base_wire = sum(_eff(c, "wire_eff", "wire_bytes") for c in base_fwd)
    sp_wire = sum(_eff(c, "wire_eff", "wire_bytes") for c in sp_fwd)
    if not any(c["op"] == "all-reduce" for c in base_fwd):
        msgs.append(
            "baseline boundary has no forward all-reduce — source-line "
            "attribution to sharding.tp_out_proj is broken (the check "
            "would be vacuous)")
    if not any(c["op"] == "reduce-scatter" for c in sp_fwd):
        msgs.append(
            "seq_parallel=True boundary carries no true reduce-scatter — "
            "GSPMD fell back to all-reduce + slice (the docstring claim "
            "does not hold)")
    if any(c["op"] == "all-reduce" for c in sp_fwd):
        msgs.append(
            "seq_parallel=True still all-reduces at the block boundary "
            "in the forward pass")
    if sp_wire >= base_wire:
        msgs.append(
            f"seq_parallel forward boundary wire bytes did not drop: "
            f"{sp_wire} (SP) >= {base_wire} (baseline)")
    return msgs


def boundary_collectives(colls: list[dict]) -> list[dict]:
    """Collectives attributed to ``sharding.tp_out_proj``'s source lines
    — the block-boundary TP reduction (fwd + bwd)."""
    lines, start = inspect.getsourcelines(sh.tp_out_proj)
    rng = range(start, start + len(lines))
    return [c for c in colls
            if c["source_file"].endswith("repro/sharding.py")
            and c["source_line"] in rng]


# ---------------------------------------------------------------------------
# Pass 5a — host-side rule lint (zero devices needed)
# ---------------------------------------------------------------------------

def rule_lint(cfg, msize: int = 16, rules=None) -> list[str]:
    """Every ≥2-D param matches a ``_RULES`` entry, and a matched rule's
    target dim may not fall back to replication when it IS divisible by
    the model axis (the silent-fallback bug class; the documented
    fallback is only for genuinely indivisible dims).  The target dims
    are found by probing the rule with an all-divisible shape.  Runs on
    the FULL config — this is where the 1T/340B layouts get verified
    without any devices."""
    rules = sh._RULES if rules is None else rules
    params = params_abstract(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    msgs: list[str] = []
    for path, leaf in flat:
        ps = sh._path_str(path)
        n_stack = 1 if ("layer_stacks" in ps or ps.startswith("encoder")) \
            else 0
        base = leaf.shape[n_stack:]
        rule = next(((pat, fn) for pat, fn in rules
                     if re.search(pat, ps)), None)
        if rule is None:
            if len(base) >= 2:
                msgs.append(
                    f"{cfg.name}: param {ps} {tuple(leaf.shape)} matches "
                    f"no sharding._RULES entry — add a rule (or it "
                    f"silently replicates onto every device)")
            continue
        pat, fn = rule
        actual = list(fn(base, msize))
        probe = list(fn(tuple(max(d, 1) * msize for d in base), msize))
        for i, want in enumerate(probe):
            if (want == "M" and i < len(actual) and actual[i] is None
                    and base[i] % msize == 0):
                msgs.append(
                    f"{cfg.name}: param {ps} {tuple(leaf.shape)} dim {i} "
                    f"({base[i]}) divides the {msize}-way model axis but "
                    f"rule '{pat}' left it replicated — silent "
                    f"replicated fallback")
    return msgs


# ---------------------------------------------------------------------------
# Pass 5b — annotation survival in the lowered StableHLO
# ---------------------------------------------------------------------------

_DEVICES_RE = re.compile(r"devices=\[([0-9,]+)\]")


def annotation_findings(stablehlo: str, desc: MeshDescriptor,
                        seq_parallel: bool,
                        n_sharded_params: int) -> list[str]:
    msgs = []
    ann = [ln for ln in stablehlo.splitlines() if "@Sharding" in ln]
    tiled_dims = []
    for ln in ann:
        m = _DEVICES_RE.search(ln)
        if m:
            tiled_dims.append([int(x) for x in m.group(1).split(",")])
    if not tiled_dims:
        msgs.append(
            "no tiled @Sharding annotation survived lowering — "
            "shard_activation/shard_logits silently no-opped (divisibility "
            "fallback?) and the whole activation path runs replicated")
    if seq_parallel:
        msize = desc.axis_size(desc.model_axis)
        if not any(len(d) >= 3 and d[1] == msize for d in tiled_dims):
            msgs.append(
                f"seq_parallel: no rank≥3 @Sharding annotation shards "
                f"dim 1 (sequence) {msize}-way over the model axis — the "
                f"S-sharded boundary activations fell back to replicated")
    n_got = stablehlo.count('mhlo.sharding = "{devices=')
    if n_got < n_sharded_params:
        msgs.append(
            f"only {n_got} non-replicated mhlo.sharding annotations in "
            f"the lowering but {n_sharded_params} params requested "
            f"non-replicated NamedShardings — propagation dropped some")
    return msgs


# ---------------------------------------------------------------------------
# Lowering drivers
# ---------------------------------------------------------------------------

def _with_shardings(tree, shard_tree, force_dtype=None):
    def one(leaf, s):
        dt = force_dtype or leaf.dtype
        return SDS(leaf.shape, dt, sharding=s)
    return jax.tree.map(one, tree, shard_tree)


def _attach_batch(batch, mesh, daxes):
    def one(leaf):
        if not hasattr(leaf, "shape"):
            return leaf                      # python ints trace as scalars
        s = sh.batch_shardings(leaf, mesh, daxes)
        return SDS(leaf.shape, leaf.dtype, sharding=s)
    return jax.tree.map(one, batch)


def demo_packed_plan(cfg, num_replicas: int):
    """A real host-side planner run sized to the mesh's data axis (rows
    must divide it or the batch replicates and every data-axis check goes
    vacuous).  Waves are not needed here — any packed plan does."""
    from repro.train.planner import PlannerConfig, plan_window
    lc = audit_loader_config(cfg)
    pc = PlannerConfig(lookahead=2, num_replicas=num_replicas)
    for seed in range(40):
        window = [_forest(1000 * seed + b, lc.trees_per_batch,
                          cfg.vocab_size) for b in range(pc.lookahead)]
        for ps in plan_window(cfg, lc, pc, window):
            if ps.is_empty:
                continue
            plan = ps.execution_plan()
            if plan.packed is not None:
                return plan
    raise RuntimeError(f"no packed demo plan for {cfg.name} at "
                       f"{num_replicas} replicas")


def _require_devices(desc: MeshDescriptor) -> None:
    if jax.device_count() < desc.device_count:
        raise RuntimeError(
            f"mesh {desc.name} needs {desc.device_count} (fake) devices "
            f"but jax sees {jax.device_count()} — run via "
            f"'python -m repro.analysis.lint --comms' which sets "
            f"--xla_force_host_platform_device_count before jax init")


def lower_packed(cfg, impl: str, desc: MeshDescriptor, *,
                 seq_parallel: bool, compile_: bool = True):
    """Lower (and optionally compile) the engine's packed train step under
    a mesh descriptor.  Returns (lowered, colls, aux); collectives carry
    axes + loop-multiplied ``elems_eff``/``wire_eff``.  The engine's jits
    are lru-cached and ``seq_parallel`` is read at trace time, so the jit
    caches are cleared first for a fresh trace per context."""
    from repro.train.engine import NUM_SCALARS, _packed_exec_fn
    _require_devices(desc)
    jax.clear_caches()
    mesh = desc.build()
    with sh.use_mesh(mesh, data_axes=desc.data_axes,
                     model_axis=desc.model_axis,
                     seq_parallel=seq_parallel):
        params_a = params_abstract(cfg)
        pshard = sh.param_shardings(params_a, mesh,
                                    model_axis=desc.model_axis)
        plan = demo_packed_plan(cfg, desc.data_axis_size)
        batch = dict(plan.packed.inputs)
        batch["num_trees"] = max(plan.num_trees, 1)
        args = (
            _with_shardings(params_a, pshard),
            _attach_batch(batch, mesh, desc.data_axes),
            _with_shardings(params_a, pshard, force_dtype=jnp.float32),
            SDS((NUM_SCALARS,), jnp.float32,
                sharding=NamedSharding(mesh, P())),
        )
        fn = _packed_exec_fn(cfg, impl, True, with_acc=True)
        lowered = fn.lower(*args)
        colls: list[dict] = []
        if compile_:
            hlo = lowered.compile().as_text()
            colls = hlo_comms.attach_axes(
                hlo_comms.parse_collectives(hlo), desc.shape,
                desc.axis_names)
    S = batch["tokens"].shape[1]
    mult = hlo_comms.loop_multiplier(cfg)
    chunks = S // cfg.ssm.chunk_size if needs_chunks(cfg) else 1
    for c in colls:
        m = hlo_comms._mult(c, mult, chunks)
        c["elems_eff"] = c["elems"] * m
        c["wire_eff"] = c["wire_bytes"] * m
    # post-SPMD AR results are per-device shards, so the exactly-once
    # grad-psum ledger counts each param's LOCAL shard elements; the
    # all-gather check matches FULL param sizes (an AG materializing a
    # param yields the whole tensor)
    grad_elems = 0
    grad_min = 0
    param_elems = set()
    msize = desc.axis_size(desc.model_axis)
    for leaf, ns in zip(jax.tree.leaves(params_a),
                        jax.tree.leaves(pshard)):
        n_local = 1
        for d in ns.shard_shape(leaf.shape):
            n_local *= d
        grad_elems += n_local
        # replicated params: XLA may re-associate the reduction over
        # (model, data), leaving only a 1/msize slice on the data axis
        grad_min += (n_local // msize if ns.is_fully_replicated
                     else n_local)
        n_full = 1
        for d in leaf.shape:
            n_full *= d
        if n_full >= 256:
            param_elems.add(n_full)
    n_sharded = sum(1 for s in jax.tree.leaves(pshard)
                    if not s.is_fully_replicated)
    aux = {"grad_elems": grad_elems, "grad_elems_min": grad_min,
           "param_elems": param_elems,
           "n_sharded_params": n_sharded, "loop_multiplier": mult,
           "rows": batch["tokens"].shape[0], "seq_len": S}
    return lowered, colls, aux


def lower_decode(cfg, desc: MeshDescriptor, *, buf_len: int = 64):
    """Lower + compile one ``DecodeSession.step`` with the cache batch
    sized to the data axis (the registry's K=4 would replicate on a
    16-way axis and make the zero-data-collectives check vacuous)."""
    from repro.serve.decode import _init_cache
    from repro.serve.session import _step_exec
    _require_devices(desc)
    jax.clear_caches()
    mesh = desc.build()
    B = desc.data_axis_size
    enc = cfg.encdec.src_len if cfg.encdec is not None else 0
    i32 = jnp.int32
    with sh.use_mesh(mesh, data_axes=desc.data_axes,
                     model_axis=desc.model_axis):
        params_a = params_abstract(cfg)
        pshard = sh.param_shardings(params_a, mesh,
                                    model_axis=desc.model_axis)
        cache_a = jax.eval_shape(lambda: _init_cache(cfg, B, buf_len, enc))
        cshard = sh.cache_shardings(cache_a, mesh, desc.data_axes,
                                    desc.model_axis)
        dspec = NamedSharding(mesh, P(desc.data_axes))
        args = (
            _with_shardings(params_a, pshard),
            _with_shardings(cache_a, cshard),
            SDS((B, 1), i32, sharding=NamedSharding(
                mesh, P(desc.data_axes, None))),
            SDS((B,), i32, sharding=dspec),
            SDS((), i32, sharding=NamedSharding(mesh, P())),
        )
        hlo = _step_exec(cfg, True).lower(*args).compile().as_text()
    return hlo_comms.attach_axes(hlo_comms.parse_collectives(hlo),
                                 desc.shape, desc.axis_names)


# ---------------------------------------------------------------------------
# The lint entrypoints
# ---------------------------------------------------------------------------

def _f(target: str, check: str, msgs: list[str]) -> list[Finding]:
    return [Finding(target, check, m) for m in msgs]


def audit_mesh(cfg, impl: str, desc: MeshDescriptor, *,
               check_sp: bool, verbose: bool = True
               ) -> tuple[list[Finding], dict]:
    """Pass 4 + 5b for one mesh descriptor on one config."""
    def say(msg):
        if verbose:
            print(f"[shardlint] {msg}", flush=True)

    findings: list[Finding] = []
    rep: dict = {"mesh": desc.name, "shape": list(desc.shape),
                 "data_axes": list(desc.data_axes),
                 "dci_axes": list(desc.dci_axes)}
    tag = f"{cfg.name}@{desc.name}"

    t0 = time.perf_counter()
    lowered, colls, aux = lower_packed(cfg, impl, desc,
                                       seq_parallel=False)
    rep["engine.packed"] = {
        "collectives": hlo_comms.summarize(colls),
        "per_axis_wire_bytes": hlo_comms.per_axis_wire_bytes(colls),
        **{k: aux[k] for k in ("grad_elems", "rows", "seq_len",
                               "loop_multiplier")}}
    findings += _f(f"{tag}:engine.packed+acc", "comms/grad-psum",
                   check_grad_psum(colls, desc.data_axes,
                                   aux["grad_elems"],
                                   aux["grad_elems_min"]))
    findings += _f(f"{tag}:engine.packed+acc", "comms/param-allgather",
                   check_no_param_allgather(colls, aux["param_elems"]))
    findings += _f(f"{tag}:engine.packed+acc", "sharding/annotations",
                   annotation_findings(lowered.as_text(), desc, False,
                                       aux["n_sharded_params"]))
    base_boundary_fwd = [c for c in boundary_collectives(colls)
                         if hlo_comms.is_forward(c)]
    say(f"{tag} engine.packed baseline: {sum(s['count'] for s in rep['engine.packed']['collectives'].values())} "
        f"collectives, grad_elems={aux['grad_elems']} "
        f"[{time.perf_counter() - t0:.1f}s]")

    if check_sp and desc.axis_size(desc.model_axis) > 1:
        t0 = time.perf_counter()
        sp_lowered, sp_colls, sp_aux = lower_packed(cfg, impl, desc,
                                                    seq_parallel=True)
        sp_boundary_fwd = [c for c in boundary_collectives(sp_colls)
                           if hlo_comms.is_forward(c)]
        findings += _f(f"{tag}:engine.packed+acc", "comms/seq-parallel",
                       check_seq_parallel_boundary(base_boundary_fwd,
                                                   sp_boundary_fwd))
        findings += _f(f"{tag}:engine.packed+acc",
                       "sharding/annotations-sp",
                       annotation_findings(sp_lowered.as_text(), desc,
                                           True,
                                           sp_aux["n_sharded_params"]))
        base_wire = sum(c["wire_eff"] for c in base_boundary_fwd)
        sp_wire = sum(c["wire_eff"] for c in sp_boundary_fwd)
        rep["seq_parallel"] = {
            "boundary_fwd_wire_bytes": {"all_reduce_baseline": base_wire,
                                        "seq_parallel": sp_wire},
            "collectives": hlo_comms.summarize(sp_colls),
            "per_axis_wire_bytes":
                hlo_comms.per_axis_wire_bytes(sp_colls)}
        say(f"{tag} seq_parallel boundary fwd wire bytes: "
            f"{sp_wire} (SP) vs {base_wire} (baseline) "
            f"[{time.perf_counter() - t0:.1f}s]")

    t0 = time.perf_counter()
    dcolls = lower_decode(cfg, desc)
    rep["session.step"] = {
        "collectives": hlo_comms.summarize(dcolls),
        "per_axis_wire_bytes": hlo_comms.per_axis_wire_bytes(dcolls)}
    findings += _f(f"{tag}:session.step", "comms/data-axis",
                   check_zero_data_axis(dcolls, desc.data_axes))
    say(f"{tag} session.step: "
        f"{sum(s['count'] for s in rep['session.step']['collectives'].values())} "
        f"collectives, 0 on data axes required "
        f"[{time.perf_counter() - t0:.1f}s]")
    return findings, rep


def run_comms_lint(*, fast: bool = False, impl: str = "ref",
                   verbose: bool = True) -> tuple[list[Finding], dict]:
    """Passes 4–6.  ``fast``: host-mesh (16,1) descriptor + dense config,
    rule lint on the two smoke archs — the <15 s CI gate.  Full: the
    production (16,16) and (2,16,16) descriptors with the seq-parallel
    A/B, rule lint over every arch's FULL config."""
    from repro.analysis.lock_lint import lock_findings

    def say(msg):
        if verbose:
            print(f"[shardlint] {msg}", flush=True)

    findings: list[Finding] = []
    report: dict = {"mode": "fast" if fast else "full", "meshes": {}}

    # pass 6 — lock discipline (pure AST)
    findings += _f("async-layers", "lock-discipline", lock_findings())
    say("lock discipline: PlanPipeline/WeightStore/AsyncTreeRLService "
        f"audited, {len(findings)} findings")

    # pass 5a — rule lint on FULL configs (host-side, zero devices)
    t0 = time.perf_counter()
    rule_archs = (DENSE_ARCH, "qwen3_30b_a3b") if fast else ARCH_IDS
    rl: list[str] = []
    for arch in rule_archs:
        rl += rule_lint(get_config(arch))
    findings += _f("sharding._RULES", "sharding/rule-coverage", rl)
    report["rule_lint"] = {"archs": list(rule_archs),
                           "findings": len(rl),
                           "seconds": round(time.perf_counter() - t0, 2)}
    say(f"rule lint: {len(rule_archs)} full configs, {len(rl)} findings "
        f"[{report['rule_lint']['seconds']}s]")

    # comm-contract closed coverage over the dense registry
    cfg = get_config(DENSE_ARCH, smoke=True)
    cov = comm_coverage_findings(build_targets(cfg, impl))
    findings += _f("registry", "comms/coverage", cov)
    say(f"comm-contract coverage: {len(cov)} undeclared entrypoints")

    # pass 4 — lower under the mesh descriptors
    descs = ([host_descriptor(min(16, jax.device_count()))] if fast else
             [production_descriptor(False), production_descriptor(True)])
    for desc in descs:
        mesh_f, mesh_rep = audit_mesh(cfg, impl, desc,
                                      check_sp=not fast, verbose=verbose)
        findings += mesh_f
        report["meshes"][desc.name] = mesh_rep

    report["findings"] = [
        {"target": f.target, "check": f.check, "message": f.message}
        for f in findings]
    return findings, report


# ---------------------------------------------------------------------------
# Per-family lowering sweep (nightly / slow tests)
# ---------------------------------------------------------------------------

def lower_sweep(archs=SHARDLINT_SWEEP_ARCHS, impl: str = "ref",
                verbose: bool = True) -> tuple[list[Finding], dict]:
    """Prove every family (and the production-scale configs) lowers
    cleanly under the production mesh: smoke dims for the trace (family
    structure is what lowering exercises), FULL dims for the rule lint."""
    desc = production_descriptor(False)
    findings: list[Finding] = []
    rep: dict = {}
    for arch in archs:
        t0 = time.perf_counter()
        entry: dict = {}
        try:
            cfg = get_config(arch, smoke=True)
            lowered, _, aux = lower_packed(cfg, impl, desc,
                                           seq_parallel=False,
                                           compile_=False)
            entry["lowered"] = True
            findings += _f(f"{arch}@{desc.name}", "sharding/annotations",
                           annotation_findings(lowered.as_text(), desc,
                                               False,
                                               aux["n_sharded_params"]))
        except Exception as e:  # noqa: BLE001 — a sweep must report, not die
            entry["lowered"] = False
            findings.append(Finding(f"{arch}@{desc.name}",
                                    "sharding/lowering",
                                    f"failed to lower under "
                                    f"{desc.shape}: {e!r}"[:400]))
        rl = rule_lint(get_config(arch))
        findings += _f(arch, "sharding/rule-coverage", rl)
        entry["rule_findings"] = len(rl)
        entry["seconds"] = round(time.perf_counter() - t0, 1)
        rep[arch] = entry
        if verbose:
            print(f"[shardlint] sweep {arch}: lowered="
                  f"{entry['lowered']} rule_findings={entry['rule_findings']} "
                  f"[{entry['seconds']}s]", flush=True)
    return findings, rep


def main(argv=None) -> int:
    import argparse
    import sys
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.comms_audit",
        description="shardlint per-family lowering sweep")
    ap.add_argument("--sweep", action="store_true",
                    help="lower every family + production-scale config "
                         "under the production mesh")
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--impl", default="ref", choices=("ref", "pallas"))
    args = ap.parse_args(argv)
    archs = args.arch or list(SHARDLINT_SWEEP_ARCHS)
    if not args.sweep and not args.arch:
        ap.error("pass --sweep (or --arch)")
    findings, _rep = lower_sweep(archs, args.impl)
    for f in findings:
        print(f"FINDING {f}", file=sys.stderr)
    print(f"[shardlint] sweep {'FAILED' if findings else 'OK'}: "
          f"{len(findings)} findings across {len(archs)} arch(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
