"""treelint CLI: ``python -m repro.analysis.lint [--fast]``.

Runs every static pass and exits non-zero on any finding:

  1. jaxpr audit — trace each registered entrypoint per arch, prove the
     callback/donation/dtype contracts (``jaxpr_audit`` + ``registry``);
  2. jit-site coverage — every ``jax.jit`` under src/repro is audited or
     allow-listed;
  3. host-transfer AST — the engine funnels its ONE device→host read
     through ``TreeTrainEngine._sync`` (together with pass 1 this is the
     one-host-sync proof: zero in-jaxpr callbacks + one caller-side
     transfer site);
  4. signature lint — a real lookahead planner run emits only
     in-universe jit signatures (``signatures``), replayed twice: once
     on the default forest and once graft-enabled over a template-heavy
     stream, so cross-tree grafted plans stay inside the same
     SignatureUniverse;
  5. mask soundness — the Pallas block-skip predicate over the bucketed
     boundary universe + packed random trees (``mask_check``).

``--comms`` instead runs shardlint (treelint passes 4–6): the abstract-
mesh SPMD & collective-comms audit (``comms_audit`` — CommContract
checks, sharding-rule/propagation lint) plus the lock-discipline AST
lint (``lock_lint``).  It needs fake devices, so this module sets
``--xla_force_host_platform_device_count`` in ``main()`` before any jax
import — module-level imports here must stay stdlib-only.  ``--out``
writes the ``comms.json`` artifact (nightly uploads it).

``--fast`` restricts to two smoke archs and the small mask universe
(< 2 min, the CI fast gate); with ``--comms`` it audits the host mesh
on the dense config only (< 15 s).  The full sweeps run nightly and
write the ``treelint.json`` / ``comms.json`` artifacts via ``--out``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

FAST_ARCHS = ("qwen1p5_0p5b", "qwen3_30b_a3b")


def _engine_host_transfer_findings() -> list:
    """The engine's host-sync funnel contract: exactly one np/device_get
    transfer site, inside ``TreeTrainEngine._sync``."""
    import os

    from repro.analysis.jaxpr_audit import Finding
    from repro.analysis.registry import (host_transfer_sites,
                                         repro_src_root)
    path = os.path.join(repro_src_root(), "train", "engine.py")
    sites = host_transfer_sites(path)
    want = ["TreeTrainEngine._sync"]
    got = [q for q, _ in sites]
    if got != want:
        return [Finding(
            "train.engine", "host-transfer",
            f"engine host-transfer sites {got} != {want}: every "
            f"device→host read must funnel through _sync so host_syncs "
            f"stays auditable (lines {[ln for _, ln in sites]})")]
    return []


def _warmup_crosscheck(cfg, lc, pc, sig_reps) -> tuple[list, dict]:
    """Pass 6: the AOT warmup compile list IS the signature universe.

    ``train/warmup.universe_signatures`` (the list the warmup service
    compiles) and ``SignatureUniverse.enumerate_signatures`` (the list
    this auditor proves reachable) are deliberately independent
    implementations; they must agree EXACTLY — no live signature left
    silently unprecompiled, no dead bucket compiled — every enumerated
    signature must pass ``contains``, and every signature the planner
    replay actually emitted must be on the list.  Pure host enumeration:
    nothing traces or compiles, so the fast gate stays fast."""
    from repro.analysis.jaxpr_audit import Finding
    from repro.analysis.signatures import SignatureUniverse
    from repro.train.warmup import universe_signatures

    caps = [max(r["observed_caps"][i] for r in sig_reps)
            for i in range(4)]
    universe = SignatureUniverse(
        seq_len=lc.seq_len, batch_rows=lc.batch_rows,
        num_replicas=pc.num_replicas,
        max_rows=(pc.max_rows if pc.max_rows is not None
                  else lc.batch_rows),
        capacity=lc.capacity or lc.seq_len)
    enum = universe.enumerate_signatures(*caps)
    warm = universe_signatures(lc, pc, caps)
    findings: list = []
    tgt = f"{cfg.name}:warmup"
    miss = set(enum) - set(warm)
    extra = set(warm) - set(enum)
    if miss or extra:
        findings.append(Finding(
            tgt, "aot-universe",
            f"warmup compile list != enumerated universe: "
            f"{len(miss)} signature(s) would go unprecompiled "
            f"(e.g. {sorted(map(str, miss))[:2]}), {len(extra)} dead "
            f"bucket(s) would compile (e.g. "
            f"{sorted(map(str, extra))[:2]})"))
    dead = [s for s in enum if not universe.contains(s)[0]]
    if dead:
        findings.append(Finding(
            tgt, "aot-universe",
            f"{len(dead)} enumerated signature(s) fail "
            f"universe.contains (e.g. {sorted(map(str, dead))[:2]}) — "
            f"the enumeration escaped its own membership test"))
    on_list = {str(s) for s in enum}
    observed = set().union(*({s for s in r["distinct"]}
                             for r in sig_reps))
    off = sorted(observed - on_list)
    if off:
        findings.append(Finding(
            tgt, "aot-universe",
            f"{len(off)} planner-observed signature(s) missing from the "
            f"warmup compile list (e.g. {off[:2]}) — the engine would "
            f"hit the synchronous slow path mid-training"))
    report = {"caps": caps, "compile_list": len(warm),
              "enumerated": len(enum), "observed": len(observed),
              "findings": len(findings)}
    return findings, report


def run_lint(archs, *, impl: str = "ref", lookahead: int = 2,
             fast: bool = True, verbose: bool = True) -> tuple[list, dict]:
    from dataclasses import replace

    from repro.analysis import jaxpr_audit, mask_check, signatures
    from repro.analysis.registry import (audit_loader_config,
                                         build_targets,
                                         coverage_findings)
    from repro.configs import get_config
    from repro.train.planner import PlannerConfig

    def say(msg: str) -> None:
        if verbose:
            print(f"[treelint] {msg}", flush=True)

    findings: list = []
    report: dict = {"mode": "fast" if fast else "full", "archs": {}}
    all_targets: list = []

    for arch in archs:
        t0 = time.perf_counter()
        cfg = get_config(arch, smoke=True)
        targets = build_targets(cfg, impl)
        all_targets += targets
        arch_f = jaxpr_audit.audit_all(targets)
        findings += arch_f

        lc = audit_loader_config(cfg)
        pc = PlannerConfig(lookahead=lookahead, num_replicas=2)
        src = signatures.synthetic_source(cfg, n_batches=2 * lookahead,
                                          trees_per=lc.trees_per_batch)
        sig_f, sig_rep = signatures.lint_signatures(cfg, lc, pc, src)
        findings += sig_f
        # graft replay: the same universe must contain every signature a
        # graft-enabled plan emits on a template-heavy stream (grafted
        # forests pack/partition through the same shape buckets)
        pcg = replace(pc, graft=True, min_graft=max(lc.seq_len // 8, 8))
        gsrc = signatures.template_source(cfg, lc,
                                          n_batches=2 * lookahead,
                                          trees_per=lc.trees_per_batch)
        gsig_f, gsig_rep = signatures.lint_signatures(cfg, lc, pcg, gsrc)
        findings += gsig_f
        # warmup cross-check: the AOT warmup service's compile list must
        # equal the enumerated universe (and cover everything observed)
        wu_f, wu_rep = _warmup_crosscheck(cfg, lc, pc,
                                          [sig_rep, gsig_rep])
        findings += wu_f
        report["archs"][arch] = {
            "targets": [t.name for t in targets],
            "jaxpr_findings": len(arch_f),
            "signatures": sig_rep,
            "graft_signatures": gsig_rep,
            "warmup": wu_rep,
            "seconds": round(time.perf_counter() - t0, 2),
        }
        say(f"{arch}: {len(targets)} entrypoints audited, "
            f"{sig_rep['signatures_distinct']} distinct jit signatures "
            f"(AOT universe {sig_rep['aot_universe_size']}, "
            f"+{gsig_rep['signatures_distinct']} grafted, warmup list "
            f"{wu_rep['compile_list']}), "
            f"{len(arch_f) + len(sig_f) + len(gsig_f) + len(wu_f)} "
            f"findings [{report['archs'][arch]['seconds']}s]")

    cov = [jaxpr_audit.Finding("registry", "coverage", m)
           for m in coverage_findings(all_targets)]
    findings += cov
    say(f"jit-site coverage: {len(cov)} uncovered sites")

    findings += _engine_host_transfer_findings()

    t0 = time.perf_counter()
    mask_f, mask_rep = mask_check.check_predicate(fast=fast)
    mask_f += mask_check.check_bwd_shares_predicate()
    emp_f, emp_rep = mask_check.empirical_mask_check()
    findings += mask_f + emp_f
    report["mask"] = {**mask_rep, "empirical": emp_rep,
                      "seconds": round(time.perf_counter() - t0, 2)}
    say(f"mask soundness: {mask_rep['points']} boundary points over "
        f"{mask_rep['buckets']} buckets, proven skip rate "
        f"{mask_rep.get('proven_skip_rate', 0):.3f}, "
        f"{len(mask_f) + len(emp_f)} findings "
        f"[{report['mask']['seconds']}s]")

    report["findings"] = [
        {"target": f.target, "check": f.check, "message": f.message}
        for f in findings]
    return findings, report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="treelint: static jaxpr/plan/kernel auditor")
    ap.add_argument("--fast", action="store_true",
                    help="CI fast gate: two smoke archs, small mask "
                         "universe")
    ap.add_argument("--comms", action="store_true",
                    help="shardlint (passes 4-6): abstract-mesh comms "
                         "audit + sharding lint + lock lint")
    ap.add_argument("--arch", action="append", default=None,
                    help="audit this arch (repeatable; default: fast "
                         "pair or all)")
    ap.add_argument("--impl", default="ref", choices=("ref", "pallas"))
    ap.add_argument("--lookahead", type=int, default=2,
                    help="planner lookahead for the signature lint")
    ap.add_argument("--out", default=None,
                    help="write the JSON report here (treelint.json)")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.comms:
        # fake devices must exist before jax initializes: 16 covers the
        # fast host mesh, 512 the (2,16,16) production descriptor
        import os
        n = 16 if args.fast else 512
        if "xla_force_host_platform_device_count" not in os.environ.get(
                "XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "")
                + f" --xla_force_host_platform_device_count={n}")
        from repro.analysis.comms_audit import run_comms_lint
        t0 = time.perf_counter()
        findings, report = run_comms_lint(fast=args.fast, impl=args.impl,
                                          verbose=not args.quiet)
        report["total_seconds"] = round(time.perf_counter() - t0, 2)
        if args.out:
            with open(args.out, "w") as fh:
                json.dump(report, fh, indent=2, sort_keys=True)
        for f in findings:
            print(f"FINDING {f}", file=sys.stderr)
        if not args.quiet:
            print(f"[shardlint] {'FAILED' if findings else 'OK'}: "
                  f"{len(findings)} findings in "
                  f"{report['total_seconds']}s")
        return 1 if findings else 0

    if args.arch:
        archs = args.arch
    elif args.fast:
        archs = list(FAST_ARCHS)
    else:
        from repro.configs import ARCH_IDS
        archs = list(ARCH_IDS)

    t0 = time.perf_counter()
    findings, report = run_lint(archs, impl=args.impl,
                                lookahead=args.lookahead, fast=args.fast,
                                verbose=not args.quiet)
    report["total_seconds"] = round(time.perf_counter() - t0, 2)

    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)

    for f in findings:
        print(f"FINDING {f}", file=sys.stderr)
    status = "FAILED" if findings else "OK"
    if not args.quiet:
        print(f"[treelint] {status}: {len(findings)} findings across "
              f"{len(archs)} arch(s) in {report['total_seconds']}s")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
