"""Kernel mask-soundness checker: prove ``block_live`` never skips work.

The Pallas tree-attention kernels (fwd and bwd) skip a (q-block,
kv-block) pair when the scalar-prefetch predicate
``kernels/tree_attention.block_live`` says no visible (query, key) pair
can exist inside it.  An unsound predicate silently zeroes attention —
gradients stay finite and training "works", just wrong.  This pass
verifies soundness *statically*, with no kernel launch:

  boundary sweep    for every bucketed (block shape, q_off, window)
                    combination the configs can reach, enumerate the
                    predicate's scalar inputs at their boundary values
                    (block_max at q_start±1/q_end±1, window gap at
                    window±1, …) and check the predicate against an
                    independent per-pair oracle — the ref.py visibility
                    ``j ≤ i ∧ kv_last[j] ≥ i ∧ pos_q−pos_k < window``
                    evaluated on the *extremal* in-block assignment
                    (every kv_last at the block max, every position at
                    its extremum).  Visibility is monotone in kv_last and
                    anti-monotone in the position gap, so the extremal
                    assignment dominates every concrete block and the
                    boundary values dominate the integer ranges between
                    them: the finite sweep is exhaustive over the bucket
                    universe.
  empirical sweep   pack real random trees into rows and require
                    ``block_live_mask`` ⊇ the dense per-pair visibility,
                    reporting the proven block-skip rate.

Also pins the fwd/bwd kernels to the SAME predicate object — a fork of
the skip logic between them is exactly the drift this file exists to
prevent.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.kernels.tree_attention import block_live, block_live_mask

BIG = 1 << 20


@dataclass
class MaskPoint:
    """One predicate evaluation point (all *global* query indices)."""
    q_start: int
    q_end: int
    kv_start: int
    kv_end: int
    block_max: int
    window: Optional[int] = None
    gap: int = 0              # qp_min − kp_max (windowed only)


def oracle_any_visible(pt: MaskPoint) -> bool:
    """Independent per-pair oracle (ref.py visibility, evaluated on the
    extremal in-block assignment): does ANY (query i, key j) pair inside
    the block admit visibility under the block's summary scalars?"""
    i = np.arange(pt.q_start, pt.q_end + 1)[:, None]
    j = np.arange(pt.kv_start, pt.kv_end + 1)[None, :]
    vis = (j <= i) & (pt.block_max >= i)      # kv_last[j] ≡ block_max
    if pt.window is not None:                 # pos at extrema: gap const
        vis = vis & (pt.gap < pt.window)
    return bool(vis.any())


def predicate_live(pt: MaskPoint, live_fn: Callable = block_live) -> bool:
    if pt.window is None:
        return bool(live_fn(pt.q_start, pt.q_end, pt.kv_start,
                            pt.block_max))
    kp_max = BIG
    return bool(live_fn(pt.q_start, pt.q_end, pt.kv_start, pt.block_max,
                        qp_min=kp_max + pt.gap, kp_max=kp_max,
                        window=pt.window))


def boundary_points(block_q: int, block_k: int, q_off: int,
                    window: Optional[int], nq: int = 3):
    """Boundary-value enumeration for one bucket: all q blocks of a
    small grid, kv blocks straddling each causal/visibility boundary,
    block_max and window gap at ±1 around every decision threshold."""
    S_kv = q_off + nq * block_q
    nk = -(-S_kv // block_k)
    gaps = ([0] if window is None else
            sorted({-3, 0, window - 2, window - 1, window, window + 1,
                    BIG // 2}))
    for qi in range(nq):
        q_start = q_off + qi * block_q
        q_end = q_start + block_q - 1
        kis = sorted({0, q_start // block_k - 1, q_start // block_k,
                      q_end // block_k, q_end // block_k + 1, nk - 1})
        for ki in kis:
            if ki < 0 or ki >= nk:
                continue
            kv_start = ki * block_k
            kv_end = kv_start + block_k - 1
            for m in sorted({-1, q_start - 1, q_start, q_end, q_end + 1,
                             S_kv + 7}):
                for g in gaps:
                    yield MaskPoint(q_start, q_end, kv_start, kv_end, m,
                                    window, g)


def _fit_blocks(seq_lens, want: int = 128) -> set:
    from repro.kernels.ops import _fit_block
    return {_fit_block(S, want) for S in seq_lens}


def bucket_universe(fast: bool = False) -> list[tuple]:
    """(block_q, block_k, q_off, window) combinations reachable from
    configs/*: seq buckets → ``ops._fit_block`` block sizes, gateway
    ancestor pads → pow2 q_off ≥ 8, windows → {None} plus the
    long-context 8192 and adversarial small values."""
    seq_caps = [128, 256] if fast else [128, 256, 512, 1024, 2048, 4096]
    blocks = sorted(_fit_blocks(seq_caps) | {8, 16})
    off_cap = 64 if fast else 1024
    q_offs = [0] + [b for b in
                    (8 << i for i in range(20)) if b <= off_cap]
    windows = [None, 63, 8192] if fast else [None, 1, 7, 63, 257, 8192]
    return [(bq, bq, q_off, w)
            for bq in blocks for q_off in q_offs for w in windows]


def check_predicate(live_fn: Callable = block_live, *,
                    buckets=None, fast: bool = False
                    ) -> tuple[list, dict]:
    """Sweep the bucket universe; a finding is a block the predicate
    skips while the oracle proves a visible pair exists (unsoundness).
    The report carries the proven-safe skip fraction and the count of
    live-but-empty blocks (completeness, informational only)."""
    from repro.analysis.jaxpr_audit import Finding
    buckets = bucket_universe(fast) if buckets is None else buckets
    findings: list = []
    total = skipped_safe = live_empty = 0
    for bq, bk, q_off, window in buckets:
        for pt in boundary_points(bq, bk, q_off, window):
            total += 1
            live = predicate_live(pt, live_fn)
            vis = oracle_any_visible(pt)
            if vis and not live:
                findings.append(Finding(
                    "kernels.block_live", "mask",
                    f"UNSOUND skip: block q[{pt.q_start},{pt.q_end}] × "
                    f"kv[{pt.kv_start},{pt.kv_end}] block_max="
                    f"{pt.block_max} window={pt.window} gap={pt.gap} "
                    f"holds a visible pair but the predicate skips it"))
                if len(findings) >= 20:
                    report = {"points": total, "buckets": len(buckets),
                              "truncated": True}
                    return findings, report
            elif not live:
                skipped_safe += 1
            elif not vis:
                live_empty += 1
    report = {
        "points": total,
        "buckets": len(buckets),
        "proven_skip_rate": skipped_safe / max(total, 1),
        "live_empty_blocks": live_empty,
        "unsound_skips": len(findings),
    }
    return findings, report


def check_bwd_shares_predicate() -> list:
    """The backward kernels must use THE SAME predicate object — proven
    by identity, so the skip logic cannot fork."""
    from repro.analysis.jaxpr_audit import Finding
    from repro.kernels import tree_attention_bwd as bwd
    out = []
    if getattr(bwd, "block_live", None) is not block_live:
        out.append(Finding(
            "kernels.tree_attention_bwd", "mask",
            "backward kernel does not share tree_attention.block_live — "
            "fwd/bwd skip predicates can drift apart"))
    return out


def empirical_mask_check(*, seeds=(0, 1, 2), seq_len: int = 128,
                         block: int = 32, window: Optional[int] = None
                         ) -> tuple[list, dict]:
    """Pack real random trees and require the kernel's block mask to
    cover every block holding a dense-visible pair; report the proven
    skip rate on realistic packings."""
    from repro.analysis.jaxpr_audit import Finding
    from repro.core.packing import materialize_tree_rows, plan_tree_rows
    from repro.core.tree import serialize_tree
    from repro.data.synthetic import random_tree

    findings: list = []
    total = live = 0
    for seed in seeds:
        rng = np.random.default_rng(seed)
        sers = [serialize_tree(random_tree(rng, vocab_size=97))
                for _ in range(6)]
        sers = [s for s in sers if s.n <= seq_len]
        rows = plan_tree_rows([s.n for s in sers], seq_len)
        tb = materialize_tree_rows(sers, rows, seq_len)
        nq = nk = seq_len // block
        for r in range(tb.tokens.shape[0]):
            kv_last = tb.kv_last[r]
            pos = tb.pos_ids[r]
            mask = np.asarray(block_live_mask(
                kv_last, seq_len, block, block,
                pos_q=pos if window else None,
                pos_k=pos if window else None, window=window))
            i = np.arange(seq_len)[:, None]
            j = np.arange(seq_len)[None, :]
            vis = (j <= i) & (kv_last[None, :] >= i)
            if window is not None:
                vis &= (pos[:, None] - pos[None, :]) < window
            vis_blocks = vis.reshape(nq, block, nk, block).any((1, 3))
            bad = vis_blocks & ~mask
            if bad.any():
                qi, ki = map(int, np.argwhere(bad)[0])
                findings.append(Finding(
                    "kernels.block_live_mask", "mask",
                    f"seed {seed} row {r}: visible pair in block "
                    f"({qi},{ki}) skipped by the packed-row mask"))
            total += mask.size
            live += int(mask.sum())
    report = {"blocks": total,
              "proven_skip_rate": 1.0 - live / max(total, 1)}
    return findings, report
