"""Compile-signature lint: the static front half of AOT warmup.

The engine retraces on shape, and the planner's job is to keep every
emitted shape inside a small, enumerable pow2-bucket universe
(``core/plan_cost.pow2`` for waves, fixed ``[rows, seq_len]`` for the
packed batch).  This pass:

  1. derives each planned step's jit signatures
     (``core/plan_cost.packed_signature`` / ``wave_signature``) exactly
     the way ``train/engine`` keys its retraces;
  2. checks every signature of a real planner run against the reachable
     universe — an out-of-universe signature means a silent mid-training
     recompile stall;
  3. enumerates (counts) the bounded universe: the list an AOT warmup
     pass would precompile (ROADMAP item 4's static front half).

Pure host code — no jax imports, safe in CI's fast gate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Hashable, Iterable, Optional

from repro.core.plan_cost import (CompileCacheSim, packed_signature, pow2,
                                  round_to_multiple, wave_signature,
                                  wave_signature_of)

__all__ = ["wave_signature_of", "step_signatures", "SignatureUniverse",
           "lint_signatures", "synthetic_source", "template_source"]


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def step_signatures(ps) -> list[Hashable]:
    """All jit signatures one PlannedStep will dispatch (packed batch +
    every partition wave)."""
    sigs: list[Hashable] = []
    sb = ps.step_batch()
    if sb.tb is not None:
        B, S = sb.tb.tokens.shape
        sigs.append(packed_signature(B, S))
    plan = ps.execution_plan()
    if plan.partition is not None:
        sigs.extend(wave_signature_of(wp, ps.lc.seq_len)
                    for wp in plan.partition.waves)
    return sigs


@dataclass(frozen=True)
class SignatureUniverse:
    """The reachable jit-signature set for one (LoaderConfig,
    PlannerConfig) pair.  Membership is exact for the packed batch and
    pow2-bucket-shaped for waves; ``count`` bounds the enumeration an AOT
    warmup would precompile."""
    seq_len: int
    batch_rows: int
    num_replicas: int
    max_rows: int
    capacity: int

    @property
    def packed_rows(self) -> int:
        return round_to_multiple(self.batch_rows, self.num_replicas)

    @property
    def max_wave_rows(self) -> int:
        R = max(self.num_replicas, 1)
        return R * pow2(-(-self.max_rows // R))

    def contains(self, sig: Hashable) -> tuple[bool, str]:
        kind = sig[0]
        if kind == "packed":
            _, rows, S = sig
            if rows != self.packed_rows:
                return False, (f"packed rows {rows} != replica-rounded "
                               f"batch_rows {self.packed_rows}")
            if S != self.seq_len:
                return False, f"packed seq {S} != {self.seq_len}"
            return True, ""
        if kind == "wave":
            _, rows, S, anc, ncut, plen, n_extra = sig
            R = max(self.num_replicas, 1)
            if S != self.seq_len:
                return False, f"wave seq {S} != {self.seq_len}"
            if rows % R or not _is_pow2(rows // R):
                return False, (f"wave rows {rows} not a pow2 multiple of "
                               f"{R} replicas")
            if rows > self.max_wave_rows:
                return False, (f"wave rows {rows} exceed the max_rows "
                               f"bucket {self.max_wave_rows}")
            if anc and (not _is_pow2(anc) or anc < 8):
                return False, f"ancestor pad {anc} not a pow2 ≥ 8 bucket"
            if ncut and not _is_pow2(ncut):
                return False, f"cut count {ncut} not pow2-bucketed"
            if plen and (not _is_pow2(plen) or plen > pow2(self.capacity)):
                return False, f"path pad {plen} out of pow2 buckets"
            if n_extra and not _is_pow2(n_extra):
                return False, f"extra pad {n_extra} not pow2-bucketed"
            return True, ""
        return False, f"unknown signature kind {kind!r}"

    def count(self, anc_cap: int, ncut_cap: int, plen_cap: int,
              extra_cap: int) -> int:
        """Bounding-box size of the universe under observed per-field
        maxima: 1 packed + every wave bucket combination.  An upper bound
        on — and sanity check for — :meth:`enumerate_signatures`, which
        is the exact list the AOT warmup service compiles."""
        def nopts(cap: int, lo: int = 1) -> int:
            n, b = 1, lo                       # the 0 bucket
            while b <= cap:
                n, b = n + 1, b * 2
            return n
        R = max(self.num_replicas, 1)
        rows_opts = 0
        b = R
        while b <= self.max_wave_rows:
            rows_opts, b = rows_opts + 1, b * 2
        return 1 + (rows_opts * nopts(anc_cap, 8) * nopts(ncut_cap)
                    * nopts(plen_cap) * nopts(extra_cap))

    def _buckets(self, cap: int, lo: int = 1) -> list[int]:
        out, b = [0], lo
        while b <= cap:
            out.append(b)
            b *= 2
        return out

    def enumerate_signatures(self, anc_cap: int, ncut_cap: int,
                             plen_cap: int, extra_cap: int
                             ) -> list[Hashable]:
        """THE AOT compile list: every *live* signature in the universe,
        bounded by observed (or configured) per-field caps.  ``count``
        is the loose bounding-box upper bound; this enumeration drops the
        structurally dead corners a real planner run can never emit, so
        the warmup service compiles no dead bucket:

          - ``ncut == 0`` ⟺ ``plen == 0`` ⟺ ``n_extra == 0`` — the
            capture plans drive both the path pad and the boundary-extra
            columns, so the three vanish together (a leaf wave);
          - ``anc == 0 ⇒ ncut ≥ 1`` — a root wave comes from partitioning
            an oversized tree (≥ 2 fragments), so its fragments always
            cut to children; a wave with neither gateway nor cuts would
            be a row-sized tree, which packs instead;
          - ``n_extra ≤ ncut`` — per-row boundary extras are bucketed
            from per-row cut counts, never exceeding the wave total.

        Every returned signature passes :meth:`contains`;
        ``len(result) ≤ count(same caps)``."""
        sigs: list[Hashable] = [packed_signature(self.packed_rows,
                                                 self.seq_len)]
        R = max(self.num_replicas, 1)
        rows_list, b = [], R
        while b <= self.max_wave_rows:
            rows_list.append(b)
            b *= 2
        plen_cap = min(plen_cap, pow2(self.capacity))
        for rows in rows_list:
            for anc in self._buckets(anc_cap, lo=8):
                for ncut in self._buckets(ncut_cap):
                    if ncut == 0:
                        if anc > 0:     # leaf wave: gateway in, no cuts
                            sigs.append(wave_signature(
                                rows, self.seq_len, anc, 0, 0, 0))
                        continue
                    for plen in self._buckets(plen_cap)[1:]:
                        for n_extra in self._buckets(
                                min(extra_cap, ncut))[1:]:
                            sigs.append(wave_signature(
                                rows, self.seq_len, anc, ncut, plen,
                                n_extra))
        return sigs


def lint_signatures(cfg, lc, pc, source,
                    universe: Optional[SignatureUniverse] = None
                    ) -> tuple[list, dict]:
    """Run the planner over ``source`` (host-side only) and check every
    emitted jit signature against the reachable universe.  Returns
    (findings, report) where the report carries the distinct signature
    set, the simulated compile-miss count, and the AOT-universe size."""
    from repro.analysis.jaxpr_audit import Finding
    from repro.train.planner import plan_stream

    universe = universe or SignatureUniverse(
        seq_len=lc.seq_len, batch_rows=lc.batch_rows,
        num_replicas=pc.num_replicas,
        max_rows=(pc.max_rows if pc.max_rows is not None
                  else lc.batch_rows),
        capacity=lc.capacity or lc.seq_len)
    sim = CompileCacheSim()
    findings: list = []
    all_sigs: list = []
    steps = 0
    for ps in plan_stream(cfg, lc, source, pc):
        steps += 1
        sigs = step_signatures(ps)
        all_sigs.extend(sigs)
        for sig in sigs:
            ok, why = universe.contains(sig)
            if not ok:
                findings.append(Finding(
                    f"{cfg.name}:planner", "signature",
                    f"step {steps}: out-of-universe jit signature "
                    f"{sig}: {why} — would recompile mid-training"))
        sim.commit(sigs)
    distinct = sorted(set(map(str, all_sigs)))
    waves = [s for s in all_sigs if s[0] == "wave"]
    caps = [max((s[i] for s in waves), default=0) for i in (3, 4, 5, 6)]
    report = {
        "steps": steps,
        "signatures_emitted": len(all_sigs),
        "signatures_distinct": len(distinct),
        "distinct": distinct,
        "compile_misses": len(sim.seen),
        "out_of_universe": len(findings),
        "observed_caps": list(caps),
        "aot_universe_size": universe.count(*caps),
        "aot_compile_list": len(universe.enumerate_signatures(*caps)),
    }
    return findings, report


def synthetic_source(cfg, n_batches: int, trees_per: int, seed: int = 0):
    """Deterministic forests sized to exercise both packed rows and
    partition waves under the audit LoaderConfig."""
    from repro.analysis.registry import _forest
    return [_forest(1000 * seed + b, trees_per, cfg.vocab_size)
            for b in range(n_batches)]


def template_source(cfg, lc, n_batches: int, trees_per: int,
                    seed: int = 0):
    """Template-heavy forests scaled to the audit LoaderConfig's unit —
    every tree opens with one of two verbatim system-prompt templates
    (``data.synthetic.template_tree``), so a graft-enabled planner replay
    actually merges trees and its grafted plans' signatures get checked
    against the same :class:`SignatureUniverse` as ungrafted ones."""
    from repro.data.synthetic import trees_for_batch
    unit = max(lc.seq_len // 8, 8)
    return [trees_for_batch(1000 * seed + b, n_trees=trees_per,
                            kind="template", vocab_size=cfg.vocab_size,
                            num_templates=2, template_len=2 * unit,
                            num_turns=2,
                            turn_len_range=(unit // 2, 2 * unit))
            for b in range(n_batches)]
