"""Jaxpr-level auditor: trace (never execute) a jitted entrypoint with
abstract inputs and prove its contract on the resulting ClosedJaxpr +
lowering:

  callbacks   no host-callback primitive anywhere (recursing into scan /
              cond / remat sub-jaxprs) — the static half of the engine's
              one-host-sync proof: a jaxpr with zero callbacks cannot
              transfer to host mid-step, so the only syncs are what the
              caller does with the outputs (checked by the AST pass in
              ``registry.host_transfer_sites``);
  donation    the lowering's ``args_info`` must donate exactly the
              declared buffers (params/opt_state/grad-accumulator/KV
              cache) — an undonated accumulator silently doubles peak
              HBM;
  dtype       declared args/outputs are fp32, and the *accumulation
              chain* feeding each fp32 output runs in fp32: walking back
              through adds and layout-only ops, every add must produce
              fp32, and a low-precision sum upcast only at the output
              (accumulate-in-bf16-then-convert) is flagged.  The
              sanctioned pattern is ``acc + convert(g)->f32`` — upcasts
              of *addends* are exactly the dtype policy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

try:                                     # jax ≥ 0.4.36
    from jax.extend.core import ClosedJaxpr, Jaxpr, Literal
except ImportError:                      # pragma: no cover - older jax
    from jax.core import ClosedJaxpr, Jaxpr, Literal


@dataclass
class Finding:
    """One proven contract violation."""
    target: str
    check: str        # callback | donation | dtype | coverage | ...
    message: str

    def __str__(self) -> str:
        return f"[{self.check}] {self.target}: {self.message}"


# host-callback primitives: any of these in a step's jaxpr means a
# device→host round trip inside the step
CALLBACK_PRIMS = frozenset({
    "debug_callback", "pure_callback", "io_callback", "callback",
    "outside_call", "host_callback", "infeed", "outfeed", "debug_print",
})

# layout-only primitives: dtype-preserving, safe to walk through when
# following an accumulation chain backwards
_PASS_PRIMS = frozenset({
    "reshape", "broadcast_in_dim", "transpose", "squeeze", "expand_dims",
    "slice", "dynamic_slice", "dynamic_update_slice", "concatenate",
    "pad", "rev", "gather", "select_n", "copy", "stop_gradient",
})
_ADD_PRIMS = frozenset({"add", "add_any"})
_F32 = (jnp.dtype(jnp.float32), jnp.dtype(jnp.float64))


def _sub_jaxprs(v: Any) -> Iterable[Jaxpr]:
    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _sub_jaxprs(x)


def find_callbacks(closed: ClosedJaxpr) -> list[str]:
    """All callback-primitive occurrences, recursing into sub-jaxprs
    (scan bodies, cond branches, remat/custom-vjp closures)."""
    hits: list[str] = []
    stack = [closed.jaxpr]
    seen: set[int] = set()
    while stack:
        jx = stack.pop()
        if id(jx) in seen:
            continue
        seen.add(id(jx))
        for eqn in jx.eqns:
            if eqn.primitive.name in CALLBACK_PRIMS:
                hits.append(eqn.primitive.name)
            for v in eqn.params.values():
                stack.extend(_sub_jaxprs(v))
    return hits


# ---------------------------------------------------------------------------
# Donation
# ---------------------------------------------------------------------------

def _arg_donations(lowered, i: int) -> list[bool]:
    return [bool(a.donated)
            for a in jax.tree.leaves(lowered.args_info[0][i])]


def check_donation(target, lowered) -> list[Finding]:
    out = []
    for i in target.contract.donate:
        d = _arg_donations(lowered, i)
        if d and not all(d):
            out.append(Finding(
                target.name, "donation",
                f"arg {i} must be donated (buffer reuse) but "
                f"{d.count(False)}/{len(d)} leaves are not — a second "
                f"live copy of this buffer survives the dispatch"))
    for i in target.contract.keep:
        d = _arg_donations(lowered, i)
        if any(d):
            out.append(Finding(
                target.name, "donation",
                f"arg {i} must NOT be donated (shared/reread buffer) but "
                f"{sum(d)}/{len(d)} leaves are"))
    return out


# ---------------------------------------------------------------------------
# Dtype policy
# ---------------------------------------------------------------------------

def _float_leaves(tree) -> list:
    return [l for l in jax.tree.leaves(tree)
            if hasattr(l, "dtype") and jnp.issubdtype(l.dtype,
                                                      jnp.floating)]


def _out_parts(target, traced):
    outs = getattr(traced, "out_info", None)
    if outs is None:
        outs = jax.eval_shape(target.fn, *target.args)
    return outs if isinstance(outs, (tuple, list)) else (outs,)


def check_fp32_args(target) -> list[Finding]:
    out = []
    for i in target.contract.fp32_args:
        bad = {str(l.dtype) for l in _float_leaves(target.args[i])
               if jnp.dtype(l.dtype) not in _F32}
        if bad:
            out.append(Finding(
                target.name, "dtype",
                f"arg {i} must hold fp32 accumulators, found "
                f"{sorted(bad)}"))
    return out


def _accum_chain_problems(closed: ClosedJaxpr,
                          out_leaf_idx: Iterable[int]) -> list[str]:
    """Walk each flagged output leaf backwards through adds/layout ops;
    report non-fp32 adds and low-precision sums upcast only at the
    output.  ``through_add`` distinguishes the sanctioned pattern
    (convert an *addend* up to fp32) from the violation (convert the
    already-reduced sum)."""
    var_eqn: dict[int, Any] = {}
    for eqn in closed.jaxpr.eqns:
        for ov in eqn.outvars:
            var_eqn[id(ov)] = eqn
    problems: list[str] = []
    seen: set[tuple[int, bool]] = set()
    stack = [(closed.jaxpr.outvars[i], False) for i in out_leaf_idx]
    while stack:
        v, through_add = stack.pop()
        if isinstance(v, Literal) or (id(v), through_add) in seen:
            continue
        seen.add((id(v), through_add))
        dt = getattr(getattr(v, "aval", None), "dtype", None)
        if dt is None or not jnp.issubdtype(dt, jnp.floating):
            continue
        eqn = var_eqn.get(id(v))
        if eqn is None:                       # input / constant
            if jnp.dtype(dt) not in _F32:
                problems.append(f"accumulation input is {dt}")
            continue
        name = eqn.primitive.name
        if name == "convert_element_type":
            src = getattr(getattr(eqn.invars[0], "aval", None), "dtype",
                          None)
            if (not through_add and src is not None
                    and jnp.issubdtype(src, jnp.floating)
                    and jnp.dtype(src).itemsize < 4):
                problems.append(
                    f"accumulator produced by upcasting a {src} value — "
                    f"the accumulation ran below fp32")
            continue                          # addend upcast: sanctioned
        if name in _ADD_PRIMS:
            if jnp.dtype(dt) not in _F32:
                problems.append(f"accumulation add in {dt}")
            stack.extend((iv, True) for iv in eqn.invars)
            continue
        if name in _PASS_PRIMS:
            stack.extend((iv, through_add) for iv in eqn.invars)
            continue
        if jnp.dtype(dt) not in _F32:
            problems.append(f"accumulator fed by {name} in {dt}")
    return problems


def check_fp32_outs(target, traced) -> list[Finding]:
    contract = target.contract
    if not contract.fp32_outs:
        return []
    out = []
    parts = _out_parts(target, traced)
    offsets = np.cumsum([0] + [len(jax.tree.leaves(p)) for p in parts])
    for i in contract.fp32_outs:
        part = parts[i]
        bad = {str(l.dtype) for l in _float_leaves(part)
               if jnp.dtype(l.dtype) not in _F32}
        if bad:
            out.append(Finding(
                target.name, "dtype",
                f"output {i} must be fp32, found {sorted(bad)}"))
            continue
        leaf_idx = range(offsets[i], offsets[i + 1])
        for p in sorted(set(_accum_chain_problems(traced.jaxpr,
                                                  leaf_idx))):
            out.append(Finding(target.name, "dtype",
                               f"output {i}: {p}"))
    return out


# ---------------------------------------------------------------------------
# The audit
# ---------------------------------------------------------------------------

def audit_target(target) -> list[Finding]:
    """Trace + lower one registered entrypoint and prove its contract.
    Nothing is compiled or executed."""
    findings: list[Finding] = []
    traced = target.fn.trace(*target.args)
    cbs = find_callbacks(traced.jaxpr)
    if len(cbs) > target.contract.max_callbacks:
        findings.append(Finding(
            target.name, "callback",
            f"jaxpr contains host callbacks {sorted(set(cbs))} "
            f"({len(cbs)} > allowed {target.contract.max_callbacks}) — "
            f"each is a device→host round trip inside the step"))
    findings += check_donation(target, traced.lower())
    findings += check_fp32_args(target)
    findings += check_fp32_outs(target, traced)
    return findings


def audit_all(targets) -> list[Finding]:
    out: list[Finding] = []
    for t in targets:
        out.extend(audit_target(t))
    return out
