"""treelint — static auditor for the tree-training engine's invariants.

Three passes, all *static* (nothing is compiled or executed on device):

  jaxpr audit       trace every registered jitted entrypoint with abstract
                    inputs and walk the ClosedJaxpr: no host
                    callbacks (the one-host-sync proof), declared buffers
                    donated (params/opt_state/accumulator/KV cache), fp32
                    accumulation contracts honoured
                    (``repro.analysis.jaxpr_audit`` +
                    ``repro.analysis.registry``);
  signature lint    the reachable jit-signature universe from planner
                    outputs (packed + partition-wave pow2 buckets) — every
                    signature a real planner run emits must fall inside
                    it; the universe enumeration is the static front half
                    of AOT warmup (``repro.analysis.signatures``);
  mask soundness    exhaustive boundary-value verification that the Pallas
                    ``block_live`` skip predicate never skips a block
                    containing a visible (query, key) pair under the
                    ref.py visibility oracle (``repro.analysis.mask_check``).

CLI: ``python -m repro.analysis.lint [--fast]`` — exits non-zero on any
finding.  New jitted entrypoints FAIL lint until they declare their
sync/donation/dtype contract in ``registry.py`` (or are explicitly
allow-listed with a reason).
"""
from repro.analysis.jaxpr_audit import Finding, audit_target  # noqa: F401
from repro.analysis.registry import (AuditTarget, Contract,  # noqa: F401
                                     build_targets)
