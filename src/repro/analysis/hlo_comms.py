"""Post-SPMD HLO collective parsing + the wire-byte cost model.

Shared by the multi-pod dry-run tool (``launch/dryrun``) and shardlint
(``analysis/comms_audit``): one definition of what counts as a collective,
how its bytes are measured, which mesh axes it spans, and how while-loop
trip counts multiply it.

Byte model (per device, ring algorithms, the (n−1)/n factor dropped):

  all-reduce          2 × full tensor bytes (reduce-scatter + all-gather
                      halves of the ring; post-SPMD result is the full
                      replicated tensor, so 2 × result bytes)
  all-gather          1 × full tensor bytes (= result bytes: the result is
                      the gathered, group-replicated tensor)
  reduce-scatter      1 × full tensor bytes (= result bytes × group size:
                      the result is one scattered shard)
  collective-permute / all-to-all   1 × result bytes

so a ring all-reduce costs exactly reduce-scatter + all-gather — the
conservation law behind sequence parallelism: SP does not shrink fwd+bwd
boundary totals, it halves the *forward* edge (RS instead of AR) and pays
the other half as the backward's all-gather.  shardlint gates on the
forward edge for precisely this reason.

Pure string/regex code — no jax import, safe anywhere.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "f8": 1}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

# `%name = TYPE op(...)` where TYPE is `f32[4,8]{1,0}` or a tuple
# `(f32[4]{0}, s32[8]{0})` (XLA combines per-tensor all-reduces).
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\]\S*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?[\s(]")
_TYPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
_SOURCE_RE = re.compile(r'source_file="([^"]*)"\s+source_line=(\d+)')
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")


def _parse_replica_groups(line: str) -> Optional[list[list[int]]]:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ng, gs, rdims, perm = m.groups()
        rdims = [int(d) for d in rdims.split(",")]
        total = 1
        for d in rdims:
            total *= d
        ids = list(range(total))
        # reshape(rdims) → transpose(perm) → reshape(ng, gs)
        if perm:
            perm_t = [int(p) for p in perm.split(",")]
            strides = [1] * len(rdims)
            for i in range(len(rdims) - 2, -1, -1):
                strides[i] = strides[i + 1] * rdims[i + 1]
            out = []
            tdims = [rdims[p] for p in perm_t]
            tstrides = [strides[p] for p in perm_t]

            def emit(depth: int, off: int) -> None:
                if depth == len(tdims):
                    out.append(off)
                    return
                for j in range(tdims[depth]):
                    emit(depth + 1, off + j * tstrides[depth])

            emit(0, 0)
            ids = out
        ng, gs = int(ng), int(gs)
        return [ids[g * gs:(g + 1) * gs] for g in range(ng)]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return [[int(x) for x in grp.split(",") if x]
                for grp in re.findall(r"\{([^}]*)\}", m.group(1))]
    if "replica_groups={}" in line:
        return []                    # empty = one group of all devices
    return None


def _parse_pairs(line: str) -> Optional[list[tuple[int, int]]]:
    m = _PAIRS_RE.search(line)
    if not m:
        return None
    return [(int(a), int(b)) for a, b in
            re.findall(r"\{(\d+),(\d+)\}", m.group(1))]


def parse_collectives(hlo: str) -> list[dict]:
    """Every collective op in a post-SPMD HLO dump, with result bytes,
    wire bytes (the module-docstring model), replica groups, source
    attribution (``op_name`` / ``source_file`` / ``source_line`` metadata)
    and loop attribution.

    Post-optimization HLO wraps ops into called computations, so lexical
    position says nothing about loops.  We build the computation call
    graph (to_apply / body / condition / branch edges) and mark a
    collective as in-loop when some while body transitively reaches its
    computation; the nesting depth (≥2 = inside the per-layer scan's inner
    chunk scan) is recorded for the trip-count multiplier.
    """
    comp = "entry"
    comp_of_line: list[tuple[str, str]] = []
    edges: dict[str, set] = {}
    while_bodies: set[str] = set()
    for line in hlo.splitlines():
        # computation headers sit at column 0: `%name (args...) -> ty {`
        # (args may nest parens — tuple types — so don't try to span them)
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m:
                comp = m.group(1)
        comp_of_line.append((comp, line))
        for attr in re.findall(
                r"(?:to_apply|body|condition)=%?([\w\.\-]+)", line):
            edges.setdefault(comp, set()).add(attr)
        mb = re.search(r"body=%?([\w\.\-]+)", line)
        if mb and "while(" in line:
            while_bodies.add(mb.group(1))

    # loop depth per computation: BFS from each while body
    depth: dict[str, int] = {}

    def mark(c: str, d: int):
        if depth.get(c, 0) >= d:
            return
        depth[c] = d
        for nxt in edges.get(c, ()):  # descend; nested whiles add depth
            mark(nxt, d + 1 if nxt in while_bodies else d)

    for b in while_bodies:
        mark(b, 1)

    out = []
    for comp, line in comp_of_line:
        m = _COLL_RE.search(line)
        if not m:
            continue
        rtype, op, _start = m.groups()
        n_bytes = 0
        n_elems = 0
        dt = "f32"
        for dt_i, dims in _TYPE_RE.findall(rtype):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            n_elems += n
            n_bytes += n * _DTYPE_BYTES.get(dt_i, 4)
            dt = dt_i
        groups = _parse_replica_groups(line)
        pairs = _parse_pairs(line)
        group_size = len(groups[0]) if groups else None
        if op == "all-reduce":
            wire = 2 * n_bytes
        elif op == "reduce-scatter":
            wire = n_bytes * (group_size or 1)
        else:
            wire = n_bytes
        # primary loop signal: the op's own jax-level op_name metadata
        # ("jit(step)/jvp()/while/body/..."); nested scans repeat "while/".
        mo = _OPNAME_RE.search(line)
        op_name = mo.group(1) if mo else ""
        d_meta = op_name.count("while/")
        d_cg = depth.get(comp, 0)
        d_final = max(d_meta, d_cg)
        ms = _SOURCE_RE.search(line)
        out.append({"op": op, "dtype": dt,
                    "bytes": n_bytes,
                    "elems": n_elems,
                    "wire_bytes": wire,
                    "comp": comp,
                    "op_name": op_name,
                    "source_file": ms.group(1) if ms else "",
                    "source_line": int(ms.group(2)) if ms else -1,
                    "replica_groups": groups,
                    "source_target_pairs": pairs,
                    "loop_depth": d_final,
                    "in_loop": d_final >= 1})
    return out


# ---------------------------------------------------------------------------
# Mesh-axis attribution
# ---------------------------------------------------------------------------

def _coords(device_id: int, shape: Sequence[int]) -> tuple[int, ...]:
    """Row-major unravel — jax.make_mesh lays device ids out row-major
    over the mesh shape."""
    out = []
    for s in reversed(shape):
        out.append(device_id % s)
        device_id //= s
    return tuple(reversed(out))


def collective_axes(coll: dict, shape: Sequence[int],
                    axis_names: Sequence[str]) -> tuple[str, ...]:
    """Mesh axes a collective spans: axes along which some replica group
    (or permute pair) holds more than one distinct coordinate."""
    total = 1
    for s in shape:
        total *= s
    groups = coll.get("replica_groups")
    if groups == []:                         # empty = all devices
        groups = [list(range(total))]
    if not groups and coll.get("source_target_pairs"):
        groups = [list(p) for p in coll["source_target_pairs"]]
    if not groups:
        return tuple(axis_names)             # unknown: assume everything
    spanned = set()
    for grp in groups:
        cs = [_coords(d, shape) for d in grp if d < total]
        for ax in range(len(shape)):
            if len({c[ax] for c in cs}) > 1:
                spanned.add(axis_names[ax])
    return tuple(a for a in axis_names if a in spanned)


def attach_axes(colls: list[dict], shape: Sequence[int],
                axis_names: Sequence[str]) -> list[dict]:
    for c in colls:
        c["axes"] = collective_axes(c, shape, axis_names)
    return colls


def is_forward(coll: dict) -> bool:
    """Backward-pass ops carry ``transpose(...)`` in their jax op_name;
    ops inside the VJP inherit the forward's source line, so source-line
    attribution alone cannot split fwd from bwd — this can."""
    return "transpose(" not in coll.get("op_name", "")


# ---------------------------------------------------------------------------
# Summaries
# ---------------------------------------------------------------------------

def loop_multiplier(cfg) -> int:
    """Scan-over-layers trip count (dominant while loop)."""
    from repro.models.transformer import layer_groups
    groups = layer_groups(cfg)
    if cfg.family == "hybrid":
        return cfg.hybrid.attn_every
    return max(n for _, n in groups)


def _mult(coll: dict, loop_mult: int, chunk_mult: int) -> int:
    if coll["loop_depth"] >= 2:
        return loop_mult * chunk_mult
    if coll["loop_depth"] == 1:
        return loop_mult
    return 1


def summarize(colls: list[dict], loop_mult: int = 1,
              chunk_mult: int = 1) -> dict[str, dict]:
    """Per-op totals: count, result bytes, wire bytes, and the same with
    while-loop trip counts re-multiplied (scan bodies are in the HLO
    once)."""
    summary: dict[str, dict] = {}
    for c in colls:
        s = summary.setdefault(c["op"], {
            "count": 0, "bytes": 0, "bytes_with_loops": 0,
            "wire_bytes": 0, "wire_bytes_with_loops": 0})
        m = _mult(c, loop_mult, chunk_mult)
        s["count"] += 1
        s["bytes"] += c["bytes"]
        s["bytes_with_loops"] += c["bytes"] * m
        s["wire_bytes"] += c["wire_bytes"]
        s["wire_bytes_with_loops"] += c["wire_bytes"] * m
    return summary


def per_axis_wire_bytes(colls: list[dict], loop_mult: int = 1,
                        chunk_mult: int = 1) -> dict[str, int]:
    """Wire bytes attributed to each mesh axis a collective spans (a
    collective spanning k axes charges each; requires ``attach_axes``)."""
    out: dict[str, int] = {}
    for c in colls:
        m = _mult(c, loop_mult, chunk_mult)
        for a in c.get("axes", ()):
            out[a] = out.get(a, 0) + c["wire_bytes"] * m
    return out
