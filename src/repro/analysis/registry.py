"""The audited-entrypoint registry: every jitted function the engine or
server dispatches declares an :class:`AuditTarget` here — abstract inputs
plus a :class:`Contract` of what its jaxpr must (not) contain.

Coverage is *closed*: an AST pass (:func:`jit_sites` /
:func:`coverage_findings`) enumerates every ``jax.jit(...)`` call site
under ``src/repro`` and requires each to be either covered by a built
target or allow-listed with a reason — so a new jitted entrypoint fails
lint until it declares its sync/donation/dtype expectations.

All inputs are ``jax.ShapeDtypeStruct``\\ s built with ``jax.eval_shape``
over the real constructors (``init_params``, ``_init_cache``, a real
host-side planner run over synthetic trees), so the audited shapes are
exactly the shapes production traces — no device buffer is ever
allocated.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.loader import LoaderConfig
from repro.data.synthetic import random_tree
from repro.models.model import needs_chunks
from repro.models.transformer import init_params, layer_groups
from repro.serve.decode import _init_cache
from repro.serve.rollout import _decode_scan
from repro.serve.session import _fork_exec, _prefill_exec, _step_exec
from repro.train.engine import (NUM_SCALARS, _packed_exec_fn,
                                _wave_exec_fns)
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.planner import PlannerConfig, plan_window
from repro.train.train_step import jitted_update, make_train_step


@dataclass(frozen=True)
class Contract:
    """What an entrypoint's jaxpr/lowering must satisfy.

    Positions index the *top-level* positional args (``donate`` / ``keep``
    / ``fp32_args``) or the top-level components of the returned tuple
    (``fp32_outs``)."""
    max_callbacks: int = 0     # host callbacks allowed in the jaxpr
    donate: tuple = ()         # args that MUST be donated (buffer reuse)
    keep: tuple = ()           # args that must NOT be donated
    fp32_args: tuple = ()      # args whose float leaves must be fp32
    fp32_outs: tuple = ()      # outputs: fp32 leaves + fp32 add chain


@dataclass
class AuditTarget:
    """One jitted entrypoint with its abstract inputs and contract.
    ``covers`` lists the ``jax.jit`` call sites (``path::qualname``) this
    target audits — consumed by the coverage pass."""
    name: str
    fn: Any
    args: tuple
    contract: Contract
    covers: tuple = ()
    notes: list = field(default_factory=list)


@dataclass(frozen=True)
class CommContract:
    """What collectives an entrypoint's post-SPMD lowering may carry under
    the production meshes — checked by shardlint (pass 4,
    ``analysis/comms_audit``).  Every registered entrypoint must match a
    ``COMM_CONTRACTS`` pattern or be listed in ``COMM_ALLOWED`` with a
    reason; otherwise lint fails (closed coverage, like the jaxpr pass).

    ``grad_psum``: the lowering must reduce each fp32 gradient element
    over the data axes exactly once (total non-scalar data-axis fp32
    all-reduce elements == grad element count — a missing psum trains on
    per-replica grads, a doubled one silently scales the LR).
    ``no_param_allgather_fwd``: no forward all-gather materializing a
    full parameter (the FSDP regression shardlint exists to catch).
    ``zero_data_axis_collectives``: decode-style entrypoints may not
    communicate over the data axes at all — replicas serve independent
    rows.  ``seq_parallel_boundary``: with ``seq_parallel=True`` the
    block-boundary forward reduction must lower as a true reduce-scatter
    with strictly fewer forward wire bytes than the all-reduce baseline
    (the ``sharding.use_mesh`` docstring claim)."""
    grad_psum: bool = False
    no_param_allgather_fwd: bool = False
    zero_data_axis_collectives: bool = False
    seq_parallel_boundary: bool = False
    note: str = ""


# (regex over the target name after "<arch>:", contract); first match wins.
COMM_CONTRACTS: list[tuple[str, CommContract]] = [
    (r"^engine\.packed\+acc$", CommContract(
        grad_psum=True, no_param_allgather_fwd=True,
        seq_parallel_boundary=True,
        note="THE training step: one fp32 grad psum over data, params "
             "stay resident (no fwd all-gather), SP boundary audited")),
    (r"^engine\.packed$", CommContract(
        grad_psum=True, no_param_allgather_fwd=True,
        note="no-accumulator variant of engine.packed+acc")),
    (r"^engine\.wave\d+(\+gw)?\.fwd$", CommContract(
        no_param_allgather_fwd=True,
        note="partition-wave forward: same TP collectives as the packed "
             "forward; gateway tensors are activations, not params")),
    (r"^engine\.wave\d+(\+gw)?\.bwd$", CommContract(
        grad_psum=True,
        note="per-wave grads psum over data exactly like the packed bwd "
             "(GSPMD reduces sharded-batch grads onto replicated params)")),
    (r"^train_step\.jitted_update$", CommContract(
        note="elementwise optimizer on already-reduced fp32 grads; "
             "model-axis psum for the global grad-norm scalar only")),
    (r"^train_step\.make_train_step$", CommContract(
        grad_psum=True,
        note="legacy fused step: grad psum inside, then elementwise")),
    (r"^session\.step(\.snapshot)?$", CommContract(
        zero_data_axis_collectives=True,
        note="decode replicas own disjoint cache rows — any data-axis "
             "collective here serializes every serving step")),
    (r"^session\.fork$", CommContract(
        zero_data_axis_collectives=True,
        note="pure cache tiling, no cross-replica math")),
    (r"^rollout\.decode_scan$", CommContract(
        zero_data_axis_collectives=True,
        note="scanned session.step + on-device sampling")),
    (r"^session\.prefill$", CommContract(
        note="B=1 prefill replicates the batch; model-axis TP "
             "collectives only — no data-axis contract until multi-row "
             "serving lands")),
]

# entrypoints deliberately carrying NO comm contract, with the reason
COMM_ALLOWED: dict[str, str] = {}


def comm_contract_for(name: str) -> Optional[CommContract]:
    """The CommContract for a target name (``<arch>:<entrypoint>``)."""
    tail = name.split(":", 1)[-1]
    for pat, c in COMM_CONTRACTS:
        if re.search(pat, tail):
            return c
    return None


def comm_coverage_findings(targets: list["AuditTarget"]) -> list[str]:
    """Closed coverage for pass 4: every registered entrypoint declares a
    CommContract or an allow-list reason."""
    missing = []
    for t in targets:
        tail = t.name.split(":", 1)[-1]
        if comm_contract_for(t.name) is None and tail not in COMM_ALLOWED:
            missing.append(
                f"{t.name} has no CommContract — declare one in "
                f"COMM_CONTRACTS (or add '{tail}' to COMM_ALLOWED with a "
                f"reason) so its collective behavior is pinned")
    return missing


# ---------------------------------------------------------------------------
# Abstract-input builders
# ---------------------------------------------------------------------------

# abstractify moved to train/exec_cache (the runtime shares it with the
# warmup service); re-exported here for the existing audit callers
from repro.train.exec_cache import abstractify  # noqa: E402,F401


def _f32_like(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), tree)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def params_abstract(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.random.key(0))


def _forest(seed: int, n: int, vocab: int):
    rng = np.random.default_rng(seed)
    return [random_tree(rng, vocab_size=vocab, max_depth=4,
                        seg_len_range=(2, 9)) for _ in range(n)]


def audit_loader_config(cfg: ModelConfig) -> LoaderConfig:
    """The tiny schedule the auditor plans against: chunk-aligned seq/cap
    small enough that the synthetic forest yields both packed rows and
    (for partition-capable families) multi-wave partitions — the gateway
    shapes."""
    unit = cfg.ssm.chunk_size if needs_chunks(cfg) else 8
    return LoaderConfig(seq_len=8 * unit, batch_rows=3, trees_per_batch=4,
                        auto_partition=cfg.family in PARTITION_FAMILIES,
                        capacity=6 * unit)


# families partition_forward can execute (models/transformer) — other
# families train packed-only, so the registry audits no wave targets
PARTITION_FAMILIES = ("dense", "moe", "ssm", "hybrid")


def demo_planned_step(cfg: ModelConfig, *, num_replicas: int = 2):
    """A real (host-only) planner run whose winning step carries a packed
    microbatch AND — for partition-capable families — gateway-bearing
    partition waves: the full shape surface ``TreeTrainEngine.step``
    dispatches.  Deterministic: scans seeds until the forest produces
    one."""
    lc = audit_loader_config(cfg)
    want_waves = lc.auto_partition
    pc = PlannerConfig(lookahead=2, num_replicas=num_replicas)
    for seed in range(40):
        window = [_forest(1000 * seed + b, lc.trees_per_batch,
                          cfg.vocab_size) for b in range(pc.lookahead)]
        for ps in plan_window(cfg, lc, pc, window):
            if ps.is_empty:
                continue
            plan = ps.execution_plan()
            if plan.packed is None:
                continue
            if not want_waves:
                return ps, plan, lc, pc
            if (plan.partition is not None
                    and any(wp.has_gw for wp in plan.partition.waves)):
                return ps, plan, lc, pc
    raise RuntimeError(f"no packed+wave demo plan found for {cfg.name}")


# ---------------------------------------------------------------------------
# Target builders
# ---------------------------------------------------------------------------

def _packed_batch_abstract(plan) -> dict:
    batch = dict(plan.packed.inputs)
    batch["num_trees"] = max(plan.num_trees, 1)
    return abstractify(batch)


def _engine_targets(cfg: ModelConfig, impl: str, plan, params_a,
                    opt_a) -> list[AuditTarget]:
    acc_a = _f32_like(params_a)
    scal_a = _sds((NUM_SCALARS,), jnp.float32)
    scale_a = _sds((), jnp.float32)
    batch_a = _packed_batch_abstract(plan)
    targets = [
        AuditTarget(
            name=f"{cfg.name}:engine.packed+acc",
            fn=_packed_exec_fn(cfg, impl, True, with_acc=True),
            args=(params_a, batch_a, acc_a, scal_a),
            contract=Contract(donate=(2, 3), keep=(0,),
                              fp32_args=(2, 3), fp32_outs=(0, 1)),
            covers=("repro/train/engine.py::_packed_exec_fn",)),
        AuditTarget(
            name=f"{cfg.name}:engine.packed",
            fn=_packed_exec_fn(cfg, impl, True, with_acc=False),
            args=(params_a, batch_a, scal_a),
            contract=Contract(donate=(2,), keep=(0,),
                              fp32_args=(2,), fp32_outs=(0, 1)),
            covers=("repro/train/engine.py::_packed_exec_fn",)),
        AuditTarget(
            name=f"{cfg.name}:train_step.jitted_update",
            fn=jitted_update(OptimizerConfig(), True),
            args=(params_a, acc_a, opt_a),
            contract=Contract(donate=(0, 1, 2), fp32_args=(1,)),
            covers=("repro/train/train_step.py::jitted_update",)),
        AuditTarget(
            name=f"{cfg.name}:train_step.make_train_step",
            fn=make_train_step(cfg, OptimizerConfig(), impl),
            args=(params_a, opt_a, abstractify(dict(plan.packed.inputs))),
            contract=Contract(donate=(0, 1), keep=(2,)),
            covers=("repro/train/train_step.py::make_train_step",)),
    ]
    if plan.partition is not None:
        targets.extend(_wave_targets(cfg, impl, plan.partition, params_a,
                                     acc_a, scal_a, scale_a))
    return targets


def _wave_targets(cfg: ModelConfig, impl: str, partition, params_a,
                  acc_a, scal_a, scale_a) -> list[AuditTarget]:
    """One (fwd, bwd) target pair per distinct wave shape signature,
    from ``train/warmup.abstract_wave_io`` — the shared ``jax.eval_shape``
    replay of run_partition_plan's forward sweep that the AOT warmup
    service also pre-warms from (one replay, two consumers: what the
    auditor proves is exactly what warmup compiles)."""
    from repro.train.warmup import abstract_wave_io

    targets: list[AuditTarget] = []
    seen: set = set()
    for io in abstract_wave_io(cfg, partition, params_a, impl=impl,
                               donate=True):
        wp = io["wp"]
        batch_a = io["fwd_args"][1]
        sig = (wp.has_gw, batch_a["tokens"].shape, wp.anc_A_max,
               len(wp.capspecs))
        if sig in seen:
            continue
        seen.add(sig)
        tag = f"{cfg.name}:engine.wave{io['w']}" + ("+gw" if wp.has_gw
                                                    else "")
        targets.append(AuditTarget(
            name=tag + ".fwd", fn=io["fwd"], args=io["fwd_args"],
            contract=Contract(donate=(4,), keep=(0,),
                              fp32_args=(4,), fp32_outs=(1,)),
            covers=("repro/train/engine.py::_wave_exec_fns",)))
        targets.append(AuditTarget(
            name=tag + ".bwd", fn=io["bwd"], args=io["bwd_args"],
            contract=Contract(donate=(5,), keep=(0,),
                              fp32_args=(5,), fp32_outs=(0,)),
            covers=("repro/train/engine.py::_wave_exec_fns",)))
    return targets


def _serve_targets(cfg: ModelConfig, impl: str,
                   params_a) -> list[AuditTarget]:
    K, buf = 4, 64
    enc = cfg.encdec.src_len if cfg.encdec is not None else 0
    cache1 = jax.eval_shape(lambda: _init_cache(cfg, 1, buf, enc))
    cacheK = jax.eval_shape(lambda: _init_cache(cfg, K, buf, enc))
    i32 = jnp.int32
    targets = [
        AuditTarget(
            name=f"{cfg.name}:session.step",
            fn=_step_exec(cfg, True),
            args=(params_a, cacheK, _sds((K, 1), i32), _sds((K,), i32),
                  _sds((), i32)),
            contract=Contract(donate=(1,), keep=(0,)),
            covers=("repro/serve/session.py::_step_exec",)),
        AuditTarget(
            # snapshot-frozen sessions share buffers: donation forbidden
            name=f"{cfg.name}:session.step.snapshot",
            fn=_step_exec(cfg, False),
            args=(params_a, cacheK, _sds((K, 1), i32), _sds((K,), i32),
                  _sds((), i32)),
            contract=Contract(keep=(0, 1)),
            covers=("repro/serve/session.py::_step_exec",)),
        AuditTarget(
            # the parent session must stay steppable after a fork
            name=f"{cfg.name}:session.fork",
            fn=_fork_exec(K), args=(cache1,),
            contract=Contract(keep=(0,)),
            covers=("repro/serve/session.py::_fork_exec",)),
        AuditTarget(
            name=f"{cfg.name}:rollout.decode_scan",
            fn=_decode_scan(cfg, 4, 1.0),
            args=(params_a, cacheK, _sds((), i32), _sds((K,), i32),
                  jax.random.key(0)),
            contract=Contract(donate=(1,), keep=(0,)),
            covers=("repro/serve/rollout.py::_decode_scan",)),
    ]
    if (cfg.family in ("dense", "moe") and cfg.attn is not None
            and cfg.attn.window is None and cfg.frontend is None):
        t0 = P = 16
        B = 1
        gs = range(len(layer_groups(cfg)))
        gw_a = jax.eval_shape(lambda c: {
            f"g{gi}": {"attn": {"k": c[f"g{gi}"]["k"][:, :, :t0],
                                "v": c[f"g{gi}"]["v"][:, :, :t0]}}
            for gi in gs}, cache1)
        batch_a = dict(tokens=_sds((B, P), i32), pos_ids=_sds((B, P), i32),
                       kv_last=_sds((B, P), i32),
                       prev_idx=_sds((B, P), i32),
                       valid=_sds((B, P), jnp.bool_),
                       anc_pos=_sds((B, t0), i32),
                       anc_valid=_sds((B, t0), jnp.bool_))
        targets.append(AuditTarget(
            name=f"{cfg.name}:session.prefill",
            fn=_prefill_exec(cfg, impl),
            args=(params_a, batch_a, gw_a, _sds((P,), i32)),
            contract=Contract(keep=(0,)),
            covers=("repro/serve/session.py::_prefill_exec",)))
    return targets


def build_targets(cfg: ModelConfig, impl: str = "ref"
                  ) -> list[AuditTarget]:
    """Every audited entrypoint for one config: the engine's packed/wave
    executions and optimizer update on a real planned step's shapes, plus
    the serving session/rollout executables."""
    params_a = params_abstract(cfg)
    opt_a = jax.eval_shape(init_opt_state, params_a)
    _, plan, _, _ = demo_planned_step(cfg)
    targets = _engine_targets(cfg, impl, plan, params_a, opt_a)
    targets += _serve_targets(cfg, impl, params_a)
    return targets


# ---------------------------------------------------------------------------
# jit-site coverage (AST): the registry must stay closed
# ---------------------------------------------------------------------------

# jit sites that are deliberately NOT audited, each with its reason
ALLOWED_JIT_SITES = {
    "repro/core/gateway.py::_part_fns":
        "legacy B=1 depth-first partition driver (superseded by the "
        "engine's wave executions; kept for unit-level equivalence tests)",
    "repro/train/train_step.py::make_grad_fn":
        "diagnostic gradient probe (launch/rl_loop check_frozen_grads), "
        "never on the training hot path",
    "repro/launch/dryrun.py::run_combo":
        "sharding dry-run tool: AOT-lowers per-combo fns to count "
        "collectives in the HLO; prints layouts, never a training "
        "entrypoint",
}


class _JitSiteVisitor(ast.NodeVisitor):
    def __init__(self, attr: str, roots: tuple):
        self.attr, self.roots = attr, roots
        self.stack: list[str] = []
        self.sites: list[tuple[str, int]] = []

    def _scoped(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = visit_AsyncFunctionDef = visit_ClassDef = _scoped

    def visit_Call(self, node):
        f = node.func
        if (isinstance(f, ast.Attribute) and f.attr == self.attr
                and isinstance(f.value, ast.Name)
                and f.value.id in self.roots):
            self.sites.append((".".join(self.stack) or "<module>",
                               node.lineno))
        self.generic_visit(node)


def _scan_calls(src_root: str, attr: str, roots: tuple
                ) -> dict[str, list[tuple[str, int]]]:
    out: dict[str, list[tuple[str, int]]] = {}
    for dirpath, _, names in sorted(os.walk(src_root)):
        for fn in sorted(names):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path) as fh:
                tree = ast.parse(fh.read())
            v = _JitSiteVisitor(attr, roots)
            v.visit(tree)
            if v.sites:
                rel = os.path.relpath(path, os.path.dirname(src_root))
                out[rel] = v.sites
    return out


def jit_sites(src_root: str) -> dict[str, list[tuple[str, int]]]:
    """Every ``jax.jit(...)`` call site under ``src_root`` (the repro
    package dir), as {relpath: [(qualname, lineno), ...]}."""
    return _scan_calls(src_root, "jit", ("jax",))


def host_transfer_sites(path: str) -> list[tuple[str, int]]:
    """Device→host transfer call sites in one file: ``np.asarray`` /
    ``np.array`` / ``jax.device_get`` (``jnp.*`` does not count — it
    stays on device)."""
    with open(path) as fh:
        tree = ast.parse(fh.read())
    v_np = _JitSiteVisitor("asarray", ("np", "numpy"))
    v_np.visit(tree)
    v_arr = _JitSiteVisitor("array", ("np", "numpy"))
    v_arr.visit(tree)
    v_get = _JitSiteVisitor("device_get", ("jax",))
    v_get.visit(tree)
    return sorted(v_np.sites + v_arr.sites + v_get.sites,
                  key=lambda s: s[1])


def repro_src_root() -> str:
    # repro is a namespace package (no __init__): anchor on this file
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def coverage_findings(targets: list[AuditTarget],
                      src_root: Optional[str] = None) -> list[str]:
    """Uncovered jit sites: every ``jax.jit`` call under src/repro must be
    claimed by a built target's ``covers`` or allow-listed with a reason."""
    src_root = src_root or repro_src_root()
    covered = {c for t in targets for c in t.covers}
    covered |= set(ALLOWED_JIT_SITES)
    missing = []
    for rel, sites in jit_sites(src_root).items():
        for qual, line in sites:
            key = f"{rel}::{qual.split('.')[0]}"
            full = f"{rel}::{qual}"
            if key not in covered and full not in covered:
                missing.append(
                    f"{rel}:{line} jax.jit in {qual} is neither audited "
                    f"nor allow-listed — declare an AuditTarget (covers="
                    f"'{key}') or add it to ALLOWED_JIT_SITES with a "
                    f"reason")
    return missing
