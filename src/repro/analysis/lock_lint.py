"""treelint pass 6 — lock-discipline AST lint.

The async layers (``train/planner.PlanPipeline``, ``serve/service``'s
``WeightStore`` / ``AsyncTreeRLService``) are exactly the code a refactor
breaks silently: an unlocked write to shared queue state races the
consumer and shows up as a once-a-week hang, not a test failure.  This
pass pins the lock→fields discipline as data (:data:`LOCK_RULES`) and
proves by AST walk that every mutation of a guarded attribute happens
under a ``with self.<lock>:`` block.

What counts as a mutation of ``self.f``:

  * ``self.f = ...`` / ``self.f += ...``      (Assign / AugAssign)
  * ``self.f[k] = ...`` / ``del self.f[k]``   (Subscript store/delete)
  * ``self.f.append(...)`` and friends        (known mutator methods)

``__init__`` is exempt (no concurrent reader exists before construction
returns).  Fields in a rule's ``exempt`` map are skipped with their
documented reason — e.g. single-writer stats counters, or fields whose
happens-before edge is a ``Queue`` put/get rather than a lock.

Pure stdlib AST code — no jax import.  ``check_source`` takes raw source
text so the self-test can seed an unlocked write and watch it get caught.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field as dc_field

MUTATORS = frozenset({
    "append", "extend", "insert", "remove", "pop", "popleft", "clear",
    "add", "discard", "update", "setdefault", "put", "put_nowait",
    "sort", "reverse",
})


@dataclass(frozen=True)
class LockRule:
    """lock: attribute name of the owning lock/condition (None = the
    class is lock-free by design and documents that via ``exempt``)."""
    lock: str | None
    fields: frozenset[str]
    exempt: dict[str, str] = dc_field(default_factory=dict)


# file (relative to src/repro) → class → rule.  THE declared discipline.
LOCK_RULES: dict[str, dict[str, LockRule]] = {
    "train/planner.py": {
        "PlanPipeline": LockRule(
            lock="_cv",
            fields=frozenset({"_results", "_next_pull", "_next_out",
                              "_exhausted", "_stop"}),
            exempt={
                "schedule_s": "stats counter: written under _cv on the "
                              "worker path, unlocked only on the "
                              "workers=0 synchronous path (one thread)",
                "build_s": "same as schedule_s",
                "exposed_s": "same as schedule_s",
                "built": "same as schedule_s",
            }),
    },
    "serve/service.py": {
        "WeightStore": LockRule(
            lock="_cond",
            fields=frozenset({"_params", "_version"})),
        "AsyncTreeRLService": LockRule(
            lock=None,
            fields=frozenset(),
            exempt={
                "_error": "written only by the producer thread before it "
                          "enqueues the None sentinel; Queue.put/get is "
                          "the happens-before edge the consumer reads "
                          "through",
                "stats": "single-writer-per-field counters: the gen "
                         "thread owns the generation counters, the "
                         "consumer owns exposed_wait_s",
            }),
    },
}


class _LockVisitor(ast.NodeVisitor):
    """Walks one class body tracking the ``with self.<lock>:`` nesting."""

    def __init__(self, rule: LockRule, cls: str):
        self.rule, self.cls = rule, cls
        self.lock_depth = 0
        self.method: str | None = None
        self.findings: list[str] = []

    # -- scoping -----------------------------------------------------------
    def _visit_method(self, node):
        prev, self.method = self.method, node.name
        prev_d, self.lock_depth = self.lock_depth, 0
        self.generic_visit(node)
        self.method, self.lock_depth = prev, prev_d

    visit_FunctionDef = visit_AsyncFunctionDef = _visit_method

    def _holds_lock(self, item_expr) -> bool:
        return (self.rule.lock is not None
                and isinstance(item_expr, ast.Attribute)
                and item_expr.attr == self.rule.lock
                and isinstance(item_expr.value, ast.Name)
                and item_expr.value.id == "self")

    def visit_With(self, node):
        locked = any(self._holds_lock(i.context_expr) for i in node.items)
        self.lock_depth += 1 if locked else 0
        self.generic_visit(node)
        self.lock_depth -= 1 if locked else 0

    # -- mutation detection ------------------------------------------------
    def _guarded_field(self, expr) -> str | None:
        """self.f → f when f is a guarded field (unwraps self.f[k])."""
        if isinstance(expr, ast.Subscript):
            expr = expr.value
        if (isinstance(expr, ast.Attribute)
                and isinstance(expr.value, ast.Name)
                and expr.value.id == "self"
                and expr.attr in self.rule.fields):
            return expr.attr
        return None

    def _flag(self, f: str, lineno: int) -> None:
        if self.method == "__init__":
            return
        if self.lock_depth == 0:
            self.findings.append(
                f"{self.cls}.{self.method or '<class>'} line {lineno}: "
                f"mutation of self.{f} outside 'with self."
                f"{self.rule.lock}:' — declared lock discipline "
                f"(analysis/lock_lint.LOCK_RULES) requires the owning "
                f"lock for every write")

    def visit_Assign(self, node):
        for t in node.targets:
            f = self._guarded_field(t)
            if f:
                self._flag(f, node.lineno)
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        f = self._guarded_field(node.target)
        if f:
            self._flag(f, node.lineno)
        self.generic_visit(node)

    def visit_Delete(self, node):
        for t in node.targets:
            f = self._guarded_field(t)
            if f:
                self._flag(f, node.lineno)
        self.generic_visit(node)

    def visit_Call(self, node):
        fn = node.func
        if (isinstance(fn, ast.Attribute) and fn.attr in MUTATORS):
            f = self._guarded_field(fn.value)
            if f:
                self._flag(f, node.lineno)
        self.generic_visit(node)


def check_source(source: str, rules: dict[str, LockRule],
                 filename: str = "<source>") -> list[str]:
    """Lint one file's source against {class_name: LockRule}."""
    tree = ast.parse(source)
    findings: list[str] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name in rules:
            v = _LockVisitor(rules[node.name], node.name)
            for stmt in node.body:
                v.visit(stmt)
            findings += [f"{filename}: {m}" for m in v.findings]
    return findings


def lock_findings(src_root: str | None = None) -> list[str]:
    """Run the declared LOCK_RULES over the real sources."""
    if src_root is None:
        src_root = os.path.dirname(os.path.dirname(os.path.abspath(
            __file__)))
    out: list[str] = []
    for rel, rules in sorted(LOCK_RULES.items()):
        path = os.path.join(src_root, rel)
        with open(path) as fh:
            out += check_source(fh.read(), rules, filename=rel)
    return out
