"""Nemotron-4 340B [arXiv:2402.16819] — dense, 96L, d_model 18432,
96H (GQA kv=8, head_dim 192), squared-ReLU MLP (2-matrix), vocab 256000."""
from repro.configs.base import AttnCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="dense",
        n_layers=96, d_model=18432, d_ff=73728, vocab_size=256000,
        attn=AttnCfg(n_heads=96, n_kv_heads=8, head_dim=192,
                     rope_theta=1e4),
        mlp_activation="squared_relu",
        source="arXiv:2402.16819",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=96, d_ff=256, vocab_size=512,
        attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=24, rope_theta=1e4),
        dtype="float32", vocab_pad_multiple=8, name="nemotron-smoke")
