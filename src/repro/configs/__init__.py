"""Architecture registry: assigned pool (10) + paper's own models.

Each module exposes ``config()`` (exact published dims, cited) and
``smoke()`` (reduced same-family variant: ≤2 layers, d_model ≤ 512,
≤4 experts — CPU-runnable).  ``long_context_variant`` swaps full attention
for the sliding-window sub-quadratic variant used by the ``long_500k``
decode shape (see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (INPUT_SHAPES, AttnCfg, EncDecCfg, HybridCfg,
                                InputShape, ModelConfig, MoECfg, SSMCfg)

ARCH_IDS = [
    "qwen3_8b",
    "seamless_m4t_large_v2",
    "llama4_scout_17b_a16e",
    "zamba2_1p2b",
    "phi3_vision_4p2b",
    "rwkv6_1p6b",
    "qwen1p5_0p5b",
    "kimi_k2_1t_a32b",
    "nemotron_4_340b",
    "qwen2_1p5b",
    # paper's own table models
    "qwen3_32b",
    "qwen3_30b_a3b",
    "qwen3p5_gdn_2b",
]

ASSIGNED_IDS = ARCH_IDS[:10]

# shardlint's lowering sweep (analysis/comms_audit): one representative
# per family plus the production-scale configs no single host can run —
# those are statically verified under the fake-device production mesh.
SHARDLINT_SWEEP_ARCHS = (
    "qwen1p5_0p5b",           # dense
    "qwen3_30b_a3b",          # moe (paper table)
    "rwkv6_1p6b",             # ssm
    "zamba2_1p2b",            # hybrid
    "phi3_vision_4p2b",       # vlm
    "seamless_m4t_large_v2",  # audio
    "kimi_k2_1t_a32b",        # 1T MoE — production scale
    "nemotron_4_340b",        # 340B dense — production scale
)


def _canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "p")


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_canon(arch)}")
    return mod.smoke() if smoke else mod.config()


def long_context_variant(cfg: ModelConfig, window: int = 8192) -> ModelConfig:
    """Sub-quadratic variant for long_500k: sliding-window attention for
    full-attention layers (SSM layers are already O(1) in context)."""
    if cfg.attn is None:
        return cfg
    return cfg.replace(attn=dataclasses.replace(cfg.attn, window=window))


def supports_long_decode(cfg: ModelConfig) -> bool:
    """seamless (enc-dec translation) has no 500k-decode task semantics —
    skipped per DESIGN.md; everything else runs it (SSM natively, dense/
    MoE/VLM via the sliding-window variant)."""
    return cfg.family != "audio"


def supports_shape(cfg: ModelConfig, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return supports_long_decode(cfg)
    return True
