"""RWKV6 "Finch" 1.6B [arXiv:2404.05892] — attention-free; data-dependent
per-channel decay time-mix + channel-mix; token-shift everywhere."""
from repro.configs.base import ModelConfig, SSMCfg


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        n_layers=24, d_model=2048, d_ff=7168, vocab_size=65536,
        ssm=SSMCfg(kind="rwkv6", head_dim=64, expand=1, chunk_size=32),
        source="arXiv:2404.05892",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, d_ff=128, vocab_size=512,
        ssm=SSMCfg(kind="rwkv6", head_dim=16, expand=1, chunk_size=8),
        dtype="float32", vocab_pad_multiple=8, name="rwkv6-smoke")
