"""Phi-3-vision 4.2B [hf:microsoft/Phi-3-vision-128k-instruct] — phi3-mini
LM backbone (32L, d_model 3072, 32H/32KV) consuming CLIP-ViT patch
embeddings through a projector; frontend is a stub (patch embeddings of
projector-output shape arrive pre-computed)."""
from repro.configs.base import AttnCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        n_layers=32, d_model=3072, d_ff=8192, vocab_size=32064,
        attn=AttnCfg(n_heads=32, n_kv_heads=32, head_dim=96),
        frontend="vision", frontend_len=576,   # 24x24 CLIP-L patch grid
        mlp_activation="swiglu",
        source="hf:microsoft/Phi-3-vision-128k-instruct",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, d_ff=128, vocab_size=512,
        attn=AttnCfg(n_heads=4, n_kv_heads=4, head_dim=16),
        frontend_len=8, dtype="float32", vocab_pad_multiple=8,
        name="phi3v-smoke")
