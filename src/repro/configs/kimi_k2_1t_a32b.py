"""Kimi K2 1T-A32B [arXiv:2501.kimi2] — trillion-parameter MoE: 61 layers
(first dense), 384 routed experts top-8 + 1 shared, d_expert 2048,
d_model 7168, 64 q heads (GQA kv=8).  Paper-table scale model."""
from repro.configs.base import AttnCfg, ModelConfig, MoECfg


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, d_ff=18432, vocab_size=163840,
        attn=AttnCfg(n_heads=64, n_kv_heads=8, head_dim=128),
        moe=MoECfg(num_experts=384, top_k=8, d_expert=2048,
                   num_shared_experts=1, first_dense_layers=1,
                   capacity_factor=1.25),
        mlp_activation="swiglu",
        source="arXiv:2501.kimi2",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, d_ff=128, vocab_size=512,
        attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=16),
        moe=MoECfg(num_experts=4, top_k=2, d_expert=32,
                   num_shared_experts=1, first_dense_layers=1,
                   capacity_factor=2.0),
        dtype="float32", vocab_pad_multiple=8, name="kimi-smoke")
