"""Qwen3-8B [hf:Qwen/Qwen3-8B] — dense, GQA (32H/8KV, head_dim 128),
qk_norm, SwiGLU."""
from repro.configs.base import AttnCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b", family="dense",
        n_layers=36, d_model=4096, d_ff=12288, vocab_size=151936,
        attn=AttnCfg(n_heads=32, n_kv_heads=8, head_dim=128, qk_norm=True,
                     rope_theta=1e6),
        mlp_activation="swiglu",
        source="hf:Qwen/Qwen3-8B",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, d_ff=256, vocab_size=512,
        attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=32, qk_norm=True),
        dtype="float32", vocab_pad_multiple=8, name="qwen3-8b-smoke")
