"""Zamba2-1.2B [arXiv:2411.15242] — hybrid: 38 Mamba2 blocks + one shared
full-attention block applied every 6 layers with [hidden ; embed]
concatenation (the model's signature weight-sharing design)."""
from repro.configs.base import AttnCfg, HybridCfg, ModelConfig, SSMCfg


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, d_ff=8192, vocab_size=32000,
        attn=AttnCfg(n_heads=32, n_kv_heads=32, head_dim=64),
        ssm=SSMCfg(kind="mamba2", d_state=64, head_dim=64, expand=2,
                   conv_kernel=4, chunk_size=64),
        hybrid=HybridCfg(attn_every=6, concat_embed=True),
        mlp_activation="swiglu",
        source="arXiv:2411.15242",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=4, d_model=64, d_ff=128, vocab_size=512,
        attn=AttnCfg(n_heads=4, n_kv_heads=4, head_dim=16),
        ssm=SSMCfg(kind="mamba2", d_state=8, head_dim=16, expand=2,
                   conv_kernel=4, chunk_size=8),
        hybrid=HybridCfg(attn_every=2, concat_embed=True),
        dtype="float32", vocab_pad_multiple=8, name="zamba2-smoke")
