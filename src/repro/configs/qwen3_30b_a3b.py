"""Qwen3-30B-A3B [arXiv:2505.09388] — the paper's MoE experiment model
(Fig. 7 left): 48L, d_model 2048, 32H/4KV, 128 experts top-8,
d_expert 768."""
from repro.configs.base import AttnCfg, ModelConfig, MoECfg


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-30b-a3b", family="moe",
        n_layers=48, d_model=2048, d_ff=6144, vocab_size=151936,
        attn=AttnCfg(n_heads=32, n_kv_heads=4, head_dim=128, qk_norm=True,
                     rope_theta=1e6),
        moe=MoECfg(num_experts=128, top_k=8, d_expert=768,
                   capacity_factor=1.25),
        mlp_activation="swiglu",
        source="arXiv:2505.09388 (paper Fig. 7)",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, d_ff=128, vocab_size=512,
        attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=16, qk_norm=True),
        moe=MoECfg(num_experts=4, top_k=2, d_expert=32,
                   capacity_factor=2.0),
        dtype="float32", vocab_pad_multiple=8, name="qwen3-moe-smoke")
