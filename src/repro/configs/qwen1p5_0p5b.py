"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B] — dense, MHA 16H (kv=16),
QKV bias."""
from repro.configs.base import AttnCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b", family="dense",
        n_layers=24, d_model=1024, d_ff=2816, vocab_size=151936,
        attn=AttnCfg(n_heads=16, n_kv_heads=16, head_dim=64,
                     qkv_bias=True),
        mlp_activation="swiglu",
        source="hf:Qwen/Qwen1.5-0.5B",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, d_ff=128, vocab_size=512,
        attn=AttnCfg(n_heads=4, n_kv_heads=4, head_dim=16, qkv_bias=True),
        dtype="float32", vocab_pad_multiple=8, name="qwen1.5-smoke")
