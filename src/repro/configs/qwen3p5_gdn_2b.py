"""Qwen3.5-style GDN stack (paper App. A's hybrid SSM component) — a 2B
Gated-Delta-Net decoder exercising the paper's tree state routing +
tree-correct causal conv for GDN exactly as in App. A.2/A.3."""
from repro.configs.base import ModelConfig, SSMCfg


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3p5-gdn-2b", family="ssm",
        n_layers=24, d_model=2048, d_ff=8192, vocab_size=151936,
        ssm=SSMCfg(kind="gdn", head_dim=128, expand=1, conv_kernel=4,
                   chunk_size=64),
        mlp_activation="swiglu",
        source="paper App. A (GDN; Qwen3.5 component)",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, d_ff=128, vocab_size=512,
        ssm=SSMCfg(kind="gdn", head_dim=16, expand=1, conv_kernel=4,
                   chunk_size=8),
        dtype="float32", vocab_pad_multiple=8, name="gdn-smoke")
