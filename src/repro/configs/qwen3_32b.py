"""Qwen3-32B [arXiv:2505.09388] — the paper's dense experiment model
(Fig. 7 right): 64L, d_model 5120, 64H/8KV head_dim 128, qk_norm."""
from repro.configs.base import AttnCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, d_ff=25600, vocab_size=151936,
        attn=AttnCfg(n_heads=64, n_kv_heads=8, head_dim=128, qk_norm=True,
                     rope_theta=1e6),
        mlp_activation="swiglu",
        source="arXiv:2505.09388 (paper Fig. 7)",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, d_ff=256, vocab_size=512,
        attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=32, qk_norm=True),
        dtype="float32", vocab_pad_multiple=8, name="qwen3-32b-smoke")
