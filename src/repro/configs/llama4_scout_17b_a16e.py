"""Llama-4 Scout 17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE,
16 experts top-1 + shared expert, every layer MoE, early fusion
(text-only backbone here)."""
from repro.configs.base import AttnCfg, ModelConfig, MoECfg


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        n_layers=48, d_model=5120, d_ff=8192, vocab_size=202048,
        attn=AttnCfg(n_heads=40, n_kv_heads=8, head_dim=128,
                     rope_theta=5e5),
        moe=MoECfg(num_experts=16, top_k=1, d_expert=8192,
                   num_shared_experts=1, capacity_factor=1.25),
        mlp_activation="swiglu",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, d_ff=128, vocab_size=512,
        attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=16),
        moe=MoECfg(num_experts=4, top_k=1, d_expert=64,
                   num_shared_experts=1, capacity_factor=2.0),
        dtype="float32", vocab_pad_multiple=8, name="llama4-smoke")
