"""Qwen2-1.5B [arXiv:2407.10671] — dense, GQA 12H/2KV, QKV bias.
Note: 12 heads do not divide the 16-way model axis, but the q feature dim
(1536) does — projections shard by features and heads straddle devices
(GSPMD inserts the halo collectives; dry-run-verified)."""
from repro.configs.base import AttnCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, d_ff=8960, vocab_size=151936,
        attn=AttnCfg(n_heads=12, n_kv_heads=2, head_dim=128,
                     qkv_bias=True),
        mlp_activation="swiglu",
        source="arXiv:2407.10671",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=96, d_ff=192, vocab_size=512,
        attn=AttnCfg(n_heads=6, n_kv_heads=2, head_dim=16, qkv_bias=True),
        dtype="float32", vocab_pad_multiple=8, name="qwen2-smoke")
