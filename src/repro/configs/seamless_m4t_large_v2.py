"""SeamlessM4T-large v2 [arXiv:2308.11596] — enc-dec multimodal (audio)
backbone: 24 enc + 24 dec, d_model 1024, 16H (kv=16), d_ff 8192,
vocab 256206 (padded to a model-axis multiple).  The mel+conv audio
frontend is a stub: input_specs provides frame embeddings."""
from repro.configs.base import AttnCfg, EncDecCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        n_layers=24, d_model=1024, d_ff=8192, vocab_size=256206,
        attn=AttnCfg(n_heads=16, n_kv_heads=16, head_dim=64),
        encdec=EncDecCfg(enc_layers=24, dec_layers=24, src_len=1024),
        frontend="audio", frontend_len=1024,
        mlp_activation="swiglu",
        source="arXiv:2308.11596",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, d_ff=128, vocab_size=512,
        attn=AttnCfg(n_heads=4, n_kv_heads=4, head_dim=16),
        encdec=EncDecCfg(enc_layers=2, dec_layers=2, src_len=16),
        frontend_len=16, dtype="float32", vocab_pad_multiple=8,
        name="seamless-smoke")
