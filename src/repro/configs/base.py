"""Model / run configuration schema.

One ``ModelConfig`` fully describes an architecture; ``src/repro/configs/``
holds one module per assigned architecture returning the exact paper/model-
card config plus a reduced ``smoke()`` variant for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class AttnCfg:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    window: Optional[int] = None       # sliding-window size (positions); None = full
    softmax_scale: Optional[float] = None

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


@dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_expert: int                       # per-expert FFN hidden dim
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0         # leading dense layers (DeepSeek/Kimi style)
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-4


@dataclass(frozen=True)
class SSMCfg:
    kind: str                           # 'mamba2' | 'rwkv6' | 'gdn'
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4                # causal conv width (mamba2/gdn)
    chunk_size: int = 64                # tree chunk grid

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class HybridCfg:
    """Zamba2-style: shared full-attention block every k SSM layers."""
    attn_every: int = 6
    concat_embed: bool = True           # shared block consumes [h ; embed0]


@dataclass(frozen=True)
class EncDecCfg:
    enc_layers: int
    dec_layers: int
    src_len: int = 1024                 # frontend frames for dry-run specs


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                         # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: Optional[AttnCfg] = None
    mlp_activation: str = "swiglu"      # swiglu | squared_relu | relu_sq_glu
    mlp_bias: bool = False
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    hybrid: Optional[HybridCfg] = None
    encdec: Optional[EncDecCfg] = None
    frontend: Optional[str] = None      # None | 'audio' | 'vision'
    frontend_len: int = 0               # stub prefix length (patches/frames)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    remat: str = "none"                 # none | full (checkpoint scan body)
    source: str = ""                    # citation

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (roofline MODEL_FLOPS) ----------------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; active_only counts routed experts
        at top_k/num_experts utilization (for 6·N_active·D)."""
        D, F, L = self.d_model, self.d_ff, self.n_layers
        emb = self.padded_vocab * D * (1 if self.tie_embeddings else 2)
        per_attn = 0
        if self.attn is not None:
            a = self.attn
            per_attn = D * a.q_dim + 2 * D * a.kv_dim + a.q_dim * D

        def mlp_params(ff: int) -> int:
            mult = 3 if self.mlp_activation == "swiglu" else 2
            return mult * D * ff

        if self.moe is not None:
            m = self.moe
            dense_l = m.first_dense_layers
            moe_l = L - dense_l
            routed = m.num_experts * mlp_params(m.d_expert)
            if active_only:
                routed = m.top_k * mlp_params(m.d_expert)
            shared = m.num_shared_experts * mlp_params(m.d_expert)
            router = D * m.num_experts
            body = (dense_l * (per_attn + mlp_params(F))
                    + moe_l * (per_attn + routed + shared + router))
        elif self.ssm is not None and self.family == "ssm":
            s = self.ssm
            di = s.d_inner(D)
            if s.kind == "rwkv6":
                per_tm = 4 * D * D + D * D  # r,k,v,g,o (+ small loras ignored)
                per_cm = 2 * D * self.d_ff
                body = L * (per_tm + per_cm)
            else:
                per_ssm = D * (2 * di + 2 * s.d_state * s.n_heads(D)) + di * D
                body = L * (per_ssm + mlp_params(F))
        elif self.hybrid is not None:
            s = self.ssm
            di = s.d_inner(D)
            per_ssm = D * 2 * di + di * D + di * s.d_state * 2
            shared_attn = per_attn + mlp_params(F) + (2 * D) * D
            body = L * per_ssm + shared_attn
        else:
            body = L * (per_attn + mlp_params(F))
            if self.encdec is not None:
                e = self.encdec
                body = (e.enc_layers + e.dec_layers) * (per_attn + mlp_params(F))
                body += e.dec_layers * per_attn  # cross attention
        return emb + body


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                           # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
