# Pallas TPU kernels for the paper's compute hot spot: tree flash
# attention forward (tree_attention.py), fused flash-recompute backward
# (tree_attention_bwd.py), custom_vjp wrapper (ops.py) + jnp oracle
# (ref.py — test oracle only, no longer on the training path).
# Validated with interpret=True on CPU.
