# Pallas TPU kernels for the paper's compute hot spot: tree flash
# attention (tree_attention.py) + jit wrapper (ops.py) + jnp oracle
# (ref.py).  Validated with interpret=True on CPU.
