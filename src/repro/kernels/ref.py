"""Pure-jnp oracle for the tree flash-attention kernel.

visible(i, j) ⇔ j ≤ i ∧ kv_last[j] ≥ i   (paper §3.2 tree mask, encoded as
one int per key — see core/tree.py).  GQA by head-group broadcast.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def tree_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                       kv_last: jax.Array, scale: float) -> jax.Array:
    """q: [B,S,H,hd]; k/v: [B,S,Kh,hd]; kv_last: [B,S] int32 → [B,S,H,hd]."""
    return tree_attention_ref_ext(q, k, v, kv_last, scale)


def tree_attention_ref_ext(q: jax.Array, k: jax.Array, v: jax.Array,
                           kv_last: jax.Array, scale: float, *,
                           q_off: int = 0, window=None,
                           pos_q=None, pos_k=None) -> jax.Array:
    """Gateway/window-aware oracle: q: [B,S,H,hd]; k/v: [B,Skv,Kh,hd] with
    ``q_off`` front-concatenated ancestor keys (query i has global index
    q_off + i); ``window`` adds pos_q[i] − pos_k[j] < window over
    positions.  Mirrors the full fused-kernel visibility predicate."""
    B, S, H, hd = q.shape
    Skv, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    qg = q.reshape(B, S, Kh, G, hd)
    logits = jnp.einsum("bikgd,bjkd->bkgij", qg, k).astype(jnp.float32)
    i_idx = q_off + jnp.arange(S)[:, None]
    j_idx = jnp.arange(Skv)[None, :]
    vis = (j_idx <= i_idx)[None] & (kv_last[:, None, :] >= i_idx[None])
    if window is not None:
        vis = vis & ((pos_q[:, :, None] - pos_k[:, None, :]) < window)
    logits = logits * scale + jnp.where(vis, 0.0, NEG_INF)[:, None, None]
    w = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (invalid queries) → zero output, not NaN
    any_vis = vis.any(axis=-1)[:, None, None, :, None]
    w = jnp.where(any_vis, w, 0.0)
    o = jnp.einsum("bkgij,bjkd->bikgd", w.astype(v.dtype), v)
    return o.reshape(B, S, H, hd)
