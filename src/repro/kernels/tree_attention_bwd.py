"""Pallas TPU kernels: fused backward for tree flash attention.

Flash-style recomputation: the forward (tree_attention.py with
``save_residuals=True``) saves only the per-row logsumexp
``lse[b,h,i] = m_i + log l_i`` — O(S) instead of the O(S²) probability
matrix — and the backward regenerates ``p_ij = exp(s_ij − lse_i)`` block
by block on the fly.  With ``Δ_i = Σ_d do_id·o_id`` (precomputed XLA-side,
one elementwise reduction):

    dv_j = Σ_i p_ij do_i
    ds_ij = p_ij (do_i·v_j − Δ_i) · scale
    dq_i = Σ_j ds_ij k_j
    dk_j = Σ_i ds_ij q_i

Both kernels reuse the forward's two-comparison visibility predicate
(``j ≤ i ∧ kv_last[j] ≥ i``) and its block-skip rule: a (q-block,
kv-block) pair is skipped when anti-causal (kv_start > q_end) or entirely
invisible (max_j kv_last[j] < q_start).  Fully-masked rows (padding,
lse = NEG_INF) contribute nothing because the visibility mask already
zeroes every p entry in their row.

Two kernels because the two reductions run along opposite grid axes and
TPU output revisiting must be consecutive:

  - **dq**: grid (B, H, nq, nk) — innermost over kv blocks, dq accumulated
    in VMEM scratch, written once at the last kv step (mirrors forward).
  - **dk/dv**: grid (B, Kh, nk, G, nq) — innermost over q blocks *and* the
    G query heads of the group, so the GQA head-group reduction happens
    in-kernel in the same VMEM accumulator (no [B,S,H,hd] staging buffer
    + XLA reduction afterwards).

Validated on CPU with interpret=True against jax.vjp through
kernels/ref.py (tests/test_kernels_bwd.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tree_attention import block_kmax_flat, block_live

NEG_INF = -1e30


def _vis_and_p(qq, kk, kl, lse, scale, q_start, kv_start, block_q, block_k):
    """Recompute the masked probability block p_ij = exp(s_ij − lse_i)."""
    logits = jax.lax.dot_general(
        qq, kk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    i_idx = q_start + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    j_idx = kv_start + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    vis = (j_idx <= i_idx) & (kl[None, :] >= i_idx)
    # clamp the exponent: invisible entries are discarded by the select but
    # must not overflow to inf first (inf is fine for select, but keep the
    # VPU in normal range); visible entries satisfy s ≤ m ≤ lse + log l.
    expo = jnp.where(vis, logits - lse[:, None], NEG_INF)
    return jnp.where(vis, jnp.exp(expo), 0.0)


def _bwd_dq(q, k, v, kv_last, lse, delta, do, scale,
            block_q, block_k, interpret):
    B, S, H, hd = q.shape
    Kh = k.shape[2]
    G = max(1, H // Kh)
    nq, nk = S // block_q, S // block_k
    kmax_flat = block_kmax_flat(kv_last, B, nk, block_k)

    def kernel(kmax_ref, q_ref, k_ref, v_ref, kl_ref, lse_ref, dl_ref,
               do_ref, dq_ref, dq_scr):
        b = pl.program_id(0)
        qi = pl.program_id(2)
        ki = pl.program_id(3)
        num_kv = pl.num_programs(3)
        q_start = qi * block_q
        q_end = q_start + block_q - 1
        kv_start = ki * block_k

        @pl.when(ki == 0)
        def _init():
            dq_scr[...] = jnp.zeros_like(dq_scr)

        live = block_live(q_start, q_end, kv_start, kmax_ref[b * nk + ki])

        @pl.when(live)
        def _compute():
            qq = q_ref[0, :, 0, :].astype(jnp.float32)      # [BQ, hd]
            kk = k_ref[0, :, 0, :].astype(jnp.float32)      # [BK, hd]
            vv = v_ref[0, :, 0, :].astype(jnp.float32)
            kl = kl_ref[0, :]
            lse = lse_ref[0, 0, :]                          # [BQ]
            dlt = dl_ref[0, 0, :]                           # [BQ]
            dd = do_ref[0, :, 0, :].astype(jnp.float32)     # [BQ, hd]
            p = _vis_and_p(qq, kk, kl, lse, scale, q_start, kv_start,
                           block_q, block_k)
            dp = jax.lax.dot_general(                        # do·vᵀ [BQ,BK]
                dd, vv, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            ds = p * (dp - dlt[:, None]) * scale
            dq_scr[...] += jax.lax.dot_general(              # ds·k [BQ,hd]
                ds, kk, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        @pl.when(ki == num_kv - 1)
        def _finalize():
            dq_ref[0, :, 0, :] = dq_scr[...].astype(dq_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, H, nq, nk),
            in_specs=[
                pl.BlockSpec((1, block_q, 1, hd),
                             lambda b, h, qi, ki, kmax: (b, qi, h, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, qi, ki, kmax: (b, ki, h // G, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, qi, ki, kmax: (b, ki, h // G, 0)),
                pl.BlockSpec((1, block_k),
                             lambda b, h, qi, ki, kmax: (b, ki)),
                pl.BlockSpec((1, 1, block_q),
                             lambda b, h, qi, ki, kmax: (b, h, qi)),
                pl.BlockSpec((1, 1, block_q),
                             lambda b, h, qi, ki, kmax: (b, h, qi)),
                pl.BlockSpec((1, block_q, 1, hd),
                             lambda b, h, qi, ki, kmax: (b, qi, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_q, 1, hd),
                                   lambda b, h, qi, ki, kmax: (b, qi, h, 0)),
            scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        interpret=interpret,
    )(kmax_flat, q, k, v, kv_last, lse, delta, do)


def _bwd_dkv(q, k, v, kv_last, lse, delta, do, scale,
             block_q, block_k, interpret):
    B, S, H, hd = q.shape
    Kh = k.shape[2]
    G = max(1, H // Kh)
    nq, nk = S // block_q, S // block_k
    kmax_flat = block_kmax_flat(kv_last, B, nk, block_k)

    def kernel(kmax_ref, q_ref, k_ref, v_ref, kl_ref, lse_ref, dl_ref,
               do_ref, dk_ref, dv_ref, dk_scr, dv_scr):
        b = pl.program_id(0)
        ki = pl.program_id(2)
        g = pl.program_id(3)
        qi = pl.program_id(4)
        num_g = pl.num_programs(3)
        num_q = pl.num_programs(4)
        q_start = qi * block_q
        q_end = q_start + block_q - 1
        kv_start = ki * block_k

        @pl.when((g == 0) & (qi == 0))
        def _init():
            dk_scr[...] = jnp.zeros_like(dk_scr)
            dv_scr[...] = jnp.zeros_like(dv_scr)

        live = block_live(q_start, q_end, kv_start, kmax_ref[b * nk + ki])

        @pl.when(live)
        def _compute():
            qq = q_ref[0, :, 0, :].astype(jnp.float32)      # [BQ, hd]
            kk = k_ref[0, :, 0, :].astype(jnp.float32)      # [BK, hd]
            vv = v_ref[0, :, 0, :].astype(jnp.float32)
            kl = kl_ref[0, :]
            lse = lse_ref[0, 0, :]
            dlt = dl_ref[0, 0, :]
            dd = do_ref[0, :, 0, :].astype(jnp.float32)     # [BQ, hd]
            p = _vis_and_p(qq, kk, kl, lse, scale, q_start, kv_start,
                           block_q, block_k)
            dv_scr[...] += jax.lax.dot_general(              # pᵀ·do [BK,hd]
                p, dd, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            dp = jax.lax.dot_general(
                dd, vv, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            ds = p * (dp - dlt[:, None]) * scale
            dk_scr[...] += jax.lax.dot_general(              # dsᵀ·q [BK,hd]
                ds, qq, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        @pl.when((g == num_g - 1) & (qi == num_q - 1))
        def _finalize():
            dk_ref[0, :, 0, :] = dk_scr[...].astype(dk_ref.dtype)
            dv_ref[0, :, 0, :] = dv_scr[...].astype(dv_ref.dtype)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, Kh, nk, G, nq),
            in_specs=[
                pl.BlockSpec(
                    (1, block_q, 1, hd),
                    lambda b, kh, ki, g, qi, kmax: (b, qi, kh * G + g, 0)),
                pl.BlockSpec(
                    (1, block_k, 1, hd),
                    lambda b, kh, ki, g, qi, kmax: (b, ki, kh, 0)),
                pl.BlockSpec(
                    (1, block_k, 1, hd),
                    lambda b, kh, ki, g, qi, kmax: (b, ki, kh, 0)),
                pl.BlockSpec(
                    (1, block_k),
                    lambda b, kh, ki, g, qi, kmax: (b, ki)),
                pl.BlockSpec(
                    (1, 1, block_q),
                    lambda b, kh, ki, g, qi, kmax: (b, kh * G + g, qi)),
                pl.BlockSpec(
                    (1, 1, block_q),
                    lambda b, kh, ki, g, qi, kmax: (b, kh * G + g, qi)),
                pl.BlockSpec(
                    (1, block_q, 1, hd),
                    lambda b, kh, ki, g, qi, kmax: (b, qi, kh * G + g, 0)),
            ],
            out_specs=[
                pl.BlockSpec(
                    (1, block_k, 1, hd),
                    lambda b, kh, ki, g, qi, kmax: (b, ki, kh, 0)),
                pl.BlockSpec(
                    (1, block_k, 1, hd),
                    lambda b, kh, ki, g, qi, kmax: (b, ki, kh, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_k, hd), jnp.float32),
                pltpu.VMEM((block_k, hd), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Kh, hd), k.dtype),
            jax.ShapeDtypeStruct((B, S, Kh, hd), v.dtype),
        ],
        interpret=interpret,
    )(kmax_flat, q, k, v, kv_last, lse, delta, do)
    return out[0], out[1]


def tree_attention_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                       kv_last: jax.Array, o: jax.Array, lse: jax.Array,
                       do: jax.Array, scale: float, *,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool = False):
    """Fused dq/dk/dv for tree attention.

    q/o/do: [B,S,H,hd]; k/v: [B,S,Kh,hd]; kv_last: [B,S] int32;
    lse: [B,H,S] f32 from the forward's ``save_residuals=True``.
    Returns (dq, dk, dv) in the input dtypes.
    """
    B, S, H, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    kv_last = kv_last.astype(jnp.int32)
    # Δ_i = Σ_d do_id o_id, [B,H,S] — cheap elementwise reduce, XLA-side.
    delta = (do.astype(jnp.float32) * o.astype(jnp.float32)
             ).sum(-1).transpose(0, 2, 1)
    dq = _bwd_dq(q, k, v, kv_last, lse, delta, do, scale,
                 block_q, block_k, interpret)
    dk, dv = _bwd_dkv(q, k, v, kv_last, lse, delta, do, scale,
                      block_q, block_k, interpret)
    return dq, dk, dv
