"""Pallas TPU kernels: fused backward for tree flash attention.

Flash-style recomputation: the forward (tree_attention.py with
``save_residuals=True``) saves only the per-row logsumexp
``lse[b,h,i] = m_i + log l_i`` — O(S) instead of the O(S²) probability
matrix — and the backward regenerates ``p_ij = exp(s_ij − lse_i)`` block
by block on the fly.  With ``Δ_i = Σ_d do_id·o_id`` (precomputed XLA-side,
one elementwise reduction):

    dv_j = Σ_i p_ij do_i
    ds_ij = p_ij (do_i·v_j − Δ_i) · scale
    dq_i = Σ_j ds_ij k_j
    dk_j = Σ_i ds_ij q_i

Both kernels reuse the forward's visibility predicate — global query
index ``i = q_off + i_local``, ``j ≤ i ∧ kv_last[j] ≥ i``, and (windowed)
``pos_q[i] − pos_k[j] < window`` — and its block-skip rule via the shared
``skip_scalars`` prefetch array, so gateway-extended KV layouts
(front-concatenated ancestors, paper §3.3) and sliding-window configs
backprop through exactly the visibility the forward computed.  dk/dv are
produced for the FULL KV length: rows [0, q_off) are the ancestor
cotangents (``d_extra_k``/``d_extra_v``) the partition driver routes back
to the parent partition.  Fully-masked rows (padding, lse = NEG_INF)
contribute nothing because the visibility mask already zeroes every p
entry in their row.

Two kernels because the two reductions run along opposite grid axes and
TPU output revisiting must be consecutive:

  - **dq**: grid (B, H, nq, nk) — innermost over kv blocks, dq accumulated
    in VMEM scratch, written once at the last kv step (mirrors forward).
  - **dk/dv**: grid (B, Kh, nk, G, nq) — innermost over q blocks *and* the
    G query heads of the group, so the GQA head-group reduction happens
    in-kernel in the same VMEM accumulator (no [B,S,H,hd] staging buffer
    + XLA reduction afterwards).

Validated on CPU with interpret=True against jax.vjp through
kernels/ref.py (tests/test_kernels_bwd.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tree_attention import block_live, skip_scalars

NEG_INF = -1e30


def _vis_and_p(qq, kk, kl, lse, scale, q_start, kv_start, block_q, block_k,
               pq=None, pk=None, window=None):
    """Recompute the masked probability block p_ij = exp(s_ij − lse_i).
    ``q_start`` is the GLOBAL query index of the block's first row."""
    logits = jax.lax.dot_general(
        qq, kk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    i_idx = q_start + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    j_idx = kv_start + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    vis = (j_idx <= i_idx) & (kl[None, :] >= i_idx)
    if window is not None:
        vis = vis & ((pq[:, None] - pk[None, :]) < window)
    # clamp the exponent: invisible entries are discarded by the select but
    # must not overflow to inf first (inf is fine for select, but keep the
    # VPU in normal range); visible entries satisfy s ≤ m ≤ lse + log l.
    expo = jnp.where(vis, logits - lse[:, None], NEG_INF)
    return jnp.where(vis, jnp.exp(expo), 0.0)


def _bwd_dq(q, k, v, kv_last, lse, delta, do, scale, skip,
            block_q, block_k, q_off, window, pos_q, pos_k, interpret):
    B, S, H, hd = q.shape
    Skv, Kh = k.shape[1], k.shape[2]
    G = max(1, H // Kh)
    nq, nk = S // block_q, Skv // block_k
    windowed = window is not None

    def kernel(skip_ref, *refs):
        q_ref, k_ref, v_ref, kl_ref, lse_ref, dl_ref, do_ref = refs[:7]
        rest = refs[7:]
        if windowed:
            pq_ref, pk_ref = rest[:2]
            rest = rest[2:]
        dq_ref, dq_scr = rest
        b = pl.program_id(0)
        qi = pl.program_id(2)
        ki = pl.program_id(3)
        num_kv = pl.num_programs(3)
        q_start = q_off + qi * block_q          # global DFS index
        q_end = q_start + block_q - 1
        kv_start = ki * block_k

        @pl.when(ki == 0)
        def _init():
            dq_scr[...] = jnp.zeros_like(dq_scr)

        if windowed:
            live = block_live(q_start, q_end, kv_start,
                              skip_ref[b * nk + ki],
                              skip_ref[2 * B * nk + b * nq + qi],
                              skip_ref[B * nk + b * nk + ki], window)
        else:
            live = block_live(q_start, q_end, kv_start,
                              skip_ref[b * nk + ki])

        @pl.when(live)
        def _compute():
            qq = q_ref[0, :, 0, :].astype(jnp.float32)      # [BQ, hd]
            kk = k_ref[0, :, 0, :].astype(jnp.float32)      # [BK, hd]
            vv = v_ref[0, :, 0, :].astype(jnp.float32)
            kl = kl_ref[0, :]
            lse = lse_ref[0, 0, :]                          # [BQ]
            dlt = dl_ref[0, 0, :]                           # [BQ]
            dd = do_ref[0, :, 0, :].astype(jnp.float32)     # [BQ, hd]
            pq = pq_ref[0, :] if windowed else None
            pk = pk_ref[0, :] if windowed else None
            p = _vis_and_p(qq, kk, kl, lse, scale, q_start, kv_start,
                           block_q, block_k, pq, pk, window)
            dp = jax.lax.dot_general(                        # do·vᵀ [BQ,BK]
                dd, vv, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            ds = p * (dp - dlt[:, None]) * scale
            dq_scr[...] += jax.lax.dot_general(              # ds·k [BQ,hd]
                ds, kk, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        @pl.when(ki == num_kv - 1)
        def _finalize():
            dq_ref[0, :, 0, :] = dq_scr[...].astype(dq_ref.dtype)

    in_specs = [
        pl.BlockSpec((1, block_q, 1, hd),
                     lambda b, h, qi, ki, skip: (b, qi, h, 0)),
        pl.BlockSpec((1, block_k, 1, hd),
                     lambda b, h, qi, ki, skip: (b, ki, h // G, 0)),
        pl.BlockSpec((1, block_k, 1, hd),
                     lambda b, h, qi, ki, skip: (b, ki, h // G, 0)),
        pl.BlockSpec((1, block_k),
                     lambda b, h, qi, ki, skip: (b, ki)),
        pl.BlockSpec((1, 1, block_q),
                     lambda b, h, qi, ki, skip: (b, h, qi)),
        pl.BlockSpec((1, 1, block_q),
                     lambda b, h, qi, ki, skip: (b, h, qi)),
        pl.BlockSpec((1, block_q, 1, hd),
                     lambda b, h, qi, ki, skip: (b, qi, h, 0)),
    ]
    inputs = [q, k, v, kv_last, lse, delta, do]
    if windowed:
        in_specs += [
            pl.BlockSpec((1, block_q),
                         lambda b, h, qi, ki, skip: (b, qi)),
            pl.BlockSpec((1, block_k),
                         lambda b, h, qi, ki, skip: (b, ki)),
        ]
        inputs += [pos_q.astype(jnp.int32), pos_k.astype(jnp.int32)]

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, H, nq, nk),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, block_q, 1, hd),
                                   lambda b, h, qi, ki, skip: (b, qi, h, 0)),
            scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        interpret=interpret,
    )(skip, *inputs)


def _bwd_dkv(q, k, v, kv_last, lse, delta, do, scale, skip,
             block_q, block_k, q_off, window, pos_q, pos_k, interpret):
    B, S, H, hd = q.shape
    Skv, Kh = k.shape[1], k.shape[2]
    G = max(1, H // Kh)
    nq, nk = S // block_q, Skv // block_k
    windowed = window is not None

    def kernel(skip_ref, *refs):
        q_ref, k_ref, v_ref, kl_ref, lse_ref, dl_ref, do_ref = refs[:7]
        rest = refs[7:]
        if windowed:
            pq_ref, pk_ref = rest[:2]
            rest = rest[2:]
        dk_ref, dv_ref, dk_scr, dv_scr = rest
        b = pl.program_id(0)
        ki = pl.program_id(2)
        g = pl.program_id(3)
        qi = pl.program_id(4)
        num_g = pl.num_programs(3)
        num_q = pl.num_programs(4)
        q_start = q_off + qi * block_q          # global DFS index
        q_end = q_start + block_q - 1
        kv_start = ki * block_k

        @pl.when((g == 0) & (qi == 0))
        def _init():
            dk_scr[...] = jnp.zeros_like(dk_scr)
            dv_scr[...] = jnp.zeros_like(dv_scr)

        if windowed:
            live = block_live(q_start, q_end, kv_start,
                              skip_ref[b * nk + ki],
                              skip_ref[2 * B * nk + b * nq + qi],
                              skip_ref[B * nk + b * nk + ki], window)
        else:
            live = block_live(q_start, q_end, kv_start,
                              skip_ref[b * nk + ki])

        @pl.when(live)
        def _compute():
            qq = q_ref[0, :, 0, :].astype(jnp.float32)      # [BQ, hd]
            kk = k_ref[0, :, 0, :].astype(jnp.float32)      # [BK, hd]
            vv = v_ref[0, :, 0, :].astype(jnp.float32)
            kl = kl_ref[0, :]
            lse = lse_ref[0, 0, :]
            dlt = dl_ref[0, 0, :]
            dd = do_ref[0, :, 0, :].astype(jnp.float32)     # [BQ, hd]
            pq = pq_ref[0, :] if windowed else None
            pk = pk_ref[0, :] if windowed else None
            p = _vis_and_p(qq, kk, kl, lse, scale, q_start, kv_start,
                           block_q, block_k, pq, pk, window)
            dv_scr[...] += jax.lax.dot_general(              # pᵀ·do [BK,hd]
                p, dd, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            dp = jax.lax.dot_general(
                dd, vv, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            ds = p * (dp - dlt[:, None]) * scale
            dk_scr[...] += jax.lax.dot_general(              # dsᵀ·q [BK,hd]
                ds, qq, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        @pl.when((g == num_g - 1) & (qi == num_q - 1))
        def _finalize():
            dk_ref[0, :, 0, :] = dk_scr[...].astype(dk_ref.dtype)
            dv_ref[0, :, 0, :] = dv_scr[...].astype(dv_ref.dtype)

    in_specs = [
        pl.BlockSpec(
            (1, block_q, 1, hd),
            lambda b, kh, ki, g, qi, skip: (b, qi, kh * G + g, 0)),
        pl.BlockSpec(
            (1, block_k, 1, hd),
            lambda b, kh, ki, g, qi, skip: (b, ki, kh, 0)),
        pl.BlockSpec(
            (1, block_k, 1, hd),
            lambda b, kh, ki, g, qi, skip: (b, ki, kh, 0)),
        pl.BlockSpec(
            (1, block_k),
            lambda b, kh, ki, g, qi, skip: (b, ki)),
        pl.BlockSpec(
            (1, 1, block_q),
            lambda b, kh, ki, g, qi, skip: (b, kh * G + g, qi)),
        pl.BlockSpec(
            (1, 1, block_q),
            lambda b, kh, ki, g, qi, skip: (b, kh * G + g, qi)),
        pl.BlockSpec(
            (1, block_q, 1, hd),
            lambda b, kh, ki, g, qi, skip: (b, qi, kh * G + g, 0)),
    ]
    inputs = [q, k, v, kv_last, lse, delta, do]
    if windowed:
        in_specs += [
            pl.BlockSpec((1, block_q),
                         lambda b, kh, ki, g, qi, skip: (b, qi)),
            pl.BlockSpec((1, block_k),
                         lambda b, kh, ki, g, qi, skip: (b, ki)),
        ]
        inputs += [pos_q.astype(jnp.int32), pos_k.astype(jnp.int32)]

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, Kh, nk, G, nq),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec(
                    (1, block_k, 1, hd),
                    lambda b, kh, ki, g, qi, skip: (b, ki, kh, 0)),
                pl.BlockSpec(
                    (1, block_k, 1, hd),
                    lambda b, kh, ki, g, qi, skip: (b, ki, kh, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_k, hd), jnp.float32),
                pltpu.VMEM((block_k, hd), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, Skv, Kh, hd), k.dtype),
            jax.ShapeDtypeStruct((B, Skv, Kh, hd), v.dtype),
        ],
        interpret=interpret,
    )(skip, *inputs)
    return out[0], out[1]


def tree_attention_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                       kv_last: jax.Array, o: jax.Array, lse: jax.Array,
                       do: jax.Array, scale: float, *,
                       block_q: int = 128, block_k: int = 128,
                       q_off: int = 0, window: Optional[int] = None,
                       pos_q: Optional[jax.Array] = None,
                       pos_k: Optional[jax.Array] = None,
                       interpret: bool = False):
    """Fused dq/dk/dv for tree attention.

    q/o/do: [B,S,H,hd]; k/v: [B,Skv,Kh,hd]; kv_last: [B,Skv] int32;
    lse: [B,H,S] f32 from the forward's ``save_residuals=True``.
    q_off/window/pos_q/pos_k: same gateway/window layout as the forward.
    Returns (dq, dk, dv) in the input dtypes; dk/dv cover the full Skv,
    including the ancestor rows [0, q_off) (d_extra_k / d_extra_v).
    """
    B, S, H, hd = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, Skv)
    assert S % block_q == 0 and Skv % block_k == 0, \
        (S, Skv, block_q, block_k)
    assert Skv >= q_off + S, (Skv, q_off, S)
    kv_last = kv_last.astype(jnp.int32)
    # Δ_i = Σ_d do_id o_id, [B,H,S] — cheap elementwise reduce, XLA-side.
    delta = (do.astype(jnp.float32) * o.astype(jnp.float32)
             ).sum(-1).transpose(0, 2, 1)
    # one shared prefetch array for both kernels (same blocks, same skip)
    skip = skip_scalars(kv_last, B, S // block_q, Skv // block_k,
                        block_q, block_k, pos_q, pos_k, window)
    dq = _bwd_dq(q, k, v, kv_last, lse, delta, do, scale, skip,
                 block_q, block_k, q_off, window, pos_q, pos_k, interpret)
    dk, dv = _bwd_dkv(q, k, v, kv_last, lse, delta, do, scale, skip,
                      block_q, block_k, q_off, window, pos_q, pos_k,
                      interpret)
    return dq, dk, dv
