"""Pallas TPU kernel: tree flash attention (FlashMask → TPU adaptation).

The paper implements its tree mask as a FlashAttention-V3 + FlashMask GPU
kernel (App. A.1).  The TPU-native equivalent built here:

  - visibility is one int per key: visible(i,j) ⇔ j ≤ i ∧ kv_last[j] ≥ i;
  - grid (batch, q_head, q_blocks, kv_blocks); the innermost dim is
    sequential on TPU, so online-softmax accumulators live in VMEM scratch
    across kv steps;
  - MXU-aligned blocks (default 128×128), fp32 accumulation;
  - **block skipping**: a kv block is skipped when it is entirely
    anti-causal (kv_start > q_end), entirely invisible
    (max_j kv_last[j] < q_start — every key's subtree ends before this
    query block), or — with sliding-window attention — entirely out of
    window (min_i pos_q[i] − max_j pos_k[j] ≥ window).  Per-block extrema
    are precomputed XLA-side and prefetched as scalars, so the predicate
    is resolved before any MXU work.  This is the FlashMask block-sparsity
    analogue; skipped blocks still have their DMA issued by the pipeline
    (removing it needs a data-dependent grid — logged as a §Perf follow-up
    in EXPERIMENTS.md).
  - **partition gateways** (paper §3.3): queries may attend a KV sequence
    longer than themselves — ``q_off`` ancestor keys are front-concatenated
    (k/v: [B, q_off + S, ...]).  Query i's global DFS index is
    ``q_off + i``; ancestors are marked always-visible (kv_last = BIG) or
    padding (kv_last = −1) by the caller, so one predicate covers plain,
    windowed, and gateway-extended attention.
  - **sliding window** (long-context variants): with ``window`` set,
    visibility additionally requires pos_q[i] − pos_k[j] < window —
    *positions*, not DFS indices, so the window applies along the path and
    across partition gateways (ancestor positions travel in ``pos_k``).
  - ``save_residuals=True`` additionally emits the per-row logsumexp
    ``lse[b, h, i] = m_i + log(l_i)`` (``NEG_INF`` for fully-masked rows),
    the O(S) statistic the fused backward (tree_attention_bwd.py) needs to
    regenerate softmax probabilities without the O(S²) matrix.

GQA: q head h reads kv head h // (H/Kh) via the BlockSpec index map.
Validated on CPU with interpret=True against kernels/ref.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def block_kmax_flat(kv_last, B: int, nk: int, block_k: int):
    """Per-(batch, kv-block) max of kv_last, flattened to 1-D for scalar
    prefetch; indexed with b*nk + ki inside the kernels.  Shared by the
    forward and both backward kernels so the skip inputs cannot drift."""
    return kv_last.reshape(B, nk, block_k).max(-1).reshape(B * nk)


def skip_scalars(kv_last, B: int, nq: int, nk: int, block_q: int,
                 block_k: int, pos_q=None, pos_k=None, window=None):
    """The flat int32 scalar-prefetch array driving ``block_live``.

    Layout: ``[kmax (B·nk)]`` and, when windowed, additionally
    ``[kpos_max (B·nk), qpos_min (B·nq)]`` — indexed with the static
    offsets B·nk and 2·B·nk inside the kernels.  One array (not three)
    keeps ``num_scalar_prefetch=1`` and the index-map signatures stable."""
    kmax = block_kmax_flat(kv_last, B, nk, block_k)
    if window is None:
        return kmax
    kpmax = pos_k.astype(jnp.int32).reshape(B, nk, block_k).max(-1)
    qpmin = pos_q.astype(jnp.int32).reshape(B, nq, block_q).min(-1)
    return jnp.concatenate(
        [kmax, kpmax.reshape(B * nk), qpmin.reshape(B * nq)])


def block_live(q_start, q_end, kv_start, block_max,
               qp_min=None, kp_max=None, window: Optional[int] = None):
    """The block-skip predicate (forward AND backward): a (q-block,
    kv-block) pair is live unless entirely anti-causal (kv_start > q_end),
    entirely invisible (block_max = max_j kv_last[j] < q_start), or —
    windowed — entirely out of window (min_i pos_q − max_j pos_k ≥ window).
    q_start/q_end are *global* query indices (ancestor offset applied).
    Works on traced kernel scalars and on numpy arrays alike."""
    live = (kv_start <= q_end) & (block_max >= q_start)
    if window is not None:
        live = live & ((qp_min - kp_max) < window)
    return live


def block_live_mask(kv_last, S: int, block_q: int, block_k: int,
                    *, q_off: int = 0, pos_q=None, pos_k=None,
                    window: Optional[int] = None):
    """[nq, nk] bool per batch row: which (q-block, kv-block) pairs the
    kernel actually computes.  ``S`` is the query length; the kv length is
    ``kv_last``'s (= q_off + S for gateway layouts).  Used by benchmarks
    to report block sparsity."""
    import numpy as np
    kv_last = np.asarray(kv_last)
    Skv = kv_last.shape[-1]
    nq, nk = S // block_q, Skv // block_k
    kmax = kv_last.reshape(nk, block_k).max(-1)
    qi = np.arange(nq)[:, None]
    ki = np.arange(nk)[None, :]
    qpmin = kpmax = None
    if window is not None:
        qpmin = np.asarray(pos_q).reshape(nq, block_q).min(-1)[:, None]
        kpmax = np.asarray(pos_k).reshape(nk, block_k).max(-1)[None, :]
    return block_live(q_off + qi * block_q,
                      q_off + qi * block_q + block_q - 1,
                      ki * block_k, kmax[None, :], qpmin, kpmax, window)


def tree_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   kv_last: jax.Array, scale: float, *,
                   block_q: int = 128, block_k: int = 128,
                   q_off: int = 0, window: Optional[int] = None,
                   pos_q: Optional[jax.Array] = None,
                   pos_k: Optional[jax.Array] = None,
                   save_residuals: bool = False,
                   interpret: bool = False):
    """q: [B,S,H,hd]; k/v: [B,Skv,Kh,hd]; kv_last: [B,Skv] int32
    → [B,S,H,hd].

    ``q_off``: static ancestor offset — query i has global DFS index
    q_off + i (Skv ≥ q_off + S; any key beyond that is padding the caller
    marked kv_last = −1).  ``window``: static sliding-window size over
    *positions*; requires pos_q [B,S] / pos_k [B,Skv].

    With ``save_residuals`` returns ``(o, lse)`` where lse is [B,H,S] f32.
    """
    B, S, H, hd = q.shape
    Skv, Kh = k.shape[1], k.shape[2]
    G = max(1, H // Kh)
    block_q = min(block_q, S)
    block_k = min(block_k, Skv)
    assert S % block_q == 0 and Skv % block_k == 0, \
        (S, Skv, block_q, block_k)
    assert Skv >= q_off + S, (Skv, q_off, S)
    windowed = window is not None
    if windowed:
        assert pos_q is not None and pos_k is not None
    nq, nk = S // block_q, Skv // block_k
    kv_last = kv_last.astype(jnp.int32)
    skip = skip_scalars(kv_last, B, nq, nk, block_q, block_k,
                        pos_q, pos_k, window)

    def kernel(skip_ref, *refs):
        q_ref, k_ref, v_ref, kl_ref = refs[:4]
        rest = refs[4:]
        if windowed:
            pq_ref, pk_ref = rest[:2]
            rest = rest[2:]
        o_ref = rest[0]
        if save_residuals:
            lse_ref, m_scr, l_scr, acc_scr = rest[1:]
        else:
            m_scr, l_scr, acc_scr = rest[1:]
        b = pl.program_id(0)
        qi = pl.program_id(2)
        ki = pl.program_id(3)
        num_kv = pl.num_programs(3)
        q_start = q_off + qi * block_q          # global DFS index
        q_end = q_start + block_q - 1
        kv_start = ki * block_k

        @pl.when(ki == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        if windowed:
            live = block_live(q_start, q_end, kv_start,
                              skip_ref[b * nk + ki],
                              skip_ref[2 * B * nk + b * nq + qi],
                              skip_ref[B * nk + b * nk + ki], window)
        else:
            live = block_live(q_start, q_end, kv_start,
                              skip_ref[b * nk + ki])

        @pl.when(live)
        def _compute():
            qq = q_ref[0, :, 0, :].astype(jnp.float32)       # [BQ, hd]
            kk = k_ref[0, :, 0, :].astype(jnp.float32)       # [BK, hd]
            vv = v_ref[0, :, 0, :].astype(jnp.float32)
            kl = kl_ref[0, :]                                # [BK]
            logits = jax.lax.dot_general(
                qq, kk, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale
            i_idx = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            j_idx = kv_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            vis = (j_idx <= i_idx) & (kl[None, :] >= i_idx)
            if windowed:
                vis = vis & ((pq_ref[0, :][:, None]
                              - pk_ref[0, :][None, :]) < window)
            lg = jnp.where(vis, logits, NEG_INF)
            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, lg.max(axis=1))
            p = jnp.where(vis, jnp.exp(lg - m_new[:, None]), 0.0)
            corr = jnp.exp(m_prev - m_new)
            l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
            acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
                p, vv, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_scr[...] = m_new

        @pl.when(ki == num_kv - 1)
        def _finalize():
            l = l_scr[...]
            o = acc_scr[...] / jnp.maximum(l, 1e-37)[:, None]
            o = jnp.where((l > 0)[:, None], o, 0.0)
            o_ref[0, :, 0, :] = o.astype(o_ref.dtype)
            if save_residuals:
                m = m_scr[...]
                lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-37)),
                                NEG_INF)
                lse_ref[0, 0, :] = lse

    in_specs = [
        pl.BlockSpec((1, block_q, 1, hd),
                     lambda b, h, qi, ki, skip: (b, qi, h, 0)),
        pl.BlockSpec((1, block_k, 1, hd),
                     lambda b, h, qi, ki, skip: (b, ki, h // G, 0)),
        pl.BlockSpec((1, block_k, 1, hd),
                     lambda b, h, qi, ki, skip: (b, ki, h // G, 0)),
        pl.BlockSpec((1, block_k),
                     lambda b, h, qi, ki, skip: (b, ki)),
    ]
    inputs = [q, k, v, kv_last]
    if windowed:
        in_specs += [
            pl.BlockSpec((1, block_q),
                         lambda b, h, qi, ki, skip: (b, qi)),
            pl.BlockSpec((1, block_k),
                         lambda b, h, qi, ki, skip: (b, ki)),
        ]
        inputs += [pos_q.astype(jnp.int32), pos_k.astype(jnp.int32)]

    out_shape = [jax.ShapeDtypeStruct((B, S, H, hd), q.dtype)]
    out_specs = [pl.BlockSpec((1, block_q, 1, hd),
                              lambda b, h, qi, ki, skip: (b, qi, h, 0))]
    if save_residuals:
        out_shape.append(jax.ShapeDtypeStruct((B, H, S), jnp.float32))
        out_specs.append(pl.BlockSpec((1, 1, block_q),
                                      lambda b, h, qi, ki, skip: (b, h, qi)))

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, H, nq, nk),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q, hd), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(skip, *inputs)
    if save_residuals:
        return out[0], out[1]
    return out[0]
