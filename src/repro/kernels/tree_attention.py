"""Pallas TPU kernel: tree flash attention (FlashMask → TPU adaptation).

The paper implements its tree mask as a FlashAttention-V3 + FlashMask GPU
kernel (App. A.1).  The TPU-native equivalent built here:

  - visibility is one int per key: visible(i,j) ⇔ j ≤ i ∧ kv_last[j] ≥ i;
  - grid (batch, q_head, q_blocks, kv_blocks); the innermost dim is
    sequential on TPU, so online-softmax accumulators live in VMEM scratch
    across kv steps;
  - MXU-aligned blocks (default 128×128), fp32 accumulation;
  - **block skipping**: a kv block is skipped when it is entirely
    anti-causal (kv_start > q_end) or entirely invisible
    (max_j kv_last[j] < q_start — every key's subtree ends before this
    query block).  Per-block maxima are precomputed XLA-side and prefetched
    as scalars, so the predicate is resolved before any MXU work.  This is
    the FlashMask block-sparsity analogue; skipped blocks still have their
    DMA issued by the pipeline (removing it needs a data-dependent grid —
    logged as a §Perf follow-up in EXPERIMENTS.md).
  - ``save_residuals=True`` additionally emits the per-row logsumexp
    ``lse[b, h, i] = m_i + log(l_i)`` (``NEG_INF`` for fully-masked rows),
    the O(S) statistic the fused backward (tree_attention_bwd.py) needs to
    regenerate softmax probabilities without the O(S²) matrix.

GQA: q head h reads kv head h // (H/Kh) via the BlockSpec index map.
Validated on CPU with interpret=True against kernels/ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def block_kmax_flat(kv_last, B: int, nk: int, block_k: int):
    """Per-(batch, kv-block) max of kv_last, flattened to 1-D for scalar
    prefetch; indexed with b*nk + ki inside the kernels.  Shared by the
    forward and both backward kernels so the skip inputs cannot drift."""
    return kv_last.reshape(B, nk, block_k).max(-1).reshape(B * nk)


def block_live(q_start, q_end, kv_start, block_max):
    """The block-skip predicate (forward AND backward): a (q-block,
    kv-block) pair is live unless entirely anti-causal (kv_start > q_end)
    or entirely invisible (block_max = max_j kv_last[j] < q_start).
    Works on traced kernel scalars and on numpy arrays alike."""
    return (kv_start <= q_end) & (block_max >= q_start)


def block_live_mask(kv_last, S: int, block_q: int, block_k: int):
    """[nq, nk] bool per batch row: which (q-block, kv-block) pairs the
    kernel actually computes.  Used by benchmarks to report block
    sparsity."""
    import numpy as np
    kv_last = np.asarray(kv_last)
    nq, nk = S // block_q, S // block_k
    kmax = kv_last.reshape(nk, block_k).max(-1)
    qi = np.arange(nq)[:, None]
    ki = np.arange(nk)[None, :]
    return block_live(qi * block_q, qi * block_q + block_q - 1,
                      ki * block_k, kmax[None, :])


def tree_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   kv_last: jax.Array, scale: float, *,
                   block_q: int = 128, block_k: int = 128,
                   save_residuals: bool = False,
                   interpret: bool = False):
    """q: [B,S,H,hd]; k/v: [B,S,Kh,hd]; kv_last: [B,S] int32 → [B,S,H,hd].

    With ``save_residuals`` returns ``(o, lse)`` where lse is [B,H,S] f32.
    """
    B, S, H, hd = q.shape
    Kh = k.shape[2]
    G = max(1, H // Kh)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    kv_last = kv_last.astype(jnp.int32)
    kv_last_max_flat = block_kmax_flat(kv_last, B, nk, block_k)

    def kernel(kmax_ref, q_ref, k_ref, v_ref, kl_ref, o_ref, *rest):
        if save_residuals:
            lse_ref, m_scr, l_scr, acc_scr = rest
        else:
            m_scr, l_scr, acc_scr = rest
        b = pl.program_id(0)
        qi = pl.program_id(2)
        ki = pl.program_id(3)
        num_kv = pl.num_programs(3)
        q_start = qi * block_q
        q_end = q_start + block_q - 1
        kv_start = ki * block_k

        @pl.when(ki == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        live = block_live(q_start, q_end, kv_start, kmax_ref[b * nk + ki])

        @pl.when(live)
        def _compute():
            qq = q_ref[0, :, 0, :].astype(jnp.float32)       # [BQ, hd]
            kk = k_ref[0, :, 0, :].astype(jnp.float32)       # [BK, hd]
            vv = v_ref[0, :, 0, :].astype(jnp.float32)
            kl = kl_ref[0, :]                                # [BK]
            logits = jax.lax.dot_general(
                qq, kk, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale
            i_idx = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            j_idx = kv_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            vis = (j_idx <= i_idx) & (kl[None, :] >= i_idx)
            lg = jnp.where(vis, logits, NEG_INF)
            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, lg.max(axis=1))
            p = jnp.where(vis, jnp.exp(lg - m_new[:, None]), 0.0)
            corr = jnp.exp(m_prev - m_new)
            l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
            acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
                p, vv, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_scr[...] = m_new

        @pl.when(ki == num_kv - 1)
        def _finalize():
            l = l_scr[...]
            o = acc_scr[...] / jnp.maximum(l, 1e-37)[:, None]
            o = jnp.where((l > 0)[:, None], o, 0.0)
            o_ref[0, :, 0, :] = o.astype(o_ref.dtype)
            if save_residuals:
                m = m_scr[...]
                lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-37)),
                                NEG_INF)
                lse_ref[0, 0, :] = lse

    out_shape = [jax.ShapeDtypeStruct((B, S, H, hd), q.dtype)]
    out_specs = [pl.BlockSpec((1, block_q, 1, hd),
                              lambda b, h, qi, ki, kmax: (b, qi, h, 0))]
    if save_residuals:
        out_shape.append(jax.ShapeDtypeStruct((B, H, S), jnp.float32))
        out_specs.append(pl.BlockSpec((1, 1, block_q),
                                      lambda b, h, qi, ki, kmax: (b, h, qi)))

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, H, nq, nk),
            in_specs=[
                pl.BlockSpec((1, block_q, 1, hd),
                             lambda b, h, qi, ki, kmax: (b, qi, h, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, qi, ki, kmax: (b, ki, h // G, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, qi, ki, kmax: (b, ki, h // G, 0)),
                pl.BlockSpec((1, block_k),
                             lambda b, h, qi, ki, kmax: (b, ki)),
            ],
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q, hd), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(kv_last_max_flat, q, k, v, kv_last)
    if save_residuals:
        return out[0], out[1]
    return out[0]
