"""Pallas TPU kernel: tree flash attention (FlashMask → TPU adaptation).

The paper implements its tree mask as a FlashAttention-V3 + FlashMask GPU
kernel (App. A.1).  The TPU-native equivalent built here:

  - visibility is one int per key: visible(i,j) ⇔ j ≤ i ∧ kv_last[j] ≥ i;
  - grid (batch, q_head, q_blocks, kv_blocks); the innermost dim is
    sequential on TPU, so online-softmax accumulators live in VMEM scratch
    across kv steps;
  - MXU-aligned blocks (default 128×128), fp32 accumulation;
  - **block skipping**: a kv block is skipped when it is entirely
    anti-causal (kv_start > q_end) or entirely invisible
    (max_j kv_last[j] < q_start — every key's subtree ends before this
    query block).  Per-block maxima are precomputed XLA-side and prefetched
    as scalars, so the predicate is resolved before any MXU work.  This is
    the FlashMask block-sparsity analogue; skipped blocks still have their
    DMA issued by the pipeline (removing it needs a data-dependent grid —
    logged as a §Perf follow-up in EXPERIMENTS.md).

GQA: q head h reads kv head h // (H/Kh) via the BlockSpec index map.
Validated on CPU with interpret=True against kernels/ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def tree_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   kv_last: jax.Array, scale: float, *,
                   block_q: int = 128, block_k: int = 128,
                   interpret: bool = False) -> jax.Array:
    """q: [B,S,H,hd]; k/v: [B,S,Kh,hd]; kv_last: [B,S] int32 → [B,S,H,hd]."""
    B, S, H, hd = q.shape
    Kh = k.shape[2]
    G = max(1, H // Kh)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    kv_last = kv_last.astype(jnp.int32)
    # skip predicate: per-(batch, kv block) max of kv_last, flattened to 1-D
    # for scalar prefetch; indexed with b*nk + ki inside the kernel.
    kv_last_max_flat = kv_last.reshape(B, nk, block_k).max(-1).reshape(B * nk)

    def kernel(kmax_ref, q_ref, k_ref, v_ref, kl_ref, o_ref,
               m_scr, l_scr, acc_scr):
        b = pl.program_id(0)
        qi = pl.program_id(2)
        ki = pl.program_id(3)
        num_kv = pl.num_programs(3)
        q_start = qi * block_q
        q_end = q_start + block_q - 1
        kv_start = ki * block_k

        @pl.when(ki == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        block_max = kmax_ref[b * nk + ki]
        live = (kv_start <= q_end) & (block_max >= q_start)

        @pl.when(live)
        def _compute():
            qq = q_ref[0, :, 0, :].astype(jnp.float32)       # [BQ, hd]
            kk = k_ref[0, :, 0, :].astype(jnp.float32)       # [BK, hd]
            vv = v_ref[0, :, 0, :].astype(jnp.float32)
            kl = kl_ref[0, :]                                # [BK]
            logits = jax.lax.dot_general(
                qq, kk, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale
            i_idx = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            j_idx = kv_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            vis = (j_idx <= i_idx) & (kl[None, :] >= i_idx)
            lg = jnp.where(vis, logits, NEG_INF)
            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, lg.max(axis=1))
            p = jnp.where(vis, jnp.exp(lg - m_new[:, None]), 0.0)
            corr = jnp.exp(m_prev - m_new)
            l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
            acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
                p, vv, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_scr[...] = m_new

        @pl.when(ki == num_kv - 1)
        def _finalize():
            l = l_scr[...]
            o = acc_scr[...] / jnp.maximum(l, 1e-37)[:, None]
            o = jnp.where((l > 0)[:, None], o, 0.0)
            o_ref[0, :, 0, :] = o.astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, H, nq, nk),
            in_specs=[
                pl.BlockSpec((1, block_q, 1, hd),
                             lambda b, h, qi, ki, kmax: (b, qi, h, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, qi, ki, kmax: (b, ki, h // G, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, qi, ki, kmax: (b, ki, h // G, 0)),
                pl.BlockSpec((1, block_k),
                             lambda b, h, qi, ki, kmax: (b, ki)),
            ],
            out_specs=pl.BlockSpec((1, block_q, 1, hd),
                                   lambda b, h, qi, ki, kmax: (b, qi, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        interpret=interpret,
    )(kv_last_max_flat, q, k, v, kv_last)
