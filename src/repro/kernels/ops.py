"""jit'd public wrapper for the tree-attention kernel.

Dispatches to the Pallas TPU kernels on TPU backends and to interpret mode
on CPU (kernel body executed in Python — bit-level semantics identical).
The custom_vjp is fully fused: the forward saves only O(S) logsumexp
residuals and the backward runs the flash-style recomputation kernels in
kernels/tree_attention_bwd.py (dq, dk, dv) with the same visibility
predicate and block-skip rule as the forward.  The dense jnp reference
(kernels/ref.py) is no longer on the training path — it survives purely
as the test oracle.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.tree_attention import tree_attention as _pallas_fwd
from repro.kernels.tree_attention_bwd import tree_attention_bwd as _pallas_bwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _fit_block(S: int, want: int) -> int:
    """Largest block ≤ ``want`` dividing S (kernels require S % block == 0);
    halves until it fits.  Refuses pathological fits: a block below the
    TPU sublane multiple of 8 (unless the whole row is one block) would
    silently compile a thousands-of-programs grid — pad S instead."""
    want = min(want, S)
    while want > 1 and S % want:
        want //= 2
    if want % 8 and want != S:
        raise ValueError(
            f"no usable block for S={S} (fitted {want}); pad the sequence "
            f"to a multiple of 8 for the pallas impl")
    return want


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def tree_attention(q, k, v, kv_last, scale: float,
                   block_q: int = 128, block_k: int = 128):
    S = q.shape[1]
    return _pallas_fwd(q, k, v, kv_last, scale, block_q=_fit_block(S, block_q),
                       block_k=_fit_block(S, block_k),
                       interpret=not _on_tpu())


def _fwd(q, k, v, kv_last, scale, block_q, block_k):
    S = q.shape[1]
    o, lse = _pallas_fwd(q, k, v, kv_last, scale,
                         block_q=_fit_block(S, block_q),
                         block_k=_fit_block(S, block_k), save_residuals=True,
                         interpret=not _on_tpu())
    return o, (q, k, v, kv_last, o, lse)


def _bwd(scale, block_q, block_k, res, do):
    q, k, v, kv_last, o, lse = res
    S = q.shape[1]
    dq, dk, dv = _pallas_bwd(q, k, v, kv_last, o, lse, do, scale,
                             block_q=_fit_block(S, block_q),
                             block_k=_fit_block(S, block_k),
                             interpret=not _on_tpu())
    return dq, dk, dv, None


tree_attention.defvjp(_fwd, _bwd)
