"""jit'd public wrapper for the tree-attention kernel.

Dispatches to the Pallas TPU kernel on TPU backends and to interpret mode
on CPU (kernel body executed in Python — bit-level semantics identical).
A custom_vjp provides the backward pass by flash-style recomputation
through the reference implementation, keeping training usable behind the
same entry point; on TPU the forward hot path is the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ref import tree_attention_ref
from repro.kernels.tree_attention import tree_attention as _pallas_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def tree_attention(q, k, v, kv_last, scale: float,
                   block_q: int = 128, block_k: int = 128):
    return _pallas_fwd(q, k, v, kv_last, scale, block_q=block_q,
                       block_k=block_k, interpret=not _on_tpu())


def _fwd(q, k, v, kv_last, scale, block_q, block_k):
    o = _pallas_fwd(q, k, v, kv_last, scale, block_q=block_q,
                    block_k=block_k, interpret=not _on_tpu())
    return o, (q, k, v, kv_last)


def _bwd(scale, block_q, block_k, res, do):
    q, k, v, kv_last = res
    # Recompute-based backward via the jnp reference (exact same mask
    # semantics).  A dedicated Pallas dq/dk/dv kernel is a §Perf follow-up.
    _, vjp = jax.vjp(lambda q_, k_, v_:
                     tree_attention_ref(q_, k_, v_, kv_last, scale),
                     q, k, v)
    dq, dk, dv = vjp(do)
    return dq, dk, dv, None


tree_attention.defvjp(_fwd, _bwd)
