"""jit'd public wrapper for the tree-attention kernel.

Dispatches to the Pallas TPU kernels on TPU backends and to interpret mode
on CPU (kernel body executed in Python — bit-level semantics identical).
The custom_vjp is fully fused: the forward saves only O(S) logsumexp
residuals and the backward runs the flash-style recomputation kernels in
kernels/tree_attention_bwd.py (dq, dk, dv) with the same visibility
predicate and block-skip rule as the forward.  The dense jnp reference
(kernels/ref.py) is no longer on the training path — it survives purely
as the test oracle.

Partition gateways (paper §3.3) and sliding windows ride the same op:
``q_off`` front-concatenated ancestor keys extend the KV axis (query i's
global index is q_off + i), and ``window``/``pos_q``/``pos_k`` add the
position-based sliding-window term to the visibility predicate.  The
backward emits dk/dv for the FULL KV length, so the ancestor cotangents
(d_extra_k/d_extra_v) flow out through the caller's concatenation — XLA's
concat transpose slices them back apart for the fp32 child→parent routing
in core/gateway.py.  Awkward KV lengths (real ancestor depths are not
MXU-aligned) are back-padded here with invisible keys (kv_last = −1) to
the TPU sublane multiple; the padding lives outside the custom_vjp, so
its cotangent slice-off is free and automatic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.tree_attention import tree_attention as _pallas_fwd
from repro.kernels.tree_attention_bwd import tree_attention_bwd as _pallas_bwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _fit_block(S: int, want: int) -> int:
    """Largest block ≤ ``want`` dividing S (kernels require S % block == 0);
    halves until it fits.  Refuses pathological fits: a block below the
    TPU sublane multiple of 8 (unless the whole row is one block) would
    silently compile a thousands-of-programs grid — pad S instead."""
    want = min(want, S)
    while want > 1 and S % want:
        want //= 2
    if want % 8 and want != S:
        raise ValueError(
            f"no usable block for S={S} (fitted {want}); pad the sequence "
            f"to a multiple of 8 for the pallas impl")
    return want


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10))
def _tree_attention(q, k, v, kv_last, pos_q, pos_k,
                    scale, window, q_off, block_q, block_k):
    S, Skv = q.shape[1], k.shape[1]
    return _pallas_fwd(q, k, v, kv_last, scale,
                       block_q=_fit_block(S, block_q),
                       block_k=_fit_block(Skv, block_k),
                       q_off=q_off, window=window, pos_q=pos_q, pos_k=pos_k,
                       interpret=not _on_tpu())


def _fwd(q, k, v, kv_last, pos_q, pos_k, scale, window, q_off,
         block_q, block_k):
    S, Skv = q.shape[1], k.shape[1]
    o, lse = _pallas_fwd(q, k, v, kv_last, scale,
                         block_q=_fit_block(S, block_q),
                         block_k=_fit_block(Skv, block_k),
                         q_off=q_off, window=window, pos_q=pos_q,
                         pos_k=pos_k, save_residuals=True,
                         interpret=not _on_tpu())
    return o, (q, k, v, kv_last, pos_q, pos_k, o, lse)


def _bwd(scale, window, q_off, block_q, block_k, res, do):
    q, k, v, kv_last, pos_q, pos_k, o, lse = res
    S, Skv = q.shape[1], k.shape[1]
    dq, dk, dv = _pallas_bwd(q, k, v, kv_last, o, lse, do, scale,
                             block_q=_fit_block(S, block_q),
                             block_k=_fit_block(Skv, block_k),
                             q_off=q_off, window=window, pos_q=pos_q,
                             pos_k=pos_k, interpret=not _on_tpu())
    return dq, dk, dv, None, None, None


_tree_attention.defvjp(_fwd, _bwd)


def tree_attention(q, k, v, kv_last, scale: float,
                   block_q: int = 128, block_k: int = 128, *,
                   q_off: int = 0, window: Optional[int] = None,
                   pos_q: Optional[jax.Array] = None,
                   pos_k: Optional[jax.Array] = None):
    """Fused tree attention.  q: [B,S,H,hd]; k/v: [B,Skv,Kh,hd] with
    Skv = q_off + S (q_off ancestor keys front-concatenated); kv_last:
    [B,Skv].  ``window`` (static) adds the sliding-window visibility term
    over positions pos_q [B,S] / pos_k [B,Skv].  Differentiable in q, k, v
    — the k/v cotangents cover the ancestor rows too."""
    if window is None:
        pos_q = pos_k = None          # unused: keep them out of residuals
    Skv = k.shape[1]
    try:
        _fit_block(Skv, block_k)
    except ValueError:
        # gateway-extended KV lengths need not be MXU-aligned: back-pad
        # with invisible keys (kv_last = −1) to the sublane multiple; the
        # pad sits outside the custom_vjp so dk/dv slice back automatically
        pad = -Skv % 8
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_last = jnp.pad(kv_last, ((0, 0), (0, pad)), constant_values=-1)
        if pos_k is not None:
            pos_k = jnp.pad(pos_k, ((0, 0), (0, pad)))
    return _tree_attention(q, k, v, kv_last, pos_q, pos_k,
                           scale, window, q_off, block_q, block_k)


def prefill_attention(q, k, v, scale: float, *,
                      ctx_k: Optional[jax.Array] = None,
                      ctx_v: Optional[jax.Array] = None,
                      ctx_valid: Optional[jax.Array] = None,
                      window: Optional[int] = None,
                      pos_q: Optional[jax.Array] = None,
                      ctx_pos: Optional[jax.Array] = None,
                      block_q: int = 128, block_k: int = 128):
    """Shared-prefix prefill through the fused tree kernel.

    The decode-session prefill shape: S new chain tokens (q: [B,S,H,hd],
    their already-roped keys/values k/v: [B,S,Kh,hd]) attend causally to
    themselves plus ``ctx_k``/``ctx_v`` [B,A,Kh,hd] — a previously
    prefilled (possibly forked) prefix whose KV was computed ONCE and is
    visible everywhere ``ctx_valid`` [B,A] holds.  That is exactly the
    partition-gateway layout, so it lowers to ``tree_attention`` with
    ``q_off=A``: no re-scoring of the context against itself, and the
    same Pallas kernel that trains the tree serves its rollouts.
    ``window`` adds the sliding-window term over ``pos_q`` [B,S] /
    ``ctx_pos`` [B,A] absolute positions.  Returns [B,S,H,hd]."""
    B, S = q.shape[:2]
    kv_last = jnp.broadcast_to(jnp.asarray(S - 1, jnp.int32), (B, S))
    if ctx_k is None:
        return tree_attention(q, k, v, kv_last, scale, block_q, block_k,
                              window=window, pos_q=pos_q, pos_k=pos_q)
    A = ctx_k.shape[1]
    big = jnp.asarray(1 << 30, jnp.int32)
    ctx_last = jnp.broadcast_to(big, (B, A))
    if ctx_valid is not None:
        ctx_last = jnp.where(ctx_valid, big, -1)
    pos_k = None
    if window is not None:
        if ctx_pos is None or pos_q is None:
            raise ValueError("window needs pos_q and ctx_pos")
        pos_k = jnp.concatenate([ctx_pos, pos_q], axis=1)
    return tree_attention(q, jnp.concatenate([ctx_k, k], axis=1),
                          jnp.concatenate([ctx_v, v], axis=1),
                          jnp.concatenate([ctx_last, kv_last + A], axis=1),
                          scale, block_q, block_k, q_off=A,
                          window=window, pos_q=pos_q, pos_k=pos_k)
