"""Sharding rules: mesh context + per-tensor PartitionSpecs.

Megatron-style layout on a (data…, model) mesh:
  - batch dims of activations      → data axes ("pod","data" when multi-pod)
  - attention head / ffn / vocab / expert dims of weights → "model"
  - tensors whose sharded dim is not divisible by the model-axis size fall
    back to replication (e.g. qwen2-1.5b's 12 heads on a 16-way axis) —
    the rules are per-tensor, so the rest of the layer still shards.

Activation constraints are applied through ``shard_activation`` /
``shard_logits`` which no-op when no mesh is active (unit tests, CPU runs).
"""
from __future__ import annotations

import re
from contextlib import contextmanager
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class _Ctx:
    mesh: Optional[Mesh] = None
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    seq_parallel: bool = False


_CTX = _Ctx()


@contextmanager
def use_mesh(mesh: Mesh, data_axes=("data",), model_axis="model",
             seq_parallel: bool = False):
    """seq_parallel: additionally shard the sequence dim of inter-block
    activations over the model axis (Megatron sequence parallelism).  The
    *forward* TP reduction after each block's output projection is issued
    as a true reduce-scatter (``tp_out_proj``) instead of an all-reduce —
    half the forward wire bytes on that edge — and its backward re-gather
    is an all-gather.  Total fwd+bwd boundary bytes are conserved (ring
    all-reduce ≡ reduce-scatter + all-gather); the win is the halved
    forward path plus boundary activations living S/tp-sharded.  shardlint
    (``analysis/comms_audit``) proves the forward-path drop statically —
    this docstring is a lint invariant, not a hope."""
    old = (_CTX.mesh, _CTX.data_axes, _CTX.model_axis, _CTX.seq_parallel)
    _CTX.mesh, _CTX.data_axes, _CTX.model_axis, _CTX.seq_parallel = \
        mesh, tuple(data_axes), model_axis, seq_parallel
    try:
        with mesh:
            yield
    finally:
        (_CTX.mesh, _CTX.data_axes, _CTX.model_axis,
         _CTX.seq_parallel) = old


def current_mesh() -> Optional[Mesh]:
    """The active mesh, if any.  jax's ``with mesh:`` context is
    thread-local — a worker thread that dispatches jitted computations
    must re-enter it or it will trace (and compile) against no mesh."""
    return _CTX.mesh


def _ns(spec: P) -> Optional[NamedSharding]:
    if _CTX.mesh is None:
        return None
    return NamedSharding(_CTX.mesh, spec)


def shard_activation(x: jax.Array) -> jax.Array:
    """[B, S, D] (or [B, S]) activations: batch over data axes; with
    sequence parallelism also S over the model axis."""
    if _CTX.mesh is None:
        return x
    seq = None
    if (_CTX.seq_parallel and x.ndim >= 2
            and x.shape[1] % _CTX.mesh.shape[_CTX.model_axis] == 0):
        seq = _CTX.model_axis
    s = _ns(P(_CTX.data_axes, seq, *([None] * (x.ndim - 2))))
    return x if s is None else jax.lax.with_sharding_constraint(x, s)


def seq_sharded(S: int) -> bool:
    """True when sequence parallelism is active and a length-``S`` sequence
    dim divides the model axis (the condition under which
    ``shard_activation`` shards S and ``tp_out_proj`` scatters)."""
    return (_CTX.mesh is not None and _CTX.seq_parallel
            and S % _CTX.mesh.shape[_CTX.model_axis] == 0)


def tp_out_proj(h: jax.Array, w: jax.Array) -> jax.Array:
    """TP output projection ``h @ w`` (h: [B, S, F] feature-sharded over the
    model axis, w: [F, D] row-sharded).

    Without sequence parallelism this is a plain matmul — GSPMD inserts the
    usual all-reduce of the partial products.  With ``seq_parallel=True``
    the reduction is issued explicitly as ``psum_scatter`` inside a
    ``shard_map``, so the lowered HLO carries a true reduce-scatter (result
    [B, S, D] sharded S-over-model) instead of all-reduce + slice: half the
    wire bytes on the boundary, and the backward of the scatter is an
    all-gather rather than another all-reduce.  Falls back to the plain
    matmul whenever any dim doesn't divide its axis."""
    mesh, m = _CTX.mesh, _CTX.model_axis
    if (not seq_sharded(h.shape[1]) or h.ndim != 3
            or h.shape[-1] % mesh.shape[m] != 0
            or w.shape[0] % mesh.shape[m] != 0):
        return h @ w
    from jax.experimental.shard_map import shard_map
    daxes = _CTX.data_axes
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    bspec = daxes if h.shape[0] % dsize == 0 else None

    def local(hl, wl):
        return jax.lax.psum_scatter(hl @ wl, m, scatter_dimension=1,
                                    tiled=True)

    return shard_map(local, mesh,
                     in_specs=(P(bspec, None, m), P(m, None)),
                     out_specs=P(bspec, m, None),
                     check_rep=False)(h, w)


def shard_spec(x: jax.Array, *axes) -> jax.Array:
    """Constrain arbitrary dims: axes entries are None, 'data' (the data
    axes tuple), or 'model'.  No-op without an active mesh or when a
    requested dim is not divisible by its axis size."""
    if _CTX.mesh is None:
        return x
    parts = []
    for i, a in enumerate(axes):
        if a == "data":
            size = 1
            for ax in _CTX.data_axes:
                size *= _CTX.mesh.shape[ax]
            parts.append(_CTX.data_axes if x.shape[i] % size == 0 else None)
        elif a == "model":
            m = _CTX.model_axis
            parts.append(m if x.shape[i] % _CTX.mesh.shape[m] == 0
                         else None)
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(x, _ns(P(*parts)))


def shard_logits(x: jax.Array) -> jax.Array:
    """[B, S, V]: batch over data axes, vocab over model."""
    if _CTX.mesh is None:
        return x
    V = x.shape[-1]
    m = _CTX.model_axis
    msize = _CTX.mesh.shape[m]
    spec = P(_CTX.data_axes, None, m if V % msize == 0 else None)
    return jax.lax.with_sharding_constraint(x, _ns(spec))


# ---------------------------------------------------------------------------
# Parameter shardings
# ---------------------------------------------------------------------------

# (path regex, spec builder given (shape, model_size)); first match wins.
# Specs are for the UNSTACKED tensor; a leading layer-stack dim is handled
# by rank offset (None prepended for each extra leading dim).
_RULES: list[tuple[str, Any]] = [
    # embeddings / lm head: vocab dim sharded
    (r"embed/table$",      lambda s, m: P("M" if s[0] % m == 0 else None, None)),
    (r"lm_head/w$",        lambda s, m: P(None, "M" if s[1] % m == 0 else None)),
    # attention
    (r"attn/wq$|xattn/wq$", lambda s, m: P(None, "M" if s[1] % m == 0 else None)),
    (r"attn/wk$|attn/wv$|xattn/wk$|xattn/wv$",
     lambda s, m: P(None, "M" if s[1] % m == 0 else None)),
    (r"attn/wo$|xattn/wo$", lambda s, m: P("M" if s[0] % m == 0 else None, None)),
    (r"attn/b[qkv]$",      lambda s, m: P("M" if s[0] % m == 0 else None)),
    # MoE: expert-parallel over the expert dim
    (r"moe/router$",       lambda s, m: P(None, None)),
    (r"moe/wi_gate$|moe/wi_up$|moe/wo$",
     lambda s, m: P("M" if s[0] % m == 0 else None, None, None)),
    (r"moe/shared_wi_gate$|moe/shared_wi_up$",
     lambda s, m: P(None, "M" if s[1] % m == 0 else None)),
    (r"moe/shared_wo$",    lambda s, m: P("M" if s[0] % m == 0 else None, None)),
    # dense MLP
    (r"mlp/wi_gate$|mlp/wi_up$|cm/wk$",
     lambda s, m: P(None, "M" if s[1] % m == 0 else None)),
    (r"mlp/wo$|cm/wv$",    lambda s, m: P("M" if s[0] % m == 0 else None, None)),
    (r"mlp/bi$",           lambda s, m: P("M" if s[0] % m == 0 else None)),
    # SSM projections: z/x (d_inner) shard on model; B/C/dt stay replicated
    # on their tiny output dims (see mamba2.init_mamba2 docstring)
    (r"ssm/in_[zx]$|ssm/in_proj$|tm/w[rkvg]$|ssm/w[qkvz]$",
     lambda s, m: P(None, "M" if s[1] % m == 0 else None)),
    (r"ssm/in_[BC]$|ssm/in_dt$|ssm/w[ab]$",
     lambda s, m: P(None, None)),
    # intentionally replicated ≥2-D tensors (explicit so shardlint's
    # closed-coverage rule lint proves intent, not fall-through):
    # depthwise conv taps follow the locally-resident d_inner slice; the
    # rwkv6 mix interpolants, per-head bonus, decay LoRA and channelmix
    # gate are small and stay off the collective hot path (see the
    # test_sharding replicate-allowlist and per-module init docstrings)
    (r"ssm/conv_w$|tm/mix$|cm/mix$|cm/wr$|tm/u$|tm/w_lora_[ab]$",
     lambda s, m: P(None, None)),
    (r"ssm/out_proj$|tm/wo$",
     lambda s, m: P("M" if s[0] % m == 0 else None, None)),
    (r"shared_in$",        lambda s, m: P(None, None)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(path_str: str, shape: tuple[int, ...], model_size: int,
               model_axis: str, n_stack_dims: int = 0,
               fsdp_axis: Optional[str] = None,
               fsdp_size: int = 1) -> P:
    base_shape = shape[n_stack_dims:]
    for pat, fn in _RULES:
        if re.search(pat, path_str):
            spec = fn(base_shape, model_size)
            parts = [model_axis if a == "M" else a for a in spec]
            # FSDP (ZeRO-3): shard one non-model dim over the data axis —
            # the weight all-gather appears at use, exactly like MaxText's
            # fsdp axis.  Only ≥2-D tensors; pick the largest eligible dim.
            if fsdp_axis is not None and len(base_shape) >= 2:
                cand = [i for i, a in enumerate(parts)
                        if a is None and base_shape[i] % fsdp_size == 0]
                if cand:
                    best = max(cand, key=lambda i: base_shape[i])
                    parts[best] = fsdp_axis
            return P(*([None] * n_stack_dims), *parts)
    return P()  # replicate


def param_shardings(params_shape: Any, mesh: Mesh,
                    model_axis: str = "model",
                    fsdp_axis: Optional[str] = None) -> Any:
    """Pytree of NamedShardings for a (possibly layer-stacked) param tree.

    Stacked tensors are recognized by path: anything under ``layer_stacks``
    or ``encoder`` has one leading layer dim.  fsdp_axis: additionally
    shard weights over that (data) axis — required for the 340B/1T archs
    where 16-way tensor parallelism alone cannot hold the weights.
    """
    msize = mesh.shape[model_axis]
    fsize = mesh.shape[fsdp_axis] if fsdp_axis else 1
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        n_stack = 1 if ("layer_stacks" in ps or ps.startswith("encoder")) \
            else 0
        spec = param_spec(ps, leaf.shape, msize, model_axis, n_stack,
                          fsdp_axis, fsize)
        if len(spec) > len(leaf.shape):
            spec = P(*list(spec)[:len(leaf.shape)])
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch_shape: Any, mesh: Mesh,
                    data_axes=("data",)) -> Any:
    """Batch arrays: first dim over data axes, rest replicated."""
    def one(leaf):
        total = 1
        for a in data_axes:
            total *= mesh.shape[a]
        lead = data_axes if leaf.shape and leaf.shape[0] % total == 0 else None
        return NamedSharding(mesh, P(lead, *([None] * (len(leaf.shape) - 1)))
                             if leaf.shape else P())
    return jax.tree_util.tree_map(one, batch_shape)


def cache_shardings(cache_shapes: Any, mesh: Mesh, daxes=("data",),
                    model_axis: str = "model") -> Any:
    """Decode-cache layout: batch dim over the data axes; KV sequence dim
    (flash-decode style) / SSM heads / conv channels over the model axis.
    Shared by the dry-run tool and shardlint's DecodeSession audit."""
    msize = mesh.shape[model_axis]
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]

    def rule(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = leaf.shape
        spec = [None] * len(shape)
        # batch dim: attn caches [L,B,...] / ssm [L,B,...] / cross valid [B,F]
        bdim = 1 if len(shape) >= 2 and name != "valid" else 0
        if shape[bdim] % dsize == 0 and shape[bdim] >= dsize:
            spec[bdim] = daxes
        if name in ("k", "v", "pos") and len(shape) >= 3:
            # shard the cache sequence dim over model (flash-decode style)
            if shape[2] % msize == 0:
                spec[2] = model_axis
        elif name in ("h", "S") and len(shape) >= 3:
            if shape[2] % msize == 0:          # heads
                spec[2] = model_axis
        elif name == "conv" and len(shape) == 4:
            if shape[3] % msize == 0:
                spec[3] = model_axis
        elif name in ("x_tm", "x_cm"):
            pass
        return NamedSharding(mesh, P(*spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(p, l) for p, l in flat])
