"""DecodeSession — the serving API: prefill / fork / step / snapshot.

One session owns a decode cache for ``batch`` synchronized branches:

  ``create``    allocate the cache (``serve/decode._init_cache`` layout).
  ``prefill``   run a token prefix through the model and populate the
                cache.  Dense/MoE full-history sessions take the
                *parallel* path: one tree-training forward over the whole
                prefix (a chain is a 1-path tree) with per-layer K/V
                captured post-rope straight into the cache — and on a
                session that already holds context (a fork, or a second
                prefill) the cached slots ride in as gateway ancestors,
                i.e. the fused tree-attention kernel's forked-prefix
                ``q_off`` shape (see ``kernels/ops.prefill_attention``).
                Other families (SSM state, sliding windows, enc-dec) fall
                back to the step-wise loop — still one computation of the
                prefix per session, shared by every later ``fork``.
  ``fork``      split a 1-branch session into K branches that *share* the
                prefilled prefix: the cache rows are tiled, the prefix is
                NOT recomputed (this is the shared-prefix KV reuse the
                tree kernels train against — paper §2).
  ``step``      one decode token per branch (jitted, cached per config).
  ``snapshot``  O(1) fork-point capture: caches are immutable jax arrays,
                so a snapshot is an independent session sharing buffers.

Token accounting (``SessionStats``, shared across forks/snapshots of a
group) records prefill vs decode tokens — the benchmark's proof that each
common prefix is computed exactly once per rollout group.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from functools import lru_cache
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import logits_from_hidden
from repro.models.transformer import layer_groups, partition_forward
from repro.serve.decode import _decode_step, _init_cache
from repro.sharding import shard_logits


@lru_cache(maxsize=32)
def _step_exec(cfg: ModelConfig, donate: bool = True):
    """One decode step.  The cache (arg 1) is donated by default — the
    step's output cache recycles the input buffers instead of holding
    both alive — except for sessions whose buffers are shared with a
    ``snapshot`` (``DecodeSession.donate`` gates it per session)."""
    f = lambda p, c, t, pos, w: _decode_step(cfg, p, c, t, pos, w)
    return jax.jit(f, donate_argnums=(1,) if donate else ())


@lru_cache(maxsize=8)
def _fork_exec(k: int):
    """Tile every cache row ``k``× in ONE jitted dispatch (fork used to
    issue one ``jnp.repeat`` per leaf).  The parent's cache (arg 0) is
    deliberately NOT donated: the parent session stays steppable after
    the fork — a contract the static auditor (repro.analysis) checks."""
    def tile(cache):
        out = {}
        for name, grp in cache.items():
            if name == "cross":
                # cross "valid" is [B, enc] (batch axis 0); k/v are
                # [L, B, enc, ...] like every other leaf
                out[name] = {kk: jnp.repeat(vv, k, axis=0
                                            if kk == "valid" else 1)
                             for kk, vv in grp.items()}
            else:
                out[name] = jax.tree.map(
                    lambda a: jnp.repeat(a, k, axis=1), grp)
        return out

    return jax.jit(tile)


@lru_cache(maxsize=32)
def _prefill_exec(cfg: ModelConfig, impl: str):
    """Parallel prefill: one partition-mode forward over the prefix chain
    with every position captured.  ``gw`` carries the session's existing
    cache slots as gateway ancestors (the kernel's q_off path); ``idx``
    is the capture index array (arange over the new positions)."""
    def f(params, batch, gw, idx):
        capspecs = {"pf": {"path_idx": idx}}
        hidden, _, caps = partition_forward(cfg, params, batch, gw,
                                            capspecs, impl)
        logits = logits_from_hidden(params["embed"], params.get("lm_head"),
                                    hidden[:, -1:])
        return shard_logits(logits)[:, 0], caps

    return jax.jit(f)


@dataclass
class SessionStats:
    """Token accounting, shared by every fork/snapshot of one group."""
    prefill_tokens: int = 0   # prefix tokens computed (once per session)
    decode_tokens: int = 0    # single-token steps × branches


@dataclass
class DecodeSession:
    """A decode cache + position cursor for ``batch`` lockstep branches."""
    cfg: ModelConfig
    params: dict
    cache: dict
    batch: int
    t: int = 0                        # next absolute position
    enc_len: int = 0
    stats: SessionStats = field(default_factory=SessionStats)
    # step() may donate the cache back to XLA (in-place buffer reuse)
    # unless a live snapshot shares these buffers — snapshot() clears it.
    donate: bool = True

    # -- construction ------------------------------------------------------
    @classmethod
    def create(cls, cfg: ModelConfig, params: dict, *, batch: int = 1,
               buf_len: int, enc_len: int = 0) -> "DecodeSession":
        return cls(cfg=cfg, params=params,
                   cache=_init_cache(cfg, batch, buf_len, enc_len),
                   batch=batch, enc_len=enc_len)

    @property
    def _ring(self) -> Optional[int]:
        """KV ring-buffer length (None for pure-SSM caches)."""
        for name in ("g0", "g1", "shared"):
            grp = self.cache.get(name)
            if isinstance(grp, dict) and "pos" in grp:
                return grp["pos"].shape[2]
        for name, grp in self.cache.items():
            if name != "cross" and isinstance(grp, dict) and "pos" in grp:
                return grp["pos"].shape[2]
        return None

    def load_cross(self, k: jax.Array, v: jax.Array,
                   valid: Optional[jax.Array] = None) -> None:
        """Install encoder cross K/V (audio enc-dec sessions)."""
        cross = dict(self.cache["cross"])
        cross["k"] = k.astype(cross["k"].dtype)
        cross["v"] = v.astype(cross["v"].dtype)
        if valid is not None:
            cross["valid"] = valid
        self.cache = {**self.cache, "cross": cross}

    # -- prefill -----------------------------------------------------------
    def _can_parallel_prefill(self, P: int) -> bool:
        cfg = self.cfg
        if cfg.family not in ("dense", "moe"):
            return False
        if cfg.attn is None or cfg.attn.window is not None:
            return False
        if cfg.frontend is not None:
            return False
        ring = self._ring
        return ring is not None and self.t + P <= ring

    def prefill(self, tokens, impl: str = "ref") -> jax.Array:
        """Run a prefix through the model, populate the cache, and return
        the last position's logits [batch, padded_vocab].

        ``tokens``: 1-D [P] (same prefix for every branch).  May be called
        again on a session that already holds context (e.g. after fork):
        the new tokens extend the chain, attending to the cached slots."""
        toks = np.asarray(tokens, np.int32).reshape(-1)
        P = toks.shape[0]
        assert P > 0, "empty prefill"
        if self._can_parallel_prefill(P):
            logits = self._prefill_parallel(toks, impl)
        else:
            logits = self._prefill_steps(toks)
        self.stats.prefill_tokens += self.batch * P
        return logits

    def _prefill_parallel(self, toks: np.ndarray, impl: str) -> jax.Array:
        cfg, B, P, t0 = self.cfg, self.batch, len(toks), self.t
        batch = dict(
            tokens=jnp.broadcast_to(jnp.asarray(toks)[None], (B, P)),
            pos_ids=jnp.broadcast_to(
                t0 + jnp.arange(P, dtype=jnp.int32)[None], (B, P)),
            kv_last=jnp.full((B, P), P - 1, jnp.int32),
            prev_idx=jnp.broadcast_to(
                jnp.arange(P, dtype=jnp.int32)[None] - 1, (B, P)),
            valid=jnp.ones((B, P), bool))
        groups = layer_groups(cfg)
        gw = None
        if t0 > 0:
            # cached slots ride in as gateway ancestors → the fused
            # kernel's forked-prefix q_off shape (prefix computed once,
            # regardless of how many branches extend it)
            gw = {f"g{gi}": {"attn": {"k": self.cache[f"g{gi}"]["k"]
                                      [:, :, :t0],
                                      "v": self.cache[f"g{gi}"]["v"]
                                      [:, :, :t0]}}
                  for gi in range(len(groups))}
            anc_pos = self.cache["g0"]["pos"][0][:, :t0]
            batch["anc_pos"] = anc_pos
            batch["anc_valid"] = anc_pos >= 0
        logits, caps = _prefill_exec(cfg, impl)(
            self.params, batch, gw, np.arange(P))
        new_cache = dict(self.cache)
        for gi in range(len(groups)):
            grp = dict(new_cache[f"g{gi}"])
            cap = caps[f"g{gi}"]["attn"]["pf"]      # [L, B, P, Kh, hd]
            grp["k"] = grp["k"].at[:, :, t0:t0 + P].set(
                cap["k"].astype(grp["k"].dtype))
            grp["v"] = grp["v"].at[:, :, t0:t0 + P].set(
                cap["v"].astype(grp["v"].dtype))
            grp["pos"] = grp["pos"].at[:, :, t0:t0 + P].set(
                t0 + jnp.arange(P, dtype=jnp.int32))
            new_cache[f"g{gi}"] = grp
        self.cache = new_cache
        self.t = t0 + P
        return logits

    def _prefill_steps(self, toks: np.ndarray) -> jax.Array:
        logits = None
        for tok in toks:
            logits = self._advance(
                jnp.full((self.batch,), int(tok), jnp.int32))
        return logits

    # -- branching ---------------------------------------------------------
    def fork(self, k: int) -> "DecodeSession":
        """Split into ``k`` branches sharing this session's cache content.

        The prefilled prefix is NOT recomputed — its KV rows are tiled
        (identical rows; a production server would alias one copy).  Only
        1-branch sessions fork; the forks share this session's stats."""
        assert self.batch == 1, "fork() requires a 1-branch session"
        new_cache = _fork_exec(k)(self.cache)
        # the fork's cache rows are fresh buffers, so it may donate them
        # on step() even if this parent is snapshot-frozen
        return replace(self, cache=new_cache, batch=k, donate=True)

    def snapshot(self) -> "DecodeSession":
        """O(1) capture of the current state: an independent session that
        can be stepped separately (caches are immutable device arrays).
        Shares the group's stats — compute on abandoned branches still
        counts."""
        # both sessions now alias the same cache buffers: neither may let
        # XLA donate (overwrite) them on step()
        self.donate = False
        return replace(self)

    # -- decode ------------------------------------------------------------
    def _advance(self, tokens: jax.Array) -> jax.Array:
        ring = self._ring
        widx = jnp.asarray(self.t % ring if ring else 0, jnp.int32)
        pos = jnp.full((self.batch,), self.t, jnp.int32)
        logits, self.cache = _step_exec(self.cfg, self.donate)(
            self.params, self.cache, tokens.reshape(self.batch, 1),
            pos, widx)
        self.t += 1
        return logits

    def step(self, tokens) -> jax.Array:
        """Decode one token per branch.  ``tokens``: [batch] (or [batch,1])
        int32.  Returns logits [batch, padded_vocab]."""
        tokens = jnp.asarray(tokens, jnp.int32)
        logits = self._advance(tokens)
        self.stats.decode_tokens += self.batch
        return logits
