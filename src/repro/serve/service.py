"""Async tree-RL service: rollout → advantage tree → planner queue.

Closes the loop the training side already speaks (ROADMAP item 1): a
background generation thread decodes rollout groups with shared-prefix
KV reuse (:mod:`serve/rollout`), tags each merged tree with the weight
version that generated it, and feeds a bounded queue that the planner
consumes as a live tree source — so plan-build AND generation overlap
training, AREAL-style, with *bounded* staleness:

  trainer ──publish(params, v)──▶ WeightStore ──wait_for(s − A)──▶ gen
     ▲                                                             │
     └── PlanPipeline ◀── tree_batches() ◀── Queue(maxsize=A) ◀────┘

Two mechanisms bound the off-policy lag to ``max_ahead_steps`` (= A):

* the generator *gates*: before producing step ``s``'s trees it blocks
  until the trainer has published version ≥ ``s − A`` — generation runs
  at most A optimizer steps ahead of the weights it samples from;
* the queue *backpressures*: ``put`` blocks once A step-batches are
  waiting, so a stalled trainer also stalls generation instead of
  accumulating arbitrarily stale rollouts.

``WeightStore.publish`` deep-copies params onto fresh device buffers —
the training step donates its argument buffers, so the service must
never hold references into the optimizer's donated memory.
"""
from __future__ import annotations

import contextlib
import threading
import time
from dataclasses import dataclass, field
from queue import Queue
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as sh
from repro.configs.base import ModelConfig
from repro.core.tree import TrajectoryTree
from repro.serve.rollout import GroupStats, RolloutConfig, rollout_group


class WeightStore:
    """Versioned, thread-safe parameter snapshot shared trainer→service.

    ``version`` counts completed optimizer steps; the initial params are
    version 0.  All reads/writes hold one condition variable, so
    ``wait_for`` wakes exactly when the trainer publishes."""

    def __init__(self, params: dict, version: int = 0):
        self._cond = threading.Condition()
        self._params = jax.tree.map(jnp.copy, params)
        self._version = version

    @property
    def version(self) -> int:
        with self._cond:
            return self._version

    def get(self) -> tuple[dict, int]:
        with self._cond:
            return self._params, self._version

    def publish(self, params: dict, version: int) -> None:
        """Install new weights.  Copies every leaf: the caller's buffers
        may be donated to the next update step."""
        fresh = jax.tree.map(jnp.copy, params)
        with self._cond:
            self._params = fresh
            self._version = version
            self._cond.notify_all()

    def wait_for(self, version: int, timeout: Optional[float] = None
                 ) -> bool:
        with self._cond:
            return self._cond.wait_for(
                lambda: self._version >= version, timeout)


@dataclass(frozen=True)
class ServiceConfig:
    """Knobs of the async loop."""
    groups_per_step: int = 2          # rollout groups per optimizer step
    max_ahead_steps: int = 1          # A: generation lead ≤ A steps
    rollout: RolloutConfig = field(default_factory=RolloutConfig)
    seed: int = 0
    gate_timeout_s: float = 120.0     # deadlock guard on wait_for


@dataclass
class ServiceStats:
    gen_busy_s: float = 0.0           # wall time generating
    exposed_wait_s: float = 0.0       # consumer time blocked on the queue
    steps_generated: int = 0
    trees_generated: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    saved_prefill_tokens: int = 0     # (k−1)·P per group: KV-reuse savings
    min_version: Optional[int] = None
    max_gen_lag: int = 0              # max (step − weight_version) at gen


class AsyncTreeRLService:
    """Generates ``num_steps`` step-batches of advantage trees on a daemon
    thread, bounded-staleness gated against a :class:`WeightStore`.

    Consume with :meth:`tree_batches` — an iterator of
    ``list[TrajectoryTree]`` (the planner's native source element), each
    tree carrying ``weight_version``.  Exceptions in the generator are
    re-raised to the consumer."""

    def __init__(self, cfg: ModelConfig, store: WeightStore,
                 sc: ServiceConfig, num_steps: int):
        self.cfg, self.store, self.sc = cfg, store, sc
        self.num_steps = num_steps
        self.stats = ServiceStats()
        self._queue: Queue = Queue(maxsize=max(1, sc.max_ahead_steps))
        self._error: Optional[BaseException] = None
        # jax's mesh context is thread-local: capture the constructing
        # thread's mesh so generation traces hit the same jit cache
        self._mesh = sh.current_mesh()
        self._thread = threading.Thread(
            target=self._run, name="tree-rl-gen", daemon=True)

    # -- producer ----------------------------------------------------------
    def start(self) -> "AsyncTreeRLService":
        self._thread.start()
        return self

    def _run(self) -> None:
        try:
            with self._mesh or contextlib.nullcontext():
                self._generate()
        except BaseException as e:          # noqa: BLE001 — relayed
            self._error = e
            self._queue.put(None)

    def _generate(self) -> None:
        sc, rc = self.sc, self.sc.rollout
        rng = np.random.default_rng(sc.seed)
        key = jax.random.key(sc.seed)
        for s in range(self.num_steps):
            # staleness gate: weights can lag at most A steps behind
            if not self.store.wait_for(s - sc.max_ahead_steps,
                                       timeout=sc.gate_timeout_s):
                raise TimeoutError(
                    f"weight store stuck below version "
                    f"{s - sc.max_ahead_steps} for "
                    f"{sc.gate_timeout_s}s (generation step {s})")
            params, ver = self.store.get()
            t0 = time.perf_counter()
            trees: list[TrajectoryTree] = []
            for _ in range(sc.groups_per_step):
                prompt = rng.integers(0, self.cfg.vocab_size,
                                      rc.prompt_len)
                key, sub = jax.random.split(key)
                tree, gs = rollout_group(self.cfg, params, prompt,
                                         rc, sub)
                tree.weight_version = ver
                trees.append(tree)
                self._absorb(gs)
            st = self.stats
            st.gen_busy_s += time.perf_counter() - t0
            st.steps_generated += 1
            st.trees_generated += len(trees)
            st.min_version = (ver if st.min_version is None
                              else min(st.min_version, ver))
            st.max_gen_lag = max(st.max_gen_lag, s - ver)
            self._queue.put(trees)     # backpressure: ≤ A waiting
        self._queue.put(None)

    def _absorb(self, gs: GroupStats) -> None:
        st = self.stats
        st.prefill_tokens += gs.prefill_tokens
        st.decode_tokens += gs.decode_tokens
        st.saved_prefill_tokens += gs.saved_prefill_tokens

    # -- consumer ----------------------------------------------------------
    def tree_batches(self) -> Iterator[list[TrajectoryTree]]:
        """Live planner source: one list of trees per optimizer step.
        Time spent blocked here is generation *exposed* to training
        (recorded in ``stats.exposed_wait_s``)."""
        while True:
            t0 = time.perf_counter()
            item = self._queue.get()
            self.stats.exposed_wait_s += time.perf_counter() - t0
            if item is None:
                if self._error is not None:
                    raise RuntimeError(
                        "rollout generation failed") from self._error
                return
            yield item

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)
