"""Serving internals: per-layer caches + single-token decode.

The supported serving surface is ``serve/session.DecodeSession``
(prefill / fork / step / snapshot); this module holds the cache layout
(``_init_cache``) and the jit-able one-token step (``_decode_step``) the
session drives, plus ``rollouts_to_tree``.  The old free functions
``init_cache`` / ``decode_step`` remain as deprecated wrappers for one
release.

``decode_32k`` / ``long_500k`` dry-run shapes lower ``_decode_step`` — one
new token against a KV/SSM cache.  Caches are layer-stacked pytrees so the
decode layer loop is a lax.scan (same compile-size discipline as training).

``rollouts_to_tree`` closes the RL loop: K sampled rollouts + rewards →
one shared-prefix trajectory tree with GRPO branch advantages, ready for
the training engine's ``loss_mode="rl"``.

Cache kinds:
  attention   : ring-buffer K/V of ``buf_len`` slots (full history for
                decode_32k; sliding window for long_500k dense variants)
  mamba2/gdn  : recurrent state + causal-conv tail (O(1) in context)
  rwkv6       : wkv state + token-shift tails (O(1))
  hybrid      : mamba2 stack + per-application shared-attn caches
  audio       : decoder self-cache + cross K/V from the (stub) encoder
"""
from __future__ import annotations

import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.attention import decode_attention, project_cross_kv
from repro.models.layers import logits_from_hidden, mlp, rmsnorm
from repro.models.moe import moe
from repro.models.ssm.gdn import gdn_decode, init_gdn_cache
from repro.models.ssm.mamba2 import init_mamba2_cache, mamba2_decode
from repro.models.ssm.rwkv6 import (init_rwkv6_cache,
                                    rwkv6_channelmix_decode,
                                    rwkv6_timemix_decode)
from repro.models.transformer import _dtype, layer_groups
from repro.sharding import shard_logits


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def _attn_cache(L: int, B: int, T: int, cfg: ModelConfig, dt) -> dict:
    a = cfg.attn
    return {
        "k": jnp.zeros((L, B, T, a.n_kv_heads, a.head_dim), dt),
        "v": jnp.zeros((L, B, T, a.n_kv_heads, a.head_dim), dt),
        "pos": jnp.full((L, B, T), -1, jnp.int32),
    }


def _ssm_cache(L: int, B: int, cfg: ModelConfig, dt) -> dict:
    s = cfg.ssm
    if s.kind == "rwkv6":
        base = init_rwkv6_cache(B, s, cfg.d_model, dt)
    elif s.kind == "gdn":
        base = init_gdn_cache(B, s, cfg.d_model, dt)
    else:
        base = init_mamba2_cache(B, s, cfg.d_model, dt)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), base)


def _init_cache(cfg: ModelConfig, batch: int, buf_len: int,
                enc_len: int = 0) -> dict:
    """buf_len: KV slots (= max context, or window size for sliding)."""
    dt = _dtype(cfg)
    a = cfg.attn
    if a is not None and a.window is not None:
        buf_len = min(buf_len, a.window)
    cache: dict[str, Any] = {}
    groups = layer_groups(cfg)
    for gi, (kind, n) in enumerate(groups):
        if kind in ("dense", "moe"):
            cache[f"g{gi}"] = _attn_cache(n, batch, buf_len, cfg, dt)
        elif kind in ("mamba2", "rwkv6", "gdn"):
            cache[f"g{gi}"] = _ssm_cache(n, batch, cfg, dt)
        elif kind == "decoder_cross":
            cache[f"g{gi}"] = _attn_cache(n, batch, buf_len, cfg, dt)
    if cfg.family == "hybrid":
        n_apps = -(-cfg.n_layers // cfg.hybrid.attn_every)
        cache["shared"] = _attn_cache(n_apps, batch, buf_len, cfg, dt)
    if cfg.family == "audio":
        e = cfg.encdec
        cache["cross"] = {
            "k": jnp.zeros((e.dec_layers, batch, enc_len,
                            a.n_kv_heads, a.head_dim), dt),
            "v": jnp.zeros((e.dec_layers, batch, enc_len,
                            a.n_kv_heads, a.head_dim), dt),
            "valid": jnp.ones((batch, enc_len), bool),
        }
    return cache


# ---------------------------------------------------------------------------
# Per-layer decode
# ---------------------------------------------------------------------------

def _decode_layer(cfg: ModelConfig, p: dict, kind: str, x, cache_l, pos,
                  widx, cross_l=None):
    eps = cfg.norm_eps
    if kind in ("dense", "moe"):
        a, kv = decode_attention(p["attn"], cfg.attn,
                                 rmsnorm(p["ln1"], x, eps), cache_l, pos,
                                 widx)
        x = x + a
        h = rmsnorm(p["ln2"], x, eps)
        if kind == "moe":
            m, _ = moe(p["moe"], cfg.moe, h,
                       jnp.ones(h.shape[:2], bool), cfg.mlp_activation)
        else:
            m = mlp(p["mlp"], h, cfg.mlp_activation)
        return x + m, kv
    if kind == "decoder_cross":
        a, kv = decode_attention(p["attn"], cfg.attn,
                                 rmsnorm(p["ln1"], x, eps), cache_l, pos,
                                 widx, cross_cache=None)
        x = x + a
        kvx = project_cross_kv(p["xattn"], cfg.attn, cross_l["enc_out"]) \
            if "enc_out" in (cross_l or {}) else (cross_l["k"], cross_l["v"])
        from repro.models.attention import _attend_ref, _scale, NEG_INF
        B = x.shape[0]
        qc = (rmsnorm(p["ln_x"], x, eps) @ p["xattn"]["wq"]).reshape(
            B, 1, cfg.attn.n_heads, cfg.attn.head_dim)
        cb = jnp.where(cross_l["valid"][:, None, :], 0.0,
                       NEG_INF)[:, None, None]
        oc = _attend_ref(qc, kvx[0], kvx[1], cb, _scale(cfg.attn))
        x = x + oc.reshape(B, 1, -1) @ p["xattn"]["wo"]
        m = mlp(p["mlp"], rmsnorm(p["ln2"], x, eps), cfg.mlp_activation)
        return x + m, kv
    if kind == "rwkv6":
        t, cache_l = rwkv6_timemix_decode(p["tm"], cfg.ssm,
                                          rmsnorm(p["ln1"], x, eps), cache_l)
        x = x + t
        c, cache_l = rwkv6_channelmix_decode(p["cm"],
                                             rmsnorm(p["ln2"], x, eps),
                                             cache_l)
        return x + c, cache_l
    if kind == "mamba2":
        s, cache_l = mamba2_decode(p["ssm"], cfg.ssm,
                                   rmsnorm(p["ln1"], x, eps), cache_l)
        return x + s, cache_l
    if kind == "gdn":
        s, cache_l = gdn_decode(p["ssm"], cfg.ssm,
                                rmsnorm(p["ln1"], x, eps), cache_l)
        x = x + s
        m = mlp(p["mlp"], rmsnorm(p["ln2"], x, eps), cfg.mlp_activation)
        return x + m, cache_l
    raise ValueError(kind)


def rollouts_to_tree(sequences, rewards, *, prompt_len: int = 0,
                     normalize: bool = True):
    """Sampled rollouts → a trajectory tree for the RL update phase.

    ``sequences[k]`` is the full token sequence of rollout k (prompt +
    completion, e.g. collected by looping ``decode_step``); ``rewards[k]``
    its scalar reward.  Shared prefixes are merged into one trie — the
    tree the training engine natively ingests — and each leaf gets the
    GRPO group-normalized advantage (A = (r − mean)/std over the K
    rollouts; ``normalize=False`` keeps raw rewards).  Tokens before
    ``prompt_len`` are ``trained=False`` (prompt/context, no loss).

    A rollout that is a strict prefix of another (or duplicated rollouts)
    contributes an empty leaf node so its advantage still lands on its
    own branch.  Train the result with ``loss_mode="rl"``.
    """
    from repro.core.tree import TrajectoryTree, TreeNode
    from repro.data.synthetic import group_normalized_advantages

    seqs = [np.asarray(s, np.int32).reshape(-1) for s in sequences]
    assert seqs and len(seqs) == len(rewards)
    adv = group_normalized_advantages(rewards, normalize)

    def node(lo: int, hi: int, k: int) -> "TreeNode":
        toks = seqs[k][lo:hi]
        trained = np.arange(lo, hi) >= prompt_len
        return TreeNode(tokens=toks, trained=trained)

    def build(idx: list, off: int) -> "TreeNode":
        # maximal segment shared by every rollout in ``idx`` from ``off``
        end = min(len(seqs[i]) for i in idx)
        cp = off
        while cp < end and all(seqs[i][cp] == seqs[idx[0]][cp]
                               for i in idx[1:]):
            cp += 1
        n = node(off, cp, idx[0])
        ended = [i for i in idx if len(seqs[i]) == cp]
        by_tok: dict[int, list] = {}
        for i in idx:
            if len(seqs[i]) > cp:
                by_tok.setdefault(int(seqs[i][cp]), []).append(i)
        if not by_tok and len(ended) == 1:
            n.branch_adv = float(adv[ended[0]])
            return n
        # rollouts ending exactly here (prefixes / duplicates) become
        # empty leaves so each keeps its own branch advantage
        for i in ended:
            n.children.append(TreeNode(tokens=np.zeros(0, np.int32),
                                       branch_adv=float(adv[i])))
        for _, sub in sorted(by_tok.items()):
            n.children.append(build(sub, cp))
        return n

    return TrajectoryTree(root=build(list(range(len(seqs))), 0))


def _decode_step(cfg: ModelConfig, params: dict, cache: dict,
                 tokens: jax.Array, pos: jax.Array, write_idx: jax.Array
                 ) -> tuple[jax.Array, dict]:
    """tokens: [B, 1]; pos: [B] absolute positions; write_idx: scalar ring
    slot.  Returns (logits [B, vocab], new_cache)."""
    from repro.models.layers import embed
    x = embed(params["embed"], tokens)
    new_cache: dict = {}
    groups = layer_groups(cfg)
    if cfg.family == "hybrid":
        stacked = params["layer_stacks"][0]
        L, step = cfg.n_layers, cfg.hybrid.attn_every
        emb0 = x
        g0 = cache["g0"]
        sh_new = []
        new_g0_parts = []
        i = si = 0
        while i < L:
            j = min(i + step, L)
            stage = jax.tree.map(lambda a: a[i:j], stacked)
            cstage = jax.tree.map(lambda a: a[i:j], g0)

            def body(xc, inp):
                lp, cl = inp
                xn, cn = _decode_layer(cfg, lp, "mamba2", xc, cl, pos, widx=0)
                return xn, cn

            x, cnew = jax.lax.scan(body, x, (stage, cstage))
            new_g0_parts.append(cnew)
            if cfg.hybrid.concat_embed:
                h_in = jnp.concatenate([x, emb0], axis=-1) \
                    @ params["shared_in"]
            else:
                h_in = x
            csh = jax.tree.map(lambda a: a[si], cache["shared"])
            h_out, kv = _decode_layer(cfg, params["shared_attn"], "dense",
                                      h_in, csh, pos, write_idx)
            sh_new.append(kv)
            x = x + (h_out - h_in)
            i = j
            si += 1
        new_cache["g0"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_g0_parts)
        new_cache["shared"] = jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=0), *sh_new)
    else:
        for gi, ((kind, n), stacked) in enumerate(
                zip(groups, params["layer_stacks"])):
            cross_l = cache.get("cross") if kind == "decoder_cross" else None

            def body(xc, inp, kind=kind, cross_l=cross_l):
                if cross_l is not None:
                    lp, cl, cx = inp
                else:
                    lp, cl = inp
                    cx = None
                xn, cn = _decode_layer(cfg, lp, kind, xc, cl, pos,
                                       write_idx, cx)
                return xn, cn

            xs = (stacked, cache[f"g{gi}"])
            if cross_l is not None:
                xs = xs + ({"k": cross_l["k"], "v": cross_l["v"],
                            "valid": jnp.broadcast_to(
                                cross_l["valid"][None],
                                (n,) + cross_l["valid"].shape)},)
            x, cnew = jax.lax.scan(body, x, xs)
            new_cache[f"g{gi}"] = cnew
        if "cross" in cache:
            new_cache["cross"] = cache["cross"]

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(params["embed"], params.get("lm_head"), x)
    return shard_logits(logits)[:, 0], new_cache


# ---------------------------------------------------------------------------
# Deprecated free-function surface (one release) — use DecodeSession
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, buf_len: int,
               enc_len: int = 0) -> dict:
    """Deprecated: use ``serve.session.DecodeSession.create`` instead."""
    warnings.warn(
        "serve.decode.init_cache is deprecated and will be removed next "
        "release; use serve.session.DecodeSession.create(cfg, params, "
        "batch=..., buf_len=...)", DeprecationWarning, stacklevel=2)
    return _init_cache(cfg, batch, buf_len, enc_len)


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, pos: jax.Array, write_idx: jax.Array
                ) -> tuple[jax.Array, dict]:
    """Deprecated: use ``serve.session.DecodeSession.step`` instead."""
    warnings.warn(
        "serve.decode.decode_step is deprecated and will be removed next "
        "release; use serve.session.DecodeSession.step(tokens)",
        DeprecationWarning, stacklevel=2)
    return _decode_step(cfg, params, cache, tokens, pos, write_idx)
