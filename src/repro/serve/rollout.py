"""K-branch rollout groups over one shared-prefix DecodeSession.

``rollout_group`` is the generation half of the RL loop (paper §2's
model-update phase): prefill the common prompt ONCE through the tree
kernels' parallel path, ``fork`` K branch tails off the cached prefix,
decode the branches in lockstep, score them, and merge the group back
into a single advantage-weighted :class:`TrajectoryTree` via
``rollouts_to_tree`` — the exact tree shape the training engine ingests.

The session's token accounting is returned per group: ``prefill_tokens``
must equal the prompt length (not K× it) — the proof, asserted by the
``rl_service`` benchmark, that the shared prefix is computed exactly
once per group no matter how many branches reuse it.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.tree import TrajectoryTree
from repro.serve.decode import rollouts_to_tree
from repro.serve.session import DecodeSession


@dataclass(frozen=True)
class RolloutConfig:
    """Shape of one rollout group."""
    k: int = 4                        # branches per prompt
    prompt_len: int = 12
    max_new: int = 16                 # decode steps per branch
    temperature: float = 1.0          # 0 → greedy (all branches collapse)
    eos_token: Optional[int] = None   # truncate a branch after this token
    impl: str = "ref"                 # attention impl for the prefill pass

    @property
    def buf_len(self) -> int:
        return self.prompt_len + self.max_new


@dataclass
class GroupStats:
    """Per-group compute accounting (from the shared SessionStats)."""
    k: int
    prompt_len: int
    prefill_tokens: int      # prefix positions actually computed
    decode_tokens: int       # branch steps × branches
    rewards: list

    @property
    def saved_prefill_tokens(self) -> int:
        """Prefix tokens NOT recomputed thanks to the shared-KV fork."""
        return self.k * self.prompt_len - self.prefill_tokens


def sample_tokens(logits: jax.Array, vocab_size: int, key,
                  temperature: float) -> jax.Array:
    """Sample one token per row from [B, padded_vocab] logits; the
    padding columns (≥ vocab_size) are masked out before sampling."""
    logits = jnp.where(jnp.arange(logits.shape[-1]) < vocab_size,
                       logits, -jnp.inf)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature,
                                  axis=-1).astype(jnp.int32)


@lru_cache(maxsize=32)
def _decode_scan(cfg: ModelConfig, steps: int, temperature: float):
    """One jitted sample-decode loop: ``steps`` lockstep branch tokens per
    dispatch instead of one dispatch per token — the rollout loop is
    latency-bound by host dispatch on small models, not FLOPs."""
    from repro.serve.decode import _decode_step

    def run(params, cache, t0, tok0, key):
        # KV ring length, robust to cache layout (hybrid/SSM groups may
        # carry no "pos"; pure-SSM caches have no ring at all) — mirrors
        # DecodeSession._ring
        ring = 1
        for grp in cache.values():
            if isinstance(grp, dict) and "pos" in grp:
                ring = grp["pos"].shape[2]
                break
        K = tok0.shape[0]

        def body(carry, i):
            cache, tok, key = carry
            pos = jnp.full((K,), t0 + i, jnp.int32)
            logits, cache = _decode_step(cfg, params, cache, tok[:, None],
                                         pos, (t0 + i) % ring)
            key, sub = jax.random.split(key)
            nxt = sample_tokens(logits, cfg.vocab_size, sub, temperature)
            return (cache, nxt, key), tok

        (cache, tok, _), toks = jax.lax.scan(
            body, (cache, tok0, key), jnp.arange(steps - 1, dtype=jnp.int32))
        # toks[i] is the token FED at step i (= generated token i); the
        # final carry holds generated token steps−1
        return jnp.concatenate([toks, tok[None]], axis=0), cache

    # the cache (arg 1) is a fork's freshly tiled buffers and the caller
    # reassigns ``branches.cache`` to the scan's output — donating it lets
    # XLA run the whole decode loop in-place in one cache's worth of HBM
    return jax.jit(run, donate_argnums=(1,))


def default_reward(seq: np.ndarray, prompt_len: int) -> float:
    """Deterministic toy reward: mean residue of the completion tokens.
    Content-dependent, so a sampled group gets reward variance, while
    identical rollouts get identical rewards (zero advantage)."""
    comp = np.asarray(seq)[prompt_len:]
    if comp.size == 0:
        return 0.0
    return float(np.mean(comp % 7)) / 6.0


def rollout_group(cfg: ModelConfig, params: dict, prompt, rc: RolloutConfig,
                  key, reward_fn: Callable[[np.ndarray, int], float]
                  = default_reward) -> tuple[TrajectoryTree, GroupStats]:
    """Decode ``rc.k`` branch rollouts of ``prompt`` and merge them into
    one advantage tree.

    ``prompt``: 1-D int tokens (length rc.prompt_len); ``key``: jax PRNG
    key.  Returns ``(tree, stats)`` — train the tree with
    ``loss_mode="rl"``."""
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    P, K = len(prompt), rc.k
    session = DecodeSession.create(cfg, params, buf_len=rc.buf_len)
    logits = session.prefill(prompt, impl=rc.impl)      # prefix: ONCE
    branches = session.fork(K)                          # KV reuse, no FLOPs

    # first branch token: K independent samples from the one prefill row
    key, sub = jax.random.split(key)
    tok = sample_tokens(jnp.broadcast_to(logits, (K, logits.shape[-1])),
                        cfg.vocab_size, sub, rc.temperature)
    # the decode loop runs as ONE fused scan dispatch per group; the
    # session's cursor/cache/stats are advanced to match
    toks, cache = _decode_scan(cfg, rc.max_new, rc.temperature)(
        params, branches.cache, jnp.asarray(branches.t, jnp.int32),
        tok, key)
    branches.cache = cache
    branches.t += rc.max_new - 1
    branches.stats.decode_tokens += K * (rc.max_new - 1)
    gen = np.asarray(toks).T                            # [K, max_new]

    seqs, rewards = [], []
    for kk in range(K):
        comp = gen[kk]
        if rc.eos_token is not None:
            hits = np.nonzero(comp == rc.eos_token)[0]
            if hits.size:
                comp = comp[:hits[0] + 1]               # keep the eos
        seq = np.concatenate([prompt, comp])
        seqs.append(seq)
        rewards.append(reward_fn(seq, P))
    tree = rollouts_to_tree(seqs, rewards, prompt_len=P)
    stats = GroupStats(k=K, prompt_len=P,
                       prefill_tokens=session.stats.prefill_tokens,
                       decode_tokens=session.stats.decode_tokens,
                       rewards=rewards)
    return tree, stats
