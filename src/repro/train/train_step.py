"""Train step primitives: tree-training and baseline modes behind one
interface.

``make_train_step(cfg, opt_cfg, impl)`` returns a jit-able
``(params, opt_state, batch) → (params, opt_state, metrics)``.  Whether a
step is "tree" or "baseline" is decided purely by how the batch was packed
(core/packing.pack_trees vs pack_linear_paths) — the model code is shared,
which is what makes the speedup comparison apples-to-apples.

The production trainer composes these pieces differently: the unified
plan→execute engine (train/engine.py) accumulates per-microbatch grads
on-device and applies ``jitted_update`` — the AdamW executable cached per
OptimizerConfig below.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax

from repro.configs.base import ModelConfig
from repro.models.model import loss_and_metrics
from repro.train.optimizer import OptimizerConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    impl: str = "ref", donate: bool = True):
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_and_metrics(cfg, p, batch, impl), has_aux=True)(
                params)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {**metrics, **opt_metrics, "total": loss}

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def make_grad_fn(cfg: ModelConfig, impl: str = "ref"):
    """Gradient-only fn (for accumulation / partitioned drivers)."""
    def gfn(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_and_metrics(cfg, p, batch, impl),
            has_aux=True)(params)
        return loss, grads, metrics

    return jax.jit(gfn)


@lru_cache(maxsize=16)
def jitted_update(opt_cfg: OptimizerConfig, donate: bool = False):
    """The jitted AdamW update, cached per (OptimizerConfig, donate) —
    tracing once instead of on every call.  ``donate=True`` donates
    (params, grads, opt_state) for in-place buffer reuse; this is the
    cache the unified engine (train/engine.py) uses too.

    Signature of the returned fn: ``(params, grads, opt_state) →
    (new_params, new_opt_state, metrics)``."""
    fn = partial(adamw_update, opt_cfg)
    return jax.jit(fn, donate_argnums=(0, 1, 2) if donate else ())


def apply_grads(opt_cfg: OptimizerConfig, params, opt_state, grads):
    return jitted_update(opt_cfg)(params, grads, opt_state)
