"""Signature-keyed AOT executable cache — the runtime half of the
compile-signature story (ROADMAP item 4).

``analysis/signatures`` proves a planner run stays inside an enumerable
pow2-bucket :class:`SignatureUniverse`; this module holds the compiled
artifacts for that universe.  Every engine dispatch variant (packed
microbatch with/without accumulator, wave forward/backward, optimizer
update) is keyed by

    (variant, signature, arg fingerprint)

where the *signature* is the planner-level shape bucket
(``core/plan_cost.packed_signature`` / ``wave_signature``) and the
*fingerprint* pins the residual aval structure the signature does not
capture (exact leaf shapes/dtypes and the pytree layout — e.g. an SSM
conv tail shorter than the tap count on an unusually short ancestor
path).  A hit returns a ``jax.stages.Compiled`` the engine calls
directly — no tracing, no XLA compile, no stall; a miss falls back to a
synchronous ``lower().compile()`` the engine counts as a retrace.

MaxText's bucketed-executable-cache idiom (``offline_inference.py``):
the warmup service (``train/warmup``) fills this cache ahead of time on
background threads, and the planner pre-warms exact upcoming shapes from
its build workers, so by the time ``TreeTrainEngine.step`` looks a key
up the executable is already here.

Thread-safe; imports jax only (no model/engine deps) so every layer can
share it without cycles.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Hashable, Optional

import jax


def abstractify(x):
    """Pytree of arrays/np scalars → ShapeDtypeStructs (non-array leaves
    pass through: python ints become weak-typed traced scalars, matching
    what a real dispatch traces)."""
    def one(leaf):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)
        return leaf
    return jax.tree.map(one, x)


def arg_fingerprint(args: tuple) -> Hashable:
    """Structural fingerprint of a call's positional args: the pytree
    layout plus every array leaf's (shape, dtype).  Non-array leaves
    (python ints — e.g. the batch's ``num_trees``) fingerprint by *type*,
    not value: jit traces them as weak-typed scalars, so one executable
    serves every value.  Two calls with equal fingerprints trace to the
    same avals, hence dispatch the same compiled executable."""
    leaves, treedef = jax.tree.flatten(args)

    def one(leaf):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            return (tuple(leaf.shape), str(leaf.dtype))
        return ("py", type(leaf).__name__)

    return (treedef, tuple(one(leaf) for leaf in leaves))


def exec_key(variant: str, sig: Hashable, args: tuple) -> Hashable:
    """The cache key one engine dispatch resolves to."""
    return (variant, sig, arg_fingerprint(args))


class ExecutableCache:
    """Thread-safe {exec_key: jax.stages.Compiled} with hit/miss/compile
    accounting.  One instance is shared by the warmup service (producer),
    the planner's pre-warm hook (producer, on build threads) and the
    engine (consumer) — ``compile_once`` makes concurrent fills of the
    same key idempotent (both threads compile, one insert wins; XLA's
    own in-process cache dedups the backend work)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._store: dict[Hashable, Any] = {}
        self.hits = 0
        self.misses = 0            # lookups that found nothing
        self.inserts = 0           # distinct executables cached
        self.compile_s = 0.0       # total seconds spent compiling into
        #                            this cache, across all threads

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._store

    def signatures(self) -> set:
        """The distinct planner-level signatures currently compiled."""
        with self._lock:
            return {k[1] for k in self._store}

    def get(self, key: Hashable):
        with self._lock:
            c = self._store.get(key)
            if c is None:
                self.misses += 1
            else:
                self.hits += 1
            return c

    def put(self, key: Hashable, compiled) -> bool:
        """Insert; returns False if the key was already present (the
        existing executable is kept — first insert wins)."""
        with self._lock:
            if key in self._store:
                return False
            self._store[key] = compiled
            self.inserts += 1
            return True

    def compile_once(self, key: Hashable, fn, args: tuple) -> tuple[Any, bool]:
        """Lower+compile ``fn`` on (abstract or concrete) ``args`` and
        cache it under ``key``; a no-op returning the cached executable
        if the key is already filled.  Returns (compiled, was_new)."""
        with self._lock:
            c = self._store.get(key)
        if c is not None:
            return c, False
        t0 = time.perf_counter()
        compiled = fn.lower(*args).compile()
        dt = time.perf_counter() - t0
        with self._lock:
            if key in self._store:
                return self._store[key], False
            self._store[key] = compiled
            self.inserts += 1
            self.compile_s += dt
            return compiled, True

    def stats(self) -> dict:
        with self._lock:
            return dict(size=len(self._store), hits=self.hits,
                        misses=self.misses, inserts=self.inserts,
                        compile_s=self.compile_s)


ExecLookup = Callable[[str, Hashable, Any, tuple], Any]


def make_lookup(cache: Optional[ExecutableCache]) -> Optional[ExecLookup]:
    """A bare (variant, sig, fn, args) → callable resolver over a cache,
    for callers outside the engine (no retrace accounting): hit returns
    the compiled executable, miss compiles synchronously and fills."""
    if cache is None:
        return None

    def lookup(variant: str, sig: Hashable, fn, args: tuple):
        compiled, _ = cache.compile_once(exec_key(variant, sig, args), fn,
                                         args)
        return compiled

    return lookup
