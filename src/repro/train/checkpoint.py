"""Checkpointing: flat npz + JSON manifest (offline-friendly, no orbax).

Single-host implementation; on a real multi-host pod each process would
write its addressable shards (the manifest format already records the
flattened key paths needed to reassemble).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, params: Any, opt_state: Any = None,
                    meta: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, "opt_state.npz"), **_flatten(opt_state))
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"meta": meta or {},
                   "keys": sorted(_flatten(params))}, f, indent=1)


def load_meta(path: str) -> dict:
    """The manifest's ``meta`` dict (e.g. ``steps`` for mid-stream
    resume)."""
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f).get("meta", {})


def load_checkpoint(path: str, params_like: Any,
                    opt_state_like: Any = None):
    """Restore into the structure of ``params_like`` (shape/dtype checked)."""
    def restore(npz_path, like):
        data = np.load(npz_path)
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in flat:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = data[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape,
                                                    leaf.shape)
            leaves.append(jnp.asarray(arr, leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    params = restore(os.path.join(path, "params.npz"), params_like)
    if opt_state_like is None:
        return params
    opt = restore(os.path.join(path, "opt_state.npz"), opt_state_like)
    return params, opt
