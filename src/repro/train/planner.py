"""Plan-ahead scheduler: global cost-model-driven Tree Packing.

The paper's Tree Packing preserves prefix reuse *within* a step; this
module owns everything above it — the schedule level:

  lookahead packing   trees from a window of ``lookahead`` generator
                      batches are bin-packed **globally** into the
                      window's steps (candidate heuristics scored by
                      ``core/plan_cost``), instead of first-fit inside
                      each batch — holes left by one batch are filled by
                      the next one's trees;
  forest grafting     with ``PlannerConfig.graft``, trees whose heads
                      share a ≥ ``min_graft`` token prefix *across* the
                      window are merged into grafted forests
                      (``core/forest``) so each cross-tree prefix is
                      computed once per window; every merge is gated by
                      the cost model's dedup term
                      (``plan_cost.graft_gain``) and the loss stays a
                      mean over SOURCE trees (``n_src`` rides through
                      FitTree/OversizedTree into the normalizer);
  auto capacity       with ``LoaderConfig.auto_capacity`` the partition
                      token cap is chosen per window from
                      ``core/partition.choose_capacity`` (pow2 fractions
                      of seq_len scored by ``partition_schedule_load``)
                      instead of a user-fixed ``--capacity``;
  replica balance     every emitted batch's row count is a multiple of
                      the mesh data-axis size and rows are permuted so
                      contiguous per-replica shards carry non-empty-row
                      counts within 1 of each other (token loads dealt
                      snake-wise); partition waves round their bucketed
                      row counts the same way;
  oversized balance   trees routed to Redundancy-Free Tree Partitioning
                      are spread across the window's steps by their
                      partitioned token load (each tree is partitioned
                      exactly ONCE — the forest is reused by
                      ``core/gateway.build_partition_plan``);
  async pipeline      ``PlanPipeline`` double-buffers the host-side numpy
                      plan construction against ``TreeTrainEngine.step``
                      on background threads, so the device never waits on
                      packing; it tracks built vs *exposed* (consumer-
                      visible) plan-build time.

Invariants (property-tested in tests/test_planner.py):
  - token conservation: every generated tree is packed, partitioned, or
    counted in ``dropped`` — Σ unique tokens is preserved;
  - parents never schedule later than children (wave topology);
  - per-replica row-load imbalance ≤ 1 non-empty row.

``data/loader.py`` shrank to tree ingestion; its ``step_batches`` /
``execution_plans`` wrappers are deprecated in favour of :func:`plans`,
which also accepts a *live* source (the async rollout service) in place
of a batch count.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field, replace
from itertools import islice
from typing import Any, Callable, Iterable, Iterator, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.forest import graft_trees
from repro.core.packing import (DoesNotFitError, pack_linear_paths,
                                materialize_tree_rows)
from repro.core.partition import (TreePartition, choose_capacity,
                                  partition_schedule_load, partition_tree)
from repro.core.plan_cost import (DEFAULT_WEIGHTS, CompileCacheSim,
                                  CostWeights, PackingCost,
                                  _packing_live_blocks, balanced_row_order,
                                  graft_gain, packed_signature, pow2,
                                  round_to_multiple, score_packing)
from repro.core.tree import TrajectoryTree, serialize_tree
from repro.data.loader import LoaderConfig, StepBatch, tree_stream
from repro.models.model import needs_chunks, prepare_batch


@dataclass(frozen=True)
class PlannerConfig:
    """Schedule-level knobs (the data-level ones live in LoaderConfig)."""
    lookahead: int = 1            # generator batches planned jointly
    plan_workers: int = 0         # background plan builders (0 = sync)
    num_replicas: int = 1         # mesh data-axis size (row multiples)
    heuristics: tuple = ("ffd", "bfd")   # candidate packings to score
    block: int = 64               # kernel block for the skip estimate
    weights: CostWeights = DEFAULT_WEIGHTS
    max_rows: Optional[int] = None  # wave row cap (None: batch_rows)
    pipeline_depth: int = 2       # plans buffered ahead (double buffer)
    graft: bool = False           # cross-tree forest grafting (core/forest)
    min_graft: int = 16           # min shared-prefix tokens worth a graft


@dataclass
class FitTree:
    """One row-sized tree with its serialization artifacts, computed ONCE
    for the whole schedule (fit filter, candidate packings, eviction
    retries and materialization all reuse it)."""
    tree: TrajectoryTree
    ser: Any                      # SerializedTree (loss_mode applied)
    paths: list[dict]             # linearize_paths() output
    n_unique: int
    src: int                      # source generator batch (step index)
    n_src: int = 1                # source trees this entry represents
    lam_map: Optional[dict] = None  # grafted forest: id(node) → λ


@dataclass
class OversizedTree:
    """A tree routed to the partitioned driver, with its partition forest
    computed lazily and exactly once (build_partition_plan reuses it)."""
    tree: TrajectoryTree
    src: int
    parts: Optional[list[TreePartition]] = None
    n_src: int = 1                # source trees (grafted forests > 1)
    lam_map: Optional[dict] = None  # grafted forest: id(node) → λ

    def forest(self, capacity: int, chunk: Optional[int],
               loss_mode: str) -> list[TreePartition]:
        if self.parts is None:
            self.parts = partition_tree(self.tree, capacity,
                                        chunk_size=chunk,
                                        loss_mode=loss_mode,
                                        lam_map=self.lam_map)
        return self.parts

    def load(self, capacity: int, chunk: Optional[int],
             loss_mode: str) -> int:
        return partition_schedule_load(
            self.forest(capacity, chunk, loss_mode))["tokens"]


# ---------------------------------------------------------------------------
# Window scheduling: global bin packing + cost-model candidate choice
# ---------------------------------------------------------------------------

def _fit_split(trees: Sequence[TrajectoryTree], seq_len: int,
               chunk: Optional[int], loss_mode: str, src: int
               ) -> tuple[list[FitTree], list[OversizedTree]]:
    """Split one generator batch into row-sized FitTrees and oversized
    trees.  The filter checks BOTH serializations so tree and baseline
    modes see the exact same dataset — step-wise loss comparisons stay
    pure.  Each tree is serialized exactly once."""
    keep: list[FitTree] = []
    over: list[OversizedTree] = []
    for t in trees:
        ser = serialize_tree(t, chunk_size=chunk, loss_mode=loss_mode)
        paths = t.linearize_paths()
        n_path = max(len(p["tokens"]) for p in paths)
        if chunk:
            n_path = ((n_path + chunk - 1) // chunk) * chunk
        if max(ser.n, n_path) <= seq_len:
            keep.append(FitTree(tree=t, ser=ser, paths=paths,
                                n_unique=t.num_unique_tokens(), src=src))
        else:
            over.append(OversizedTree(tree=t, src=src))
    return keep, over


def _graft_fits(fits: list[FitTree], lc: LoaderConfig, pc: PlannerConfig,
                chunk: Optional[int], cap: int
                ) -> tuple[list[FitTree], list[OversizedTree]]:
    """Cross-tree forest grafting over the window's row-sized trees
    (``core/forest``): merge shared heads so each cross-tree prefix is
    computed once per window.  Every candidate is gated by the cost
    model's dedup term (``plan_cost.graft_gain`` on serialized, i.e.
    chunk-padded, lengths) — a losing graft falls back to its sources
    untouched.  A winning graft that no longer fits a packed row routes
    to Redundancy-Free Tree Partitioning like any oversized tree, its
    λ map and source count riding along.

    Oversized candidates are refined by recursive bisection: any
    consecutive slice of a graft group still shares the ≥ ``min_graft``
    prefix (groups are maximal runs in member-sorted order), so a group
    whose merged forest overflows the row is split in half and re-grafted
    whenever the halves' summed gain beats the whole — trading a little
    prefix redundancy (the prefix is computed once per slice) for
    row-sized forests that pack without partition-wave padding."""

    def gain_of(srcs: list[int], ser_n: int,
                parts: Optional[int] = None) -> float:
        return graft_gain(sum(fits[i].ser.n for i in srcs), ser_n,
                          lc.seq_len, cap, pc.weights, parts=parts)

    def plan_slice(srcs: list[int]) -> tuple[float, list]:
        """Best placement of a consecutive member slice: (gain,
        placements), a placement being a passthrough fit index or a
        (graft, ser, window-indices) triple."""
        if len(srcs) == 1:
            return 0.0, [srcs[0]]
        gs, ps = graft_trees([fits[i].tree for i in srcs],
                             loss_mode=lc.loss_mode,
                             min_graft=pc.min_graft)
        gain_tot: float = 0.0
        placed: list = [srcs[j] for j in ps]
        for g2 in gs:
            gsrcs = [srcs[j] for j in g2.srcs]
            ser = serialize_tree(g2.tree, chunk_size=chunk,
                                 lam_map=g2.lam_map)
            parts = (len(partition_tree(g2.tree, cap, chunk_size=chunk,
                                        lam_map=g2.lam_map))
                     if ser.n > lc.seq_len else None)
            whole = gain_of(gsrcs, ser.n, parts)
            best: tuple[float, list] = ((whole, [(g2, ser, gsrcs)])
                                        if whole > 0 else (0.0, gsrcs))
            if ser.n > lc.seq_len and len(gsrcs) >= 2:
                mid = len(gsrcs) // 2
                gl, pl = plan_slice(gsrcs[:mid])
                gr, pr = plan_slice(gsrcs[mid:])
                if gl + gr > best[0]:
                    best = (gl + gr, pl + pr)
            gain_tot += best[0]
            placed += best[1]
        return gain_tot, placed

    grafts, passthrough = graft_trees(
        [f.tree for f in fits], loss_mode=lc.loss_mode,
        min_graft=pc.min_graft)
    out = [fits[i] for i in passthrough]
    over: list[OversizedTree] = []
    for g in grafts:
        _, placed = plan_slice(g.srcs)
        for p in placed:
            if isinstance(p, int):
                out.append(fits[p])
                continue
            g2, ser, gsrcs = p
            src = min(fits[i].src for i in gsrcs)
            n_src = sum(fits[i].n_src for i in gsrcs)
            if ser.n <= lc.seq_len:
                out.append(FitTree(tree=g2.tree, ser=ser, paths=[],
                                   n_unique=int(ser.valid.sum()), src=src,
                                   n_src=n_src, lam_map=g2.lam_map))
            else:
                over.append(OversizedTree(tree=g2.tree, src=src,
                                          n_src=n_src,
                                          lam_map=g2.lam_map))
    return out, over


def _assign_window(sizes: Sequence[int], num_steps: int, rows_per_step: int,
                   seq_len: int, heuristic: str
                   ) -> tuple[Optional[list[list[list[int]]]], Optional[int]]:
    """Global bin packing of the window's trees into ``num_steps`` steps of
    ``rows_per_step`` rows each (largest-first).  Returns (per-step rows
    of item indices, None) on success, or (None, i) where i is the first
    item that found no slot — since placement is largest-first, i is the
    largest *unplaceable* item, the right eviction victim (everything
    bigger provably fits and keeps training)."""
    rows: list[list[list[int]]] = [[] for _ in range(num_steps)]
    used: list[list[int]] = [[] for _ in range(num_steps)]
    order = sorted(range(len(sizes)), key=lambda i: (-sizes[i], i))
    for i in order:
        n = sizes[i]
        if n > seq_len:
            return None, i
        best: Optional[tuple[int, int]] = None
        for s in range(num_steps):
            for r, u in enumerate(used[s]):
                if u + n > seq_len:
                    continue
                if heuristic == "ffd":
                    best = (s, r)
                    break
                if best is None or u > used[best[0]][best[1]]:
                    best = (s, r)       # bfd: tightest fitting row
            if heuristic == "ffd" and best is not None:
                break
        if best is None:
            for s in range(num_steps):
                if len(rows[s]) < rows_per_step:
                    best = (s, len(rows[s]))
                    rows[s].append([])
                    used[s].append(0)
                    break
            if best is None:
                return None, i
        s, r = best
        rows[s][r].append(i)
        used[s][r] += n
    return rows, None


def _score_window(steps_rows: list[list[list[int]]],
                  sizes: Sequence[int], rows_per_step: int, seq_len: int,
                  cache: CompileCacheSim, pc: PlannerConfig
                  ) -> tuple[PackingCost, list]:
    """Cost of one candidate window schedule: every non-empty step
    materializes ``rows_per_step`` rows (empty rows pad to the fixed
    batch), one packed jit signature per non-empty step."""
    row_sizes: list[list[int]] = []
    sigs = []
    for rows in steps_rows:
        if not any(rows):
            continue
        row_sizes.extend([sizes[i] for i in r] for r in rows)
        row_sizes.extend([] for _ in range(rows_per_step - len(rows)))
        sigs.append(packed_signature(rows_per_step, seq_len))
    cost = score_packing(row_sizes, seq_len, block=pc.block,
                         signatures=sigs, cache=cache,
                         weights=pc.weights)
    return cost, sigs


def _schedule_tree_window(
    fits: list[FitTree], num_steps: int, rows_per_step: int, seq_len: int,
    cache: CompileCacheSim, pc: PlannerConfig,
) -> tuple[list[list[list[int]]], list[int], Optional[PackingCost]]:
    """Choose the window's packed schedule: try every candidate heuristic
    on the current fit set, score the feasible ones with the cost model,
    and evict only when NO candidate can hold everything.  The victim is
    the largest item the candidates could not place — NOT the globally
    largest tree, which provably fits and keeps training (evicting it
    could pack *less* data than per-step greedy would).  Returns
    (per-step rows of fit indices, evicted fit indices, winning cost)."""
    active = list(range(len(fits)))
    evicted: list[int] = []
    while active:
        sizes = [fits[i].ser.n for i in range(len(fits))]
        cands = []
        blocked: list[int] = []
        for h in pc.heuristics:
            sub, stuck = _assign_window([sizes[i] for i in active],
                                        num_steps, rows_per_step, seq_len,
                                        h)
            if sub is not None:
                remap = [[[active[i] for i in r] for r in rows]
                         for rows in sub]
                cands.append(remap)
            else:
                blocked.append(active[stuck])
        if cands:
            best = None
            for steps_rows in cands:
                cost, sigs = _score_window(steps_rows, sizes,
                                           rows_per_step, seq_len, cache,
                                           pc)
                if best is None or cost.total < best[0].total:
                    best = (cost, sigs, steps_rows)
            cache.commit(best[1])
            return best[2], evicted, best[0]
        big = max(blocked, key=lambda i: (fits[i].n_unique, i))
        active.remove(big)
        evicted.append(big)
    return [[] for _ in range(num_steps)], evicted, None


def _permute_tb_rows(tb, order: Sequence[int]):
    """Reorder a TreeBatch's rows (replica load balancing is a pure row
    permutation — per-row metadata is row-local, so gradients are
    unchanged)."""
    if list(order) == list(range(len(order))):
        return tb
    idx = np.asarray(order)
    sl = lambda a: None if a is None else a[idx]
    from repro.core.packing import TreeBatch
    return TreeBatch(tokens=tb.tokens[idx], pos_ids=tb.pos_ids[idx],
                     kv_last=tb.kv_last[idx], weight=tb.weight[idx],
                     prev_idx=tb.prev_idx[idx], valid=tb.valid[idx],
                     chunk_parent=sl(tb.chunk_parent),
                     num_trees=tb.num_trees,
                     extra_embeds=sl(tb.extra_embeds),
                     row_trees=sl(tb.row_trees))


# ---------------------------------------------------------------------------
# Planned steps (host-side schedule → materialized batches/plans)
# ---------------------------------------------------------------------------

@dataclass
class PlannedStep:
    """One optimizer step's schedule.  ``step_batch()`` materializes the
    packed rows (numpy + model inputs); ``execution_plan()`` additionally
    builds the partition waves — both are cached, so the two loader
    wrappers share one materialization."""
    cfg: ModelConfig
    lc: LoaderConfig
    pc: PlannerConfig
    index: int                        # source batch / step index
    fits: list[FitTree] = field(default_factory=list)
    rows: list[list[int]] = field(default_factory=list)  # idx into fits
    oversized: list[OversizedTree] = field(default_factory=list)
    dropped: int = 0
    cost: Optional[PackingCost] = None
    capacity: Optional[int] = None    # resolved partition cap (auto mode)
    baseline_tb: Any = None           # baseline mode pre-packs paths
    _sb: Optional[StepBatch] = None
    _plan: Any = None

    @property
    def num_trees(self) -> int:
        # SOURCE trees, not schedule entries: a grafted forest carries
        # n_src members and the loss stays a mean over source trees
        return (sum(f.n_src for f in self.fits)
                + sum(o.n_src for o in self.oversized))

    @property
    def is_empty(self) -> bool:
        return (not self.fits and not self.oversized
                and self.dropped == 0)

    # -- packed rows -------------------------------------------------------
    def step_batch(self) -> StepBatch:
        if self._sb is not None:
            return self._sb
        cfg, lc, pc = self.cfg, self.lc, self.pc
        chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
        tb = None
        if self.baseline_tb is not None:
            # baseline rows get the same replica balance as tree rows
            tb = _permute_tb_rows(
                self.baseline_tb,
                balanced_row_order(
                    [int(v) for v in self.baseline_tb.valid.sum(axis=1)],
                    pc.num_replicas))
        elif any(self.rows):
            B = round_to_multiple(lc.batch_rows, pc.num_replicas)
            rows = [list(r) for r in self.rows]
            rows.extend([] for _ in range(B - len(rows)))
            loads = [sum(self.fits[i].ser.n for i in r) for r in rows]
            order = balanced_row_order(loads, pc.num_replicas)
            rows = [rows[r] for r in order]
            tb = materialize_tree_rows(
                [f.ser for f in self.fits], rows, lc.seq_len,
                chunk_size=chunk,
                tree_counts=[f.n_src for f in self.fits])
        inputs = None
        if tb is not None:
            extra = None
            if cfg.frontend is not None:
                rng = np.random.default_rng(
                    [lc.seed, 7919, self.index])
                extra = rng.normal(
                    size=(tb.tokens.shape[0], cfg.frontend_len,
                          cfg.d_model)).astype(np.float32)
            # normalize by the step's FULL tree count: oversized trees on
            # the partition waves share this step's mean-over-trees loss
            inputs = prepare_batch(
                cfg, tb, extra,
                num_trees=self.num_trees if self.oversized else None)
        self._sb = StepBatch(inputs=inputs, tb=tb,
                             oversized=[o.tree for o in self.oversized],
                             dropped=self.dropped,
                             num_trees=self.num_trees)
        return self._sb

    # -- full execution plan ----------------------------------------------
    def execution_plan(self):
        if self._plan is not None:
            return self._plan
        from repro.core.gateway import build_partition_plan
        from repro.train.engine import ExecutionPlan, PackedExec

        cfg, lc, pc = self.cfg, self.lc, self.pc
        chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
        cap = self.capacity or lc.capacity or lc.seq_len
        sb = self.step_batch()
        packed = None
        if sb.inputs is not None:
            B, S = sb.tb.tokens.shape
            packed = PackedExec(inputs=sb.inputs,
                                tokens=int(sb.tb.valid.sum()),
                                cells=B * S)
        partition = None
        if self.oversized:
            partition = build_partition_plan(
                cfg, [o.tree for o in self.oversized], cap,
                seq_len=lc.seq_len, loss_mode=lc.loss_mode,
                max_rows=(pc.max_rows if pc.max_rows is not None
                          else lc.batch_rows),
                row_multiple=pc.num_replicas,
                forest=[o.forest(cap, chunk, lc.loss_mode)
                        for o in self.oversized])
        vers = [v for v in
                (getattr(f.tree, "weight_version", None)
                 for f in self.fits)
                if v is not None]
        vers += [v for v in
                 (getattr(o.tree, "weight_version", None)
                  for o in self.oversized)
                 if v is not None]
        self._plan = ExecutionPlan(packed=packed, partition=partition,
                                   num_trees=self.num_trees,
                                   dropped=self.dropped,
                                   versions=((min(vers), max(vers))
                                             if vers else None))
        return self._plan


# ---------------------------------------------------------------------------
# The schedule stream
# ---------------------------------------------------------------------------

def plan_window(cfg: ModelConfig, lc: LoaderConfig, pc: PlannerConfig,
                window: Sequence[Sequence[TrajectoryTree]],
                cache: Optional[CompileCacheSim] = None,
                first_index: int = 0) -> list[PlannedStep]:
    """Schedule one lookahead window (``window[b]`` = generator batch b's
    trees) into ``len(window)`` PlannedSteps.  Pure host-side decisions —
    nothing is materialized yet."""
    chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    route = lc.auto_partition and lc.mode == "tree"
    cap = lc.capacity or lc.seq_len
    cache = cache if cache is not None else CompileCacheSim()
    W = len(window)
    rows_per_step = round_to_multiple(lc.batch_rows, pc.num_replicas)

    fits: list[FitTree] = []
    over: list[OversizedTree] = []
    for s, trees in enumerate(window):
        f, o = _fit_split(trees, lc.seq_len, chunk, lc.loss_mode,
                          first_index + s)
        fits.extend(f)
        over.extend(o)

    if pc.graft and lc.mode == "tree" and len(fits) > 1:
        fits, grafted_over = _graft_fits(fits, lc, pc, chunk, cap)
        over = over + grafted_over

    if (lc.auto_capacity and lc.capacity is None and route and over):
        # planner-chosen partition capacity, resolved once per window so
        # load balancing and wave building agree (PlannedStep.capacity)
        cap = choose_capacity([o.tree for o in over], lc.seq_len,
                              chunk_size=chunk)

    steps = [PlannedStep(cfg=cfg, lc=lc, pc=pc, index=first_index + s,
                         capacity=cap)
             for s in range(W)]

    if lc.mode == "tree":
        steps_rows, evicted, cost = _schedule_tree_window(
            fits, W, rows_per_step, lc.seq_len, cache, pc)
        over = over + [OversizedTree(tree=fits[i].tree, src=fits[i].src,
                                     n_src=fits[i].n_src,
                                     lam_map=fits[i].lam_map)
                       for i in evicted]
        for s in range(W):
            placed = sorted({i for r in steps_rows[s] for i in r})
            local = {i: j for j, i in enumerate(placed)}
            steps[s].fits = [fits[i] for i in placed]
            steps[s].rows = [[local[i] for i in r]
                             for r in steps_rows[s]]
            steps[s].cost = cost
    else:
        # baseline mode: per-batch path packing (kept comparable with the
        # tree mode stream — no cross-batch shuffling of the baseline)
        by_src: dict[int, list[FitTree]] = {}
        for f in fits:
            by_src.setdefault(f.src, []).append(f)
        for s in range(W):
            kept = sorted(by_src.get(first_index + s, []),
                          key=lambda f: f.n_unique)
            while kept:
                try:
                    steps[s].baseline_tb = pack_linear_paths(
                        [f.paths for f in kept], lc.seq_len,
                        batch_size=rows_per_step, chunk_size=chunk,
                        loss_mode=lc.loss_mode)
                    break
                except DoesNotFitError:
                    over.append(OversizedTree(tree=kept[-1].tree,
                                              src=first_index + s))
                    kept = kept[:-1]
            steps[s].fits = kept

    # ---- oversized routing / drop accounting -----------------------------
    if route:
        if W == 1 or len(over) <= 1:
            for o in over:
                steps[o.src - first_index].oversized.append(o)
        else:
            # balance partitioned token load across the window's steps,
            # steering trees toward steps where their waves reuse an
            # already-live row bucket: a fresh bucket is a fresh wave jit
            # signature, charged at CostWeights.wave_compile just like
            # score_packing charges packed signatures
            R = max(pc.num_replicas, 1)
            mrows = pc.max_rows if pc.max_rows is not None else lc.batch_rows
            max_bucket = R * pow2(-(-mrows // R))
            seen_rows = {s[1] for s in cache.seen if s[0] == "wave"}

            def depth_widths(o: OversizedTree) -> dict[int, int]:
                """Fragments per wave depth of one partitioned tree (the
                forest is cached — build_partition_plan reuses it)."""
                w: dict[int, int] = {}
                dep: dict[int, int] = {}
                for p in o.forest(cap, chunk, lc.loss_mode):
                    d = 0 if p.parent_pid < 0 else dep[p.parent_pid] + 1
                    dep[p.pid] = d
                    w[d] = w.get(d, 0) + 1
                return w

            def row_bucket(n: int) -> int:
                return min(R * pow2(-(-n // R)), max_bucket)

            def fresh_buckets(sw: dict[int, int], w: dict[int, int]) -> int:
                """Row buckets this tree's waves would newly open in a
                step already holding ``sw`` fragments per depth."""
                fresh = 0
                for d, n in w.items():
                    cur = sw.get(d, 0)
                    b = row_bucket(cur + n)
                    if b != (row_bucket(cur) if cur else None) \
                            and b not in seen_rows:
                        fresh += 1
                return fresh

            loads = [0] * W
            step_w: list[dict[int, int]] = [{} for _ in range(W)]
            wave_w = pc.weights.wave_compile
            for o in sorted(over,
                            key=lambda o: -o.load(cap, chunk,
                                                  lc.loss_mode)):
                w = depth_widths(o)
                s = min(range(W),
                        key=lambda s_: (loads[s_]
                                        + wave_w * fresh_buckets(
                                            step_w[s_], w), s_))
                steps[s].oversized.append(o)
                loads[s] += o.load(cap, chunk, lc.loss_mode)
                for d, n in w.items():
                    step_w[s][d] = step_w[s].get(d, 0) + n
    else:
        for o in over:
            steps[o.src - first_index].dropped += o.n_src
    return steps


def plan_stream(cfg: ModelConfig, lc: LoaderConfig,
                source: "int | Iterable[Sequence[TrajectoryTree]]",
                pc: Optional[PlannerConfig] = None, *,
                cache: Optional[CompileCacheSim] = None
                ) -> Iterator[PlannedStep]:
    """The scheduler's main stream: ingest trees, plan each lookahead
    window globally, yield non-empty PlannedSteps in step order.

    ``source`` is either an int — that many synthetic generator batches
    (deterministic in (cfg, lc, seed), the offline path) — or any
    iterable of tree lists, one list per optimizer step: a live rollout
    queue (``serve/service.AsyncTreeRLService.tree_batches``), a dataset
    reader, etc.  A live source is pulled at most ``lookahead`` steps
    ahead of the consumed plan, so the planner adds no extra staleness
    beyond its window.

    ``cache``: an optional shared :class:`CompileCacheSim` — pass the AOT
    warmup service's simulator so the stream's signature commits feed its
    hit-frequency warmup ordering (``train/warmup``)."""
    pc = pc or PlannerConfig()
    cache = cache if cache is not None else CompileCacheSim()
    W = max(1, pc.lookahead)
    if isinstance(source, int):
        gen: Iterator = tree_stream(cfg, lc, source)
        remaining: Optional[int] = source
    else:
        gen = iter(source)
        remaining = None
    first = 0
    while remaining is None or first < remaining:
        n = W if remaining is None else min(W, remaining - first)
        window = [list(trees) for trees in islice(gen, n)]
        if not window:
            break
        for ps in plan_window(cfg, lc, pc, window, cache=cache,
                              first_index=first):
            if not ps.is_empty:
                yield ps
        first += len(window)


# ---------------------------------------------------------------------------
# Async double-buffered plan pipeline
# ---------------------------------------------------------------------------

class PlanPipeline:
    """Builds plans on background threads while the consumer (the train
    loop / engine) executes the previous one — the host-side numpy plan
    construction is double-buffered against device work.

    ``workers=0`` degrades to synchronous in-line building (every
    scheduling/build second is exposed).  With workers ≥ 1, the
    *scheduling* iterator is pulled under its own lock — never the
    result lock, so a long window-scheduling pull cannot block the
    consumer from popping an already-built plan — and the expensive
    materialization (``build``) runs outside both; results are
    re-ordered by sequence number, at most ``depth + workers`` plans
    in flight ahead of the consumer.

    Stats: ``schedule_s`` (source-pull seconds: fit + window packing),
    ``build_s`` (materialization seconds, possibly overlapped),
    ``exposed_s`` (seconds the consumer actually waited), ``built``."""

    def __init__(self, source: Iterable, build: Callable[[Any], Any],
                 workers: int = 1, depth: int = 2):
        self._source = iter(source)
        self._build = build
        self._workers = max(0, workers)
        self._depth = max(1, depth)
        self.schedule_s = 0.0
        self.build_s = 0.0
        self.exposed_s = 0.0
        self.built = 0
        if self._workers:
            self._cv = threading.Condition()
            self._pull_lock = threading.Lock()
            self._results: dict[int, tuple[str, Any]] = {}
            self._next_pull = 0
            self._next_out = 0
            self._exhausted = False
            self._stop = False
            self._threads = [
                threading.Thread(target=self._work, daemon=True,
                                 name=f"plan-builder-{i}")
                for i in range(self._workers)]
            for t in self._threads:
                t.start()

    # -- worker side -------------------------------------------------------
    def _work(self) -> None:
        while True:
            with self._cv:
                while (not self._stop and not self._exhausted
                       and self._next_pull - self._next_out
                       >= self._depth + self._workers):
                    self._cv.wait()
                if self._stop or self._exhausted:
                    return
            # the scheduling pull serializes on its own lock; _cv stays
            # free for consumer pops of already-built plans
            with self._pull_lock:
                with self._cv:
                    if self._stop or self._exhausted:
                        return
                t0 = time.perf_counter()
                try:
                    item = self._source.__next__()
                except StopIteration:
                    with self._cv:
                        self._exhausted = True
                        self._cv.notify_all()
                    return
                except BaseException as e:  # scheduling error: re-raise in order
                    with self._cv:
                        self._results[self._next_pull] = ("err", e)
                        self._next_pull += 1
                        self._exhausted = True
                        self._cv.notify_all()
                    return
                dt = time.perf_counter() - t0
                with self._cv:      # seq assignment in pull order
                    seq = self._next_pull
                    self._next_pull += 1
                    self.schedule_s += dt
            t0 = time.perf_counter()
            try:
                out = ("ok", self._build(item))
            except BaseException as e:
                out = ("err", e)
            dt = time.perf_counter() - t0
            with self._cv:
                self._results[seq] = out
                self.build_s += dt
                self.built += 1
                self._cv.notify_all()

    def close(self) -> None:
        if self._workers:
            with self._cv:
                self._stop = True
                self._cv.notify_all()

    # -- consumer side -----------------------------------------------------
    def __iter__(self) -> Iterator:
        if self._workers == 0:
            while True:
                t0 = time.perf_counter()
                try:
                    item = self._source.__next__()
                except StopIteration:
                    return
                t1 = time.perf_counter()
                plan = self._build(item)
                t2 = time.perf_counter()
                self.schedule_s += t1 - t0
                self.build_s += t2 - t1
                self.exposed_s += t2 - t0
                self.built += 1
                yield plan
        try:
            while True:
                t0 = time.perf_counter()
                with self._cv:
                    while (self._next_out not in self._results
                           and not (self._exhausted
                                    and self._next_pull <= self._next_out)):
                        self._cv.wait()
                    self.exposed_s += time.perf_counter() - t0
                    if self._next_out not in self._results:
                        return                      # stream exhausted
                    kind, val = self._results.pop(self._next_out)
                    self._next_out += 1
                    self._cv.notify_all()
                if kind == "err":
                    raise val
                yield val
        finally:
            self.close()


def plans(cfg: ModelConfig, lc: LoaderConfig,
          source: "int | Iterable[Sequence[TrajectoryTree]]",
          pc: Optional[PlannerConfig] = None, *,
          max_rows: Optional[int] = None,
          warmup=None) -> PlanPipeline:
    """THE planner entrypoint: a :class:`PlanPipeline` of
    :class:`PlannedStep`\\ s, scheduled over ``source`` and built on
    background threads.

    ``source``: an int (that many deterministic synthetic batches — the
    offline path) or any iterable of per-step tree lists (a live rollout
    queue, a dataset reader).  Each yielded step arrives with its
    materialization pre-built: call ``step.execution_plan()`` to train it
    (``TreeTrainEngine.step``) or ``step.step_batch()`` for the raw
    packed rows — both are cached, already-paid lookups.

    ``warmup``: an :class:`~repro.train.warmup.AOTWarmupService` (or any
    object with ``prewarm(step=...)``) — each step's exact executables
    are AOT-compiled on the pipeline's build threads the moment its
    plans exist, so upcoming signatures compile while the engine trains
    the current step and ``TreeTrainEngine`` never blocks on a cold
    bucket.

    Supersedes the deprecated ``data/loader.step_batches`` and
    ``data/loader.execution_plans`` wrappers (one-release warning)."""
    pc = pc or PlannerConfig()
    if max_rows is not None and pc.max_rows is None:
        pc = replace(pc, max_rows=max_rows)

    def build(ps: PlannedStep) -> PlannedStep:
        ps.execution_plan()           # materialize on the worker thread
        if warmup is not None:
            warmup.prewarm(step=ps)   # AOT-compile before consumption
        return ps

    sim = getattr(warmup, "sim", None) if warmup is not None else None
    return PlanPipeline(plan_stream(cfg, lc, source, pc, cache=sim),
                        build, workers=pc.plan_workers,
                        depth=pc.pipeline_depth)


def plan_pipeline(cfg: ModelConfig, lc: LoaderConfig, num_batches: int,
                  pc: Optional[PlannerConfig] = None, *,
                  max_rows: Optional[int] = None,
                  warmup=None) -> PlanPipeline:
    """ExecutionPlan stream behind the async pipeline: schedule on the
    source iterator, build (materialize rows + partition waves + device-
    ready inputs) on ``plan_workers`` background threads."""
    pc = pc or PlannerConfig()
    if max_rows is not None and pc.max_rows is None:
        pc = replace(pc, max_rows=max_rows)

    def build(ps: PlannedStep):
        plan = ps.execution_plan()
        if warmup is not None:
            warmup.prewarm(step=ps)
        return plan

    return PlanPipeline(plan_stream(cfg, lc, num_batches, pc), build,
                        workers=pc.plan_workers, depth=pc.pipeline_depth)


def planned_step_features(ps: PlannedStep,
                          block: Optional[int] = None) -> dict:
    """Host-side cost-model features of one built step, paired with the
    measured step wall time by ``benchmarks/run.py`` to least-squares-fit
    :class:`~repro.core.plan_cost.CostWeights` (``--calibrate``)."""
    from repro.analysis.signatures import step_signatures
    plan = ps.execution_plan()
    block = block or ps.pc.block
    row_sizes = [[ps.fits[i].ser.n for i in r] for r in ps.rows]
    live, causal = (_packing_live_blocks(row_sizes, ps.lc.seq_len, block)
                    if row_sizes else (0, 0))
    return dict(index=ps.index,
                padded_tokens=plan.padded_tokens,
                live_blocks=live,
                causal_blocks=causal,
                num_waves=(0 if plan.partition is None
                           else len(plan.partition.waves)),
                signatures=[str(s) for s in step_signatures(ps)])
