"""AOT warmup engine: precompile the signature universe, persist the
compile cache, pre-warm the planner's upcoming shapes.

The runtime half of ROADMAP item 4.  ``analysis/signatures`` proved
(statically) that a planner run stays inside an enumerable pow2-bucket
:class:`SignatureUniverse`; this module inverts that proof into work:

  startup warmup     :class:`AOTWarmupService` enumerates the universe,
                     synthesizes abstract inputs per signature (the same
                     ``jax.eval_shape`` replay the jaxpr auditor uses —
                     ``abstract_wave_io`` is shared with
                     ``analysis/registry``) and AOT-compiles every
                     bucket on background threads, packed signature
                     first, then wave buckets by simulated hit frequency
                     (``CompileCacheSim.freq``) — MaxText's bucketed
                     executable-cache warmup idiom;
  planner pre-warm   ``prewarm(step=...)`` compiles a built
                     PlannedStep's *exact* executables from the plan's
                     real shapes; ``train/planner.plans(...,
                     warmup=svc)`` calls it on the pipeline's build
                     threads, so upcoming signatures compile while the
                     current step trains and ``TreeTrainEngine``'s
                     executable lookup never blocks on a cold bucket;
  persistence        :func:`configure_compile_cache` wires jax's
                     persistent compilation cache so a restarted run
                     compiles ~nothing (the AOT ``lower().compile()``
                     becomes a disk hit).

Run ``python -m repro.train.warmup --persist-probe DIR`` twice to
measure the restart story: each run prints JSON with the number of NEW
cache files it wrote (second run: 0) and its first-step latency —
``benchmarks/run.py``'s ``compile_warmup`` row drives exactly that.
"""
from __future__ import annotations

import argparse
import json
import logging
import os
import threading
import time
from contextlib import nullcontext
from typing import Any, Hashable, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp

import repro.sharding as sh
from repro.analysis.signatures import SignatureUniverse
from repro.configs.base import ModelConfig
from repro.core.gateway import (_cut_caps_view, _names_sig, _slice_gw_row,
                                _stack_gw_rows, assemble_child_gw)
from repro.core.plan_cost import (CompileCacheSim, packed_signature, pow2,
                                  round_to_multiple, wave_signature,
                                  wave_signature_of)
from repro.data.loader import LoaderConfig
from repro.models.model import max_conv_taps, needs_chunks
from repro.train.engine import (NUM_SCALARS, _packed_exec_fn,
                                _wave_exec_fns)
from repro.train.exec_cache import ExecutableCache, abstractify, exec_key
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_step import jitted_update

logger = logging.getLogger(__name__)

_sds = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# Persistent compilation cache
# ---------------------------------------------------------------------------

def configure_compile_cache(cache_dir: str) -> str:
    """Point jax's persistent compilation cache at ``cache_dir`` (created
    if missing) and drop the min-compile-time / min-entry-size floors —
    the defaults skip exactly the small, fast CPU modules this repo's
    shape buckets produce, which would leave a restarted run recompiling
    everything.  Idempotent; call before the first compile."""
    cache_dir = os.path.abspath(os.path.expanduser(cache_dir))
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    return cache_dir


def compile_cache_files(cache_dir: str) -> int:
    """Number of cache entries on disk — the restart metric: a warm
    restart adds 0 new files."""
    n = 0
    for _, _, names in os.walk(cache_dir):
        n += len(names)
    return n


# ---------------------------------------------------------------------------
# Abstract input synthesis (per signature, no real plan needed)
# ---------------------------------------------------------------------------

def _abstract_params(params) -> Any:
    """Params (concrete or abstract) → ShapeDtypeStructs carrying each
    leaf's sharding when present, so AOT lowering sees exactly the
    layouts the engine will dispatch with."""
    def one(leaf):
        shd = getattr(leaf, "sharding", None)
        return _sds(leaf.shape, leaf.dtype, sharding=shd)
    return jax.tree.map(one, params)


def abstract_packed_batch(cfg: ModelConfig, rows: int, seq_len: int
                          ) -> dict:
    """The abstract ``prepare_batch`` output for a [rows, seq_len] packed
    microbatch — field-for-field what ``PlannedStep.step_batch``
    materializes (``num_trees`` stays a python int: jit traces it as a
    weak scalar, so one executable serves every tree count)."""
    i32, f32 = jnp.int32, jnp.float32
    b: dict[str, Any] = {
        "tokens": _sds((rows, seq_len), i32),
        "pos_ids": _sds((rows, seq_len), i32),
        "kv_last": _sds((rows, seq_len), i32),
        "weight": _sds((rows, seq_len), f32),
        "prev_idx": _sds((rows, seq_len), i32),
        "valid": _sds((rows, seq_len), jnp.bool_),
        "num_trees": 1,
    }
    if needs_chunks(cfg):
        chunk = cfg.ssm.chunk_size
        k = max(1, max_conv_taps(cfg))
        b["chunk_parent"] = _sds((rows, seq_len // chunk), i32)
        b["prev_pows"] = _sds((rows, seq_len, k), i32)
    if cfg.frontend is not None:
        # the planner materializes float32 frontend embeds (train/planner
        # PlannedStep.step_batch), not the bf16 stub path
        b["extra_embeds"] = _sds((rows, cfg.frontend_len, cfg.d_model),
                                 f32)
    return b


def _abstract_wave_batch(cfg: ModelConfig, rows: int, seq_len: int,
                         anc: int, n_extra: int) -> dict:
    """Abstract WavePlan batch columns for one wave bucket — mirrors
    ``core/gateway.build_partition_plan``'s batch construction."""
    i32, f32 = jnp.int32, jnp.float32
    b: dict[str, Any] = {
        "tokens": _sds((rows, seq_len), i32),
        "pos_ids": _sds((rows, seq_len), i32),
        "kv_last": _sds((rows, seq_len), i32),
        "weight": _sds((rows, seq_len), f32),
        "prev_idx": _sds((rows, seq_len), i32),
        "valid": _sds((rows, seq_len), jnp.bool_),
    }
    if needs_chunks(cfg):
        chunk = cfg.ssm.chunk_size
        taps = max(1, max_conv_taps(cfg))
        b["chunk_parent"] = _sds((rows, seq_len // chunk), i32)
        b["prev_pows"] = _sds((rows, seq_len, taps), i32)
    if n_extra:
        b["extra_pos"] = _sds((rows, n_extra), i32)
        b["extra_label"] = _sds((rows, n_extra), i32)
        b["extra_weight"] = _sds((rows, n_extra), f32)
    if anc:
        b["anc_pos"] = _sds((rows, anc), i32)
        b["anc_valid"] = _sds((rows, anc), jnp.bool_)
    return b


def _abstract_capspecs(cfg: ModelConfig, ncut: int, plen: int) -> dict:
    """Abstract bucketed capture plans (``gateway._wave_capspecs``)."""
    i32 = jnp.int32
    taps = max(1, max_conv_taps(cfg))
    return {f"c{i}": {"path_idx": _sds((plen,), i32),
                      "cut_chunk": _sds((), i32),
                      "conv_pos": _sds((min(taps, plen),), i32),
                      "shift_pos": _sds((1,), i32)}
            for i in range(ncut)}


def abstract_wave_io(cfg: ModelConfig, partition, params_a, *,
                     impl: str = "ref", donate: bool = True):
    """Replay ``run_partition_plan``'s forward sweep entirely under
    ``jax.eval_shape`` over a REAL :class:`~repro.core.gateway
    .PartitionPlan` — each wave's gateway assembled abstractly from its
    parent's abstract captures, exactly like the runtime executor.

    Yields one dict per wave: ``{w, wp, fwd, bwd, fwd_args, bwd_args}``
    where the arg tuples are the abstract avals of the engine's actual
    dispatch (so AOT-compiling on them produces executables the engine's
    fingerprinted lookup hits).  Shared by the jaxpr auditor
    (``analysis/registry._wave_targets``) and the warmup service's
    pre-warm path — one replay, two consumers."""
    scal_a = _sds((NUM_SCALARS,), jnp.float32)
    scale_a = _sds((), jnp.float32)
    acc_a = jax.tree.map(lambda l: _sds(l.shape, jnp.float32), params_a)
    st: list[dict] = []
    for w, wp in enumerate(partition.waves):
        batch_a = abstractify(wp.batch)
        caps_a = abstractify(wp.capspecs)
        gw_a = None
        if wp.has_gw:
            def mk_gw(prev, _wp=wp, _ba=batch_a):
                rows_gw = []
                for ref in _wp.parents:
                    stp = prev[ref.wave]
                    pwp = partition.waves[ref.wave]
                    cname = f"c{ref.cut}"
                    p_gw_row = (None if stp["gw"] is None else
                                _slice_gw_row(stp["gw"], ref.row,
                                              pwp.A_real[ref.row]))
                    caps_view = _cut_caps_view(cfg, stp["caps"], cname,
                                               ref.row, ref.path_len)
                    rows_gw.append(
                        assemble_child_gw(cfg, p_gw_row, caps_view,
                                          cname))
                return _stack_gw_rows(rows_gw, _wp.anc_A_max,
                                      _ba["tokens"].shape[0],
                                      rows_idx=_wp.slot_rows)
            gw_a = jax.eval_shape(mk_gw, st)
        fwd, bwd = _wave_exec_fns(cfg, _names_sig(wp.capspecs), impl,
                                  wp.has_gw, donate)
        caps_out, _ = jax.eval_shape(fwd, params_a, batch_a, gw_a,
                                     caps_a, scal_a, scale_a)
        yield dict(w=w, wp=wp, fwd=fwd, bwd=bwd, caps_out=caps_out,
                   fwd_args=(params_a, batch_a, gw_a, caps_a, scal_a,
                             scale_a),
                   bwd_args=(params_a, batch_a, gw_a, caps_a,
                             (scale_a, caps_out), acc_a))
        st.append(dict(caps=caps_out, gw=gw_a))


def abstract_wave_exec(cfg: ModelConfig, sig: tuple, params_a, *,
                       impl: str = "ref", donate: bool = True) -> dict:
    """Synthesize one wave bucket's (fwd, bwd) executables and abstract
    args straight from its signature — no real plan.

    The gateway avals are derived the honest way: a minimal abstract
    *parent* wave (1 row, 1 cut) is forwarded under ``jax.eval_shape``
    for its capture structure, then one child row is cut out, assembled
    and stacked through the very gateway helpers the runtime executor
    uses (``_cut_caps_view`` → ``assemble_child_gw`` →
    ``_stack_gw_rows``), front-padded to the bucket's ancestor length.
    Fidelity is measured, not assumed: the retrace-count benchmarks
    assert the engine's fingerprinted lookup hits these executables on a
    real in-universe stream."""
    _, rows, S, anc, ncut, plen, n_extra = sig
    scal_a = _sds((NUM_SCALARS,), jnp.float32)
    scale_a = _sds((), jnp.float32)
    acc_a = jax.tree.map(lambda l: _sds(l.shape, jnp.float32), params_a)
    batch_a = _abstract_wave_batch(cfg, rows, S, anc, n_extra)
    caps_a = _abstract_capspecs(cfg, ncut, plen)
    has_gw = anc > 0
    gw_a = None
    if has_gw:
        taps = max(1, max_conv_taps(cfg))
        plen_p = pow2(taps)
        parent_batch = _abstract_wave_batch(cfg, 1, S, 0, 1)
        parent_caps = _abstract_capspecs(cfg, 1, plen_p)
        pfwd, _ = _wave_exec_fns(cfg, ("c0",), impl, False, donate)
        pcaps_out, _ = jax.eval_shape(pfwd, params_a, parent_batch, None,
                                      parent_caps, scal_a, scale_a)

        def mk_gw(caps):
            # true_len = taps ≤ anc (anc buckets start at 8): the conv
            # tail lands at its full tap count — the wave max on every
            # real gateway wave — while attention ancestors front-pad to
            # the bucket anyway, so the stacked avals match runtime
            view = _cut_caps_view(cfg, caps, "c0", 0, taps)
            row = assemble_child_gw(cfg, None, view, "c0")
            return _stack_gw_rows([row], anc, rows, rows_idx=[0])
        gw_a = jax.eval_shape(mk_gw, pcaps_out)
    names = tuple(sorted(f"c{i}" for i in range(ncut)))
    fwd, bwd = _wave_exec_fns(cfg, names, impl, has_gw, donate)
    caps_out, _ = jax.eval_shape(fwd, params_a, batch_a, gw_a, caps_a,
                                 scal_a, scale_a)
    return dict(fwd=fwd, bwd=bwd,
                fwd_args=(params_a, batch_a, gw_a, caps_a, scal_a,
                          scale_a),
                bwd_args=(params_a, batch_a, gw_a, caps_a,
                          (scale_a, caps_out), acc_a))


# ---------------------------------------------------------------------------
# Universe enumeration (independent of SignatureUniverse.enumerate_signatures
# — treelint cross-checks the two lists for equality)
# ---------------------------------------------------------------------------

DEFAULT_CAPS = (64, 8, 64, 8)     # (anc, ncut, plen, extra) fallbacks


def universe_signatures(lc: LoaderConfig, pc, caps: Sequence[int]
                        ) -> list[Hashable]:
    """Every live signature the planner can emit under (lc, pc), bounded
    by per-field ``caps = (anc, ncut, plen, extra)``.  Deliberately a
    second, independent implementation of
    ``SignatureUniverse.enumerate_signatures`` — the treelint warmup
    pass asserts the two agree, so neither can silently drift from what
    the engine actually keys."""
    S = lc.seq_len
    R = max(getattr(pc, "num_replicas", 1), 1)
    max_rows = pc.max_rows if pc.max_rows is not None else lc.batch_rows
    anc_cap, ncut_cap, plen_cap, extra_cap = caps
    plen_cap = min(plen_cap, pow2(lc.capacity or S))
    sigs: list[Hashable] = [
        packed_signature(round_to_multiple(lc.batch_rows, R), S)]

    def pow2s(lo, cap):
        b = lo
        while b <= cap:
            yield b
            b *= 2

    for rows in pow2s(R, R * pow2(-(-max_rows // R))):
        # leaf waves: gateway in, no cuts → no capture paths, no extras
        for anc in pow2s(8, anc_cap):
            sigs.append(wave_signature(rows, S, anc, 0, 0, 0))
        for ncut in pow2s(1, ncut_cap):
            for plen in pow2s(1, plen_cap):
                for n_extra in pow2s(1, min(extra_cap, ncut)):
                    # root waves (anc=0) always cut — a cut-less rootless
                    # wave would be a row-sized tree, which packs instead
                    sigs.append(wave_signature(rows, S, 0, ncut, plen,
                                               n_extra))
                    for anc in pow2s(8, anc_cap):
                        sigs.append(wave_signature(rows, S, anc, ncut,
                                                   plen, n_extra))
    return sigs


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------

class AOTWarmupService:
    """Fills an :class:`ExecutableCache` ahead of the engine.

    Two producers:

      ``start()``/``warm_all()``  enumerate the signature universe and
          AOT-compile every bucket (packed first, then waves by
          ``CompileCacheSim.freq`` hit frequency, small buckets first on
          ties), on background threads or synchronously;
      ``prewarm(step=...)``       compile one built PlannedStep's exact
          executables — the planner pipeline calls this from its build
          workers the moment a window's plans exist, so upcoming
          signatures compile while the current step trains.

    Construct it with the same ``params``/``opt_cfg``/``impl``/
    ``donate`` the engine runs with (params may be concrete or abstract;
    shardings are carried into the lowering when present), then hand
    ``service.cache`` and ``service.universe`` to
    :class:`~repro.train.engine.TreeTrainEngine`."""

    def __init__(self, cfg: ModelConfig, lc: LoaderConfig, pc=None, *,
                 params, opt_cfg: Optional[OptimizerConfig] = None,
                 opt_state=None, cache: Optional[ExecutableCache] = None,
                 impl: str = "ref", donate: bool = True,
                 universe: Optional[SignatureUniverse] = None,
                 caps: Optional[Sequence[int]] = None,
                 sim: Optional[CompileCacheSim] = None,
                 max_compiles: Optional[int] = None):
        if pc is None:
            from repro.train.planner import PlannerConfig
            pc = PlannerConfig()
        self.cfg, self.lc, self.pc = cfg, lc, pc
        self.impl, self.donate = impl, donate
        self.cache = cache if cache is not None else ExecutableCache()
        self.sim = sim
        self.caps = tuple(caps) if caps is not None else DEFAULT_CAPS
        self.max_compiles = max_compiles
        self.universe = universe or SignatureUniverse(
            seq_len=lc.seq_len, batch_rows=lc.batch_rows,
            num_replicas=pc.num_replicas,
            max_rows=(pc.max_rows if pc.max_rows is not None
                      else lc.batch_rows),
            capacity=lc.capacity or lc.seq_len)
        self.params_a = _abstract_params(params)
        self.opt_cfg = opt_cfg
        self.opt_a = (abstractify(opt_state) if opt_state is not None
                      else (jax.eval_shape(init_opt_state, self.params_a)
                            if opt_cfg is not None else None))
        self.acc_a = jax.tree.map(
            lambda l: _sds(l.shape, jnp.float32), self.params_a)
        self.scal_a = _sds((NUM_SCALARS,), jnp.float32)
        # jax's mesh context is thread-local: capture the active mesh so
        # background compiles lower under the same layouts as dispatch
        self._mesh_args = None
        if sh.current_mesh() is not None:
            ctx = sh._CTX
            self._mesh_args = (ctx.mesh, ctx.data_axes, ctx.model_axis,
                               ctx.seq_parallel)
        self.errors: list[str] = []
        self.prewarmed = 0
        self.background_s = 0.0
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()

    # -- enumeration -------------------------------------------------------
    def signature_list(self) -> list[Hashable]:
        """The warmup compile list: the live universe under ``caps``,
        packed signature first, then wave buckets by descending
        simulated hit frequency (``sim.freq``), smallest bucket first on
        ties (small modules compile fastest — more of the universe is
        warm sooner)."""
        sigs = universe_signatures(self.lc, self.pc, self.caps)
        freq = self.sim.freq if self.sim is not None else {}

        def order(s):
            if s[0] == "packed":
                return (0, 0, ())
            return (1, -freq.get(s, 0), s[1:])
        return sorted(sigs, key=order)

    # -- per-signature compile --------------------------------------------
    def _mesh_scope(self):
        if self._mesh_args is None:
            return nullcontext()
        mesh, daxes, maxis, sp = self._mesh_args
        return sh.use_mesh(mesh, data_axes=daxes, model_axis=maxis,
                           seq_parallel=sp)

    def _variants_for(self, sig: Hashable) -> list[tuple]:
        """(variant, fn, abstract args) triples one signature compiles
        to — the exact keys ``TreeTrainEngine`` resolves."""
        if sig == ("update",):
            if self.opt_cfg is None:
                return []
            return [("update", jitted_update(self.opt_cfg, self.donate),
                     (self.params_a, self.acc_a, self.opt_a))]
        if sig[0] == "packed":
            _, rows, S = sig
            batch_a = abstract_packed_batch(self.cfg, rows, S)
            out = [("packed",
                    _packed_exec_fn(self.cfg, self.impl, self.donate,
                                    with_acc=False),
                    (self.params_a, batch_a, self.scal_a)),
                   ("packed+acc",
                    _packed_exec_fn(self.cfg, self.impl, self.donate),
                    (self.params_a, batch_a, self.acc_a, self.scal_a))]
            return out
        io = abstract_wave_exec(self.cfg, sig, self.params_a,
                                impl=self.impl, donate=self.donate)
        return [("wave.fwd", io["fwd"], io["fwd_args"]),
                ("wave.bwd", io["bwd"], io["bwd_args"])]

    def warm_signature(self, sig: Hashable) -> int:
        """AOT-compile every executable variant of one signature into
        the cache; returns how many were new.  A synthesis or compile
        failure is recorded (and logged) but never raises — the engine's
        synchronous slow path stays the correctness backstop."""
        new = 0
        try:
            variants = self._variants_for(sig)
        except Exception as e:          # pragma: no cover - defensive
            self.errors.append(f"{sig}: synthesis failed: {e}")
            logger.warning("warmup synthesis failed for %s: %s", sig, e)
            return 0
        for variant, fn, args in variants:
            if self._stop.is_set():
                break
            try:
                with self._mesh_scope():
                    _, was_new = self.cache.compile_once(
                        exec_key(variant, sig, args), fn, args)
                new += was_new
            except Exception as e:      # pragma: no cover - defensive
                self.errors.append(f"{sig}/{variant}: {e}")
                logger.warning("warmup compile failed for %s/%s: %s",
                               sig, variant, e)
        return new

    # -- startup warmup ----------------------------------------------------
    def _budgeted(self, sigs: Iterable[Hashable]) -> Iterable[Hashable]:
        out = list(sigs)
        if self.max_compiles is not None:
            # 2 executables per signature (fwd+bwd / packed pair)
            keep = max(self.max_compiles // 2, 1)
            if len(out) > keep:
                logger.info(
                    "warmup budget: compiling %d of %d universe "
                    "signatures (hottest first)", keep, len(out))
                out = out[:keep]
        return out

    def warm_all(self) -> int:
        """Synchronously compile the update + the whole (budgeted)
        universe; returns the number of new executables."""
        t0 = time.perf_counter()
        new = self.warm_signature(("update",))
        for sig in self._budgeted(self.signature_list()):
            new += self.warm_signature(sig)
        self.background_s += time.perf_counter() - t0
        return new

    def start(self, threads: int = 1) -> "AOTWarmupService":
        """Background startup warmup: the universe list is compiled on
        ``threads`` daemon workers in priority order.  Returns self."""
        work = list(self._budgeted(self.signature_list()))
        work.insert(0, ("update",))
        it = iter(work)
        lock = threading.Lock()

        def run():
            t0 = time.perf_counter()
            while not self._stop.is_set():
                with lock:
                    sig = next(it, None)
                if sig is None:
                    break
                self.warm_signature(sig)
            self.background_s += time.perf_counter() - t0

        self._threads = [threading.Thread(target=run, daemon=True,
                                          name=f"aot-warmup-{i}")
                         for i in range(max(1, threads))]
        for t in self._threads:
            t.start()
        return self

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Join the background workers; True when all finished."""
        deadline = None if timeout is None else time.monotonic() + timeout
        for t in self._threads:
            t.join(None if deadline is None
                   else max(deadline - time.monotonic(), 0))
        return not any(t.is_alive() for t in self._threads)

    def stop(self) -> None:
        self._stop.set()

    # -- planner pre-warm --------------------------------------------------
    def prewarm(self, signatures: Optional[Iterable[Hashable]] = None,
                step=None) -> int:
        """Compile upcoming work before the engine consumes it; returns
        the number of new executables.

        ``step``: a built PlannedStep — its packed batch, every wave and
        the optimizer update compile from the plan's EXACT shapes (the
        ``abstract_wave_io`` replay), so the engine's fingerprinted
        lookup is guaranteed to hit.  ``signatures``: bare signatures,
        synthesized like the startup universe.  The planner pipeline
        calls this on its build threads (``plans(..., warmup=svc)``) —
        compile overlaps the current step's device work."""
        new = self.warm_signature(("update",))
        for sig in (signatures or ()):
            new += self.warm_signature(sig)
        if step is not None:
            plan = step.execution_plan()
            sigs: list[Hashable] = []
            if plan.packed is not None:
                # inputs already carry the python-int num_trees leaf
                batch_a = abstractify(dict(plan.packed.inputs))
                B, S = plan.packed.inputs["tokens"].shape
                sig = packed_signature(B, S)
                sigs.append(sig)
                has_waves = (plan.partition is not None
                             and plan.partition.waves)
                variants = ([("packed+acc",
                              _packed_exec_fn(self.cfg, self.impl,
                                              self.donate),
                              (self.params_a, batch_a, self.acc_a,
                               self.scal_a))]
                            if has_waves else
                            [("packed",
                              _packed_exec_fn(self.cfg, self.impl,
                                              self.donate,
                                              with_acc=False),
                              (self.params_a, batch_a, self.scal_a))])
                for variant, fn, args in variants:
                    try:
                        with self._mesh_scope():
                            _, was_new = self.cache.compile_once(
                                exec_key(variant, sig, args), fn, args)
                        new += was_new
                    except Exception as e:   # pragma: no cover
                        self.errors.append(f"{sig}/{variant}: {e}")
                        logger.warning("prewarm failed for %s/%s: %s",
                                       sig, variant, e)
            if plan.partition is not None and plan.partition.waves:
                seq_len = step.lc.seq_len
                try:
                    with self._mesh_scope():
                        for io in abstract_wave_io(
                                self.cfg, plan.partition, self.params_a,
                                impl=self.impl, donate=self.donate):
                            sig = wave_signature_of(io["wp"], seq_len)
                            sigs.append(sig)
                            for variant, fn, args in (
                                    ("wave.fwd", io["fwd"],
                                     io["fwd_args"]),
                                    ("wave.bwd", io["bwd"],
                                     io["bwd_args"])):
                                _, was_new = self.cache.compile_once(
                                    exec_key(variant, sig, args), fn,
                                    args)
                                new += was_new
                except Exception as e:       # pragma: no cover
                    self.errors.append(f"prewarm waves: {e}")
                    logger.warning("prewarm wave replay failed: %s", e)
            if self.sim is not None:
                self.sim.commit(sigs)
        self.prewarmed += new
        return new

    def stats(self) -> dict:
        s = self.cache.stats()
        s.update(prewarmed=self.prewarmed, errors=len(self.errors),
                 background_s=self.background_s)
        return s


# ---------------------------------------------------------------------------
# Persist probe (the restart story, measured from a fresh process)
# ---------------------------------------------------------------------------

def _probe_config() -> ModelConfig:
    from repro.configs.base import AttnCfg
    return ModelConfig(
        name="warmup-probe", family="dense", n_layers=2, d_model=32,
        d_ff=128, vocab_size=256,
        attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=8, qk_norm=True),
        dtype="float32", vocab_pad_multiple=64)


def _persist_probe(cache_dir: str) -> dict:
    """One fresh-process probe: configure the persistent cache, pre-warm
    a tiny real plan stream (packed rows + partition waves), run one
    engine step, and report how many NEW cache files this process wrote.
    Run twice with the same dir: run 1 fills the disk cache, run 2 must
    report ``new_cache_files == 0`` (the warm-restart claim) and a much
    faster warmup."""
    from repro.models.transformer import init_params
    from repro.train.engine import TreeTrainEngine
    from repro.train.planner import PlannerConfig, plan_stream

    cache_dir = configure_compile_cache(cache_dir)
    files0 = compile_cache_files(cache_dir)
    t_start = time.perf_counter()

    cfg = _probe_config()
    lc = LoaderConfig(seq_len=64, batch_rows=2, trees_per_batch=2,
                      auto_partition=True, capacity=32, seed=5,
                      gen_kwargs=dict(num_turns=2,
                                      turn_len_range=(8, 20)))
    pc = PlannerConfig()
    steps = [ps for ps in plan_stream(cfg, lc, 1, pc)]
    params = init_params(cfg, jax.random.key(0))
    opt_cfg = OptimizerConfig()
    opt_state = init_opt_state(params)

    svc = AOTWarmupService(cfg, lc, pc, params=params, opt_cfg=opt_cfg,
                           opt_state=opt_state)
    t0 = time.perf_counter()
    for ps in steps:
        svc.prewarm(step=ps)
    warm_s = time.perf_counter() - t0

    eng = TreeTrainEngine(cfg, opt_cfg, exec_cache=svc.cache,
                          universe=svc.universe)
    t0 = time.perf_counter()
    params, opt_state, metrics = eng.step(params, opt_state,
                                          steps[0].execution_plan())
    step1_ms = (time.perf_counter() - t0) * 1e3

    return dict(cache_dir=cache_dir,
                new_cache_files=compile_cache_files(cache_dir) - files0,
                aot_executables=len(svc.cache),
                compile_s=round(svc.cache.compile_s, 3),
                prewarm_s=round(warm_s, 3),
                retraces=eng.retraces,
                step1_ms=round(step1_ms, 2),
                loss=float(metrics["loss"]),
                wall_s=round(time.perf_counter() - t_start, 3))


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="AOT warmup utilities (persist-probe mode)")
    ap.add_argument("--persist-probe", metavar="CACHE_DIR",
                    help="fill/verify the persistent compile cache from "
                         "a fresh process and print JSON stats")
    args = ap.parse_args(argv)
    if args.persist_probe:
        print(json.dumps(_persist_probe(args.persist_probe)))
        return 0
    ap.print_help()
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
