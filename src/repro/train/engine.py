"""Unified tree-ingest training engine: one plan→execute loop.

The paper's systems claim is that the training engine must *natively
ingest tree-structured data* — and report its 6.2x speedup for both SFT
and the RL model-update phase.  Before this module the trainer was two
loops bolted together: a jitted step for the packed batch and a separate
host-driven wave driver for partitioned oversized trees, accumulating
gradients host-side with per-step ``float()`` syncs.

Here every step is an **ExecutionPlan** — an ordered list of uniform,
shape-bucketed microbatch executions:

  - the packed rows (tree- or baseline-packed) are a 1-element plan;
  - oversized trees contribute their partition waves via
    ``core/gateway.build_partition_plan`` (a plan *builder*, not a
    driver).

``TreeTrainEngine.step`` runs every execution through one jitted
forward/backward with a **donated fp32 gradient accumulator that never
leaves the device**; loss / token-CE / weight scalars accumulate in a
single on-device vector, and the step performs **exactly one host sync**
(the logging transfer, counted in ``engine.host_syncs``).

The loss is pluggable through the per-token weights threaded end-to-end
by the serializer: ``loss_mode="rl"`` multiplies λ_t by GRPO-style
per-branch advantages (see core/tree.serialize_tree), with advantage≡1
reducing bit-exactly to SFT — the same engine serves both scenarios.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass
from functools import lru_cache
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.gateway import (PartitionPlan, _cut_caps_view,
                                _embed_cut_cot, _embed_gw_row_cot,
                                _names_sig, _slice_gw_row, _stack_gw_rows,
                                _vjp1, _vjp2, assemble_child_gw,
                                route_child_cot)
from repro.core.plan_cost import packed_signature, wave_signature_of
from repro.models.model import loss_and_metrics
from repro.train.exec_cache import ExecutableCache, exec_key
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import jitted_update

logger = logging.getLogger(__name__)

# the on-device scalar accumulator: [loss, nll_sum, weight_sum]
NUM_SCALARS = 3


# ---------------------------------------------------------------------------
# Plan types
# ---------------------------------------------------------------------------

@dataclass
class PackedExec:
    """One uniform [B, S] microbatch execution (the packed rows)."""
    inputs: dict                 # jnp-ready model inputs (prepare_batch)
    tokens: int = 0              # host-side unique-token count (logging)
    cells: int = 0               # materialized row cells (B × S)


@dataclass
class ExecutionPlan:
    """Everything one optimizer step trains on, in execution order:
    the packed microbatch (if any) followed by the partition waves of the
    oversized trees (if any).  Built host-side by the plan-ahead
    scheduler (``train/planner``) — the engine only executes."""
    packed: Optional[PackedExec] = None
    partition: Optional[PartitionPlan] = None
    num_trees: int = 0           # packed + oversized (loss normalizer)
    dropped: int = 0             # trees lost this step (no auto-partition)
    versions: Optional[tuple] = None   # (min, max) weight_version of the
    #                              step's trees (async RL staleness; None
    #                              for offline/synthetic sources)

    @property
    def is_empty(self) -> bool:
        return self.packed is None and (
            self.partition is None or not self.partition.waves)

    @property
    def num_oversized(self) -> int:
        return 0 if self.partition is None else self.partition.num_trees

    @property
    def unique_tokens(self) -> int:
        n = 0 if self.packed is None else self.packed.tokens
        if self.partition is not None and self.partition.waves:
            n += self.partition.info["unique_tokens"]
        return n

    @property
    def padded_tokens(self) -> int:
        """Materialized row cells holding no unique token — the schedule
        overhead the plan-ahead cost model minimizes."""
        cells = 0 if self.packed is None else self.packed.cells
        if self.partition is not None and self.partition.waves:
            cells += self.partition.info.get("cells", 0)
        return cells - self.unique_tokens

    @property
    def num_executions(self) -> int:
        n = 0 if self.packed is None else 1
        if self.partition is not None:
            n += len(self.partition.waves)
        return n


# ---------------------------------------------------------------------------
# Cached jitted executions (shape-bucketed; donation recycles the
# accumulator buffers between microbatches)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=16)
def _packed_exec_fn(cfg: ModelConfig, impl: str, donate: bool,
                    with_acc: bool = True):
    """Packed microbatch: fused fwd+bwd, grads accumulated into the
    donated fp32 buffer, scalars into the donated scalar vector.

    ``with_acc=False`` is the single-execution fast path (no oversized
    trees this step): the fp32 grads ARE the accumulator, so no separate
    param-sized zero buffer is ever materialized (``0 + g ≡ g`` exactly,
    bit-for-bit)."""
    def scal_add(scal, loss, metrics):
        return scal + jnp.stack(
            [loss.astype(jnp.float32),
             metrics["nll_sum"].astype(jnp.float32),
             metrics["weight_sum"].astype(jnp.float32)])

    if with_acc:
        def f(params, batch, acc, scal):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_and_metrics(cfg, p, batch, impl),
                has_aux=True)(params)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc, grads)
            return acc, scal_add(scal, loss, metrics)

        return jax.jit(f, donate_argnums=(2, 3) if donate else ())

    def f1(params, batch, scal):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_and_metrics(cfg, p, batch, impl),
            has_aux=True)(params)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return grads, scal_add(scal, loss, metrics)

    return jax.jit(f1, donate_argnums=(2,) if donate else ())


@lru_cache(maxsize=64)
def _wave_exec_fns(cfg: ModelConfig, names: tuple, impl: str,
                   has_gw: bool, donate: bool):
    """One partition wave: jitted forward (captures out, scalars
    accumulated on-device, loss pre-scaled by the tree normalizer) and
    jitted remat-backward (grads accumulated into the donated fp32
    buffer, gateway cotangents out for child→parent routing)."""
    from repro.models.transformer import partition_loss

    def fwd(params, batch, gw, capspecs, scal, scale):
        (loss, caps), metrics = partition_loss(
            cfg, params, batch, gw if has_gw else None, capspecs, impl)
        scal = scal + jnp.stack(
            [loss.astype(jnp.float32) * scale,
             metrics["nll_sum"].astype(jnp.float32),
             metrics["weight_sum"].astype(jnp.float32)])
        return caps, scal

    def bwd(params, batch, gw, capspecs, cot, acc):
        if has_gw:
            g_params, g_gw = _vjp2(cfg, params, batch, gw, capspecs,
                                   impl, cot)
        else:
            g_params, g_gw = _vjp1(cfg, params, batch, capspecs, impl,
                                   cot)
        acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                           acc, g_params)
        return acc, g_gw

    return (jax.jit(fwd, donate_argnums=(4,) if donate else ()),
            jax.jit(bwd, donate_argnums=(5,) if donate else ()))


# ---------------------------------------------------------------------------
# Partition-plan executor (the runtime half of core/gateway's planner)
# ---------------------------------------------------------------------------

def run_partition_plan(
    cfg: ModelConfig,
    params: dict,
    plan: PartitionPlan,
    acc: Any,
    scal: jax.Array,
    *,
    impl: str = "ref",
    loss_scale: jax.Array,
    donate: bool = True,
    exec_lookup: Optional[Callable] = None,
    seq_len: Optional[int] = None,
) -> tuple[Any, jax.Array]:
    """Execute a PartitionPlan: forward sweep in wave order (assembling
    each fragment's gateway from its parent's runtime captures), backward
    sweep in reverse (routing gateway cotangents child→parent in fp32).

    ``loss_scale`` seeds every wave's backward cotangent — the engine
    passes 1/num_trees so the partitioned gradients land in the shared
    accumulator already normalized, with no extra scaling pass.  The loss
    scalar is scaled the same way; nll/weight sums stay raw.  Returns the
    updated ``(acc, scal)`` — no host sync happens here.

    ``exec_lookup(variant, sig, fn, args)`` (the engine's AOT
    executable-cache resolver) swaps each jitted wave fn for its
    precompiled executable; None dispatches the plain jit (every new
    shape bucket retraces inside jax)."""
    st: list[dict] = []
    S = seq_len
    if S is None and plan.waves:
        S = plan.waves[0].batch["tokens"].shape[1]

    def resolve(variant, wp, fn, args):
        if exec_lookup is None:
            return fn(*args)
        return exec_lookup(variant, wave_signature_of(wp, S), fn, args)

    # ---- forward sweep, wave order ---------------------------------------
    for wp in plan.waves:
        batch = {k: jnp.asarray(v) for k, v in wp.batch.items()}
        gw = None
        if wp.has_gw:
            rows_gw = []
            for ref in wp.parents:
                stp, pwp = st[ref.wave], plan.waves[ref.wave]
                cname = f"c{ref.cut}"
                p_gw_row = None if stp["gw"] is None else _slice_gw_row(
                    stp["gw"], ref.row, pwp.A_real[ref.row])
                caps_view = _cut_caps_view(cfg, stp["caps"], cname,
                                           ref.row, ref.path_len)
                rows_gw.append(
                    assemble_child_gw(cfg, p_gw_row, caps_view, cname))
            gw = _stack_gw_rows(rows_gw, wp.anc_A_max,
                                batch["tokens"].shape[0],
                                rows_idx=wp.slot_rows)
        fwd, _ = _wave_exec_fns(cfg, _names_sig(wp.capspecs), impl,
                                wp.has_gw, donate)
        caps, scal = resolve("wave.fwd", wp, fwd,
                             (params, batch, gw, wp.capspecs, scal,
                              loss_scale))
        st.append(dict(batch=batch, gw=gw, caps=caps, cot_gw=None,
                       cot_cut={}))

    # ---- backward sweep, reverse wave order ------------------------------
    for w in reversed(range(len(plan.waves))):
        wp, s = plan.waves[w], st[w]
        cot_caps = jax.tree.map(jnp.zeros_like, s["caps"])
        for cname, (r, cot_view) in s["cot_cut"].items():
            _embed_cut_cot(cot_caps, cot_view, cname, r)
        _, bwd = _wave_exec_fns(cfg, _names_sig(wp.capspecs), impl,
                                wp.has_gw, donate)
        acc, g_gw = resolve("wave.bwd", wp, bwd,
                            (params, s["batch"], s["gw"], wp.capspecs,
                             (loss_scale, cot_caps), acc))
        if not wp.has_gw:
            continue
        if s["cot_gw"] is not None:
            g_gw = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) + b, g_gw, s["cot_gw"])
        for si, ref in enumerate(wp.parents):
            row = wp.slot_rows[si]
            stp, pwp = st[ref.wave], plan.waves[ref.wave]
            cname = f"c{ref.cut}"
            cot_child_row = _slice_gw_row(g_gw, row, wp.A_real[row])
            p_gw_row = None if stp["gw"] is None else _slice_gw_row(
                stp["gw"], ref.row, pwp.A_real[ref.row])
            caps_view = _cut_caps_view(cfg, stp["caps"], cname, ref.row,
                                       ref.path_len)
            cot_gw_row = None if p_gw_row is None else jax.tree.map(
                lambda a: jnp.zeros(a.shape, jnp.float32), p_gw_row)
            cot_caps_row = jax.tree.map(jnp.zeros_like, caps_view)
            route_child_cot(cfg, p_gw_row, caps_view, cname,
                            cot_child_row, cot_gw_row, cot_caps_row)
            if cot_gw_row is not None:
                if stp["cot_gw"] is None:
                    stp["cot_gw"] = jax.tree.map(
                        lambda a: jnp.zeros(a.shape, jnp.float32),
                        stp["gw"])
                stp["cot_gw"] = _embed_gw_row_cot(stp["cot_gw"],
                                                  cot_gw_row, ref.row)
            stp["cot_cut"][cname] = (ref.row, cot_caps_row)
    return acc, scal


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class TreeTrainEngine:
    """Plan→execute training engine: ``step(params, opt_state, plan)``
    runs every microbatch execution of the plan (packed rows first, then
    the partition waves), accumulates gradients in one donated fp32
    device buffer, applies the cached jitted AdamW update, and performs
    exactly ONE host sync to materialize the logging metrics.

    ``host_syncs`` counts every device→host transfer the engine issues —
    benchmarks assert it stays ≤ 1 per optimizer step.

    With an ``exec_cache`` (:class:`~repro.train.exec_cache
    .ExecutableCache`, filled by ``train/warmup.AOTWarmupService`` and
    the planner's pre-warm hook) every dispatch first resolves a
    precompiled AOT executable keyed by its planner-level signature —
    a hit bypasses jax's tracing machinery entirely.  A miss compiles
    synchronously (the honest slow path), counted in ``retraces`` with
    the stall seconds in ``compile_wait_s``; when a ``universe``
    (``analysis/signatures.SignatureUniverse``) is attached, an
    out-of-universe miss logs a warning naming why the planner escaped
    the enumerable bucket set."""

    METRIC_NAMES = ("loss", "nll_sum", "weight_sum", "grad_norm", "lr")

    def __init__(self, cfg: ModelConfig,
                 opt_cfg: Optional[OptimizerConfig] = None, *,
                 impl: str = "ref", donate: bool = True,
                 weight_store=None,
                 exec_cache: Optional[ExecutableCache] = None,
                 universe=None):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.impl = impl
        self.donate = donate
        self.host_syncs = 0
        self.steps_done = 0
        # async RL: publish updated weights (copied — ours get donated)
        # after every optimizer step, and audit the off-policy lag of
        # each consumed plan (trainer step − oldest tree's version)
        self.weight_store = weight_store
        self.max_lag_seen = 0
        # AOT executable cache: retraces counts cold-bucket stalls the
        # warmup/prewarm path failed to hide (0 on an in-universe
        # stream after warmup — asserted by benchmarks and rl_loop)
        self.exec_cache = exec_cache
        self.universe = universe
        self.retraces = 0
        self.compile_wait_s = 0.0

    # -- AOT executable resolution ----------------------------------------
    def _exec_lookup(self, variant: str, sig, fn, args: tuple):
        """Resolve one dispatch: cache hit → the AOT-compiled executable;
        miss → synchronous ``lower().compile()`` (counted as a retrace,
        its wall time as exposed compile wait), then cached so the bucket
        stalls at most once per run."""
        key = exec_key(variant, sig, args)
        compiled = self.exec_cache.get(key)
        if compiled is not None:
            return compiled(*args)
        t0 = time.perf_counter()
        compiled, _ = self.exec_cache.compile_once(key, fn, args)
        self.compile_wait_s += time.perf_counter() - t0
        self.retraces += 1
        if self.universe is not None and sig[0] in ("packed", "wave"):
            ok, why = self.universe.contains(sig)
            if not ok:
                logger.warning(
                    "out-of-universe signature %s (%s): compiled "
                    "synchronously on the slow path — the planner "
                    "escaped the enumerable bucket set", sig, why)
            else:
                logger.info(
                    "in-universe signature %s was not prewarmed: "
                    "compiled synchronously (%s)", sig, variant)
        return compiled(*args)

    def _run(self, variant: str, sig, fn, args: tuple):
        if self.exec_cache is None:
            return fn(*args)
        return self._exec_lookup(variant, sig, fn, args)

    # -- gradient accumulation (no optimizer, no host sync) ---------------
    def accumulate(self, params, plan: ExecutionPlan):
        """Run the plan's executions; returns ``(grads, scal)`` — the
        fp32 gradient sum (normalized per tree) and the on-device
        ``[loss, nll_sum, weight_sum]`` vector.  Loss semantics match the
        pre-engine two-branch loop: mean over the step's trees."""
        scal = jnp.zeros((NUM_SCALARS,), jnp.float32)
        n = max(plan.num_trees, 1)
        has_waves = plan.partition is not None and plan.partition.waves
        if plan.packed is not None:
            batch = dict(plan.packed.inputs)
            batch["num_trees"] = n
            B, S = batch["tokens"].shape
            psig = packed_signature(B, S)
            if not has_waves:
                # single-execution fast path: the grads ARE the
                # accumulator — no param-sized zero buffer
                f = _packed_exec_fn(self.cfg, self.impl, self.donate,
                                    with_acc=False)
                return self._run("packed", psig, f, (params, batch, scal))
            acc = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32),
                               params)
            f = _packed_exec_fn(self.cfg, self.impl, self.donate)
            acc, scal = self._run("packed+acc", psig, f,
                                  (params, batch, acc, scal))
        else:
            acc = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32),
                               params)
        if has_waves:
            acc, scal = run_partition_plan(
                self.cfg, params, plan.partition, acc, scal,
                impl=self.impl,
                loss_scale=jnp.asarray(1.0 / n, jnp.float32),
                donate=self.donate,
                exec_lookup=(None if self.exec_cache is None
                             else self._exec_lookup))
        return acc, scal

    # -- one optimizer step ------------------------------------------------
    def step(self, params, opt_state, plan: ExecutionPlan):
        """Returns ``(params, opt_state, metrics)`` — metrics is a host
        dict (loss, nll, grad_norm, lr, …) pulled in a single transfer."""
        assert self.opt_cfg is not None, \
            "TreeTrainEngine.step needs an OptimizerConfig"
        grads, scal = self.accumulate(params, plan)
        upd = jitted_update(self.opt_cfg, self.donate)
        params, opt_state, om = self._run("update", ("update",), upd,
                                          (params, grads, opt_state))
        vec = jnp.concatenate(
            [scal, jnp.stack([om["grad_norm"], om["lr"]]
                             ).astype(jnp.float32)])
        host = self._sync(vec)
        metrics = dict(zip(self.METRIC_NAMES, host.tolist()))
        metrics["nll"] = metrics["nll_sum"] / max(metrics["weight_sum"],
                                                  1e-9)
        if plan.versions is not None:
            lag = self.steps_done - plan.versions[0]
            metrics["max_lag"] = lag
            self.max_lag_seen = max(self.max_lag_seen, lag)
        self.steps_done += 1
        if self.weight_store is not None:
            self.weight_store.publish(params, self.steps_done)
        return params, opt_state, metrics

    def warmup(self, params, opt_state, plan: ExecutionPlan):
        """Compile-warm every executable the plan exercises — the full
        accumulate + optimizer-update pipeline — WITHOUT the logging
        host sync: ``block_until_ready`` fences the compile+run but
        transfers nothing, so ``host_syncs`` stays 0 and the static
        auditor's one-host-sync proof (``repro.analysis``) covers warmup
        too.  Does not count as a step and publishes no weights; returns
        ``(params, opt_state)`` (donated inputs are consumed)."""
        assert self.opt_cfg is not None, \
            "TreeTrainEngine.warmup needs an OptimizerConfig"
        grads, _scal = self.accumulate(params, plan)
        upd = jitted_update(self.opt_cfg, self.donate)
        params, opt_state, _om = self._run("update", ("update",), upd,
                                           (params, grads, opt_state))
        jax.block_until_ready(params)
        return params, opt_state

    def _sync(self, vec: jax.Array) -> np.ndarray:
        """THE host sync: every device→host read the engine performs
        funnels through here so the count is auditable."""
        self.host_syncs += 1
        return np.asarray(vec)
