"""AdamW + cosine schedule + global-norm clipping (no external deps).

Optimizer state mirrors the parameter pytree, so it inherits parameter
sharding under pjit automatically (each moment tensor gets its parameter's
PartitionSpec via sharding.param_shardings on the state's leaves).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jax.tree.map(
        lambda a: jnp.zeros(a.shape, jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(a.astype(jnp.float32)))
                        for a in jax.tree.leaves(tree)))


def _decay_mask(params: Any) -> Any:
    """No weight decay on 1-D tensors (norm scales, biases)."""
    flat, treedef = jax.tree_util.tree_flatten(params)
    return jax.tree_util.tree_unflatten(
        treedef, [a.ndim > 1 for a in flat])


def adamw_update(cfg: OptimizerConfig, params: Any, grads: Any,
                 state: dict) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    b1, b2 = cfg.betas
    lr = lr_at(cfg, step)
    mask = _decay_mask(params)

    def upd(p, g, mu, nu, decay):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mu_hat = mu / (1 - b1 ** (step + 1))
        nu_hat = nu / (1 - b2 ** (step + 1))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"], mask)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step + 1}
    return new_params, new_state, {"grad_norm": gn, "lr": lr}
