"""Roofline analysis from the dry-run artifacts.

Three terms per (arch × shape), single-pod mesh (256 chips):

    compute    = FLOPs            / (chips × 197e12  bf16 FLOP/s)
    memory     = HBM bytes        / (chips × 819e9   B/s)
    collective = collective bytes / (chips × 50e9    B/s per ICI link)

Sources & methodology (also see EXPERIMENTS.md §Roofline):
  - FLOPs: analytic — 6·N·D for training (2·N·D forward-only), N = active
    params, D = tokens — plus the quadratic attention term.  XLA's
    ``cost_analysis()`` counts while-loop (scan-over-layers) bodies ONCE,
    so its raw 'flops' undercounts by ≈ the layer count; we record the raw
    value and the ratio for the remat/redundancy check instead of using it
    as the primary numerator.
  - HBM bytes: analytic traffic model (weights/grads/optimizer streams +
    activation read/write + KV/state cache reads), per chip.
  - Collective bytes: parsed per-op from the post-SPMD HLO (per-device
    shapes) with while-body ops multiplied by the scan trip count; op
    factors: all-reduce 2×, others 1× (ring cost per chip ≈ 2(N−1)/N ≈ 2
    and (N−1)/N ≈ 1 respectively).

Usage:
    python -m repro.launch.roofline --results dryrun_results --md
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

OP_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
             "all-to-all": 1.0, "collective-permute": 1.0}


# ---------------------------------------------------------------------------
# Analytic FLOPs / bytes
# ---------------------------------------------------------------------------

def _attn_flops_fwd(cfg, S: int, tokens: int) -> float:
    """Quadratic attention term (causal ⇒ ×1/2): 2·2·S·d_attn per token."""
    if cfg.attn is None:
        return 0.0
    d_attn = cfg.attn.n_heads * cfg.attn.head_dim
    n_attn_layers = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn_layers = -(-cfg.n_layers // cfg.hybrid.attn_every)
    win = cfg.attn.window
    eff_S = min(S, win) if win else S
    return tokens * eff_S * 0.5 * 4 * d_attn * n_attn_layers


def analytic_flops(cfg, shape) -> dict:
    from repro.configs.base import INPUT_SHAPES  # noqa: F401
    n_active = cfg.param_count(active_only=True)
    n_total = cfg.param_count(active_only=False)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        flops = 6.0 * n_active * tokens + 3 * _attn_flops_fwd(cfg, S,
                                                              tokens)
    elif shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * n_active * tokens + _attn_flops_fwd(cfg, S, tokens)
    else:  # decode: one token, attends over the S-token cache
        tokens = B
        flops = 2.0 * n_active * tokens
        if cfg.attn is not None:
            win = cfg.attn.window
            eff_S = min(S, win) if win else S
            d_attn = cfg.attn.n_heads * cfg.attn.head_dim
            n_attn = cfg.n_layers if cfg.family != "hybrid" else \
                -(-cfg.n_layers // cfg.hybrid.attn_every)
            flops += tokens * eff_S * 4 * d_attn * n_attn
    return {"model_flops": flops, "n_active": n_active, "n_total": n_total,
            "tokens": tokens}


def analytic_hbm_bytes(cfg, shape, chips: int) -> float:
    """Per-chip HBM traffic per step (napkin model, documented)."""
    n_total = cfg.param_count(active_only=False)
    n_active = cfg.param_count(active_only=True)
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, cfg.n_layers
    if shape.kind == "train":
        # weights(bf16 r+w) + grads(bf16) + adam moments (f32 r+w ×2)
        w_traffic = n_total / chips * (2 + 2 + 2 + 16)
        acts = B * S / chips * D * L * 20       # fwd+bwd residual r/w, f32ish
        return w_traffic + acts
    if shape.kind == "prefill":
        w_traffic = n_total / chips * 2
        acts = B * S / chips * D * L * 6
        return w_traffic + acts
    # decode: weights streamed once per token + cache read
    w_traffic = n_active / chips * 2
    cache = 0.0
    if cfg.attn is not None:
        win = cfg.attn.window
        eff_S = min(S, win) if win else S
        n_attn = cfg.n_layers if cfg.family != "hybrid" else \
            -(-cfg.n_layers // cfg.hybrid.attn_every)
        cache += (B * eff_S * cfg.attn.n_kv_heads * cfg.attn.head_dim
                  * 2 * 2 * n_attn) / chips
    if cfg.ssm is not None:
        di = cfg.ssm.d_inner(cfg.d_model)
        H = cfg.ssm.n_heads(cfg.d_model)
        st = H * (cfg.ssm.d_state if cfg.ssm.kind == "mamba2"
                  else cfg.ssm.head_dim) * cfg.ssm.head_dim
        cache += B * st * 4 * 2 * cfg.n_layers / chips
    return w_traffic + cache


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------

def load_results(results_dir: str, mesh: str = "single") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if r.get("mesh") == mesh and r.get("status") == "ok":
            out.append(r)
    return out


def analyse(rec: dict) -> dict:
    from repro.configs import get_config, long_context_variant
    from repro.configs.base import INPUT_SHAPES
    cfg = get_config(rec["arch"])
    if rec["shape"] == "long_500k":
        cfg = long_context_variant(cfg)
    shape = INPUT_SHAPES[rec["shape"]]
    chips = rec.get("chips", 256)

    af = analytic_flops(cfg, shape)
    t_compute = af["model_flops"] / (chips * PEAK_FLOPS)
    hbm = analytic_hbm_bytes(cfg, shape, chips)
    t_memory = hbm / HBM_BW
    coll_bytes = 0.0
    colls = rec.get("collectives", {})
    for op, s in colls.items():
        if isinstance(s, dict) and "bytes_with_loops" in s:
            coll_bytes += OP_FACTOR.get(op, 1.0) * s["bytes_with_loops"]
    t_coll = coll_bytes / ICI_BW

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    hlo_flops = rec.get("cost_analysis", {}).get("flops", 0.0)
    mult = rec.get("loop_multiplier", 1)
    suggest = {
        "compute": "compute-bound: increase arithmetic intensity is moot — "
                   "raise MFU via kernel fusion / better tiling "
                   "(tree-attention block skipping already removes "
                   "cross-branch FLOPs).",
        "memory": "memory-bound: cut HBM traffic — bf16 optimizer/state "
                  "sharding, fused update, activation-recompute instead of "
                  "spill, or (decode) shrink the cache (window/quant).",
        "collective": "collective-bound: reshard to cut the dominant "
                      "collective (expert-parallel all-to-all / FSDP "
                      "all-gather), or overlap with compute via async "
                      "collectives.",
    }[dominant]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "family": rec["family"],
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": af["model_flops"],
        "hlo_flops_raw": hlo_flops,
        # cost_analysis reports the per-device partitioned program with
        # while bodies counted once → correct by (chips × trip count):
        "hlo_flops_corrected_est": hlo_flops * mult * chips,
        "useful_flops_ratio_est": (af["model_flops"]
                                   / (hlo_flops * mult * chips)
                                   if hlo_flops else None),
        "collective_bytes": coll_bytes,
        "hbm_bytes_per_chip": hbm,
        "suggestion": suggest,
        "compile_s": rec.get("compile_s"),
        "temp_bytes_per_chip_est": rec.get("memory_analysis", {}).get(
            "temp_size_in_bytes"),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="dryrun_results/roofline.json")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()

    rows = [analyse(r) for r in load_results(args.results, args.mesh)]
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)

    if args.md:
        def fmt(t):
            return f"{t * 1e3:9.2f}"

        print("| arch | shape | compute ms | memory ms | collective ms "
              "| bound | useful-FLOP ratio |")
        print("|---|---|---:|---:|---:|---|---:|")
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
            ratio = r["useful_flops_ratio_est"]
            print(f"| {r['arch']} | {r['shape']} | "
                  f"{fmt(r['t_compute_s'])} | {fmt(r['t_memory_s'])} | "
                  f"{fmt(r['t_collective_s'])} | {r['dominant']} | "
                  f"{ratio:.2f} |" if ratio else
                  f"| {r['arch']} | {r['shape']} | "
                  f"{fmt(r['t_compute_s'])} | {fmt(r['t_memory_s'])} | "
                  f"{fmt(r['t_collective_s'])} | {r['dominant']} | n/a |")


if __name__ == "__main__":
    main()
