"""Multi-pod dry-run: prove every (arch × input-shape × mesh) lowers and
compiles under the production sharding, and harvest roofline inputs.

MUST set the fake device count before ANY jax import (jax locks the device
count on first init) — hence the first two lines below.

Per combination we record to JSON: compile status/time,
``compiled.cost_analysis()`` (FLOPs/bytes), ``compiled.memory_analysis()``
(per-device bytes — proves it fits), and every collective op parsed from
the post-SPMD HLO with a while-loop trip-count multiplier (scan-over-
layers bodies are counted once by XLA; we re-multiply by the known trip
counts — see launch/roofline.py for the methodology notes).

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b                 # all shapes, both meshes
  python -m repro.launch.dryrun --arch all --shape train_4k --mesh single
  python -m repro.launch.dryrun --arch all                      # the full 40×2 matrix
"""
import os

os.environ["XLA_FLAGS"] = os.environ.get(
    "XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import sharding as sh                     # noqa: E402
from repro.analysis.hlo_comms import (loop_multiplier,  # noqa: E402
                                      parse_collectives)
from repro.configs import (ARCH_IDS, get_config,     # noqa: E402
                           long_context_variant, supports_shape)
from repro.configs.base import INPUT_SHAPES, ModelConfig  # noqa: E402
from repro.launch.mesh import data_axes, make_production_mesh  # noqa: E402
from repro.models.layers import logits_from_hidden   # noqa: E402
from repro.models.model import (loss_and_metrics,    # noqa: E402
                                max_conv_taps, needs_chunks)
from repro.models import transformer as tf           # noqa: E402
from repro.serve import decode as serve              # noqa: E402
from repro.train.optimizer import (OptimizerConfig,  # noqa: E402
                                   adamw_update, init_opt_state)

SDS = jax.ShapeDtypeStruct


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: ModelConfig, B: int, S: int) -> dict:
    d = {
        "tokens": SDS((B, S), jnp.int32),
        "pos_ids": SDS((B, S), jnp.int32),
        "kv_last": SDS((B, S), jnp.int32),
        "weight": SDS((B, S), jnp.float32),
        "prev_idx": SDS((B, S), jnp.int32),
        "valid": SDS((B, S), jnp.bool_),
    }
    if needs_chunks(cfg):
        d["chunk_parent"] = SDS((B, S // cfg.ssm.chunk_size), jnp.int32)
        d["prev_pows"] = SDS((B, S, max(1, max_conv_taps(cfg))), jnp.int32)
    if cfg.frontend is not None:
        d["extra_embeds"] = SDS((B, cfg.frontend_len, cfg.d_model), _dt(cfg))
    return d


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda k: tf.init_params(cfg, k),
                          jax.random.key(0))


def build_train_fn(cfg: ModelConfig, impl: str):
    opt_cfg = OptimizerConfig()
    micro = int(os.environ.get("DRYRUN_MICROBATCH", "1"))

    def grad_fn(params, batch):
        (loss, _m), grads = jax.value_and_grad(
            lambda p: loss_and_metrics(cfg, p, batch, impl),
            has_aux=True)(params)
        return loss, grads

    def step(params, opt_state, batch):
        if micro > 1:
            # gradient accumulation: scan over microbatches; per-device
            # activation temp shrinks by ~micro× at identical math
            def split(a):
                return a.reshape(micro, a.shape[0] // micro, *a.shape[1:])

            mb = jax.tree.map(split, batch)

            def body(acc, b):
                loss, grads = grad_fn(params, b)
                return jax.tree.map(
                    lambda x, g: x + g.astype(jnp.float32), acc, grads
                ), loss

            zero = jax.tree.map(
                lambda a: jnp.zeros(a.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(body, zero, mb)
            loss = losses.sum()
        else:
            loss, grads = grad_fn(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state)
        return params, opt_state, loss, om["grad_norm"]

    return step


def build_prefill_fn(cfg: ModelConfig, impl: str):
    def prefill(params, tokens, extra=None):
        B, S = tokens.shape
        ar = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch = {
            "tokens": tokens,
            "pos_ids": ar,
            "kv_last": jnp.full((B, S), S - 1, jnp.int32),
            "prev_idx": ar - 1,
            "valid": jnp.ones((B, S), bool),
            "weight": jnp.zeros((B, S), jnp.float32),
        }
        if needs_chunks(cfg):
            C = S // cfg.ssm.chunk_size
            batch["chunk_parent"] = jnp.broadcast_to(
                jnp.arange(C, dtype=jnp.int32) - 1, (B, C))
            taps = max(1, max_conv_taps(cfg))
            batch["prev_pows"] = jnp.maximum(
                ar[..., None] - jnp.arange(1, taps + 1, dtype=jnp.int32),
                -1)
        if extra is not None:
            batch["extra_embeds"] = extra
        hidden, _ = tf.forward(cfg, params, batch, impl)
        logits = logits_from_hidden(params["embed"], params.get("lm_head"),
                                    hidden[:, -1:])
        return sh.shard_logits(logits)

    return prefill


def build_decode_fn(cfg: ModelConfig):
    def step(params, cache, tokens, pos, write_idx):
        return serve._decode_step(cfg, params, cache, tokens, pos, write_idx)

    return step


# cache_shardings moved to repro.sharding (shared with shardlint); the
# HLO collective parser moved to repro.analysis.hlo_comms — both kept as
# names here for the existing callers of this module.
cache_shardings = sh.cache_shardings


# ---------------------------------------------------------------------------
# One combo
# ---------------------------------------------------------------------------

def run_combo(arch: str, shape_name: str, multi_pod: bool, impl: str,
              outdir: str) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    if not supports_shape(cfg, shape_name):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "family": cfg.family, "status": "skipped",
                "reason": "no long-decode semantics (see DESIGN.md)"}
    if shape_name == "long_500k":
        cfg = long_context_variant(cfg)
    if os.environ.get("DRYRUN_REMAT"):
        cfg = cfg.replace(remat=os.environ["DRYRUN_REMAT"])

    mesh_shape = None
    if os.environ.get("DRYRUN_MESH_SHAPE"):
        mesh_shape = tuple(int(x) for x in
                           os.environ["DRYRUN_MESH_SHAPE"].split("x"))
    mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
    daxes = data_axes(multi_pod)
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "multi" if multi_pod else "single",
                 "family": cfg.family,
                 "chips": int(np.prod(list(mesh.shape.values())))}
    t0 = time.time()
    seq_par = bool(os.environ.get("DRYRUN_SEQ_PARALLEL"))
    rec["seq_parallel"] = seq_par
    rec["remat"] = cfg.remat
    with sh.use_mesh(mesh, data_axes=daxes, seq_parallel=seq_par):
        pspecs = params_specs(cfg)
        pshard = sh.param_shardings(pspecs, mesh, fsdp_axis="data")
        if shape.kind == "train":
            fn = build_train_fn(cfg, impl)
            ospecs = jax.eval_shape(init_opt_state, pspecs)
            oshard = {"mu": sh.param_shardings(ospecs["mu"], mesh,
                                               fsdp_axis="data"),
                      "nu": sh.param_shardings(ospecs["nu"], mesh,
                                               fsdp_axis="data"),
                      "step": NamedSharding(mesh, P())}
            bspecs = train_batch_specs(cfg, shape.global_batch,
                                       shape.seq_len)
            bshard = sh.batch_shardings(bspecs, mesh, daxes)
            jf = jax.jit(fn, in_shardings=(pshard, oshard, bshard))
            lowered = jf.lower(pspecs, ospecs, bspecs)
        elif shape.kind == "prefill":
            fn = build_prefill_fn(cfg, impl)
            B, S = shape.global_batch, shape.seq_len
            args = [pspecs, SDS((B, S), jnp.int32)]
            shards = [pshard, sh.batch_shardings(args[1], mesh, daxes)]
            if cfg.frontend is not None:
                args.append(SDS((B, cfg.frontend_len, cfg.d_model),
                                _dt(cfg)))
                shards.append(sh.batch_shardings(args[2], mesh, daxes))
            jf = jax.jit(fn, in_shardings=tuple(shards))
            lowered = jf.lower(*args)
        else:  # decode
            fn = build_decode_fn(cfg)
            B, S = shape.global_batch, shape.seq_len
            enc_len = cfg.encdec.src_len if cfg.encdec else 0
            cspecs = jax.eval_shape(
                lambda: serve._init_cache(cfg, B, S, enc_len))
            cshard = cache_shardings(cspecs, mesh, daxes)
            args = (pspecs, cspecs, SDS((B, 1), jnp.int32),
                    SDS((B,), jnp.int32), SDS((), jnp.int32))
            shards = (pshard, cshard,
                      sh.batch_shardings(args[2], mesh, daxes),
                      sh.batch_shardings(args[3], mesh, daxes),
                      NamedSharding(mesh, P()))
            jf = jax.jit(fn, in_shardings=shards)
            lowered = jf.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        rec["status"] = "ok"

        try:
            ca = compiled.cost_analysis()
            rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                    if isinstance(v, (int, float))
                                    and k in ("flops", "bytes accessed",
                                              "transcendentals",
                                              "optimal_seconds")}
        except Exception as e:  # noqa: BLE001
            rec["cost_analysis"] = {"error": str(e)[:200]}
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                a: int(getattr(ma, a)) for a in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes")
                if hasattr(ma, a)}
        except Exception as e:  # noqa: BLE001
            rec["memory_analysis"] = {"error": str(e)[:200]}
        try:
            hlo = compiled.as_text()
            if os.environ.get("DRYRUN_DUMP_HLO"):
                tag = f"{arch}__{shape_name}__" \
                      f"{'multi' if multi_pod else 'single'}"
                with open(os.path.join(outdir, tag + ".hlo.txt"), "w") as f:
                    f.write(hlo)
            colls = parse_collectives(hlo)
            mult = loop_multiplier(cfg)
            chunks = (shape.seq_len // cfg.ssm.chunk_size
                      if needs_chunks(cfg) and shape.kind != "decode"
                      else 1)
            summary: dict[str, dict] = {}
            for c in colls:
                s = summary.setdefault(c["op"], {"count": 0, "bytes": 0,
                                                 "bytes_with_loops": 0})
                s["count"] += 1
                s["bytes"] += c["bytes"]
                m = 1
                if c["loop_depth"] == 1:
                    m = mult
                elif c["loop_depth"] >= 2:
                    m = mult * chunks
                s["bytes_with_loops"] += c["bytes"] * m
            rec["collectives"] = summary
            rec["loop_multiplier"] = mult
            rec["chunk_multiplier"] = chunks
            rec["hlo_bytes"] = len(hlo)
        except Exception as e:  # noqa: BLE001
            rec["collectives"] = {"error": str(e)[:200]}
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--impl", default="chunked")
    ap.add_argument("--out", default="dryrun_results")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS[:10] if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                print(f"[run ] {tag}", flush=True)
                try:
                    rec = run_combo(arch, shape, mp, args.impl, args.out)
                except Exception:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error",
                           "traceback": traceback.format_exc()[-4000:]}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"[done] {tag}: {rec.get('status')} "
                      f"({rec.get('total_s', '?')}s)", flush=True)


if __name__ == "__main__":
    main()
