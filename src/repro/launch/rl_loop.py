"""Closed-loop async tree-RL launcher: rollout → tree → train.

  PYTHONPATH=src python -m repro.launch.rl_loop --arch qwen3-8b --smoke

One process, three overlapped stages (ROADMAP item 1 / paper §2's RL
model-update phase):

  generation   a daemon thread decodes ``--groups`` rollout groups per
               optimizer step — ``--k`` branches sharing one prompt's
               prefilled KV (``serve/rollout``, prefix computed ONCE per
               group) — merges each group into a GRPO advantage tree and
               queues it (``serve/service``);
  planning     ``train/planner.plans`` consumes the live queue exactly
               like a synthetic stream: lookahead Tree Packing, replica
               balancing, background materialization;
  training     ``TreeTrainEngine.step`` with ``loss_mode="rl"``; every
               step publishes fresh weights back to the generator's
               :class:`WeightStore`.

Staleness is *bounded*, not best-effort: generation blocks until the
trainer is within ``--max-ahead`` steps, the queue holds at most
``--max-ahead`` step-batches, and the engine audits each consumed plan's
weight versions — the run fails loudly if the observed lag ever exceeds
``max_ahead + lookahead − 1``.

``--check-grads`` freezes one rollout group at the final weights and
verifies the online plan path reproduces the offline ``loss_mode="rl"``
gradients to ≤1e-6 max-rel.  ``--ckpt-every``/``--resume`` give the
long-running service a mid-stream restart point.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as sh
from repro.configs import get_config
from repro.data.loader import LoaderConfig
from repro.launch.mesh import data_axis_size, make_host_mesh
from repro.models.model import init_params
from repro.serve.rollout import RolloutConfig, rollout_group
from repro.serve.service import (AsyncTreeRLService, ServiceConfig,
                                 WeightStore)
from repro.train.checkpoint import (load_checkpoint, load_meta,
                                    save_checkpoint)
from repro.train.engine import TreeTrainEngine
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.planner import PlannerConfig, plan_window, plans
from repro.train.train_step import make_grad_fn


def max_rel_err(a, b) -> float:
    """max over leaves of |a−b| / (max|b| + eps)."""
    err = 0.0
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        la, lb = np.asarray(la), np.asarray(lb)
        denom = max(float(np.abs(lb).max()), 1e-12)
        err = max(err, float(np.abs(la - lb).max()) / denom)
    return err


def check_frozen_grads(cfg, lc, pcfg, params, trees, impl) -> float:
    """Online plan path vs offline ``loss_mode="rl"`` gradients for a
    frozen rollout set; returns the max-rel error."""
    steps = [ps for ps in plan_window(cfg, lc, pcfg, [list(trees)])
             if not ps.is_empty]
    assert len(steps) == 1, "frozen rollout set must plan into one step"
    plan = steps[0].execution_plan()
    assert plan.packed is not None and plan.num_oversized == 0, \
        "grad check wants a purely packed plan (raise --seq-len)"
    engine = TreeTrainEngine(cfg, impl=impl, donate=False)
    grads, _ = engine.accumulate(params, plan)
    batch = dict(plan.packed.inputs)
    batch["num_trees"] = plan.num_trees
    _, ref, _ = make_grad_fn(cfg, impl)(params, batch)
    return max_rel_err(grads, ref)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=4,
                    help="optimizer steps (= generation step-batches)")
    ap.add_argument("--groups", type=int, default=2,
                    help="rollout groups (prompts) per optimizer step")
    ap.add_argument("--k", type=int, default=4,
                    help="branch rollouts per prompt (share the prefix KV)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8,
                    help="decode steps per branch")
    ap.add_argument("--seq-len", type=int, default=96)
    ap.add_argument("--rows", type=int, default=None)
    ap.add_argument("--lookahead", type=int, default=1)
    ap.add_argument("--plan-workers", type=int, default=1)
    ap.add_argument("--max-ahead", type=int, default=1,
                    help="generation may run this many optimizer steps "
                         "ahead of the weights it samples (the staleness "
                         "bound)")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--impl", default="ref",
                    choices=["ref", "chunked", "pallas"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-grads", action="store_true",
                    help="verify online vs offline RL gradients at exit")
    ap.add_argument("--compile-cache-dir", default=None,
                    help="persistent jax compilation cache: a restarted "
                         "service re-loads its compiled modules from disk")
    ap.add_argument("--save", default=None)
    ap.add_argument("--ckpt-every", type=int, default=None)
    ap.add_argument("--resume", default=None)
    args = ap.parse_args()
    if args.ckpt_every is not None and not args.save:
        ap.error("--ckpt-every needs --save (the checkpoint directory)")

    cfg = get_config(args.arch, smoke=args.smoke)
    tree_cap = args.prompt_len + args.k * args.max_new
    if tree_cap > args.seq_len:
        ap.error(f"a rollout tree can reach {tree_cap} unique tokens "
                 f"(prompt {args.prompt_len} + {args.k}×{args.max_new}) "
                 f"> --seq-len {args.seq_len}: raise --seq-len to "
                 f"guarantee zero drops")
    lag_bound = args.max_ahead + args.lookahead - 1
    print(f"[rl] arch={cfg.name} k={args.k} groups={args.groups} "
          f"steps={args.steps} max_ahead={args.max_ahead} "
          f"(lag bound {lag_bound})")

    mesh, daxes = make_host_mesh(), ("data",)
    ndata = data_axis_size(mesh, daxes)
    rows = args.rows if args.rows is not None else max(2, ndata)
    opt_cfg = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(2, args.steps // 10))
    lc = LoaderConfig(seq_len=args.seq_len, batch_rows=rows,
                      trees_per_batch=args.groups, mode="tree",
                      seed=args.seed, loss_mode="rl", auto_partition=True)
    pcfg = PlannerConfig(lookahead=args.lookahead,
                         plan_workers=args.plan_workers,
                         num_replicas=ndata, max_rows=rows)
    rc = RolloutConfig(k=args.k, prompt_len=args.prompt_len,
                       max_new=args.max_new, temperature=args.temperature,
                       impl=args.impl)
    sc = ServiceConfig(groups_per_step=args.groups,
                       max_ahead_steps=args.max_ahead, rollout=rc,
                       seed=args.seed)

    if args.compile_cache_dir:
        from repro.train.warmup import configure_compile_cache
        d = configure_compile_cache(args.compile_cache_dir)
        print(f"[rl] persistent compile cache: {d}")

    with sh.use_mesh(mesh, data_axes=daxes):
        params = init_params(cfg, jax.random.key(args.seed))
        opt_state = init_opt_state(params)
        done = 0
        if args.resume:
            params, opt_state = load_checkpoint(args.resume, params,
                                                opt_state)
            done = int(load_meta(args.resume).get("steps", 0))
            print(f"[rl] resumed {args.resume} @ step {done}")

        # warm every executable OUTSIDE the measured loop, through the
        # AOT warmup service (train/warmup): a probe rollout window is
        # planned and EVERY signature it produces — packed batch, all
        # partition waves, optimizer update — is AOT-compiled into the
        # executable cache the engine dispatches from (the hand-rolled
        # predecessor warmed only the window's first step).  The rollout
        # prefill/decode-scan warms as a side effect of generating the
        # probe trees; mid-loop, the planner pipeline pre-warms each new
        # step's exact executables on its build threads before the
        # engine can consume it, so the loop never blocks on a compile.
        from repro.core.plan_cost import CompileCacheSim
        from repro.train.warmup import AOTWarmupService
        warm = AOTWarmupService(cfg, lc, pcfg, params=params,
                                opt_cfg=opt_cfg, opt_state=opt_state,
                                impl=args.impl, sim=CompileCacheSim())
        wtrees = [rollout_group(cfg, params,
                                np.zeros(args.prompt_len, np.int32) + g,
                                rc, jax.random.key(g))[0]
                  for g in range(args.groups)]
        wsteps = [ps for ps in plan_window(cfg, lc, pcfg, [wtrees])
                  if not ps.is_empty]
        for ps in wsteps:
            warm.prewarm(step=ps)
        if wsteps:
            # run the warm window through the SHARED cache twice: the
            # update's donated inputs switch to its own committed output
            # layout after step one, and the second pass proves the AOT
            # executables absorb that without retracing
            weng = TreeTrainEngine(cfg, opt_cfg, impl=args.impl,
                                   exec_cache=warm.cache,
                                   universe=warm.universe)
            p2 = jax.tree.map(jnp.copy, params)
            o2 = jax.tree.map(jnp.copy, opt_state)
            for _ in range(2):
                p2, o2 = weng.warmup(p2, o2, wsteps[0].execution_plan())
            assert weng.host_syncs == 0, "warmup must not sync"
            assert weng.retraces == 0, \
                "prewarmed executables must cover the warm window"
            # updated params can carry different buffer layouts than the
            # init ones — warm the rollout executables for that variant
            # too, or the generator recompiles mid-loop
            rollout_group(cfg, jax.tree.map(jnp.copy, p2),
                          np.zeros(args.prompt_len, np.int32), rc,
                          jax.random.key(0))
            del p2, o2
        print(f"[rl] aot-warmup: {len(warm.cache)} executables "
              f"({warm.cache.compile_s:.1f}s compile) over "
              f"{len(warm.cache.signatures())} signatures")

        store = WeightStore(params, version=done)
        engine = TreeTrainEngine(cfg, opt_cfg, impl=args.impl,
                                 weight_store=store,
                                 exec_cache=warm.cache,
                                 universe=warm.universe)
        engine.steps_done = done
        svc = AsyncTreeRLService(cfg, store, sc,
                                 num_steps=args.steps).start()
        pipe = plans(cfg, lc, svc.tree_batches(), pcfg, warmup=warm)

        dropped = 0
        history = []
        t0 = time.time()
        for ps in pipe:
            plan = ps.execution_plan()
            dropped += plan.dropped
            if plan.is_empty:
                continue
            ts = time.time()
            params, opt_state, m = engine.step(params, opt_state, plan)
            history.append(m)
            print(f"step {engine.steps_done - 1:4d} "
                  f"loss {m['loss']:10.4f} nll/tok {m['nll']:7.4f} "
                  f"lag {m.get('max_lag', 0)} "
                  f"{(time.time() - ts) * 1e3:7.1f}ms", flush=True)
            if args.ckpt_every and engine.steps_done % args.ckpt_every == 0:
                save_checkpoint(args.save, params, opt_state,
                                meta={"arch": cfg.name,
                                      "steps": engine.steps_done})
        svc.join(10)
        wall = time.time() - t0

        st = svc.stats
        losses = [m["loss"] for m in history]
        # trainer-visible stall: every ms the train loop spent waiting on
        # a plan (which transitively waits on generation) — the honest
        # "exposed generation" number; queue-side wait (the planner's
        # prefetch thread blocking ahead of need) is reported separately
        exposed = pipe.exposed_s
        overlap = 1.0 - exposed / max(st.gen_busy_s, 1e-9)
        print(f"[rl] {len(history)} optimizer steps, "
              f"{st.trees_generated} trees, {dropped} dropped, "
              f"{wall:.1f}s wall")
        print(f"[rl] staleness: max lag {engine.max_lag_seen} "
              f"(bound {lag_bound}), min version {st.min_version}")
        print(f"[rl] generation: {st.gen_busy_s * 1e3:.0f}ms busy, "
              f"{exposed * 1e3:.0f}ms exposed to training "
              f"(overlap {overlap:.0%}; queue wait "
              f"{st.exposed_wait_s * 1e3:.0f}ms); "
              f"prefill {st.prefill_tokens} tok "
              f"(+{st.saved_prefill_tokens} reused via shared KV), "
              f"decode {st.decode_tokens} tok")
        print(f"[rl] plan-ahead: {pipe.built} plans, "
              f"{pipe.build_s * 1e3:.0f}ms built")
        print(f"[rl] aot: {engine.retraces} mid-loop retraces, "
              f"{engine.compile_wait_s * 1e3:.0f}ms exposed compile "
              f"wait, {warm.prewarmed} executables prewarmed in-stream")
        assert dropped == 0, f"{dropped} trees dropped"
        if args.smoke:
            # the loop's whole signature stream was prewarmed on the
            # pipeline's build threads — a retrace means the AOT cache
            # missed a shape the planner emitted
            assert engine.retraces == 0, \
                f"{engine.retraces} mid-loop retraces (AOT cache missed)"
        assert engine.max_lag_seen <= lag_bound, \
            (engine.max_lag_seen, lag_bound)
        assert all(np.isfinite(losses)), losses
        assert len(history) >= min(args.steps, 1)
        if args.steps >= 4:
            # short runs are dominated by the unavoidable pipeline-fill
            # wait on the very first plan; only judge overlap once it
            # can amortize
            assert exposed < 0.5 * st.gen_busy_s, \
                (f"generation not overlapped: {exposed * 1e3:.0f}ms "
                 f"exposed vs {st.gen_busy_s * 1e3:.0f}ms busy")

        if args.check_grads:
            # freeze one rollout group at the final weights and replay it
            # through the offline path
            tree, _ = rollout_group(
                cfg, params, np.arange(args.prompt_len) % cfg.vocab_size,
                rc, jax.random.key(args.seed + 1))
            err = check_frozen_grads(cfg, lc, pcfg, params, [tree],
                                     args.impl)
            print(f"[rl] frozen-rollout grad check: max-rel {err:.2e}")
            assert err <= 1e-6, err

        if args.save:
            save_checkpoint(args.save, params, opt_state,
                            meta={"arch": cfg.name,
                                  "steps": engine.steps_done})
            print(f"[rl] saved → {args.save}")


if __name__ == "__main__":
    main()
