"""Production mesh builders.

Single pod: 16×16 = 256 chips, (data, model).
Multi-pod:  2×16×16 = 512 chips, (pod, data, model) — the "pod" axis is a
second data-parallel dimension whose collectives cross the inter-pod DCI.

Functions, not module constants: importing this module must not touch jax
device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False,
                         shape: tuple[int, ...] | None = None):
    """shape: optional (data, model) override for the 256 chips of one pod
    — the §Perf mesh-shape experiments (e.g. (64, 4) or (256, 1) for
    FSDP-dominant layouts on ≤8B dense models)."""
    if shape is not None:
        assert not multi_pod and len(shape) == 2
        return jax.make_mesh(shape, ("data", "model"))
    mshape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(mshape, axes)


def data_axes(multi_pod: bool = False) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def make_host_mesh():
    """(local_devices, 1) mesh for single-host runs of the launcher: the
    data axis spans every local device, so ``--mesh host`` on a multichip
    host data-parallelizes instead of pinning everything to device 0.

    Row divisibility is no longer the user's problem: the plan-ahead
    scheduler (train/planner) sizes every batch's row count to a multiple
    of this mesh's data axis (``data_axis_size``); the launcher errors
    only when the user *forces* an indivisible ``--rows``."""
    return jax.make_mesh((jax.local_device_count(), 1), ("data", "model"))


def data_axis_size(mesh, daxes: tuple[str, ...] = ("data",)) -> int:
    """Number of data-parallel replicas = product of the mesh's data axes
    — the row multiple the planner balances batches against."""
    n = 1
    for a in daxes:
        n *= mesh.shape[a]
    return n
