"""Production mesh topology — the single source of truth.

Single pod: 16×16 = 256 chips, (data, model).
Multi-pod:  2×16×16 = 512 chips, (pod, data, model) — the "pod" axis is a
second data-parallel dimension whose collectives cross the inter-pod DCI.

Every topology is described ONCE as a :class:`MeshDescriptor` — logical
shape, axis names, which axes are data-parallel, and which axes' edges
cross the inter-pod DCI (everything else is on-pod ICI).  The runtime
``use_mesh`` path consumes ``descriptor.build()`` (a real ``jax.Mesh``);
shardlint (``repro.analysis.comms_audit``) consumes the same descriptor
to lower under fake/abstract devices and to attribute collective bytes to
ICI vs DCI edges — so the auditor can never drift from the topology the
launcher actually runs.

Functions, not module constants: importing this module must not touch jax
device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax


@dataclass(frozen=True)
class MeshDescriptor:
    """Logical description of one mesh topology.

    ``dci_axes``: axes whose collectives cross the inter-pod data-center
    interconnect; collectives spanning only the remaining (``ici_axes``)
    stay on the pod's ICI.  ``build()`` materializes the jax Mesh (needs
    that many devices — real or ``--xla_force_host_platform_device_count``
    fakes); ``abstract()`` needs zero devices and supports host-side spec
    math only (jax 0.4 AbstractMesh cannot lower)."""
    name: str
    shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    data_axes: tuple[str, ...]
    model_axis: str = "model"
    dci_axes: tuple[str, ...] = ()

    @property
    def device_count(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def ici_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.axis_names if a not in self.dci_axes)

    def axis_size(self, name: str) -> int:
        return self.shape[self.axis_names.index(name)]

    @property
    def data_axis_size(self) -> int:
        n = 1
        for a in self.data_axes:
            n *= self.axis_size(a)
        return n

    def build(self) -> jax.sharding.Mesh:
        return jax.make_mesh(self.shape, self.axis_names)

    def abstract(self):
        from jax.sharding import AbstractMesh
        return AbstractMesh(tuple(zip(self.axis_names, self.shape)))


def production_descriptor(multi_pod: bool = False,
                          shape: tuple[int, ...] | None = None
                          ) -> MeshDescriptor:
    """The deployment topologies.  ``shape``: optional (data, model)
    override for the 256 chips of one pod — the §Perf mesh-shape
    experiments (e.g. (64, 4) or (256, 1) for FSDP-dominant layouts on
    ≤8B dense models)."""
    if shape is not None:
        assert not multi_pod and len(shape) == 2
        return MeshDescriptor(name=f"pod{shape[0]}x{shape[1]}",
                              shape=tuple(shape),
                              axis_names=("data", "model"),
                              data_axes=("data",))
    if multi_pod:
        return MeshDescriptor(name="multi_pod", shape=(2, 16, 16),
                              axis_names=("pod", "data", "model"),
                              data_axes=("pod", "data"),
                              dci_axes=("pod",))
    return MeshDescriptor(name="single_pod", shape=(16, 16),
                          axis_names=("data", "model"),
                          data_axes=("data",))


def host_descriptor(n_devices: int | None = None) -> MeshDescriptor:
    """(local_devices, 1) topology for single-host launcher runs: the data
    axis spans every local device, so ``--mesh host`` on a multichip host
    data-parallelizes instead of pinning everything to device 0."""
    n = jax.local_device_count() if n_devices is None else n_devices
    return MeshDescriptor(name=f"host{n}", shape=(n, 1),
                          axis_names=("data", "model"),
                          data_axes=("data",))


def make_production_mesh(*, multi_pod: bool = False,
                         shape: tuple[int, ...] | None = None):
    return production_descriptor(multi_pod, shape).build()


def data_axes(multi_pod: bool = False) -> tuple[str, ...]:
    return production_descriptor(multi_pod).data_axes


def make_host_mesh():
    """See :func:`host_descriptor`.

    Row divisibility is no longer the user's problem: the plan-ahead
    scheduler (train/planner) sizes every batch's row count to a multiple
    of this mesh's data axis (``data_axis_size``); the launcher errors
    only when the user *forces* an indivisible ``--rows``."""
    return host_descriptor().build()


def data_axis_size(mesh, daxes: tuple[str, ...] = ("data",)) -> int:
    """Number of data-parallel replicas = product of the mesh's data axes
    — the row multiple the planner balances batches against."""
    n = 1
    for a in daxes:
        n *= mesh.shape[a]
    return n
