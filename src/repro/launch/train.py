"""Training launcher.

Runs tree-training (or the sep-avg baseline) on synthetic agentic trees:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \\
      --steps 50 --mode tree

``--auto-partition`` routes trees larger than one row through
Redundancy-Free Tree Partitioning (wave-scheduled, ``--capacity`` token
cap per partition) instead of silently dropping them — zero data loss.

``--mesh host`` (default) runs on the local device(s); ``--mesh single``/
``multi`` builds the production mesh (requires the dry-run's fake-device
env when not on a real pod — intended for lowering checks; real training
on hardware uses the same code path).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.configs import get_config
from repro.core.gateway import packed_partitioned_value_and_grad
from repro.data.loader import LoaderConfig, step_batches
from repro.launch.mesh import data_axes, make_host_mesh, \
    make_production_mesh
from repro.models.model import init_params
from repro.train.checkpoint import save_checkpoint
from repro.train.optimizer import OptimizerConfig, adamw_update, \
    init_opt_state
from repro.train.train_step import make_grad_fn, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--mode", default="tree", choices=["tree", "baseline"])
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--rows", type=int, default=2)
    ap.add_argument("--trees", type=int, default=6)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--impl", default="ref",
                    choices=["ref", "chunked", "pallas"])
    ap.add_argument("--auto-partition", action="store_true",
                    help="train oversized trees via wave-scheduled "
                         "partitioning instead of dropping them")
    ap.add_argument("--capacity", type=int, default=None,
                    help="partition token cap (default: --seq-len)")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    print(f"[train] arch={cfg.name} family={cfg.family} mode={args.mode} "
          f"impl={args.impl}")

    if args.auto_partition:
        if args.mode != "tree":
            ap.error("--auto-partition requires --mode tree (partitioning "
                     "is a tree-serialization feature; baseline mode "
                     "would silently drop oversized trees)")
        cap = args.capacity if args.capacity is not None else args.seq_len
        if not 0 < cap <= args.seq_len:
            ap.error(f"--capacity {cap} must be in (0, --seq-len "
                     f"{args.seq_len}]")
        if cfg.ssm is not None and cap % cfg.ssm.chunk_size != 0:
            ap.error(f"--capacity {cap} must be a multiple of the SSM "
                     f"chunk size {cfg.ssm.chunk_size}")
        args.capacity = cap

    if args.mesh == "host":
        mesh, daxes = make_host_mesh(), ("data",)
        ndata = mesh.shape["data"]
        if args.rows % ndata:
            ap.error(f"--rows {args.rows} must be a multiple of the host "
                     f"mesh's data axis ({ndata} local devices) so batch "
                     f"rows shard evenly; pick --rows "
                     f"{((args.rows // ndata) + 1) * ndata} or run fewer "
                     f"devices")
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        daxes = data_axes(args.mesh == "multi")

    opt_cfg = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(2, args.steps // 10))
    lc = LoaderConfig(seq_len=args.seq_len, batch_rows=args.rows,
                      trees_per_batch=args.trees, mode=args.mode,
                      kind="agentic", seed=args.seed,
                      auto_partition=args.auto_partition,
                      capacity=args.capacity,
                      gen_kwargs=dict(turn_len_range=(8, 48),
                                      num_turns=4))

    with sh.use_mesh(mesh, data_axes=daxes):
        params = init_params(cfg, jax.random.key(args.seed))
        opt_state = init_opt_state(params)

        tokens_done = 0
        part_trees = part_tokens = dropped_total = 0
        t0 = time.time()
        history = []
        if args.auto_partition:
            # grads of the packed batch and of the partitioned oversized
            # trees accumulate into ONE optimizer step (paper §3.4: the
            # partition stays inside the gradient-accumulation step)
            gfn = make_grad_fn(cfg, impl=args.impl)
            update_fn = jax.jit(
                lambda p, g, s: adamw_update(opt_cfg, p, g, s),
                donate_argnums=(0, 1, 2))
            cap = lc.capacity or lc.seq_len
            for i, sb in enumerate(step_batches(cfg, lc, args.steps)):
                ts = time.time()
                n_trees = max(sb.num_trees, 1)
                loss, grads, m = 0.0, None, {}
                nll = float("nan")
                if sb.inputs is not None:
                    sb.inputs["num_trees"] = n_trees
                    li, grads, m = gfn(params, sb.inputs)
                    loss += float(li)
                    nll = float(m["token_nll_mean"])
                    tokens_done += int(sb.tb.valid.sum())
                dropped_total += sb.dropped
                if sb.oversized:
                    tp = time.time()
                    l_p, g_p, pinfo = packed_partitioned_value_and_grad(
                        cfg, params, sb.oversized, cap,
                        seq_len=lc.seq_len, impl=args.impl,
                        loss_mode=lc.loss_mode, max_rows=lc.batch_rows)
                    m["partition_sec"] = time.time() - tp
                    loss += l_p / n_trees
                    g_p = jax.tree.map(lambda a: a / n_trees, g_p)
                    # accumulate in fp32: the wave driver's fp32 grads
                    # must not round through the packed grads' bf16
                    grads = g_p if grads is None else jax.tree.map(
                        lambda a, b: a.astype(jnp.float32) + b, grads, g_p)
                    part_trees += len(sb.oversized)
                    part_tokens += pinfo["unique_tokens"]
                    tokens_done += pinfo["unique_tokens"]
                    if sb.inputs is None:
                        # batch is entirely oversized trees: report the
                        # partitioned-path per-token nll (token CE only,
                        # comparable to token_nll_mean), not nan
                        nll = pinfo["nll_sum"] / max(pinfo["weight_sum"],
                                                     1e-9)
                if grads is None:      # nothing trainable this step
                    continue
                params, opt_state, om = update_fn(params, grads, opt_state)
                dt = time.time() - ts
                history.append({"step": i, "loss": loss, "nll": nll,
                                "sec": dt,
                                "oversized": len(sb.oversized),
                                "dropped": sb.dropped})
                if i % args.log_every == 0:
                    print(f"step {i:4d} loss {loss:10.4f} "
                          f"nll/tok {nll:7.4f} "
                          f"gnorm {float(om['grad_norm']):8.3f} "
                          f"parts {len(sb.oversized):2d} "
                          f"{dt * 1e3:7.1f}ms", flush=True)
        else:
            step_fn = make_train_step(cfg, opt_cfg, impl=args.impl)
            for i, sb in enumerate(step_batches(cfg, lc, args.steps)):
                dropped_total += sb.dropped
                if sb.inputs is None:   # every tree dropped this step
                    continue
                ts = time.time()
                params, opt_state, m = step_fn(params, opt_state, sb.inputs)
                loss = float(m["total"])
                dt = time.time() - ts
                tokens_done += int(sb.tb.valid.sum())
                history.append({"step": i, "loss": loss,
                                "nll": float(m["token_nll_mean"]),
                                "sec": dt, "oversized": 0,
                                "dropped": sb.dropped})
                if i % args.log_every == 0:
                    print(f"step {i:4d} loss {loss:10.4f} "
                          f"nll/tok {float(m['token_nll_mean']):7.4f} "
                          f"gnorm {float(m['grad_norm']):8.3f} "
                          f"{dt * 1e3:7.1f}ms", flush=True)
        wall = time.time() - t0
        print(f"[train] {len(history)} steps, {tokens_done} unique tokens, "
              f"{dropped_total} dropped trees, {wall:.1f}s wall")
        if args.auto_partition:
            print(f"[train] partitioned: {part_trees} oversized trees, "
                  f"{part_tokens} tokens, {dropped_total} dropped")
        if args.save:
            save_checkpoint(args.save, params, opt_state,
                            meta={"arch": cfg.name, "steps": len(history)})
            with open(args.save + "/history.json", "w") as f:
                json.dump(history, f)
            print(f"[train] saved → {args.save}")


if __name__ == "__main__":
    main()
