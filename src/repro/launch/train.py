"""Training launcher.

Runs tree-training (or the sep-avg baseline) on synthetic agentic trees:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \\
      --steps 50 --mode tree

``--mesh host`` (default) runs on the local device(s); ``--mesh single``/
``multi`` builds the production mesh (requires the dry-run's fake-device
env when not on a real pod — intended for lowering checks; real training
on hardware uses the same code path).
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro import sharding as sh
from repro.configs import get_config
from repro.data.loader import LoaderConfig, batches
from repro.launch.mesh import data_axes, make_host_mesh, \
    make_production_mesh
from repro.models.model import init_params
from repro.train.checkpoint import save_checkpoint
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--mode", default="tree", choices=["tree", "baseline"])
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--rows", type=int, default=2)
    ap.add_argument("--trees", type=int, default=6)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--impl", default="ref",
                    choices=["ref", "chunked", "pallas"])
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    print(f"[train] arch={cfg.name} family={cfg.family} mode={args.mode} "
          f"impl={args.impl}")

    if args.mesh == "host":
        mesh, daxes = make_host_mesh(), ("data",)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        daxes = data_axes(args.mesh == "multi")

    opt_cfg = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(2, args.steps // 10))
    lc = LoaderConfig(seq_len=args.seq_len, batch_rows=args.rows,
                      trees_per_batch=args.trees, mode=args.mode,
                      kind="agentic", seed=args.seed,
                      gen_kwargs=dict(turn_len_range=(8, 48),
                                      num_turns=4))

    with sh.use_mesh(mesh, data_axes=daxes):
        params = init_params(cfg, jax.random.key(args.seed))
        opt_state = init_opt_state(params)
        step_fn = make_train_step(cfg, opt_cfg, impl=args.impl)

        tokens_done = 0
        t0 = time.time()
        history = []
        for i, (inputs, tb) in enumerate(batches(cfg, lc, args.steps)):
            ts = time.time()
            params, opt_state, m = step_fn(params, opt_state, inputs)
            loss = float(m["total"])
            dt = time.time() - ts
            tokens_done += int(tb.valid.sum())
            history.append({"step": i, "loss": loss, "sec": dt})
            if i % args.log_every == 0:
                print(f"step {i:4d} loss {loss:10.4f} "
                      f"nll/tok {float(m['token_nll_mean']):7.4f} "
                      f"gnorm {float(m['grad_norm']):8.3f} {dt * 1e3:7.1f}ms",
                      flush=True)
        wall = time.time() - t0
        print(f"[train] {len(history)} steps, {tokens_done} unique tokens, "
              f"{wall:.1f}s wall")
        if args.save:
            save_checkpoint(args.save, params, opt_state,
                            meta={"arch": cfg.name, "steps": len(history)})
            with open(args.save + "/history.json", "w") as f:
                json.dump(history, f)
            print(f"[train] saved → {args.save}")


if __name__ == "__main__":
    main()
