"""Training launcher — ONE loop over the unified engine.

Runs tree-training (or the sep-avg baseline) on synthetic agentic trees:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \\
      --steps 50 --mode tree

Every step is an ``ExecutionPlan`` from the plan-ahead scheduler
(``train/planner``: global cost-model-driven Tree Packing over a
``--lookahead`` window, replica-balanced rows, ``--plan-workers``
background builders double-buffered against the device) executed by
``train/engine.TreeTrainEngine.step`` — the same code path for all of
``--mode tree/baseline`` × ``--auto-partition`` × ``--impl
ref/chunked/pallas`` × ``--loss-mode sep_avg/uniform/rl``.  Gradients
accumulate in a donated fp32 device buffer; each step performs exactly
one host sync (the logging transfer).  ``--rows`` defaults to auto: the
planner picks per-replica row counts sized to the mesh's data axis.

``--auto-partition`` routes trees larger than one row through
Redundancy-Free Tree Partitioning (wave-scheduled, ``--capacity`` token
cap per partition) instead of silently dropping them — zero data loss.
``--capacity`` defaults to ``auto``: the planner sizes the cap per
lookahead window from the oversized trees it actually sees
(``core.partition.choose_capacity``); an integer forces it.

``--graft`` turns on cross-tree forest grafting (``core/forest``): trees
in the lookahead window that open with the same token prefix — shared
system prompts, few-shot preambles — are merged into one grafted forest
so the shared prefix is computed once per window instead of once per
tree (pair with ``--kind template`` for the synthetic version of that
workload).

``--loss-mode rl`` trains the RL model-update objective: per-branch GRPO
advantages scale λ_t (pair with ``--kind grpo`` rollout trees; with
advantages≡1 it reproduces SFT exactly).

``--mesh host`` (default) runs on the local device(s); ``--mesh single``/
``multi`` builds the production mesh (requires the dry-run's fake-device
env when not on a real pod — intended for lowering checks; real training
on hardware uses the same code path).
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro import sharding as sh
from repro.configs import get_config
from repro.data.loader import LoaderConfig
from repro.launch.mesh import data_axes, data_axis_size, make_host_mesh, \
    make_production_mesh
from repro.models.model import init_params
from repro.train.checkpoint import (load_checkpoint, load_meta,
                                    save_checkpoint)
from repro.train.engine import TreeTrainEngine
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.planner import PlannerConfig, plans


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--mode", default="tree", choices=["tree", "baseline"])
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--rows", type=int, default=None,
                    help="row budget per step (default: auto — the "
                         "planner picks the smallest multiple of the "
                         "mesh's data axis ≥ 2)")
    ap.add_argument("--trees", type=int, default=6)
    ap.add_argument("--lookahead", type=int, default=1,
                    help="generator batches the planner bin-packs "
                         "jointly (global Tree Packing; 1 = per-step)")
    ap.add_argument("--plan-workers", type=int, default=1,
                    help="background plan-builder threads (double-"
                         "buffered against engine.step; 0 = synchronous)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--impl", default="ref",
                    choices=["ref", "chunked", "pallas"])
    ap.add_argument("--loss-mode", default="sep_avg",
                    choices=["sep_avg", "uniform", "rl"],
                    help="sep_avg: λ_t = g_t/K (SFT, Eq. 4); uniform: "
                         "λ_t = 1; rl: GRPO per-branch advantages scale "
                         "λ_t (the RL model-update phase)")
    ap.add_argument("--kind", default=None,
                    choices=["agentic", "grpo", "random", "template"],
                    help="synthetic tree generator (default: agentic; "
                         "grpo when --loss-mode rl; template = shared "
                         "system-prompt workload for --graft)")
    ap.add_argument("--auto-partition", action="store_true",
                    help="train oversized trees via wave-scheduled "
                         "partitioning instead of dropping them")
    ap.add_argument("--capacity", default="auto",
                    help="partition token cap: an integer forces it; "
                         "'auto' (default) lets the planner choose per "
                         "lookahead window from the oversized trees' "
                         "partition-count/depth trade-off "
                         "(core.partition.choose_capacity)")
    ap.add_argument("--graft", action="store_true",
                    help="cross-tree forest grafting: merge trees that "
                         "share a token prefix (core/forest) before "
                         "packing, so shared system prompts are computed "
                         "once per window")
    ap.add_argument("--min-graft", type=int, default=16,
                    help="minimum shared-prefix tokens for a graft to be "
                         "considered (shorter matches never pay for the "
                         "merge)")
    ap.add_argument("--aot-warmup", action="store_true",
                    help="AOT warmup engine: background-precompile the "
                         "reachable signature universe at startup and "
                         "pre-warm each window's exact executables from "
                         "the planner's build threads — the engine never "
                         "blocks on a cold jit bucket (train/warmup)")
    ap.add_argument("--warmup-threads", type=int, default=1,
                    help="background AOT compile threads for --aot-warmup")
    ap.add_argument("--compile-cache-dir", default=None,
                    help="persistent jax compilation cache directory: a "
                         "restarted run re-loads every compiled module "
                         "from disk instead of recompiling")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None)
    ap.add_argument("--ckpt-every", type=int, default=None,
                    help="save params+opt_state to --save every N steps "
                         "(mid-stream resume point)")
    ap.add_argument("--resume", default=None,
                    help="checkpoint dir to resume from (replays the "
                         "deterministic plan stream up to the saved step)")
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args()
    if args.ckpt_every is not None and not args.save:
        ap.error("--ckpt-every needs --save (the checkpoint directory)")

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.kind is None:
        args.kind = "grpo" if args.loss_mode == "rl" else "agentic"
    print(f"[train] arch={cfg.name} family={cfg.family} mode={args.mode} "
          f"impl={args.impl} loss_mode={args.loss_mode} kind={args.kind}")

    auto_capacity = False
    if str(args.capacity).lower() == "auto":
        args.capacity = None
        auto_capacity = True
    else:
        try:
            args.capacity = int(args.capacity)
        except ValueError:
            ap.error(f"--capacity must be an integer or 'auto', got "
                     f"{args.capacity!r}")
    if args.auto_partition:
        if args.mode != "tree":
            ap.error("--auto-partition requires --mode tree (partitioning "
                     "is a tree-serialization feature; baseline mode "
                     "would silently drop oversized trees)")
        if args.capacity is not None:
            cap = args.capacity
            if not 0 < cap <= args.seq_len:
                ap.error(f"--capacity {cap} must be in (0, --seq-len "
                         f"{args.seq_len}]")
            if cfg.ssm is not None and cap % cfg.ssm.chunk_size != 0:
                ap.error(f"--capacity {cap} must be a multiple of the SSM "
                         f"chunk size {cfg.ssm.chunk_size}")
    if args.graft and args.mode != "tree":
        ap.error("--graft requires --mode tree (grafted forests are "
                 "serialized trees; baseline rows cannot share prefixes)")

    if args.mesh == "host":
        mesh, daxes = make_host_mesh(), ("data",)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        daxes = data_axes(args.mesh == "multi")
    ndata = data_axis_size(mesh, daxes)
    if args.rows is None:
        # planner-chosen rows: one row per replica, minimum 2
        args.rows = max(2, ndata)
        print(f"[train] rows auto-chosen: {args.rows} "
              f"({args.rows // ndata} per replica × {ndata} replicas)")
    elif args.rows % ndata:
        ap.error(f"--rows {args.rows} was forced but is not a multiple "
                 f"of the mesh's data axis ({ndata} replicas) — batch "
                 f"rows cannot shard evenly; drop --rows to let the "
                 f"planner choose, or pick a multiple of {ndata}")

    opt_cfg = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(2, args.steps // 10))
    # generator kwargs differ per kind (agentic/grpo take turn shapes,
    # random takes segment shapes)
    gen_kwargs = (dict(seg_len_range=(8, 48), max_depth=4)
                  if args.kind == "random"
                  else dict(turn_len_range=(8, 48), num_turns=4))
    lc = LoaderConfig(seq_len=args.seq_len, batch_rows=args.rows,
                      trees_per_batch=args.trees, mode=args.mode,
                      kind=args.kind, seed=args.seed,
                      loss_mode=args.loss_mode,
                      auto_partition=args.auto_partition,
                      capacity=args.capacity,
                      auto_capacity=auto_capacity,
                      gen_kwargs=gen_kwargs)

    if args.compile_cache_dir:
        # before ANY compile (param init included) so every module the
        # run produces lands in — or loads from — the persistent cache
        from repro.train.warmup import configure_compile_cache
        d = configure_compile_cache(args.compile_cache_dir)
        print(f"[train] persistent compile cache: {d}")

    with sh.use_mesh(mesh, data_axes=daxes):
        params = init_params(cfg, jax.random.key(args.seed))
        opt_state = init_opt_state(params)
        done = 0
        if args.resume:
            params, opt_state = load_checkpoint(args.resume, params,
                                                opt_state)
            done = int(load_meta(args.resume).get("steps", 0))
            print(f"[train] resumed {args.resume} @ step {done}")

        pcfg = PlannerConfig(lookahead=args.lookahead,
                             plan_workers=args.plan_workers,
                             num_replicas=ndata, max_rows=args.rows,
                             graft=args.graft, min_graft=args.min_graft)

        svc = None
        if args.aot_warmup:
            from repro.core.plan_cost import CompileCacheSim
            from repro.train.warmup import AOTWarmupService
            svc = AOTWarmupService(cfg, lc, pcfg, params=params,
                                   opt_cfg=opt_cfg, opt_state=opt_state,
                                   impl=args.impl,
                                   sim=CompileCacheSim())
            svc.start(threads=args.warmup_threads)
            print(f"[train] AOT warmup: "
                  f"{len(svc.signature_list())} universe signatures "
                  f"compiling on {args.warmup_threads} background "
                  f"thread(s)")

        engine = TreeTrainEngine(
            cfg, opt_cfg, impl=args.impl,
            exec_cache=svc.cache if svc else None,
            universe=svc.universe if svc else None)
        engine.steps_done = done

        pipe = plans(cfg, lc, args.steps, pcfg, warmup=svc)

        tokens_done = padded_total = part_trees = part_tokens = 0
        dropped_total = 0
        t0 = time.time()
        history = []
        # THE training loop: every step — packed rows, partition waves,
        # SFT or RL — is one engine.step over its ExecutionPlan; the
        # planner builds the NEXT plan on background threads meanwhile
        executed = 0
        for i, ps in enumerate(pipe):
            plan = ps.execution_plan()
            dropped_total += plan.dropped
            if plan.is_empty:       # nothing trainable this step
                continue
            executed += 1
            if executed <= done:    # resume: replay the plan stream
                continue
            ts = time.time()
            params, opt_state, m = engine.step(params, opt_state, plan)
            dt = time.time() - ts
            tokens_done += plan.unique_tokens
            padded_total += plan.padded_tokens
            part_trees += plan.num_oversized
            if plan.partition is not None and plan.partition.waves:
                part_tokens += plan.partition.info["unique_tokens"]
            history.append({"step": i, "loss": m["loss"], "nll": m["nll"],
                            "sec": dt,
                            "oversized": plan.num_oversized,
                            "dropped": plan.dropped})
            if i % args.log_every == 0:
                print(f"step {i:4d} loss {m['loss']:10.4f} "
                      f"nll/tok {m['nll']:7.4f} "
                      f"gnorm {m['grad_norm']:8.3f} "
                      f"parts {plan.num_oversized:2d} "
                      f"{dt * 1e3:7.1f}ms", flush=True)
            if args.ckpt_every and engine.steps_done % args.ckpt_every == 0:
                save_checkpoint(args.save, params, opt_state,
                                meta={"arch": cfg.name,
                                      "steps": engine.steps_done})
                print(f"[train] ckpt @ step {engine.steps_done} "
                      f"→ {args.save}", flush=True)
        wall = time.time() - t0
        print(f"[train] {len(history)} steps, {tokens_done} unique tokens, "
              f"{dropped_total} dropped trees, {wall:.1f}s wall "
              f"({engine.host_syncs} host syncs / {engine.steps_done} "
              f"steps)")
        print(f"[train] plan-ahead: {pipe.built} plans, "
              f"{pipe.schedule_s * 1e3:.0f}ms scheduled + "
              f"{pipe.build_s * 1e3:.0f}ms built / "
              f"{pipe.exposed_s * 1e3:.0f}ms exposed "
              f"(lookahead {args.lookahead}, {args.plan_workers} workers), "
              f"{padded_total} padded tokens "
              f"({padded_total / max(tokens_done, 1):.2f}/unique)")
        if args.auto_partition:
            print(f"[train] partitioned: {part_trees} oversized trees, "
                  f"{part_tokens} tokens, {dropped_total} dropped")
        if svc is not None:
            svc.stop()
            st = svc.stats()
            print(f"[train] aot-warmup: {st['size']} executables "
                  f"({st['compile_s']:.1f}s compile, "
                  f"{svc.prewarmed} prewarmed), engine retraces "
                  f"{engine.retraces}, exposed compile wait "
                  f"{engine.compile_wait_s * 1e3:.0f}ms"
                  + (f", {st['errors']} warmup errors"
                     if st["errors"] else ""))
        if args.save:
            save_checkpoint(args.save, params, opt_state,
                            meta={"arch": cfg.name,
                                  "steps": engine.steps_done})
            with open(args.save + "/history.json", "w") as f:
                json.dump(history, f)
            print(f"[train] saved → {args.save}")


if __name__ == "__main__":
    main()
