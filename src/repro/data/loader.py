"""Batching pipeline: trees → packed TreeBatch stream.

Paper §3.4: each global batch is a self-contained set of whole trees —
shuffling happens *between* trees, never inside one, so tree partitioning
stays within a gradient-accumulation step and the gradient is unbiased.

Two modes behind one iterator:
  tree mode     : DFS-serialize + pack_trees      (Tree Training)
  baseline mode : linearize paths + pack           (sep-avg baseline)

With ``auto_partition`` on (tree mode), trees whose serialization exceeds
one row are no longer dropped: they ride along each step as ``oversized``
and train through the wave-scheduled partition plan
(core/gateway.build_partition_plan) — zero data loss, every token
computed exactly once under the ``capacity`` memory cap.

``execution_plans`` is the unified-engine interface: it folds the packed
rows and the partition waves of each step into ONE ``ExecutionPlan`` for
``train/engine.TreeTrainEngine.step``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.packing import (DoesNotFitError, TreeBatch,
                                pack_linear_paths, pack_trees)
from repro.core.tree import TrajectoryTree, serialize_tree
from repro.data.synthetic import trees_for_batch
from repro.models.model import needs_chunks, prepare_batch


@dataclass
class LoaderConfig:
    seq_len: int = 512
    batch_rows: int = 4
    trees_per_batch: int = 8
    mode: str = "tree"            # tree | baseline
    kind: str = "agentic"         # synthetic generator
    seed: int = 0
    loss_mode: str = "sep_avg"
    gen_kwargs: Optional[dict] = None
    auto_partition: bool = False  # route oversized trees via partitioning
    capacity: Optional[int] = None  # partition token cap (default seq_len)


@dataclass
class StepBatch:
    """One training step's data: the packed batch plus any trees routed
    to the partitioned driver instead of being dropped."""
    inputs: Optional[dict]              # model inputs (None: nothing packed)
    tb: Optional[TreeBatch]
    oversized: list[TrajectoryTree] = field(default_factory=list)
    dropped: int = 0                    # trees lost this step
    num_trees: int = 0                  # packed + oversized (normalizer)


@dataclass
class _FitTree:
    """One row-sized tree with its serialization artifacts, computed ONCE
    (the size filter and the packer used to serialize the same tree twice,
    and the does-not-fit retry loop re-serialized on every attempt)."""
    tree: TrajectoryTree
    ser: object                       # SerializedTree (loss_mode applied)
    paths: list[dict]                 # linearize_paths() output
    n_unique: int


def _fit_trees(trees: Sequence[TrajectoryTree], seq_len: int,
               chunk: Optional[int], loss_mode: str = "sep_avg"):
    """Split trees into (fits-one-row ``_FitTree``s, oversized trees).
    The filter checks BOTH serializations so tree and baseline modes see
    the exact same dataset — step-wise loss comparisons stay pure.  Each
    kept tree carries its serialization and linearized paths so callers
    never re-serialize."""
    keep, oversized = [], []
    for t in trees:
        ser = serialize_tree(t, chunk_size=chunk, loss_mode=loss_mode)
        paths = t.linearize_paths()
        n_path = max(len(p["tokens"]) for p in paths)
        if chunk:
            n_path = ((n_path + chunk - 1) // chunk) * chunk
        if max(ser.n, n_path) <= seq_len:
            keep.append(_FitTree(tree=t, ser=ser, paths=paths,
                                 n_unique=t.num_unique_tokens()))
        else:
            oversized.append(t)
    return keep, oversized


def step_batches(cfg: ModelConfig, lc: LoaderConfig,
                 num_batches: int) -> Iterator[StepBatch]:
    """Full-fidelity stream: every generated tree is accounted for — it is
    either packed, routed to the partitioned driver (``auto_partition``),
    or counted in ``dropped``."""
    chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    rng = np.random.default_rng(lc.seed)
    gk = dict(vocab_size=cfg.vocab_size)
    gk.update(lc.gen_kwargs or {})
    route = lc.auto_partition and lc.mode == "tree"
    for b in range(num_batches):
        trees = trees_for_batch(lc.seed * 100_003 + b,
                                n_trees=lc.trees_per_batch, kind=lc.kind,
                                **gk)
        fits, oversized = _fit_trees(trees, lc.seq_len, chunk,
                                     lc.loss_mode)
        dropped = 0 if route else len(oversized)
        # move the largest trees out until the pack fits the row budget;
        # only the explicit does-not-fit error is recoverable — anything
        # else is a packer bug and propagates.  Serializations were
        # computed once in _fit_trees; each retry just pops the largest.
        fits = sorted(fits, key=lambda f: f.n_unique)
        tb = None
        while fits:
            try:
                if lc.mode == "tree":
                    tb = pack_trees([f.ser for f in fits],
                                    lc.seq_len, batch_size=lc.batch_rows,
                                    chunk_size=chunk)
                else:
                    tb = pack_linear_paths(
                        [f.paths for f in fits],
                        lc.seq_len, batch_size=lc.batch_rows,
                        chunk_size=chunk, loss_mode=lc.loss_mode)
                break
            except DoesNotFitError:
                if route:
                    oversized.append(fits[-1].tree)
                else:
                    dropped += 1
                fits = fits[:-1]
        trees = [f.tree for f in fits]
        if not route:
            oversized = []
        if tb is None and not oversized and dropped == 0:
            continue
        inputs = None
        if tb is not None:
            extra = None
            if cfg.frontend is not None:
                extra = rng.normal(
                    size=(tb.tokens.shape[0], cfg.frontend_len,
                          cfg.d_model)).astype(np.float32)
            # normalize by the step's FULL tree count: oversized trees on
            # the partition waves share this step's mean-over-trees loss
            inputs = prepare_batch(
                cfg, tb, extra,
                num_trees=len(trees) + len(oversized) if oversized
                else None)
        yield StepBatch(inputs=inputs, tb=tb, oversized=oversized,
                        dropped=dropped,
                        num_trees=len(trees) + len(oversized))


def batches(cfg: ModelConfig, lc: LoaderConfig,
            num_batches: int) -> Iterator[tuple[dict, TreeBatch]]:
    """Yields (model_inputs, raw TreeBatch) pairs (packed stream only)."""
    for sb in step_batches(cfg, lc, num_batches):
        if sb.inputs is not None:
            yield sb.inputs, sb.tb


def execution_plans(cfg: ModelConfig, lc: LoaderConfig, num_batches: int,
                    *, max_rows: Optional[int] = None):
    """The loader's unified-engine interface: one ``ExecutionPlan`` per
    optimizer step — the packed rows as a 1-element execution plus the
    partition waves of any oversized trees (``auto_partition``), ready
    for ``TreeTrainEngine.step``.  Steps whose every tree was dropped
    still yield (an empty plan) so drop accounting reaches the caller."""
    from repro.core.gateway import build_partition_plan
    from repro.train.engine import ExecutionPlan, PackedExec

    cap = lc.capacity or lc.seq_len
    for sb in step_batches(cfg, lc, num_batches):
        packed = None
        if sb.inputs is not None:
            packed = PackedExec(inputs=sb.inputs,
                                tokens=int(sb.tb.valid.sum()))
        partition = None
        if sb.oversized:
            partition = build_partition_plan(
                cfg, sb.oversized, cap, seq_len=lc.seq_len,
                loss_mode=lc.loss_mode,
                max_rows=max_rows if max_rows is not None
                else lc.batch_rows)
        yield ExecutionPlan(packed=packed, partition=partition,
                            num_trees=sb.num_trees, dropped=sb.dropped)


def dataset_por(trees: Sequence[TrajectoryTree]) -> float:
    """Aggregate POR (Eq. 12) of a list of trees."""
    uniq = sum(t.num_unique_tokens() for t in trees)
    flat = sum(t.flat_tokens() for t in trees)
    return 1.0 - uniq / flat if flat else 0.0
