"""Batching pipeline: trees → packed TreeBatch stream.

Paper §3.4: each global batch is a self-contained set of whole trees —
shuffling happens *between* trees, never inside one, so tree partitioning
stays within a gradient-accumulation step and the gradient is unbiased.

Two modes behind one iterator:
  tree mode     : DFS-serialize + pack_trees      (Tree Training)
  baseline mode : linearize paths + pack           (sep-avg baseline)

With ``auto_partition`` on (tree mode), trees whose serialization exceeds
one row are no longer dropped: they ride along each step as ``oversized``
and train through the wave-scheduled partitioned driver
(core/gateway.packed_partitioned_value_and_grad) — zero data loss, every
token computed exactly once under the ``capacity`` memory cap.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.packing import (DoesNotFitError, TreeBatch,
                                pack_linear_paths, pack_trees)
from repro.core.tree import TrajectoryTree, serialize_tree
from repro.data.synthetic import trees_for_batch
from repro.models.model import needs_chunks, prepare_batch


@dataclass
class LoaderConfig:
    seq_len: int = 512
    batch_rows: int = 4
    trees_per_batch: int = 8
    mode: str = "tree"            # tree | baseline
    kind: str = "agentic"         # synthetic generator
    seed: int = 0
    loss_mode: str = "sep_avg"
    gen_kwargs: Optional[dict] = None
    auto_partition: bool = False  # route oversized trees via partitioning
    capacity: Optional[int] = None  # partition token cap (default seq_len)


@dataclass
class StepBatch:
    """One training step's data: the packed batch plus any trees routed
    to the partitioned driver instead of being dropped."""
    inputs: Optional[dict]              # model inputs (None: nothing packed)
    tb: Optional[TreeBatch]
    oversized: list[TrajectoryTree] = field(default_factory=list)
    dropped: int = 0                    # trees lost this step
    num_trees: int = 0                  # packed + oversized (normalizer)


def _fit_trees(trees: Sequence[TrajectoryTree], seq_len: int,
               chunk: Optional[int]):
    """Split trees into (fits-one-row, oversized).  The filter checks BOTH
    serializations so tree and baseline modes see the exact same dataset —
    step-wise loss comparisons stay pure."""
    keep, oversized = [], []
    for t in trees:
        n_tree = serialize_tree(t, chunk_size=chunk).n
        n_path = max(len(p["tokens"]) for p in t.linearize_paths())
        if chunk:
            n_path = ((n_path + chunk - 1) // chunk) * chunk
        (keep if max(n_tree, n_path) <= seq_len else oversized).append(t)
    return keep, oversized


def step_batches(cfg: ModelConfig, lc: LoaderConfig,
                 num_batches: int) -> Iterator[StepBatch]:
    """Full-fidelity stream: every generated tree is accounted for — it is
    either packed, routed to the partitioned driver (``auto_partition``),
    or counted in ``dropped``."""
    chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    rng = np.random.default_rng(lc.seed)
    gk = dict(vocab_size=cfg.vocab_size)
    gk.update(lc.gen_kwargs or {})
    route = lc.auto_partition and lc.mode == "tree"
    for b in range(num_batches):
        trees = trees_for_batch(lc.seed * 100_003 + b,
                                n_trees=lc.trees_per_batch, kind=lc.kind,
                                **gk)
        trees, oversized = _fit_trees(trees, lc.seq_len, chunk)
        dropped = 0 if route else len(oversized)
        # move the largest trees out until the pack fits the row budget;
        # only the explicit does-not-fit error is recoverable — anything
        # else is a packer bug and propagates
        trees = sorted(trees, key=lambda t: t.num_unique_tokens())
        tb = None
        while trees:
            try:
                if lc.mode == "tree":
                    tb = pack_trees(
                        [serialize_tree(t, chunk_size=chunk,
                                        loss_mode=lc.loss_mode)
                         for t in trees],
                        lc.seq_len, batch_size=lc.batch_rows,
                        chunk_size=chunk)
                else:
                    tb = pack_linear_paths(
                        [t.linearize_paths() for t in trees],
                        lc.seq_len, batch_size=lc.batch_rows,
                        chunk_size=chunk)
                break
            except DoesNotFitError:
                if route:
                    oversized.append(trees[-1])
                else:
                    dropped += 1
                trees = trees[:-1]
        if not route:
            oversized = []
        if tb is None and not oversized and dropped == 0:
            continue
        inputs = None
        if tb is not None:
            extra = None
            if cfg.frontend is not None:
                extra = rng.normal(
                    size=(tb.tokens.shape[0], cfg.frontend_len,
                          cfg.d_model)).astype(np.float32)
            inputs = prepare_batch(cfg, tb, extra)
        yield StepBatch(inputs=inputs, tb=tb, oversized=oversized,
                        dropped=dropped,
                        num_trees=len(trees) + len(oversized))


def batches(cfg: ModelConfig, lc: LoaderConfig,
            num_batches: int) -> Iterator[tuple[dict, TreeBatch]]:
    """Yields (model_inputs, raw TreeBatch) pairs (packed stream only)."""
    for sb in step_batches(cfg, lc, num_batches):
        if sb.inputs is not None:
            yield sb.inputs, sb.tb


def dataset_por(trees: Sequence[TrajectoryTree]) -> float:
    """Aggregate POR (Eq. 12) of a list of trees."""
    uniq = sum(t.num_unique_tokens() for t in trees)
    flat = sum(t.flat_tokens() for t in trees)
    return 1.0 - uniq / flat if flat else 0.0
