"""Tree ingestion: synthetic generator batches → the planner's stream.

Paper §3.4: each global batch is a self-contained set of whole trees —
shuffling happens *between* trees, never inside one, so tree partitioning
stays within a gradient-accumulation step and the gradient is unbiased.

This module owns the *data* side only: generator configuration
(``LoaderConfig``), the raw tree stream (``tree_stream``), and the
per-step data container (``StepBatch``).  Everything schedule-shaped —
which trees share a step, row assignment, eviction/drop accounting,
oversized routing, replica balancing — lives in the plan-ahead scheduler
(``train/planner.py``).  ``step_batches`` and ``execution_plans`` are
*deprecated* (one-release warning) in favour of the planner's single
``plans(cfg, lc, source)`` entrypoint, which also accepts a live rollout
queue in place of a batch count.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core.packing import TreeBatch
from repro.core.tree import TrajectoryTree
from repro.data.synthetic import trees_for_batch


@dataclass
class LoaderConfig:
    seq_len: int = 512
    batch_rows: int = 4
    trees_per_batch: int = 8
    mode: str = "tree"            # tree | baseline
    kind: str = "agentic"         # synthetic generator
    seed: int = 0
    loss_mode: str = "sep_avg"
    gen_kwargs: Optional[dict] = None
    auto_partition: bool = False  # route oversized trees via partitioning
    capacity: Optional[int] = None  # partition token cap (default seq_len)
    # planner-chosen capacity: with capacity=None the planner resolves the
    # cap per lookahead window via core/partition.choose_capacity instead
    # of defaulting to seq_len (an explicit ``capacity`` always wins)
    auto_capacity: bool = False


@dataclass
class StepBatch:
    """One training step's data: the packed batch plus any trees routed
    to the partitioned driver instead of being dropped."""
    inputs: Optional[dict]              # model inputs (None: nothing packed)
    tb: Optional[TreeBatch]
    oversized: list[TrajectoryTree] = field(default_factory=list)
    dropped: int = 0                    # trees lost this step
    num_trees: int = 0                  # packed + oversized (normalizer)


def tree_stream(cfg: ModelConfig, lc: LoaderConfig,
                num_batches: int) -> Iterator[list[TrajectoryTree]]:
    """The ingestion stream: one deterministic list of trees per generator
    batch (seeded per batch so lookahead windows re-slice the same data)."""
    gk = dict(vocab_size=cfg.vocab_size)
    gk.update(lc.gen_kwargs or {})
    for b in range(num_batches):
        yield trees_for_batch(lc.seed * 100_003 + b,
                              n_trees=lc.trees_per_batch, kind=lc.kind,
                              **gk)


def step_batches(cfg: ModelConfig, lc: LoaderConfig,
                 num_batches: int) -> Iterator[StepBatch]:
    """Deprecated: use ``train.planner.plans(cfg, lc, num_batches)`` and
    call ``.step_batch()`` on each yielded PlannedStep."""
    import warnings

    from repro.train.planner import plan_stream

    warnings.warn(
        "data.loader.step_batches is deprecated and will be removed next "
        "release; use train.planner.plans(cfg, lc, source) — each "
        "PlannedStep exposes .step_batch()", DeprecationWarning,
        stacklevel=2)
    for ps in plan_stream(cfg, lc, num_batches):
        yield ps.step_batch()


def batches(cfg: ModelConfig, lc: LoaderConfig,
            num_batches: int) -> Iterator[tuple[dict, TreeBatch]]:
    """Yields (model_inputs, raw TreeBatch) pairs (packed stream only)."""
    from repro.train.planner import plan_stream

    for ps in plan_stream(cfg, lc, num_batches):
        sb = ps.step_batch()
        if sb.inputs is not None:
            yield sb.inputs, sb.tb


def execution_plans(cfg: ModelConfig, lc: LoaderConfig, num_batches: int,
                    *, max_rows: Optional[int] = None, planner=None):
    """Deprecated: use ``train.planner.plans(cfg, lc, num_batches)`` and
    call ``.execution_plan()`` on each yielded PlannedStep (also accepts
    a live tree source in place of the batch count)."""
    import warnings

    from repro.train.planner import plans

    warnings.warn(
        "data.loader.execution_plans is deprecated and will be removed "
        "next release; use train.planner.plans(cfg, lc, source) — each "
        "PlannedStep exposes .execution_plan()", DeprecationWarning,
        stacklevel=2)
    for ps in plans(cfg, lc, num_batches, planner, max_rows=max_rows):
        yield ps.execution_plan()


def dataset_por(trees: Sequence[TrajectoryTree]) -> float:
    """Aggregate POR (Eq. 12) of a list of trees."""
    uniq = sum(t.num_unique_tokens() for t in trees)
    flat = sum(t.flat_tokens() for t in trees)
    return 1.0 - uniq / flat if flat else 0.0
