"""Batching pipeline: trees → packed TreeBatch stream.

Paper §3.4: each global batch is a self-contained set of whole trees —
shuffling happens *between* trees, never inside one, so tree partitioning
stays within a gradient-accumulation step and the gradient is unbiased.

Two modes behind one iterator:
  tree mode     : DFS-serialize + pack_trees      (Tree Training)
  baseline mode : linearize paths + pack           (sep-avg baseline)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.packing import TreeBatch, pack_linear_paths, pack_trees
from repro.core.tree import TrajectoryTree, serialize_tree
from repro.data.synthetic import trees_for_batch
from repro.models.model import needs_chunks, prepare_batch


@dataclass
class LoaderConfig:
    seq_len: int = 512
    batch_rows: int = 4
    trees_per_batch: int = 8
    mode: str = "tree"            # tree | baseline
    kind: str = "agentic"         # synthetic generator
    seed: int = 0
    loss_mode: str = "sep_avg"
    gen_kwargs: Optional[dict] = None


def _fit_trees(trees: Sequence[TrajectoryTree], seq_len: int,
               chunk: Optional[int], mode: str):
    """Drop trees whose serialization exceeds one row (the partitioned
    driver handles those; the packed loader keeps rows full)."""
    keep = []
    for t in trees:
        # filter on BOTH serializations so tree and baseline modes see the
        # exact same dataset — step-wise loss comparisons stay pure
        n_tree = serialize_tree(t, chunk_size=chunk).n
        n_path = max(len(p["tokens"]) for p in t.linearize_paths())
        if chunk:
            n_path = ((n_path + chunk - 1) // chunk) * chunk
        if max(n_tree, n_path) <= seq_len:
            keep.append(t)
    return keep


def batches(cfg: ModelConfig, lc: LoaderConfig,
            num_batches: int) -> Iterator[tuple[dict, TreeBatch]]:
    """Yields (model_inputs, raw TreeBatch) pairs."""
    chunk = cfg.ssm.chunk_size if needs_chunks(cfg) else None
    rng = np.random.default_rng(lc.seed)
    gk = dict(vocab_size=cfg.vocab_size)
    gk.update(lc.gen_kwargs or {})
    for b in range(num_batches):
        trees = trees_for_batch(lc.seed * 100_003 + b,
                                n_trees=lc.trees_per_batch, kind=lc.kind,
                                **gk)
        trees = _fit_trees(trees, lc.seq_len, chunk, lc.mode)
        if not trees:
            continue
        # drop the largest trees until the pack fits the row budget
        trees = sorted(trees, key=lambda t: t.num_unique_tokens())
        while True:
            try:
                if lc.mode == "tree":
                    tb = pack_trees(
                        [serialize_tree(t, chunk_size=chunk,
                                        loss_mode=lc.loss_mode)
                         for t in trees],
                        lc.seq_len, batch_size=lc.batch_rows,
                        chunk_size=chunk)
                else:
                    tb = pack_linear_paths(
                        [t.linearize_paths() for t in trees],
                        lc.seq_len, batch_size=lc.batch_rows,
                        chunk_size=chunk)
                break
            except ValueError:
                if len(trees) <= 1:
                    tb = None
                    break
                trees = trees[:-1]
        if tb is None:
            continue
        extra = None
        if cfg.frontend is not None:
            extra = rng.normal(size=(tb.tokens.shape[0], cfg.frontend_len,
                                     cfg.d_model)).astype(np.float32)
        yield prepare_batch(cfg, tb, extra), tb


def dataset_por(trees: Sequence[TrajectoryTree]) -> float:
    """Aggregate POR (Eq. 12) of a list of trees."""
    uniq = sum(t.num_unique_tokens() for t in trees)
    flat = sum(t.flat_tokens() for t in trees)
    return 1.0 - uniq / flat if flat else 0.0
