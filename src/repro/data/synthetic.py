"""Synthetic trajectory-tree generators.

Two flavors, matching the paper's experiments:
  - ``random_tree`` / ``por_controlled_trees``: controlled-POR synthetic
    datasets (paper §4.5, Fig. 8) — POR is tuned via shared-prefix depth.
  - ``agentic_tree``: qualitative mimic of the real agentic rollouts in
    Fig. 6 — long shared trunks with bursts of branching from concurrent
    tool calls / think-mode context edits, sparse and unbalanced.
  - ``grpo_tree``: the RL model-update workload — an agentic tree whose
    branches carry group-normalized per-branch advantages
    (``TreeNode.branch_adv``), consumed by ``loss_mode="rl"``.
  - ``template_tree`` / ``template_stream``: N distinct system-prompt
    templates shared verbatim across trees and batches (configurable
    overlap ratio) — the cross-tree shared-prefix workload the forest
    grafter (``core/forest``) exists for.
"""
from __future__ import annotations


import numpy as np

from repro.core.tree import TreeNode, TrajectoryTree


def random_tree(
    rng: np.random.Generator,
    *,
    vocab_size: int = 256,
    max_depth: int = 4,
    branch_prob: float = 0.5,
    max_children: int = 3,
    seg_len_range: tuple[int, int] = (2, 8),
    trained_frac: float = 0.7,
) -> TrajectoryTree:
    """Random tree with geometric-ish branching."""

    def seg() -> tuple[np.ndarray, np.ndarray]:
        L = int(rng.integers(*seg_len_range))
        toks = rng.integers(0, vocab_size, L).astype(np.int32)
        trained = rng.random(L) < trained_frac
        return toks, trained

    def rec(depth: int) -> TreeNode:
        toks, trained = seg()
        node = TreeNode(tokens=toks, trained=trained)
        if depth < max_depth and rng.random() < branch_prob:
            k = int(rng.integers(2, max_children + 1))
            node.children = [rec(depth + 1) for _ in range(k)]
        return node

    return TrajectoryTree(root=rec(0))


def chain_tree(rng: np.random.Generator, *, length: int = 3,
               vocab_size: int = 256,
               seg_len_range: tuple[int, int] = (2, 6)) -> TrajectoryTree:
    """Degenerate tree = single path (sequence special case)."""
    def seg() -> TreeNode:
        L = int(rng.integers(*seg_len_range))
        return TreeNode(tokens=rng.integers(0, vocab_size, L).astype(np.int32))
    root = seg()
    cur = root
    for _ in range(length - 1):
        nxt = seg()
        cur.children = [nxt]
        cur = nxt
    return TrajectoryTree(root=root)


def por_controlled_tree(
    rng: np.random.Generator,
    *,
    target_por: float,
    num_paths: int = 8,
    tokens_per_path: int = 256,
    vocab_size: int = 1024,
) -> TrajectoryTree:
    """K paths of equal length sharing one trunk; trunk length chosen so the
    tree's POR ≈ target (paper §4.5 keeps leaves and total tokens fixed
    while sweeping POR).

    With trunk t and per-path tail (L−t):  flat = K·L,
    unique = t + K·(L−t)  ⇒  POR = (K−1)·t / (K·L).
    """
    K, L = num_paths, tokens_per_path
    t = int(round(target_por * K * L / (K - 1)))
    t = max(1, min(t, L - 1))
    trunk = TreeNode(tokens=rng.integers(0, vocab_size, t).astype(np.int32))
    for _ in range(K):
        tail = TreeNode(
            tokens=rng.integers(0, vocab_size, L - t).astype(np.int32))
        trunk.children.append(tail)
    return TrajectoryTree(root=trunk)


def agentic_tree(
    rng: np.random.Generator,
    *,
    vocab_size: int = 32000,
    num_turns: int = 6,
    turn_len_range: tuple[int, int] = (64, 512),
    tool_branch_prob: float = 0.4,
    think_branch_prob: float = 0.3,
    max_parallel_tools: int = 4,
) -> TrajectoryTree:
    """Mimics Fig. 6: a long conversation trunk; at turn boundaries the
    trajectory may fork into parallel tool-call branches (each continuing
    the conversation) or think-mode variants (reasoning tokens replaced
    between turns)."""

    def seg(lo_hi=turn_len_range, trained_p=0.6) -> TreeNode:
        L = int(rng.integers(*lo_hi))
        toks = rng.integers(0, vocab_size, L).astype(np.int32)
        trained = rng.random(L) < trained_p
        return TreeNode(tokens=toks, trained=trained)

    def build(turn: int) -> TreeNode:
        node = seg()
        if turn >= num_turns:
            return node
        r = rng.random()
        if r < tool_branch_prob:
            k = int(rng.integers(2, max_parallel_tools + 1))
            node.children = [build(turn + 1) for _ in range(k)]
        elif r < tool_branch_prob + think_branch_prob:
            node.children = [build(turn + 1), build(turn + 1)]
        else:
            node.children = [build(turn + 1)]
        return node

    return TrajectoryTree(root=build(0))


def template_tokens(template_seed: int, template_id: int, length: int,
                    vocab_size: int) -> np.ndarray:
    """The token ids of one system-prompt template — deterministic in
    (template_seed, template_id) and independent of the per-batch rng, so
    every batch of a stream (and every lookahead window) sees the SAME
    template text: the cross-tree shared prefix the forest grafter
    (``core/forest``) dedups."""
    trng = np.random.default_rng([int(template_seed), int(template_id)])
    return trng.integers(0, vocab_size, int(length)).astype(np.int32)


def template_tree(
    rng: np.random.Generator,
    *,
    vocab_size: int = 32000,
    num_templates: int = 4,
    template_len: int = 64,
    overlap: float = 1.0,
    template_seed: int = 7,
    num_turns: int = 3,
    turn_len_range: tuple[int, int] = (16, 64),
    tool_branch_prob: float = 0.4,
    think_branch_prob: float = 0.3,
    max_parallel_tools: int = 3,
) -> TrajectoryTree:
    """The production template workload: each trajectory opens with one
    of ``num_templates`` distinct system-prompt templates (shared
    verbatim across trees AND batches — see ``template_tokens``), then
    continues as an agentic rollout tree.  ``overlap`` is the fraction of
    the template kept verbatim; the rest is per-tree noise (prompt
    suffixes, user names, timestamps), so grafting's prefix-trie has a
    configurable exact-match region.  Template tokens are context, not
    model output: ``trained=False``."""
    tid = int(rng.integers(num_templates))
    toks = template_tokens(template_seed, tid, template_len, vocab_size)
    shared = int(round(min(max(overlap, 0.0), 1.0) * template_len))
    head_toks = np.concatenate([
        toks[:shared],
        rng.integers(0, vocab_size, template_len - shared).astype(np.int32)])
    head = TreeNode(tokens=head_toks,
                    trained=np.zeros(template_len, bool))
    tail = agentic_tree(rng, vocab_size=vocab_size, num_turns=num_turns,
                        turn_len_range=turn_len_range,
                        tool_branch_prob=tool_branch_prob,
                        think_branch_prob=think_branch_prob,
                        max_parallel_tools=max_parallel_tools)
    head.children = [tail.root]
    return TrajectoryTree(root=head)


def template_stream(seed: int, *, num_batches: int, trees_per_batch: int,
                    **kw):
    """Iterator of generator batches of ``template_tree``\\ s — the
    template-heavy stream grafting benchmarks/tests plan over (usable
    directly as a ``train.planner.plans`` source)."""
    for b in range(num_batches):
        yield trees_for_batch(seed * 100_003 + b,
                              n_trees=trees_per_batch, kind="template",
                              **kw)


def group_normalized_advantages(rewards, normalize: bool = True
                                ) -> np.ndarray:
    """GRPO group baseline: A = (r − mean)/std over the group's rewards
    (``normalize=False`` passes raw rewards through).  The single source
    of the formula — synthetic trees and serve-side rollouts both use
    it."""
    r = np.asarray(rewards, np.float64)
    return (r - r.mean()) / (r.std() + 1e-6) if normalize else r


def assign_branch_advantages(
    tree: TrajectoryTree,
    rewards: np.ndarray,
    *,
    normalize: bool = True,
) -> np.ndarray:
    """Attach GRPO-style per-branch advantages to a tree's leaves.

    ``rewards[k]`` is the scalar reward of the k-th root-to-leaf
    trajectory in DFS leaf order (the order of ``tree.paths()``).  With
    ``normalize`` the group statistic is applied — A = (r − mean)/std
    over the tree's K branches, the GRPO group baseline — otherwise the
    raw rewards are used as advantages.  Returns the advantages."""
    leaves = [p[-1] for p in tree.paths()]
    r = np.asarray(rewards, np.float64)
    assert r.shape == (len(leaves),), (r.shape, len(leaves))
    adv = group_normalized_advantages(r, normalize)
    for leaf, a in zip(leaves, adv):
        leaf.branch_adv = float(a)
    return adv.astype(np.float32)


def grpo_tree(
    rng: np.random.Generator,
    *,
    vocab_size: int = 32000,
    num_turns: int = 6,
    turn_len_range: tuple[int, int] = (64, 512),
    tool_branch_prob: float = 0.4,
    think_branch_prob: float = 0.3,
    max_parallel_tools: int = 4,
    reward_scale: float = 1.0,
) -> TrajectoryTree:
    """RL model-update workload: an agentic rollout tree whose branches
    carry group-normalized GRPO advantages — each root-to-leaf trajectory
    is one sample of the group, its reward drawn per leaf and normalized
    against the tree's K siblings.  Train with ``loss_mode="rl"``."""
    t = agentic_tree(rng, vocab_size=vocab_size, num_turns=num_turns,
                     turn_len_range=turn_len_range,
                     tool_branch_prob=tool_branch_prob,
                     think_branch_prob=think_branch_prob,
                     max_parallel_tools=max_parallel_tools)
    rewards = rng.normal(scale=reward_scale, size=t.num_leaves())
    assign_branch_advantages(t, rewards)
    return t


def trees_for_batch(
    seed: int,
    *,
    n_trees: int,
    kind: str = "random",
    **kw,
) -> list[TrajectoryTree]:
    rng = np.random.default_rng(seed)
    gen = {"random": random_tree, "chain": chain_tree,
           "por": por_controlled_tree, "agentic": agentic_tree,
           "grpo": grpo_tree, "template": template_tree}[kind]
    return [gen(rng, **kw) for _ in range(n_trees)]
