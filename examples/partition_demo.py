"""Redundancy-Free Tree Partitioning demo (paper §3.3 + Fig. 5).

A trajectory tree too large for the per-step token budget is split into
connected subtrees with differentiable boundaries; every token is computed
exactly once, and the gradients match the whole-tree pass to float32
precision.

Run:  PYTHONPATH=src python examples/partition_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.gateway import partitioned_value_and_grad
from repro.core.packing import pack_trees
from repro.core.partition import (partition_token_counts, partition_tree,
                                  standard_partition_token_counts)
from repro.core.tree import serialize_tree
from repro.data.synthetic import agentic_tree
from repro.models.model import init_params, loss_and_metrics, prepare_batch

rng = np.random.default_rng(0)
tree = agentic_tree(rng, num_turns=4, turn_len_range=(12, 40),
                    vocab_size=500)
uniq = tree.num_unique_tokens()
C = max(128, ((uniq // 3) // 32) * 32)      # budget ≈ a third of the tree
print(f"tree: {uniq} unique tokens, {tree.num_leaves()} paths, "
      f"POR={tree.por():.1%}; per-step budget C={C}")

# --- Fig. 5 accounting ---------------------------------------------------
flat = tree.flat_tokens()
std = standard_partition_token_counts(tree, C)
parts = partition_tree(tree, C)
ours = partition_token_counts(parts)
print(f"tokens computed:  baseline flatten = {flat}")
print(f"                  standard partitioning (re-include ancestors) = "
      f"{std}")
print(f"                  redundancy-free (ours) = "
      f"{ours['unique_tokens']}  == unique ✓")
print(f"partitions: {ours['num_partitions']}  "
      f"(each ≤ {C} tokens; boundaries differentiable)")

# --- gradient equivalence vs the whole-tree pass --------------------------
cfg = get_config("qwen3-8b", smoke=True)
params = init_params(cfg, jax.random.key(0))

ser = serialize_tree(tree)
S = ((ser.n + 63) // 64) * 64
whole = prepare_batch(cfg, pack_trees([ser], S))
l_ref, _ = loss_and_metrics(cfg, params, whole)
g_ref = jax.grad(lambda p: loss_and_metrics(cfg, p, whole)[0])(params)

l_p, g_p, info = partitioned_value_and_grad(cfg, params, tree, C)
rels = jax.tree.map(
    lambda a, b: float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-9)),
    g_p, g_ref)
print(f"\nloss: whole-tree={float(l_ref):.6f}  partitioned={l_p:.6f}")
print(f"max grad rel deviation: {max(jax.tree.leaves(rels)):.2e} "
      "(paper App. B.8 bound: < 1e-4 in float32)")
