"""End-to-end driver: train a ~100M-param dense model for a few hundred
steps on synthetic agentic trajectory trees, comparing Tree Training
against the sep-avg baseline (same data, same seeds) — the Fig.-7
experiment at laptop scale.

Run:  PYTHONPATH=src python examples/train_agentic.py [--steps 200]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import AttnCfg, ModelConfig
from repro.data.loader import LoaderConfig, batches, dataset_por
from repro.data.synthetic import trees_for_batch
from repro.models.model import init_params
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_step import make_train_step


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="agentic-100m", family="dense",
        n_layers=8, d_model=512, d_ff=2048, vocab_size=8192,
        attn=AttnCfg(n_heads=8, n_kv_heads=4, head_dim=64, qk_norm=True),
        dtype="float32", vocab_pad_multiple=64)


def run(mode: str, steps: int, seq_len: int) -> dict:
    cfg = model_100m()
    params = init_params(cfg, jax.random.key(0))
    opt_cfg = OptimizerConfig(lr=6e-4, warmup_steps=max(2, steps // 20),
                              total_steps=steps)
    step = make_train_step(cfg, opt_cfg)
    opt = init_opt_state(params)
    lc = LoaderConfig(seq_len=seq_len, batch_rows=2, trees_per_batch=6,
                      mode=mode, kind="agentic", seed=7,
                      gen_kwargs=dict(num_turns=4,
                                      turn_len_range=(12, 56)))
    losses, times, tokens = [], [], 0
    for i, (inputs, tb) in enumerate(batches(model_100m(), lc, steps)):
        t0 = time.perf_counter()
        params, opt, m = step(params, opt, inputs)
        loss = float(m["token_nll_mean"])   # forces sync
        times.append(time.perf_counter() - t0)
        losses.append(loss)
        tokens += int(tb.valid.sum())
        if i % 20 == 0:
            print(f"  [{mode}] step {i:4d}  nll/tok {loss:.4f}  "
                  f"{times[-1] * 1e3:.0f} ms", flush=True)
    return {"losses": losses, "step_time": float(np.median(times[2:])),
            "tokens": tokens}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=512)
    args = ap.parse_args()

    n_params = sum(np.prod(l.shape) for l in jax.tree.leaves(
        jax.eval_shape(lambda k: init_params(model_100m(), k),
                       jax.random.key(0))))
    trees = trees_for_batch(7, n_trees=20, kind="agentic", num_turns=4,
                            turn_len_range=(12, 56), vocab_size=8192)
    print(f"model: {n_params / 1e6:.0f}M params; "
          f"dataset POR≈{dataset_por(trees):.1%}")

    print("== Tree Training ==")
    tree = run("tree", args.steps, args.seq_len)
    print("== sep-avg baseline ==")
    base = run("baseline", args.steps, args.seq_len)

    n = min(len(tree["losses"]), len(base["losses"]))
    dev = np.abs(np.array(tree["losses"][:n]) -
                 np.array(base["losses"][:n]))
    rel = dev / np.abs(base["losses"][:n])
    print("\n================ summary ================")
    print(f"median step time  tree={tree['step_time'] * 1e3:.0f} ms   "
          f"baseline={base['step_time'] * 1e3:.0f} ms   "
          f"speedup={base['step_time'] / tree['step_time']:.2f}x")
    print(f"unique tokens trained: tree={tree['tokens']}, "
          f"baseline(batch covers same trees)={base['tokens']}")
    print(f"loss deviation: mean rel {rel.mean():.2e}, "
          f"max rel {rel.max():.2e}  (paper Fig. 7: <1e-2)")


if __name__ == "__main__":
    main()
