"""Quickstart: Tree Training in ~60 lines.

Builds a branching agentic trajectory tree, shows the paper's core
identity (the DFS tree loss equals the per-branch sep-avg loss exactly —
Eq. 1–5), and takes one optimizer step on the tree batch.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.packing import pack_linear_paths, pack_trees
from repro.core.tree import TrajectoryTree, TreeNode, serialize_tree
from repro.models.model import init_params, loss_and_metrics, prepare_batch
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_step import make_train_step

# --- 1. a trajectory tree: one task that branched into 3 paths ----------
#   (think: two concurrent tool calls, then a think-mode fork)
rng = np.random.default_rng(0)
tok = lambda n: rng.integers(0, 500, n).astype(np.int32)
root = TreeNode(tokens=tok(12))                       # user prompt + plan
tool_a = TreeNode(tokens=tok(8))                      # tool call A branch
tool_b = TreeNode(tokens=tok(10))                     # tool call B branch
think1 = TreeNode(tokens=tok(6))                      # think-mode variant
tool_a.children = [think1]
root.children = [tool_a, tool_b]
tree = TrajectoryTree(root)
print(f"tree: {tree.num_unique_tokens()} unique tokens, "
      f"{tree.num_leaves()} paths, POR={tree.por():.1%} "
      f"(theoretical speedup bound {1 / (1 - tree.por()):.2f}x)")

# --- 2. the identity: tree loss == per-branch average, exactly ----------
cfg = get_config("qwen3-8b", smoke=True)
params = init_params(cfg, jax.random.key(0))

ser = serialize_tree(tree)                            # DFS: each token once
tree_batch = prepare_batch(cfg, pack_trees([ser], 64))
base_batch = prepare_batch(cfg, pack_linear_paths(
    [tree.linearize_paths()], 64))                    # prefixes repeated

l_tree, _ = loss_and_metrics(cfg, params, tree_batch)
l_base, _ = loss_and_metrics(cfg, params, base_batch)
print(f"tree loss     = {float(l_tree):.6f}  "
      f"({tree_batch['tokens'].size} slots)")
print(f"baseline loss = {float(l_base):.6f}  "
      f"({base_batch['tokens'].size} slots)")
assert abs(float(l_tree) - float(l_base)) < 1e-4 * abs(float(l_base))

# --- 3. one training step on the tree batch -----------------------------
opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
step = make_train_step(cfg, opt_cfg, donate=False)
params, opt_state, metrics = step(params, init_opt_state(params),
                                  tree_batch)
print(f"step 0: loss={float(metrics['total']):.4f} "
      f"grad_norm={float(metrics['grad_norm']):.3f} — "
      "every shared-prefix token computed exactly once.")
