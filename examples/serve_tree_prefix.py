"""Serving demo: K branch decodes off one shared, prefilled prompt.

The tree-training insight applied at inference (paper §2): the session
prefills the shared prompt ONCE — a single tree-kernel forward over the
whole prefix — then ``fork`` splits K branches that reuse the cached
prefix KV without recomputing a single prefix token.

Run:  PYTHONPATH=src python examples/serve_tree_prefix.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.serve.session import DecodeSession

cfg = get_config("qwen2-1.5b", smoke=True)
from repro.models.model import init_params  # noqa: E402

params = init_params(cfg, jax.random.key(0))
rng = np.random.default_rng(0)

K, PREFIX, GEN, T = 4, 24, 16, 64
shared_prompt = rng.integers(0, cfg.vocab_size, PREFIX).astype(np.int32)

# prefill the shared prefix ONCE: one parallel forward, K-way reuse
session = DecodeSession.create(cfg, params, buf_len=T)
session.prefill(shared_prompt)
branches = session.fork(K)     # shares the prefix KV — no recompute

# the K branches diverge: greedy decode from different first tokens
cur = rng.integers(0, cfg.vocab_size, K).astype(np.int32)
outs = [cur]
for _ in range(GEN):
    logits = branches.step(cur)
    cur = np.asarray(logits.argmax(-1), np.int32)
    outs.append(cur)

gen = np.stack(outs, 1)
st = session.stats
print(f"shared prefix: {PREFIX} tokens, prefilled once for {K} branches "
      f"(prefill_tokens={st.prefill_tokens}, "
      f"saved={K * PREFIX - st.prefill_tokens})")
for b in range(K):
    print(f"branch {b}: {gen[b].tolist()}")
print("decode OK — per-step logits finite:",
      bool(np.isfinite(np.asarray(logits)).all()))
