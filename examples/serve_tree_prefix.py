"""Serving demo: batched decode with a shared prompt prefix.

The tree-training insight applied at inference: N requests sharing a
system-prompt prefix decode against one cache whose prefix slots were
prefilled once (prefix caching — the inference-side sibling the paper
builds on, §2).  Decodes 4 continuations of one shared prompt.

Run:  PYTHONPATH=src python examples/serve_tree_prefix.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.serve.decode import decode_step, init_cache

cfg = get_config("qwen2-1.5b", smoke=True)
from repro.models.model import init_params  # noqa: E402

params = init_params(cfg, jax.random.key(0))
rng = np.random.default_rng(0)

B, PREFIX, GEN, T = 4, 24, 16, 64
shared_prompt = rng.integers(0, cfg.vocab_size, PREFIX).astype(np.int32)

# prefill the shared prefix ONCE (batch dim broadcast: identical KV rows —
# a production server would store one copy; jnp broadcasting shares it)
cache = init_cache(cfg, B, T)
step = jax.jit(lambda c, t, p, w: decode_step(cfg, params, c, t, p, w))
for t in range(PREFIX):
    toks = jnp.broadcast_to(jnp.asarray([[shared_prompt[t]]]), (B, 1))
    logits, cache = step(cache, toks, jnp.full((B,), t, jnp.int32),
                         jnp.asarray(t, jnp.int32))

# then 4 requests branch: greedy decode with different first tokens
cur = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
outs = [np.asarray(cur[:, 0])]
for t in range(PREFIX, PREFIX + GEN):
    logits, cache = step(cache, cur, jnp.full((B,), t, jnp.int32),
                         jnp.asarray(t, jnp.int32))
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs.append(np.asarray(cur[:, 0]))

gen = np.stack(outs, 1)
print(f"shared prefix: {PREFIX} tokens (prefilled once for {B} requests)")
for b in range(B):
    print(f"request {b}: {gen[b].tolist()}")
print("decode OK — per-step logits finite:",
      bool(np.isfinite(np.asarray(logits)).all()))
