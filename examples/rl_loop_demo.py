"""The closed async RL loop, in miniature: rollout → tree → train.

Wires the three pieces by hand (the ``repro.launch.rl_loop`` CLI does
the same with warmup + auditing):

  1. an :class:`AsyncTreeRLService` thread decodes K-branch rollout
     groups — each group's prompt prefilled ONCE, branches forked off
     the shared KV — and merges them into GRPO advantage trees;
  2. ``train.planner.plans`` consumes the live tree queue exactly like
     an offline stream (Tree Packing, background materialization);
  3. ``TreeTrainEngine.step`` trains with ``loss_mode="rl"`` and
     publishes fresh weights back to the generator's WeightStore —
     generation never runs more than ``max_ahead_steps`` ahead.

Run:  PYTHONPATH=src python examples/rl_loop_demo.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.data.loader import LoaderConfig
from repro.models.model import init_params
from repro.serve.rollout import RolloutConfig
from repro.serve.service import (AsyncTreeRLService, ServiceConfig,
                                 WeightStore)
from repro.train.engine import TreeTrainEngine
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.planner import PlannerConfig, plans

cfg = get_config("qwen2-1.5b", smoke=True)
STEPS, GROUPS = 3, 2
rc = RolloutConfig(k=4, prompt_len=8, max_new=4)

params = init_params(cfg, jax.random.key(0))
opt_state = init_opt_state(params)

# seq_len ≥ the worst-case tree (prompt + k·max_new) → zero drops
lc = LoaderConfig(seq_len=rc.prompt_len + rc.k * rc.max_new, batch_rows=2,
                  trees_per_batch=GROUPS, mode="tree", seed=0,
                  loss_mode="rl", auto_partition=True)
pcfg = PlannerConfig(lookahead=1, plan_workers=1, max_rows=2)
sc = ServiceConfig(groups_per_step=GROUPS, max_ahead_steps=1, rollout=rc)

store = WeightStore(params, version=0)
engine = TreeTrainEngine(cfg, OptimizerConfig(lr=3e-4, warmup_steps=2,
                                              total_steps=STEPS),
                         weight_store=store)
svc = AsyncTreeRLService(cfg, store, sc, num_steps=STEPS).start()

for ps in plans(cfg, lc, svc.tree_batches(), pcfg):
    plan = ps.execution_plan()
    if plan.is_empty:
        continue
    params, opt_state, m = engine.step(params, opt_state, plan)
    lo, hi = plan.versions
    print(f"step {engine.steps_done - 1}: loss {m['loss']:.4f} "
          f"trained on weights v{lo}..v{hi} "
          f"(lag {m['max_lag']})")
svc.join(10)

st = svc.stats
print(f"{st.trees_generated} trees from {st.steps_generated} generation "
      f"steps; prefix KV reuse saved {st.saved_prefill_tokens} of "
      f"{st.saved_prefill_tokens + st.prefill_tokens} prefill tokens "
      f"({rc.k} branches per prompt, each prefix computed once)")
