"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and (with ``--out``) writes
the same rows as a JSON artifact for CI:

  por_sweep_*        Fig. 8a — tree vs baseline step time across POR
  partition_tokens   Fig. 5  — token counts: flatten / standard / ours
  partition_sweep_*  Fig. 8b — partitioned tree training under memory cap
  realistic_*        Fig. 7  — agentic-tree speedup + loss deviation
  memory_overhead    §4.6    — extra tree-metadata bytes vs activations
  kernel_blocks      App. A.1 — tree-attention kernel block-skip ratio
  kernel_fwd / kernel_fwd_bwd
                     App. A.1 — fused Pallas kernel wall time, forward and
                     forward+backward (jax.grad through the op), tree
                     packing vs linearized packing of the same trees
  packed_partition   §3.4 — batched wave-scheduled partitioned step:
                     timing vs the whole-tree pass + tree-vs-partitioned
                     token accounting (unique / padded)
  gateway_impl       §3.3 — the same partitioned step with impl=pallas
                     (fused kernels on the gateway-extended KV layout)
                     vs impl=chunked (XLA scan fallback)
  engine_step        §3.4 — one optimizer step over a mixed stream
                     (packed rows + oversized trees) through the unified
                     plan→execute TreeTrainEngine vs the pre-refactor
                     two-branch loop; asserts ≤ 1 host sync per step
  plan_efficiency    schedule level — plan-ahead scheduler: padded-vs-
                     unique tokens of global lookahead packing vs greedy
                     per-step first-fit, plus plan-build ms overlapped vs
                     exposed behind engine steps (async pipeline)
  rl_service         §2 RL model-update — the closed async rollout→tree→
                     train loop: shared-prefix KV prefill savings (each
                     group's prefix computed exactly once), generation
                     overlap fraction behind training, bounded staleness,
                     zero dropped trees
  compile_warmup     runtime level — AOT warmup engine (train/warmup):
                     cold vs warm step-1 latency, retrace count (0 after
                     universe warmup on an in-universe stream), exposed
                     compile wait fraction, and persistent-compile-cache
                     restart (second process writes 0 new cache modules);
                     each timed step also emits a CostWeights calibration
                     sample into the --out artifact

Flags:
  --smoke      tiny qwen1.5-0.5B-scale config, CPU-interpret friendly,
               finishes in a few minutes — the CI benchmark gate (the
               compile_warmup row's cold-compile baseline and restart
               probes are inherently compile-bound)
  --impl X     attention impl for the model-level benches (ref/chunked/
               pallas); model benches default to ref, kernel benches
               always exercise the Pallas op
  --out F      write rows + environment metadata as JSON
  --calibrate F
               fit CostWeights from a previous --out artifact's
               calib_samples (least squares, pad-normalized) and print
               the ``CostWeights(...)`` literal; runs no benchmarks
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.run` from repo root

from benchmarks.common import (baseline_inputs, bench_model,  # noqa: E402
                               timed_loss_grad, tree_inputs)
from repro.core.gateway import partitioned_value_and_grad  # noqa: E402
from repro.core.partition import (partition_token_counts,  # noqa: E402
                                  partition_tree,
                                  standard_partition_token_counts)
from repro.core.tree import serialize_tree  # noqa: E402
from repro.data.loader import dataset_por  # noqa: E402
from repro.data.synthetic import (agentic_tree,  # noqa: E402
                                  por_controlled_tree, trees_for_batch)
from repro.models.model import init_params  # noqa: E402

ROWS: list[dict] = []
# cost-model calibration samples: one dict per timed compile_warmup step
# (wall seconds + CostWeights features) — written into the --out artifact
# and consumed by ``--calibrate`` to least-squares-fit CostWeights
CALIB: list[dict] = []


def emit(name: str, us: float, derived: str) -> None:
    ROWS.append({"name": name, "us_per_call": round(us, 1),
                 "derived": derived})
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------
# Fig. 8a — POR sweep, full tree in memory
# ---------------------------------------------------------------------------

def bench_por_sweep(impl: str = "ref") -> None:
    cfg = bench_model()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    for por in (0.2, 0.5, 0.8, 0.92):
        trees = [por_controlled_tree(rng, target_por=por, num_paths=8,
                                     tokens_per_path=96) for _ in range(2)]
        real_por = dataset_por(trees)
        # both modes pack into rows of the SAME length (as the paper's
        # sequence-packing baseline does) — baseline simply needs more rows
        n_tree = max(serialize_tree(t).n for t in trees)
        S = ((max(n_tree, 256) + 127) // 128) * 128
        bt, _ = tree_inputs(cfg, trees, S)
        bl, _ = baseline_inputs(cfg, trees, S)
        t_tree, l_tree = timed_loss_grad(cfg, params, bt, impl=impl)
        t_base, l_base = timed_loss_grad(cfg, params, bl, impl=impl)
        bound = 1.0 / (1.0 - real_por)
        emit(f"por_sweep_{int(por * 100)}", t_tree * 1e6,
             f"speedup={t_base / t_tree:.2f}x bound={bound:.2f}x "
             f"por={real_por:.3f} "
             f"loss_rel={abs(float(l_tree - l_base)) / abs(float(l_base)):.1e}")


# ---------------------------------------------------------------------------
# Fig. 5 — partition token accounting
# ---------------------------------------------------------------------------

def bench_partition_tokens() -> None:
    rng = np.random.default_rng(1)
    tree = agentic_tree(rng, num_turns=7, turn_len_range=(40, 200),
                        vocab_size=1024)
    uniq = tree.num_unique_tokens()
    C = max(256, ((uniq // 3) // 64) * 64)
    flat = tree.flat_tokens()
    std = standard_partition_token_counts(tree, C)
    ours = partition_token_counts(partition_tree(tree, C))
    emit("partition_tokens", 0.0,
         f"flatten={flat} standard={std} ours={ours['unique_tokens']} "
         f"unique={uniq} parts={ours['num_partitions']} cap={C}")
    assert ours["unique_tokens"] == uniq


# ---------------------------------------------------------------------------
# Fig. 8b — memory-constrained partitioned training
# ---------------------------------------------------------------------------

def bench_partition_sweep() -> None:
    cfg = bench_model(n_layers=2)
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(2)
    for por in (0.5, 0.8):
        tree = por_controlled_tree(rng, target_por=por, num_paths=8,
                                   tokens_per_path=128)
        C = 256
        partitioned_value_and_grad(cfg, params, tree, C)   # warm traces
        t0 = time.perf_counter()
        l_p, _, info = partitioned_value_and_grad(cfg, params, tree, C)
        t_part = time.perf_counter() - t0
        S_flat = ((tree.max_path_tokens() + 127) // 128) * 128
        bl, _ = baseline_inputs(cfg, [tree], S_flat)
        t_base, l_base = timed_loss_grad(cfg, params, bl)
        emit(f"partition_sweep_{int(por * 100)}", t_part * 1e6,
             f"speedup={t_base / t_part:.2f}x parts={info['num_partitions']} "
             f"cap={C} loss_rel="
             f"{abs(l_p - float(l_base)) / abs(float(l_base)):.1e}")


# ---------------------------------------------------------------------------
# Fig. 7 — realistic agentic trees: speedup + loss deviation
# ---------------------------------------------------------------------------

def bench_realistic(impl: str = "ref") -> None:
    cfg = bench_model()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(3)
    trees = []
    while len(trees) < 3:
        t = agentic_tree(rng, num_turns=5, turn_len_range=(16, 64),
                         vocab_size=1024)
        if t.num_leaves() > 1 and serialize_tree(t).n <= 1024:
            trees.append(t)
    por = dataset_por(trees)
    bt, _ = tree_inputs(cfg, trees, 1024)
    bl, _ = baseline_inputs(cfg, trees, 1024)
    t_tree, l_tree = timed_loss_grad(cfg, params, bt, impl=impl)
    t_base, l_base = timed_loss_grad(cfg, params, bl, impl=impl)
    emit("realistic_agentic", t_tree * 1e6,
         f"speedup={t_base / t_tree:.2f}x bound={1 / (1 - por):.2f}x "
         f"por={por:.3f} "
         f"loss_rel={abs(float(l_tree - l_base)) / abs(float(l_base)):.1e}")


# ---------------------------------------------------------------------------
# §4.6 — memory overhead of tree metadata
# ---------------------------------------------------------------------------

def bench_memory_overhead() -> None:
    cfg = bench_model()
    rng = np.random.default_rng(4)
    trees = []
    while len(trees) < 2:
        t = agentic_tree(rng, num_turns=4, turn_len_range=(16, 48),
                         vocab_size=1024)
        if serialize_tree(t).n <= 1024:
            trees.append(t)
    bt, tb = tree_inputs(cfg, trees, 1024)
    extra = sum(np.asarray(v).nbytes for k, v in bt.items()
                if k in ("pos_ids", "kv_last", "weight", "prev_idx",
                         "valid"))
    B, S = tb.tokens.shape
    act = B * S * cfg.d_model * 4 * cfg.n_layers  # one residual per layer
    emit("memory_overhead", 0.0,
         f"metadata_bytes={extra} activation_bytes~={act} "
         f"ratio={extra / act:.2e}")


# ---------------------------------------------------------------------------
# App. A.1 — kernel block-skip accounting
# ---------------------------------------------------------------------------

def _pack_greedy(seq_len: int, seed: int, n_trees: int, seg, max_depth=4):
    """Greedily fill one seq_len row with random trees; returns the packed
    TreeBatch and the kept trees (for building the linearized baseline of
    the *same* data)."""
    from repro.core.packing import pack_trees
    trees = trees_for_batch(seed, n_trees=n_trees, kind="random",
                            seg_len_range=seg, max_depth=max_depth)
    used, keep = 0, []
    for t in trees:
        s = serialize_tree(t)
        if used + s.n <= seq_len:
            keep.append((t, s))
            used += s.n
    tb = pack_trees([s for _, s in keep], seq_len, batch_size=1)
    return tb, [t for t, _ in keep]


def bench_kernel_blocks() -> None:
    from repro.kernels.tree_attention import block_live_mask
    tb, _ = _pack_greedy(512, seed=9, n_trees=6, seg=(8, 32))
    kv_last = np.asarray(tb.kv_last)[0]
    S, bq = 512, 64
    nq = S // bq
    live_mask = block_live_mask(kv_last, S, bq, bq)
    live = int(live_mask.sum())
    skipped = live_mask.size - live
    causal_live = nq * (nq + 1) // 2
    emit("kernel_blocks", 0.0,
         f"live={live} skipped={skipped} causal_would_run={causal_live} "
         f"extra_skip_vs_causal={causal_live - live}")


# ---------------------------------------------------------------------------
# App. A.1 — fused kernel wall time, fwd and fwd+bwd, tree vs linearized
# ---------------------------------------------------------------------------

def _timed(fn, *args, iters: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_kernel_fwd_bwd(smoke: bool = False) -> None:
    """Time the Pallas op itself — forward, and forward+backward via
    jax.grad — on tree-packed vs linearized-packed copies of the same
    trees.  The backward now runs the fused kernels, so this measures the
    training-step speedup the paper reports, not just inference."""
    from repro.core.packing import pack_linear_paths
    from repro.kernels.ops import tree_attention

    if smoke:
        S, H, Kh, hd, bq = 256, 4, 4, 16, 64
        n_trees, seg, iters = 4, (8, 24), 2
    else:
        S, H, Kh, hd, bq = 1024, 8, 4, 64, 128
        n_trees, seg, iters = 8, (16, 64), 3
    tb, kept = _pack_greedy(S, seed=9, n_trees=n_trees, seg=seg)
    lb = pack_linear_paths([t.linearize_paths() for t in kept], S)
    rng = np.random.default_rng(9)
    scale = hd ** -0.5

    results = {}
    for tag, kv_last in (("tree", np.asarray(tb.kv_last)),
                         ("linear", np.asarray(lb.kv_last))):
        B = kv_last.shape[0]
        q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, Kh, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, Kh, hd)), jnp.float32)
        kl = jnp.asarray(kv_last)
        fwd = jax.jit(lambda q_, k_, v_:
                      tree_attention(q_, k_, v_, kl, scale, bq, bq))
        loss = lambda q_, k_, v_: (tree_attention(
            q_, k_, v_, kl, scale, bq, bq) ** 2).sum()
        fwd_bwd = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))
        t_f = _timed(fwd, q, k, v, iters=iters)
        t_fb = _timed(fwd_bwd, q, k, v, iters=iters)
        results[tag] = (t_f, t_fb, B)
        emit(f"kernel_fwd_{tag}", t_f * 1e6, f"rows={B} S={S} block={bq}")
        emit(f"kernel_fwd_bwd_{tag}", t_fb * 1e6,
             f"rows={B} S={S} block={bq}")
    (tf_t, tfb_t, _), (tf_l, tfb_l, _) = results["tree"], results["linear"]
    emit("kernel_tree_vs_linear", 0.0,
         f"fwd_speedup={tf_l / tf_t:.2f}x "
         f"fwd_bwd_speedup={tfb_l / tfb_t:.2f}x")


# ---------------------------------------------------------------------------
# §3.4 — batched wave-scheduled partitioned training (oversized trees)
# ---------------------------------------------------------------------------

def bench_packed_partition(smoke: bool = False) -> None:
    """Step timing + token accounting of the batched partition pipeline:
    trees too big for one row train via wave-scheduled Tree Packing over
    partitions vs the whole-tree pass on one oversized row."""
    from repro.core.gateway import packed_partitioned_value_and_grad

    if smoke:
        cfg = bench_model(n_layers=2, d_model=64)
        S, C, turns, seg = 128, 64, 5, (12, 40)
    else:
        cfg = bench_model(n_layers=2)
        S, C, turns, seg = 512, 256, 7, (40, 160)
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(6)
    trees = []
    while len(trees) < 2:
        t = agentic_tree(rng, num_turns=turns, turn_len_range=seg,
                         vocab_size=1024)
        if serialize_tree(t).n > S:          # genuinely oversized
            trees.append(t)
    uniq = sum(t.num_unique_tokens() for t in trees)

    packed_partitioned_value_and_grad(cfg, params, trees, C,
                                      seq_len=S)      # warm executables
    t0 = time.perf_counter()
    l_p, _, info = packed_partitioned_value_and_grad(cfg, params, trees,
                                                     C, seq_len=S)
    t_part = time.perf_counter() - t0

    # whole-tree reference: each tree on one (oversized) row
    S_ref = ((max(serialize_tree(t).n for t in trees) + 127) // 128) * 128
    bt, _ = tree_inputs(cfg, trees, S_ref)
    t_ref, l_ref = timed_loss_grad(cfg, params, bt, iters=2)
    l_ref = float(l_ref) * len(trees)       # mean-over-trees → sum
    emit("packed_partition", t_part * 1e6,
         f"whole_tree_ratio={t_part / t_ref:.2f}x "
         f"parts={info['num_partitions']} waves={info['num_waves']} "
         f"rows={info['rows']} cap={C} unique={uniq} "
         f"padded={info['tokens']} "
         f"loss_rel={abs(l_p - l_ref) / abs(l_ref):.1e}")
    assert info["unique_tokens"] == uniq


# ---------------------------------------------------------------------------
# §3.3 / App. A.1 — fused pallas kernels on the partition-gateway path
# ---------------------------------------------------------------------------

def bench_gateway_impl(smoke: bool = False) -> None:
    """The same wave-scheduled partitioned step (ancestor gateway KV
    through attention) run with impl='pallas' (fused kernels, incl. fused
    backward with ancestor cotangents) vs impl='chunked' (XLA scan) —
    the downgrade PR 2 shipped with is gone; this row tracks what the
    fused path buys on the gateway-extended KV layout."""
    from repro.core.gateway import packed_partitioned_value_and_grad

    if smoke:
        cfg = bench_model(n_layers=2, d_model=64)
        S, C, turns, seg, n_trees = 128, 64, 5, (12, 40), 1
    else:
        cfg = bench_model(n_layers=2)
        S, C, turns, seg, n_trees = 512, 256, 7, (40, 160), 2
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(8)
    trees = []
    while len(trees) < n_trees:
        t = agentic_tree(rng, num_turns=turns, turn_len_range=seg,
                         vocab_size=1024)
        if serialize_tree(t).n > S:
            trees.append(t)

    res = {}
    for impl in ("chunked", "pallas"):
        packed_partitioned_value_and_grad(cfg, params, trees, C,
                                          seq_len=S, impl=impl)  # warm
        t0 = time.perf_counter()
        l, _, info = packed_partitioned_value_and_grad(
            cfg, params, trees, C, seq_len=S, impl=impl)
        res[impl] = (time.perf_counter() - t0, l, info)
    (t_c, l_c, _), (t_p, l_p, info) = res["chunked"], res["pallas"]
    emit("gateway_impl", t_p * 1e6,
         f"chunked_us={t_c * 1e6:.1f} pallas_vs_chunked={t_c / t_p:.2f}x "
         f"parts={info['num_partitions']} waves={info['num_waves']} "
         f"cap={C} loss_rel={abs(l_p - l_c) / max(abs(l_c), 1e-9):.1e}")


# ---------------------------------------------------------------------------
# unified plan→execute engine vs the PR-3 two-branch step
# ---------------------------------------------------------------------------

def bench_engine_step(smoke: bool = False, impl: str = "ref") -> None:
    """One optimizer step over a mixed stream (packed rows + oversized
    trees) through the unified TreeTrainEngine vs the pre-refactor
    two-branch loop (jitted packed grad + wave driver + host-side
    combine).  Also asserts the engine's host-sync discipline: ≤ 1
    device→host sync per optimizer step."""
    from repro.core.gateway import packed_partitioned_value_and_grad
    from repro.data.loader import LoaderConfig
    from repro.train.engine import TreeTrainEngine
    from repro.train.planner import plans as plan_steps
    from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                       init_opt_state)
    from repro.train.train_step import make_grad_fn

    if smoke:
        cfg = bench_model(n_layers=2, d_model=64)
        S, C, steps = 128, 64, 3
        gen = dict(turn_len_range=(8, 24), num_turns=3)
    else:
        cfg = bench_model(n_layers=2)
        S, C, steps = 512, 256, 5
        gen = dict(turn_len_range=(24, 96), num_turns=5)
    lc = LoaderConfig(seq_len=S, batch_rows=2, trees_per_batch=4,
                      mode="tree", kind="agentic", seed=11,
                      auto_partition=True, capacity=C, gen_kwargs=gen)
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    params = init_params(cfg, jax.random.key(0))

    # one planner stream materializes both views of the same schedule
    pss = list(plan_steps(cfg, lc, steps))
    eplans = [ps.execution_plan() for ps in pss
              if not ps.execution_plan().is_empty]
    sbs = [ps.step_batch() for ps in pss
           if ps.step_batch().inputs is not None
           or ps.step_batch().oversized]
    n_oversized = sum(p.num_oversized for p in eplans)

    # ---- unified engine ---------------------------------------------------
    # warm pass over EVERY plan first: each step can carry differently
    # bucketed wave shapes, and compilation must stay out of the timing
    engine = TreeTrainEngine(cfg, opt_cfg, impl=impl, donate=False)
    opt = init_opt_state(params)
    p_e = params
    for plan in eplans:
        p_e, opt, _ = engine.step(p_e, opt, plan)
    syncs0, steps0 = engine.host_syncs, engine.steps_done
    opt = init_opt_state(params)
    p_e = params
    t0 = time.perf_counter()
    loss_e = 0.0
    for plan in eplans:
        p_e, opt, m = engine.step(p_e, opt, plan)
        loss_e = m["loss"]
    t_engine = (time.perf_counter() - t0) / len(eplans)
    syncs_per_step = (engine.host_syncs - syncs0) / (engine.steps_done
                                                     - steps0)
    assert syncs_per_step <= 1.0, syncs_per_step

    # ---- pre-refactor two-branch loop ------------------------------------
    gfn = make_grad_fn(cfg, impl=impl)
    update_fn = jax.jit(lambda p, g, s: adamw_update(opt_cfg, p, g, s))
    cap = lc.capacity or lc.seq_len

    def two_branch(p, opt, sb):
        n = max(sb.num_trees, 1)
        loss, grads = 0.0, None
        if sb.inputs is not None:
            inputs = dict(sb.inputs)      # the engine shares this dict
            inputs["num_trees"] = n
            li, grads, _ = gfn(p, inputs)
            loss += float(li)
        if sb.oversized:
            l_p, g_p, _ = packed_partitioned_value_and_grad(
                cfg, p, sb.oversized, cap, seq_len=lc.seq_len, impl=impl,
                max_rows=lc.batch_rows)
            loss += l_p / n
            g_p = jax.tree.map(lambda a: a / n, g_p)
            grads = g_p if grads is None else jax.tree.map(
                lambda a, b: a.astype(jnp.float32) + b, grads, g_p)
        p, opt, om = update_fn(p, grads, opt)
        return p, opt, loss

    opt = init_opt_state(params)
    p_r = params
    for sb in sbs:                                      # warm executables
        p_r, opt, _ = two_branch(p_r, opt, sb)
    opt = init_opt_state(params)
    p_r = params
    t0 = time.perf_counter()
    loss_r = 0.0
    for sb in sbs:
        p_r, opt, loss_r = two_branch(p_r, opt, sb)
    t_two = (time.perf_counter() - t0) / len(sbs)

    emit("engine_step", t_engine * 1e6,
         f"two_branch_us={t_two * 1e6:.1f} "
         f"speedup={t_two / t_engine:.2f}x steps={len(eplans)} "
         f"oversized={n_oversized} host_syncs_per_step={syncs_per_step:.1f} "
         f"loss_rel={abs(loss_e - loss_r) / max(abs(loss_r), 1e-9):.1e}")


# ---------------------------------------------------------------------------
# schedule level — plan-ahead scheduler efficiency + async overlap
# ---------------------------------------------------------------------------

def bench_plan_efficiency(smoke: bool = False, impl: str = "ref") -> None:
    """The plan-ahead scheduler (train/planner): padded-vs-unique token
    efficiency of global lookahead bin packing (cost-model-chosen
    candidates) vs greedy per-step first-fit on the same tree stream, and
    plan-build time overlapped behind ``TreeTrainEngine.step`` by the
    async double-buffered pipeline (``--plan-workers 1``)."""
    from repro.data.loader import LoaderConfig
    from repro.train.engine import TreeTrainEngine
    from repro.train.optimizer import OptimizerConfig, init_opt_state
    from repro.train.planner import (PlannerConfig, plan_pipeline,
                                     plan_stream)

    if smoke:
        cfg = bench_model(n_layers=2, d_model=64)
        S, rows, trees, steps = 256, 2, 3, 8
        gen = dict(turn_len_range=(6, 30), num_turns=3)
    else:
        cfg = bench_model(n_layers=2)
        S, rows, trees, steps = 512, 4, 6, 16
        gen = dict(turn_len_range=(16, 64), num_turns=4)
    lc = LoaderConfig(seq_len=S, batch_rows=rows, trees_per_batch=trees,
                      mode="tree", kind="agentic", seed=13,
                      gen_kwargs=gen)

    def packed_stats(pc):
        pad = uniq = nsteps = 0
        for ps in plan_stream(cfg, lc, steps, pc):
            sb = ps.step_batch()
            if sb.tb is None:
                continue
            nsteps += 1
            pad += sb.tb.tokens.size - int(sb.tb.valid.sum())
            uniq += int(sb.tb.valid.sum())
        return pad, uniq, nsteps

    pad_g, uniq_g, steps_g = packed_stats(
        PlannerConfig(lookahead=1, heuristics=("ffd",)))
    pad_p, uniq_p, steps_p = packed_stats(PlannerConfig(lookahead=4))
    r_g = pad_g / max(uniq_g, 1)
    r_p = pad_p / max(uniq_p, 1)

    # ---- async overlap: drive the engine from the pipeline ---------------
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    params = init_params(cfg, jax.random.key(0))
    engine = TreeTrainEngine(cfg, opt_cfg, impl=impl, donate=False)
    opt = init_opt_state(params)
    p = params
    for plan in plan_pipeline(cfg, lc, steps,
                              PlannerConfig(lookahead=4)):   # warm jit
        p, opt, _ = engine.step(p, opt, plan)
    pipe = plan_pipeline(cfg, lc, steps,
                         PlannerConfig(lookahead=4, plan_workers=1))
    opt = init_opt_state(params)
    p = params
    n = 0
    t0 = time.perf_counter()
    for plan in pipe:
        p, opt, _ = engine.step(p, opt, plan)
        n += 1
    wall = time.perf_counter() - t0
    emit("plan_efficiency", pipe.build_s * 1e6 / max(pipe.built, 1),
         f"pad_per_unique_greedy={r_g:.3f} pad_per_unique_planner={r_p:.3f} "
         f"steps={steps_g}->{steps_p} sched_ms={pipe.schedule_s * 1e3:.1f} "
         f"build_ms={pipe.build_s * 1e3:.1f} "
         f"exposed_ms={pipe.exposed_s * 1e3:.1f} "
         f"exposed_frac_of_wall={pipe.exposed_s / max(wall, 1e-9):.3f}")
    assert r_p <= r_g, (r_p, r_g)   # planner never pads more than greedy


def bench_cross_tree_reuse(smoke: bool = False, impl: str = "ref") -> None:
    """Cross-tree forest grafting (core/forest + train/planner --graft):
    unique computed tokens and pad-per-unique with grafting on vs off on
    a template-heavy stream (N system-prompt templates shared verbatim
    across trees), at matched loss — the schedule-level dedup the
    within-tree Tree Packing cannot reach."""
    from repro.data.loader import LoaderConfig
    from repro.train.engine import TreeTrainEngine
    from repro.train.planner import PlannerConfig, plan_stream

    if smoke:
        cfg = bench_model(n_layers=2, d_model=64)
        S, rows, trees, steps = 256, 2, 4, 4
        gen = dict(num_templates=2, template_len=128, num_turns=1,
                   turn_len_range=(4, 16))
    else:
        cfg = bench_model(n_layers=2)
        S, rows, trees, steps = 512, 4, 6, 12
        gen = dict(num_templates=3, template_len=320, num_turns=1,
                   turn_len_range=(8, 32))
    lc = LoaderConfig(seq_len=S, batch_rows=rows, trees_per_batch=trees,
                      mode="tree", kind="template", seed=17,
                      auto_partition=True, gen_kwargs=gen)
    params = init_params(cfg, jax.random.key(0))

    def run(graft: bool):
        pc = PlannerConfig(lookahead=4, graft=graft, min_graft=32)
        eng = TreeTrainEngine(cfg, impl=impl, donate=False)
        uniq = pad = ntrees = 0
        loss_sum = 0.0
        sched = 0.0
        t0 = time.perf_counter()
        for ps in plan_stream(cfg, lc, steps, pc):
            sched += time.perf_counter() - t0
            plan = ps.execution_plan()
            _, scal = eng.accumulate(params, plan)
            n = plan.num_trees
            loss_sum += n * float(np.asarray(scal)[0])
            ntrees += n
            uniq += plan.unique_tokens
            pad += plan.padded_tokens
            t0 = time.perf_counter()
        return (uniq, pad / max(uniq, 1), loss_sum / max(ntrees, 1),
                ntrees, sched)

    u_off, ppu_off, l_off, n_off, _ = run(False)
    u_on, ppu_on, l_on, n_on, sched_on = run(True)
    assert n_on == n_off, (n_on, n_off)   # no tree gained or lost
    saved = 1.0 - u_on / max(u_off, 1)
    emit("cross_tree_reuse", sched_on * 1e6 / max(steps, 1),
         f"saved_token_frac={saved:.3f} unique={u_off}->{u_on} "
         f"pad_per_unique_off={ppu_off:.3f} pad_per_unique_on={ppu_on:.3f} "
         f"loss_rel={abs(l_on - l_off) / max(abs(l_off), 1e-9):.2e}")
    assert saved >= 0.0, saved            # grafting never computes MORE


# ---------------------------------------------------------------------------
# the closed async RL loop — prefix-KV reuse + generation/training overlap
# ---------------------------------------------------------------------------

def bench_rl_service(smoke: bool = False, impl: str = "ref") -> None:
    """The async tree-RL service end to end (launch/rl_loop's machinery):
    a generator thread decodes K-branch rollout groups off ONE shared-
    prefix KV prefill per group, merges them into advantage trees, and
    streams them through the live planner into engine steps.

    Reported: per-step wall time, the prefix compute saved by KV reuse
    (per-group token accounting — asserted exact: each prefix computed
    once, never K times), and the fraction of generation hidden behind
    training.  Also asserts zero dropped trees and the bounded-staleness
    contract (lag ≤ max_ahead + lookahead − 1)."""
    from repro.data.loader import LoaderConfig
    from repro.serve.rollout import RolloutConfig, rollout_group
    from repro.serve.service import (AsyncTreeRLService, ServiceConfig,
                                     WeightStore)
    from repro.train.engine import TreeTrainEngine
    from repro.train.optimizer import OptimizerConfig, init_opt_state
    from repro.train.planner import PlannerConfig, plan_window
    from repro.train.planner import plans as plan_steps

    if smoke:
        cfg = bench_model(n_layers=2, d_model=64)
        steps, groups = 4, 2
        rc = RolloutConfig(k=4, prompt_len=8, max_new=4, impl=impl)
    else:
        cfg = bench_model(n_layers=2)
        steps, groups = 6, 2
        rc = RolloutConfig(k=4, prompt_len=16, max_new=8, impl=impl)
    seq_len = rc.prompt_len + rc.k * rc.max_new   # any tree fits: 0 drops
    lc = LoaderConfig(seq_len=seq_len, batch_rows=2, trees_per_batch=groups,
                      mode="tree", seed=17, loss_mode="rl",
                      auto_partition=True)
    pcfg = PlannerConfig(lookahead=1, plan_workers=1, max_rows=2)
    sc = ServiceConfig(groups_per_step=groups, max_ahead_steps=1,
                       rollout=rc, seed=17)
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    params = init_params(cfg, jax.random.key(0))

    # warm every executable outside the measured loop (as launch/rl_loop
    # does): rollout prefill/decode-scan, the packed train step + two
    # optimizer updates, then a rollout against post-update buffer
    # layouts — so compiles neither starve the generator thread nor
    # masquerade as exposed generation time
    wtrees = [rollout_group(cfg, params,
                            np.zeros(rc.prompt_len, np.int32) + g, rc,
                            jax.random.key(g))[0] for g in range(groups)]
    wsteps = [ps for ps in plan_window(cfg, lc, pcfg, [wtrees])
              if not ps.is_empty]
    weng = TreeTrainEngine(cfg, opt_cfg, impl=impl)
    p2 = jax.tree.map(jnp.copy, params)
    o2 = init_opt_state(p2)
    for _ in range(2):
        p2, o2, _ = weng.step(p2, o2, wsteps[0].execution_plan())
    rollout_group(cfg, jax.tree.map(jnp.copy, p2),
                  np.zeros(rc.prompt_len, np.int32), rc, jax.random.key(0))
    del p2, o2

    store = WeightStore(params, version=0)
    engine = TreeTrainEngine(cfg, opt_cfg, impl=impl, weight_store=store)
    opt = init_opt_state(params)
    svc = AsyncTreeRLService(cfg, store, sc, num_steps=steps).start()
    pipe = plan_steps(cfg, lc, svc.tree_batches(), pcfg)

    dropped = n_steps = 0
    t0 = time.perf_counter()
    for ps in pipe:
        plan = ps.execution_plan()
        dropped += plan.dropped
        if plan.is_empty:
            continue
        params, opt, _ = engine.step(params, opt, plan)
        n_steps += 1
    svc.join(10)
    wall = time.perf_counter() - t0

    st = svc.stats
    # the acceptance numbers: prefix computed once per group, zero drops,
    # staleness inside the bound
    assert st.prefill_tokens == steps * groups * rc.prompt_len
    assert st.saved_prefill_tokens == \
        steps * groups * (rc.k - 1) * rc.prompt_len
    assert dropped == 0, dropped
    bound = sc.max_ahead_steps + pcfg.lookahead - 1
    assert engine.max_lag_seen <= bound, (engine.max_lag_seen, bound)
    exposed = pipe.exposed_s
    overlap = 1.0 - exposed / max(st.gen_busy_s, 1e-9)
    saved_frac = st.saved_prefill_tokens / max(
        st.saved_prefill_tokens + st.prefill_tokens, 1)
    emit("rl_service", wall * 1e6 / max(n_steps, 1),
         f"steps={n_steps} k={rc.k} groups={groups} "
         f"prefill_tok={st.prefill_tokens} "
         f"saved_prefill_tok={st.saved_prefill_tokens} "
         f"saved_prefill_frac={saved_frac:.2f} "
         f"gen_busy_ms={st.gen_busy_s * 1e3:.1f} "
         f"gen_exposed_ms={exposed * 1e3:.1f} "
         f"overlap_frac={max(overlap, 0.0):.2f} "
         f"max_lag={engine.max_lag_seen} dropped={dropped}")


# ---------------------------------------------------------------------------
# --smoke — tiny model fwd+bwd through the packed tree loss (CI gate)
# ---------------------------------------------------------------------------

def bench_smoke_model(impl: str) -> None:
    """qwen1.5-0.5B-scale smoke config: one model-level fwd+bwd timing,
    tree vs linearized packing, through loss_and_metrics."""
    from repro.configs.qwen1p5_0p5b import smoke
    cfg = smoke()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(5)
    trees = [t for t in trees_for_batch(7, n_trees=4, kind="agentic",
                                        num_turns=3, turn_len_range=(8, 24),
                                        vocab_size=cfg.vocab_size)
             if serialize_tree(t).n <= 256][:2]
    bt, _ = tree_inputs(cfg, trees, 256)
    bl, _ = baseline_inputs(cfg, trees, 256)
    t_tree, l_tree = timed_loss_grad(cfg, params, bt, iters=2, impl=impl)
    t_base, l_base = timed_loss_grad(cfg, params, bl, iters=2, impl=impl)
    emit("smoke_model_fwd_bwd", t_tree * 1e6,
         f"impl={impl} speedup={t_base / t_tree:.2f}x "
         f"loss_rel={abs(float(l_tree - l_base)) / abs(float(l_base)):.1e}")


# ---------------------------------------------------------------------------
# shardlint byte table — audited per-step collective wire bytes
# ---------------------------------------------------------------------------

def bench_compile_warmup(smoke: bool = False, impl: str = "ref") -> None:
    """AOT warmup engine (train/warmup): the compile economics of one
    training stream, cold vs warm.

      cold   the engine's executable cache starts empty: every first-seen
             signature pays a synchronous ``lower().compile()`` inside
             the step it lands in (counted as a retrace + exposed wait);
      warm   a fresh cache is filled by ``AOTWarmupService.warm_all`` —
             the signature universe ordered by ``CompileCacheSim`` hit
             frequency, budgeted to the stream's hot set — before the
             first step runs: the same stream must then replay with ZERO
             retraces and zero exposed compile wait;
      restart  ``python -m repro.train.warmup --persist-probe`` twice in
             fresh subprocesses against one persistent jax compilation
             cache dir: the second process must write 0 new cache files.

    Every cold/warm step also contributes a calibration sample
    (wall time + cost-model features) to the ``--out`` artifact;
    ``--calibrate`` least-squares-fits CostWeights from them."""
    import shutil
    import subprocess
    import tempfile

    from repro.analysis.signatures import step_signatures
    from repro.core.plan_cost import CompileCacheSim
    from repro.data.loader import LoaderConfig
    from repro.train.engine import TreeTrainEngine
    from repro.train.exec_cache import ExecutableCache
    from repro.train.optimizer import OptimizerConfig, init_opt_state
    from repro.train.planner import (PlannerConfig, plan_stream,
                                     planned_step_features)
    from repro.train.warmup import AOTWarmupService

    # dims distinct from every other bench in this process so the cold
    # pass pays GENUINE XLA compiles (the in-process compilation cache
    # would otherwise hit on an HLO an earlier bench already built)
    cfg = (bench_model(n_layers=2, d_model=32, vocab=512) if smoke
           else bench_model(n_layers=3, d_model=64))
    S, C, steps = (128, 64, 2) if smoke else (384, 192, 5)
    lc = LoaderConfig(seq_len=S, batch_rows=2, trees_per_batch=2,
                      mode="tree", kind="template", seed=23,
                      auto_partition=True, capacity=C,
                      gen_kwargs=dict(num_templates=1,
                                      template_len=S // 4, num_turns=2,
                                      turn_len_range=(S // 8, S // 4)))
    pc = PlannerConfig(lookahead=2)
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    params = init_params(cfg, jax.random.key(1))
    pss = list(plan_stream(cfg, lc, steps, pc))

    def run_stream(engine) -> tuple[list, float]:
        p, opt = params, init_opt_state(params)
        walls = []
        for ps in pss:
            plan = ps.execution_plan()
            sig0 = set(engine.exec_cache.signatures())
            t0 = time.perf_counter()
            p, opt, _ = engine.step(p, opt, plan)
            dt = time.perf_counter() - t0
            walls.append(dt)
            new = engine.exec_cache.signatures() - sig0
            feats = planned_step_features(ps)
            CALIB.append(dict(
                wall_s=dt, padded_tokens=feats["padded_tokens"],
                live_blocks=feats["live_blocks"], block=pc.block,
                new_packed_sigs=len([s for s in new
                                     if s[0] == "packed"]),
                new_wave_sigs=len([s for s in new if s[0] == "wave"])))
        return walls, sum(walls)

    # ---- cold: first-seen signatures compile synchronously in-step
    eng_c = TreeTrainEngine(cfg, opt_cfg, impl=impl, donate=False,
                            exec_cache=ExecutableCache())
    cold_walls, cold_wall = run_stream(eng_c)
    assert eng_c.retraces > 0, "cold baseline saw no compiles"
    assert eng_c.compile_wait_s > 0

    # ---- warm: universe warmup (hit-frequency-ordered, budgeted to the
    # stream's hot set) into a FRESH executable cache, then replay
    sim = CompileCacheSim()
    for ps in pss:
        sim.commit(step_signatures(ps))
    waves = [s for s in sim.seen if s[0] == "wave"]
    caps = [max((s[i] for s in waves), default=0) for i in (3, 4, 5, 6)]
    svc = AOTWarmupService(cfg, lc, pc, params=params, opt_cfg=opt_cfg,
                           impl=impl, donate=False, sim=sim, caps=caps,
                           max_compiles=2 * (len(sim.seen) + 1))
    t0 = time.perf_counter()
    svc.warm_all()
    warmup_s = time.perf_counter() - t0
    assert not svc.errors, svc.errors[:3]
    eng_w = TreeTrainEngine(cfg, opt_cfg, impl=impl, donate=False,
                            exec_cache=svc.cache, universe=svc.universe)
    warm_walls, warm_wall = run_stream(eng_w)
    wait_frac = eng_w.compile_wait_s / max(warm_wall, 1e-9)
    assert eng_w.retraces == 0, \
        f"{eng_w.retraces} retraces after universe warmup"
    assert wait_frac < 0.05, \
        (f"exposed compile wait {wait_frac:.1%} of wall "
         f"(cold baseline: "
         f"{eng_c.compile_wait_s / max(cold_wall, 1e-9):.1%})")

    # ---- restart: persistent compile cache across fresh processes
    cache_dir = tempfile.mkdtemp(prefix="jax-compile-cache-")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")
    env.pop("XLA_FLAGS", None)
    probes = []
    for _ in range(2):
        r = subprocess.run([sys.executable, "-m", "repro.train.warmup",
                            "--persist-probe", cache_dir], env=env,
                           capture_output=True, text=True, timeout=600)
        assert r.returncode == 0, r.stderr[-2000:]
        probes.append(json.loads(r.stdout.strip().splitlines()[-1]))
    shutil.rmtree(cache_dir, ignore_errors=True)
    assert probes[0]["new_cache_files"] > 0, probes[0]
    assert probes[1]["new_cache_files"] == 0, \
        f"warm restart recompiled {probes[1]['new_cache_files']} modules"
    assert probes[1]["loss"] == probes[0]["loss"], probes

    emit("compile_warmup", cold_walls[0] * 1e6,
         f"warm_step1_us={warm_walls[0] * 1e6:.1f} "
         f"cold_retraces={eng_c.retraces} warm_retraces=0 "
         f"cold_wait_ms={eng_c.compile_wait_s * 1e3:.0f} "
         f"warm_wait_frac={wait_frac:.3f} warmup_s={warmup_s:.1f} "
         f"aot_executables={len(svc.cache)} "
         f"restart_new_modules={probes[1]['new_cache_files']} "
         f"restart_warmup_speedup="
         f"{probes[0]['compile_s'] / max(probes[1]['compile_s'], 1e-9):.1f}x")


def bench_comms_table() -> None:
    """shardlint's fast host-mesh audit (``lint --comms --fast``) in a
    subprocess — fake devices need ``XLA_FLAGS`` set before jax
    initializes, which this already-imported process cannot redo.  Emits
    the audited engine-step wire bytes from the ``comms.json`` table (the
    number ``plan_cost.wire_bytes_per_step`` feeds the cost model)."""
    import subprocess
    import tempfile

    from repro.core.plan_cost import wire_bytes_per_step
    fd, out = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")
    env.pop("XLA_FLAGS", None)     # the audit sets its own fake devices
    t0 = time.perf_counter()
    r = subprocess.run([sys.executable, "-m", "repro.analysis.lint",
                        "--comms", "--fast", "-q", "--out", out],
                       env=env, capture_output=True, text=True,
                       timeout=600)
    us = (time.perf_counter() - t0) * 1e6
    if r.returncode != 0:
        emit("comms_table", us, "shardlint=FAILED")
        return
    with open(out) as fh:
        rep = json.load(fh)
    os.unlink(out)
    mesh, entry = next(iter(rep["meshes"].items()))
    wb = wire_bytes_per_step(entry["engine.packed"])
    dec = wire_bytes_per_step(entry["session.step"])
    emit("comms_table", us,
         f"mesh={mesh} engine_step_wire_bytes={wb} "
         f"decode_step_wire_bytes={dec} findings=0")


def calibrate(path: str) -> None:
    """Least-squares-fit :class:`~repro.core.plan_cost.CostWeights` from a
    nightly artifact's ``calib_samples`` (written by ``compile_warmup``).

    Model per timed engine step::

        wall_s ≈ a·padded_tokens + b·new_packed_sigs + c·new_wave_sigs
                 + d·live_blocks·block² + e

    then normalize by the pad coefficient (``score_packing`` is scale-free
    — only the RATIOS steer the planner) and print a ``CostWeights(...)``
    literal to paste into ``core/plan_cost.py`` or pass programmatically."""
    with open(path) as fh:
        art = json.load(fh)
    samples = art.get("calib_samples") or []
    if len(samples) < 5:
        sys.exit(f"calibrate: need >= 5 calib_samples, artifact at {path} "
                 f"has {len(samples)} — run benchmarks with --out first")
    X = np.array([[s["padded_tokens"],
                   s["new_packed_sigs"],
                   s["new_wave_sigs"],
                   s["live_blocks"] * s["block"] ** 2,
                   1.0] for s in samples])
    y = np.array([s["wall_s"] for s in samples])
    coef, *_ = np.linalg.lstsq(X, y, rcond=None)
    resid = y - X @ coef
    ss_tot = float(((y - y.mean()) ** 2).sum()) or 1.0
    r2 = 1.0 - float((resid ** 2).sum()) / ss_tot
    # a compile class the samples never exercised can come out slightly
    # negative from noise — clamp: costs are non-negative by construction
    pad, miss, wave, live = (max(float(c), 0.0) for c in coef[:4])
    if pad <= 0:
        sys.exit("calibrate: pad coefficient fit <= 0 — samples do not "
                 "vary padded_tokens enough to normalize against")
    print(f"# fit from {len(samples)} samples, R^2={r2:.3f} "
          f"(backend={art.get('backend')}, impl={art.get('impl')})")
    print(f"CostWeights(pad=1.0, compile_miss={miss / pad:.1f}, "
          f"wave_compile={wave / pad:.1f}, live_block={live / pad:.4f})")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs, CPU-friendly, < 2 min (CI gate)")
    ap.add_argument("--impl", default="ref",
                    choices=("ref", "chunked", "pallas"),
                    help="attention impl for model-level benches")
    ap.add_argument("--out", default=None,
                    help="write rows as a JSON artifact to this path")
    ap.add_argument("--calibrate", metavar="NIGHTLY_JSON", default=None,
                    help="fit CostWeights from a benchmark artifact's "
                         "calib_samples and print the literal; runs no "
                         "benchmarks")
    args = ap.parse_args(argv)
    if args.calibrate:
        calibrate(args.calibrate)
        return
    if args.out:
        parent = os.path.dirname(os.path.abspath(args.out))
        if not os.path.isdir(parent):
            ap.error(f"--out directory does not exist: {parent}")

    t0 = time.perf_counter()
    print("name,us_per_call,derived")
    if args.smoke:
        bench_kernel_fwd_bwd(smoke=True)
        bench_smoke_model(args.impl)
        bench_kernel_blocks()
        bench_packed_partition(smoke=True)
        bench_gateway_impl(smoke=True)
        bench_engine_step(smoke=True, impl=args.impl)
        bench_plan_efficiency(smoke=True, impl=args.impl)
        bench_cross_tree_reuse(smoke=True, impl=args.impl)
        bench_rl_service(smoke=True, impl=args.impl)
        bench_compile_warmup(smoke=True, impl=args.impl)
        bench_comms_table()
    else:
        bench_por_sweep(args.impl)
        bench_partition_tokens()
        bench_partition_sweep()
        bench_realistic(args.impl)
        bench_memory_overhead()
        bench_kernel_blocks()
        bench_kernel_fwd_bwd()
        bench_packed_partition()
        bench_gateway_impl()
        bench_engine_step(impl=args.impl)
        bench_plan_efficiency(impl=args.impl)
        bench_cross_tree_reuse(impl=args.impl)
        bench_rl_service(impl=args.impl)
        bench_compile_warmup(impl=args.impl)
        bench_comms_table()
    if args.out:
        artifact = {
            "smoke": args.smoke,
            "impl": args.impl,
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "wall_s": round(time.perf_counter() - t0, 2),
            "rows": ROWS,
            "calib_samples": CALIB,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
