"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  por_sweep_*        Fig. 8a — tree vs baseline step time across POR
  partition_tokens   Fig. 5  — token counts: flatten / standard / ours
  partition_sweep_*  Fig. 8b — partitioned tree training under memory cap
  realistic_*        Fig. 7  — agentic-tree speedup + loss deviation
  memory_overhead    §4.6    — extra tree-metadata bytes vs activations
  kernel_blocks      App. A.1 — tree-attention kernel block-skip ratio
"""
from __future__ import annotations

import sys

import jax
import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.run` from repo root

from benchmarks.common import (baseline_inputs, bench_model,  # noqa: E402
                               timed_loss_grad, tree_inputs)
from repro.core.gateway import partitioned_value_and_grad  # noqa: E402
from repro.core.partition import (partition_token_counts,  # noqa: E402
                                  partition_tree,
                                  standard_partition_token_counts)
from repro.core.tree import serialize_tree  # noqa: E402
from repro.data.loader import dataset_por  # noqa: E402
from repro.data.synthetic import (agentic_tree,  # noqa: E402
                                  por_controlled_tree, trees_for_batch)
from repro.models.model import init_params  # noqa: E402

ROWS: list[str] = []


def emit(name: str, us: float, derived: str) -> None:
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


# ---------------------------------------------------------------------------
# Fig. 8a — POR sweep, full tree in memory
# ---------------------------------------------------------------------------

def bench_por_sweep() -> None:
    cfg = bench_model()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    for por in (0.2, 0.5, 0.8, 0.92):
        trees = [por_controlled_tree(rng, target_por=por, num_paths=8,
                                     tokens_per_path=96) for _ in range(2)]
        real_por = dataset_por(trees)
        # both modes pack into rows of the SAME length (as the paper's
        # sequence-packing baseline does) — baseline simply needs more rows
        n_tree = max(serialize_tree(t).n for t in trees)
        S = ((max(n_tree, 256) + 127) // 128) * 128
        bt, _ = tree_inputs(cfg, trees, S)
        bl, _ = baseline_inputs(cfg, trees, S)
        t_tree, l_tree = timed_loss_grad(cfg, params, bt)
        t_base, l_base = timed_loss_grad(cfg, params, bl)
        bound = 1.0 / (1.0 - real_por)
        emit(f"por_sweep_{int(por * 100)}", t_tree * 1e6,
             f"speedup={t_base / t_tree:.2f}x bound={bound:.2f}x "
             f"por={real_por:.3f} "
             f"loss_rel={abs(float(l_tree - l_base)) / abs(float(l_base)):.1e}")


# ---------------------------------------------------------------------------
# Fig. 5 — partition token accounting
# ---------------------------------------------------------------------------

def bench_partition_tokens() -> None:
    rng = np.random.default_rng(1)
    tree = agentic_tree(rng, num_turns=7, turn_len_range=(40, 200),
                        vocab_size=1024)
    uniq = tree.num_unique_tokens()
    C = max(256, ((uniq // 3) // 64) * 64)
    flat = tree.flat_tokens()
    std = standard_partition_token_counts(tree, C)
    ours = partition_token_counts(partition_tree(tree, C))
    emit("partition_tokens", 0.0,
         f"flatten={flat} standard={std} ours={ours['unique_tokens']} "
         f"unique={uniq} parts={ours['num_partitions']} cap={C}")
    assert ours["unique_tokens"] == uniq


# ---------------------------------------------------------------------------
# Fig. 8b — memory-constrained partitioned training
# ---------------------------------------------------------------------------

def bench_partition_sweep() -> None:
    import time as _t
    cfg = bench_model(n_layers=2)
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(2)
    for por in (0.5, 0.8):
        tree = por_controlled_tree(rng, target_por=por, num_paths=8,
                                   tokens_per_path=128)
        C = 256
        partitioned_value_and_grad(cfg, params, tree, C)   # warm traces
        t0 = _t.perf_counter()
        l_p, _, info = partitioned_value_and_grad(cfg, params, tree, C)
        t_part = _t.perf_counter() - t0
        S_flat = ((tree.max_path_tokens() + 127) // 128) * 128
        bl, _ = baseline_inputs(cfg, [tree], S_flat)
        t_base, l_base = timed_loss_grad(cfg, params, bl)
        emit(f"partition_sweep_{int(por * 100)}", t_part * 1e6,
             f"speedup={t_base / t_part:.2f}x parts={info['num_partitions']} "
             f"cap={C} loss_rel="
             f"{abs(l_p - float(l_base)) / abs(float(l_base)):.1e}")


# ---------------------------------------------------------------------------
# Fig. 7 — realistic agentic trees: speedup + loss deviation
# ---------------------------------------------------------------------------

def bench_realistic() -> None:
    cfg = bench_model()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(3)
    trees = []
    while len(trees) < 3:
        t = agentic_tree(rng, num_turns=5, turn_len_range=(16, 64),
                         vocab_size=1024)
        if t.num_leaves() > 1 and serialize_tree(t).n <= 1024:
            trees.append(t)
    por = dataset_por(trees)
    bt, _ = tree_inputs(cfg, trees, 1024)
    bl, _ = baseline_inputs(cfg, trees, 1024)
    t_tree, l_tree = timed_loss_grad(cfg, params, bt)
    t_base, l_base = timed_loss_grad(cfg, params, bl)
    emit("realistic_agentic", t_tree * 1e6,
         f"speedup={t_base / t_tree:.2f}x bound={1 / (1 - por):.2f}x "
         f"por={por:.3f} "
         f"loss_rel={abs(float(l_tree - l_base)) / abs(float(l_base)):.1e}")


# ---------------------------------------------------------------------------
# §4.6 — memory overhead of tree metadata
# ---------------------------------------------------------------------------

def bench_memory_overhead() -> None:
    cfg = bench_model()
    rng = np.random.default_rng(4)
    trees = []
    while len(trees) < 2:
        t = agentic_tree(rng, num_turns=4, turn_len_range=(16, 48),
                         vocab_size=1024)
        if serialize_tree(t).n <= 1024:
            trees.append(t)
    bt, tb = tree_inputs(cfg, trees, 1024)
    extra = sum(np.asarray(v).nbytes for k, v in bt.items()
                if k in ("pos_ids", "kv_last", "weight", "prev_idx",
                         "valid"))
    B, S = tb.tokens.shape
    act = B * S * cfg.d_model * 4 * cfg.n_layers  # one residual per layer
    emit("memory_overhead", 0.0,
         f"metadata_bytes={extra} activation_bytes~={act} "
         f"ratio={extra / act:.2e}")


# ---------------------------------------------------------------------------
# App. A.1 — kernel block-skip accounting
# ---------------------------------------------------------------------------

def bench_kernel_blocks() -> None:
    from repro.core.packing import pack_trees
    trees = trees_for_batch(9, n_trees=6, kind="random",
                            seg_len_range=(8, 32), max_depth=4)
    sers = [serialize_tree(t) for t in trees]
    keep, used = [], 0
    for s in sers:
        if used + s.n <= 512:
            keep.append(s)
            used += s.n
    tb = pack_trees(keep, 512, batch_size=1)
    kv_last = tb.kv_last[0]
    S, bq = 512, 64
    nq = nk = S // bq
    kmax = kv_last.reshape(nk, bq).max(-1)
    live = skipped = 0
    for qi in range(nq):
        for ki in range(nk):
            if ki * bq > qi * bq + bq - 1 or kmax[ki] < qi * bq:
                skipped += 1
            else:
                live += 1
    causal_live = nq * (nq + 1) // 2
    emit("kernel_blocks", 0.0,
         f"live={live} skipped={skipped} causal_would_run={causal_live} "
         f"extra_skip_vs_causal={causal_live - live}")


def main() -> None:
    print("name,us_per_call,derived")
    bench_por_sweep()
    bench_partition_tokens()
    bench_partition_sweep()
    bench_realistic()
    bench_memory_overhead()
    bench_kernel_blocks()


if __name__ == "__main__":
    main()
