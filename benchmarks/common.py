"""Shared benchmark helpers: a small-but-real model and timed step fns."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.base import AttnCfg, ModelConfig
from repro.core.packing import pack_linear_paths, pack_trees
from repro.core.tree import serialize_tree
from repro.models.model import loss_and_metrics, prepare_batch


def bench_model(n_layers=4, d_model=128, vocab=1024) -> ModelConfig:
    return ModelConfig(
        name="bench", family="dense", n_layers=n_layers, d_model=d_model,
        d_ff=4 * d_model, vocab_size=vocab,
        attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=d_model // 4,
                     qk_norm=True),
        dtype="float32", vocab_pad_multiple=64)


def tree_inputs(cfg, trees, seq_len, rows=None):
    tb = pack_trees([serialize_tree(t) for t in trees], seq_len,
                    batch_size=rows)
    return prepare_batch(cfg, tb), tb


def baseline_inputs(cfg, trees, seq_len, rows=None):
    tb = pack_linear_paths([t.linearize_paths() for t in trees], seq_len,
                           batch_size=rows)
    return prepare_batch(cfg, tb), tb


def timed_loss_grad(cfg, params, inputs, iters=3, impl="ref"):
    """Median wall time (s) of jit'd loss+grad on the packed inputs."""
    fn = jax.jit(lambda p, b: jax.value_and_grad(
        lambda q: loss_and_metrics(cfg, q, b, impl)[0])(p))
    out = fn(params, inputs)            # compile + warm
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(params, inputs)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out[0]
